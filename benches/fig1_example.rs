//! EXP-F1 bench: regenerate paper Fig. 1 (load matrices + computation
//! times) and measure the per-instance solve latency.
//!
//! Run: `cargo bench --bench fig1_example`

use std::time::Duration;

use usec::exp::fig1;
use usec::optim::{solve_load_matrix, SolveParams, SolverKind};
use usec::placement::{Placement, PlacementKind};
use usec::util::benchkit::Bench;

fn main() {
    println!("{}", fig1::report().expect("fig1"));

    let speeds = fig1::fig1_speeds();
    let avail: Vec<usize> = (0..6).collect();
    let mut bench = Bench::with_budget(Duration::from_millis(400), 5000);
    for (label, kind, solver) in [
        ("solve fig1 repetition (simplex)", PlacementKind::Repetition, SolverKind::Simplex),
        ("solve fig1 cyclic (simplex)", PlacementKind::Cyclic, SolverKind::Simplex),
        ("solve fig1 repetition (flow)", PlacementKind::Repetition, SolverKind::ParametricFlow),
        ("solve fig1 cyclic (flow)", PlacementKind::Cyclic, SolverKind::ParametricFlow),
    ] {
        let p = Placement::build(kind, 6, 6, 3).unwrap();
        let params = SolveParams {
            solver,
            ..Default::default()
        };
        bench.run(label, || {
            solve_load_matrix(&p, &avail, &speeds, &params).unwrap().time
        });
    }
    println!("{}", bench.table());
}
