//! EXP-A2 ablation (paper Remark 1): the computation time `c(M*)` grows
//! with the straggler tolerance `S` — the time/robustness trade-off.
//!
//! Run: `cargo bench --bench ablation_straggler_tradeoff`

use usec::optim::{solve_load_matrix, SolveParams};
use usec::placement::{Placement, PlacementKind};
use usec::util::fmt::render_table;
use usec::util::Rng;

fn main() {
    let mut rng = Rng::new(11);
    let placements = [
        ("repetition", Placement::build(PlacementKind::Repetition, 6, 6, 3).unwrap()),
        ("cyclic", Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap()),
        ("man", Placement::build(PlacementKind::Man, 6, 20, 3).unwrap()),
    ];
    let avail: Vec<usize> = (0..6).collect();
    let trials = 200;

    let mut rows = Vec::new();
    for (name, p) in &placements {
        let mut cells = vec![name.to_string()];
        for s in 0..3usize {
            let mut mean = 0.0;
            let mut rng_local = rng.fork(s as u64);
            for _ in 0..trials {
                let speeds: Vec<f64> = (0..6)
                    .map(|_| rng_local.exponential(1.0).max(0.02) * p.submatrices() as f64)
                    .collect();
                let sol =
                    solve_load_matrix(p, &avail, &speeds, &SolveParams::with_stragglers(s))
                        .unwrap();
                mean += sol.time / trials as f64;
            }
            cells.push(format!("{mean:.4}"));
        }
        rows.push(cells);
    }
    println!("EXP-A2 (Remark 1): mean optimal c over {trials} exponential speed draws\n");
    println!(
        "{}",
        render_table(&["placement", "S=0", "S=1", "S=2"], &rows)
    );
    println!("(time normalized per-X; S=2 requires computing every row 3x — the trade-off)");
}
