//! Full-matrix vs shard-view SpMV per-worker throughput.
//!
//! A distributed worker reads its tiles through a [`StoreHandle`]: either
//! a zero-copy view of the full matrix or a placement-shaped [`RowShard`]
//! holding only its J-out-of-G share. This bench drives the exact
//! per-tile access + host matvec path over one worker's placed rows
//! through both handles, so any overhead of the shard's block lookup (and
//! any locality win from the compacted layout) is measured, alongside the
//! resident-byte difference the refactor exists to create.
//!
//! Run: `cargo bench --bench storage_view`

use std::sync::Arc;
use std::time::Duration;

use usec::linalg::partition::{submatrix_ranges, TilePlan};
use usec::linalg::gen;
use usec::placement::{Placement, PlacementKind};
use usec::runtime::BackendSpec;
use usec::storage::{RowShard, StorageView, StoreHandle};
use usec::util::benchkit::Bench;

fn main() {
    let q = 1536usize;
    let (n, g, j) = (6usize, 6usize, 3usize);
    let worker = 0usize;

    let matrix = Arc::new(gen::random_dense(q, q, 11));
    let placement = Placement::build(PlacementKind::Cyclic, n, g, j).unwrap();
    let sub_ranges = submatrix_ranges(q, g).unwrap();
    let placed = placement.stored_ranges(worker, &sub_ranges).unwrap();
    let shard = Arc::new(RowShard::from_matrix(&matrix, &placed).unwrap());

    let full = StoreHandle::Full(Arc::clone(&matrix));
    let sharded = StoreHandle::Shard(shard);
    println!(
        "worker {worker} stores {}/{} sub-matrices: full view {} bytes, shard {} bytes\n",
        j,
        g,
        full.resident_bytes(),
        sharded.resident_bytes()
    );

    let backend = BackendSpec::Host.instantiate().unwrap();
    let tile = TilePlan::new(128);
    let w: Vec<f32> = (0..q).map(|i| (i % 7) as f32 * 0.01).collect();
    let placed_rows: usize = placed.iter().map(|r| r.len()).sum();

    let mut bench = Bench::with_budget(Duration::from_millis(600), 2_000);
    for (name, view) in [("full-matrix view", &full), ("shard view", &sharded)] {
        bench.run(&format!("SpMV worker share ({name})"), || {
            let mut acc = 0.0f32;
            for r in &placed {
                for t in tile.plan(*r) {
                    let x = view.row_slice(t).unwrap();
                    let y = backend.matvec_tile(x, t.len(), q, &w).unwrap();
                    acc += y[0];
                }
            }
            acc
        });
    }
    let table = bench.table();
    println!("{table}");
    println!(
        "({placed_rows} placed rows per iteration; identical numerics, \
         shard resident bytes = {:.0}% of full)",
        sharded.resident_bytes() as f64 / full.resident_bytes() as f64 * 100.0
    );
}
