//! Serving benchmark: continuous batching vs one-job-per-request.
//!
//! A fixed offered load of personalized-PageRank requests (each riding
//! a fixed number of elastic steps) is pushed through a resident
//! [`ServeSession`] at batch widths B ∈ {1, 4, 16}. B=1 is the
//! sequential baseline — every request runs alone, exactly what a
//! one-job-per-request harness would do — while wider batches coalesce
//! up to B request columns into one distributed mat-vec per step, so
//! the workers traverse their stored rows once for all B tenants.
//! Throughput should scale with B (same steps, B× the rows per
//! traversal) while per-request latency p50/p99 stays bounded by the
//! deficit-round-robin admission order.
//!
//! Run: `cargo bench --bench serve [-- --smoke] [-- --json PATH]`
//!
//! Results land as machine-readable JSON (default `BENCH_serve.json`);
//! all variants share a unit count (requests), so `units_per_s` ratios
//! are the serving speedup, and the per-width latency quantiles print
//! alongside.

use std::time::{Duration, Instant};

use usec::config::types::RunConfig;
use usec::metrics::ServeSummary;
use usec::serve::{Query, ServeSession, SessionOpts};
use usec::util::benchkit::Bench;

const Q: usize = 96;
const SEED: u64 = 31;

fn cfg() -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 2,
        n: 3,
        steps: 1,
        speeds: vec![1.0, 2.0, 3.0],
        seed: SEED,
        ..Default::default()
    }
}

/// Serve `m` requests (each riding exactly `steps_per_req` steps) at
/// batch width `b`; return the drain wall-clock and the serve summary.
fn run_once(b: usize, m: usize, steps_per_req: usize) -> (Duration, ServeSummary) {
    let opts = SessionOpts {
        queue_cap: m.max(64),
        quantum: 1,
        max_width: b,
        ..Default::default()
    };
    let mut session = ServeSession::build(&cfg(), &opts).unwrap();
    for i in 0..m {
        session
            .submit(
                &format!("tenant{}", i % 3),
                Query::Pagerank {
                    seed_node: (7 * i) % Q,
                    damping: 0.85,
                },
                0.0, // never converges early: every request rides the full budget
                steps_per_req,
            )
            .unwrap();
    }
    let t0 = Instant::now();
    let responses = session
        .run_until_drained(2 * m * steps_per_req + 16)
        .unwrap();
    let wall = t0.elapsed();
    assert_eq!(responses.len(), m);
    assert!(responses.iter().all(|r| r.steps == steps_per_req));
    (wall, session.summary())
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_serve.json")
        .to_string();
    let (m, steps_per_req, budget, iters) = if smoke {
        (6, 4, Duration::from_millis(100), 1)
    } else {
        (24, 12, Duration::from_secs(2), 5)
    };
    let mut bench = Bench::with_budget(budget, iters);

    let mut rows = Vec::new();
    for b in [1usize, 4, 16] {
        let mut best_wall = Duration::MAX;
        let mut last_summary = ServeSummary::default();
        let label = if b == 1 {
            format!("serve sequential B=1 ({m} reqs x {steps_per_req} steps)")
        } else {
            format!("serve batched B={b} ({m} reqs x {steps_per_req} steps)")
        };
        bench.run_units(&label, m as f64, || {
            let (wall, summary) = run_once(b, m, steps_per_req);
            if wall < best_wall {
                best_wall = wall;
            }
            last_summary = summary;
            wall.as_secs_f64()
        });
        rows.push((b, best_wall, last_summary));
    }

    println!("{}", bench.table());
    let base = rows[0].1.as_secs_f64();
    for (b, wall, s) in &rows {
        println!(
            "B={b}: drained {m} reqs in {wall:?} ({:.2}x vs sequential), \
             p50 {:.3} ms, p99 {:.3} ms, {:.0} rows/s, peak queue {}",
            base / wall.as_secs_f64(),
            s.latency_p50_ns / 1e6,
            s.latency_p99_ns / 1e6,
            s.rows_per_s,
            s.queue_depth
        );
    }

    match Bench::write_json(&[&bench], &json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
