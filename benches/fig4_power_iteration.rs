//! EXP-F4 bench: the paper's headline end-to-end experiment — elastic
//! power iteration, heterogeneous (Algorithm 1) vs uniform assignment,
//! without stragglers (Fig. 4 top) and with 2 injected stragglers per
//! iteration (Fig. 4 bottom).
//!
//! Environment overrides: `FIG4_Q` (matrix dim, paper scale = 6000; note
//! that PJRT artifacts are baked for the `make artifacts COLS=… Q=…`
//! shapes), `FIG4_STEPS`, `FIG4_BACKEND` (host|pjrt).
//!
//! Run: `cargo bench --bench fig4_power_iteration`

use usec::config::types::BackendKind;
use usec::exp::fig4::{report, Fig4Params};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let base = Fig4Params {
        q: env_usize("FIG4_Q", 1536),
        steps: env_usize("FIG4_STEPS", 40),
        backend: std::env::var("FIG4_BACKEND")
            .ok()
            .and_then(|v| BackendKind::parse(&v).ok())
            .unwrap_or(BackendKind::Host),
        ..Default::default()
    };

    // Fig. 4 top: no stragglers
    println!("{}", report(&base).expect("fig4 top (no stragglers)"));

    // Fig. 4 bottom, paper's reading (§V runs S = 0): 2 *slow* stragglers
    // per iteration that the master must wait for. Fixed victims (the same
    // overloaded instances every step) — exactly what Algorithm 1's EWMA
    // speed tracking exists to absorb.
    let bottom = Fig4Params {
        injected: 2,
        tolerance: 0,
        slowdown: 3.0,
        fixed_victims: true,
        ..base
    };
    println!("{}", report(&bottom).expect("fig4 bottom (2 slow stragglers)"));

    // Variant: fresh random victims each step (unpredictable — the EWMA
    // cannot learn them, so the gain shrinks toward the top-panel split of
    // non-straggler time only).
    let random_victims = Fig4Params {
        fixed_victims: false,
        ..bottom
    };
    println!(
        "{}",
        report(&random_victims).expect("fig4 variant (random slow stragglers)")
    );

    // Variant: redundant-assignment straggler tolerance (S = 2, dropped
    // stragglers). With J = 3 replicas and S = 2 the assignment is fully
    // constrained (every replica computes everything), so both policies
    // coincide — included to document that boundary.
    let drop_variant = Fig4Params {
        injected: 2,
        tolerance: 2,
        slowdown: 0.0,
        ..base
    };
    println!(
        "{}",
        report(&drop_variant).expect("fig4 variant (S=2, dropped)")
    );
}
