//! EXP-A6 ablation: scalability beyond the paper's N=6 testbed, via the
//! step-synchronous simulator — solve latency and heterogeneous-vs-uniform
//! gain as the fleet grows, plus gain vs speed dispersion.
//!
//! Run: `cargo bench --bench ablation_scale`

use usec::config::types::AssignPolicy;
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::sched::sim::{simulate, SimParams};
use usec::util::fmt::render_table;
use usec::util::Rng;

fn base(n: usize, speeds: Vec<f64>, policy: AssignPolicy) -> SimParams {
    SimParams {
        placement: Placement::build(PlacementKind::Cyclic, n, n, 3).unwrap(),
        true_speeds: speeds,
        params: SolveParams::default(),
        policy,
        gamma: 0.5,
        steps: 100,
        measurement_noise: 0.1,
        drift_prob: 0.01,
        preempt: 0.05,
        arrive: 0.3,
        min_available: 3,
        seed: 2024,
    }
}

fn main() {
    // --- fleet-size sweep ---
    let mut rows = Vec::new();
    for n in [6usize, 12, 24, 48, 96] {
        let mut rng = Rng::new(n as u64);
        let speeds: Vec<f64> = (0..n).map(|_| rng.exponential(1.0).max(0.05)).collect();
        let h = simulate(&base(n, speeds.clone(), AssignPolicy::Heterogeneous)).unwrap();
        let u = simulate(&base(n, speeds, AssignPolicy::Uniform)).unwrap();
        let gain = 1.0 - h.total_time / u.total_time;
        rows.push(vec![
            n.to_string(),
            format!("{:.1}%", gain * 100.0),
            format!("{:.0}µs", h.mean_solve_s * 1e6),
            h.skipped.to_string(),
        ]);
    }
    println!("EXP-A6a: fleet-size sweep (cyclic G=N, J=3, 100 elastic steps)\n");
    println!(
        "{}",
        render_table(&["N", "hetero gain", "mean solve", "skipped steps"], &rows)
    );

    // --- dispersion sweep: gain vs speed heterogeneity (drift and churn
    // off so the dispersion is the only variable) ---
    let mut rows = Vec::new();
    for spread in [0.0f64, 0.25, 0.5, 1.0, 2.0] {
        let n = 12;
        let speeds: Vec<f64> = (0..n)
            .map(|i| (1.0 + spread * (i as f64 / (n - 1) as f64)).max(0.05))
            .collect();
        let still = |policy| {
            let mut p = base(n, speeds.clone(), policy);
            p.drift_prob = 0.0;
            p.preempt = 0.0;
            p.arrive = 0.0;
            p.measurement_noise = 0.02;
            p
        };
        let h = simulate(&still(AssignPolicy::Heterogeneous)).unwrap();
        let u = simulate(&still(AssignPolicy::Uniform)).unwrap();
        let gain = 1.0 - h.total_time / u.total_time;
        rows.push(vec![
            format!("{spread:.2}"),
            format!("{:.1}%", gain * 100.0),
        ]);
    }
    println!("\nEXP-A6b: gain vs speed dispersion (N=12; spread = (max−min)/min)\n");
    println!("{}", render_table(&["spread", "hetero gain"], &rows));
    println!(
        "(gain → 0 as the fleet homogenizes — the paper's framework reduces to \
         the uniform split exactly when speeds are equal)"
    );
}
