//! Tracing overhead benchmark: the same elastic power-iteration run with
//! the observability stack off vs on.
//!
//! `--trace-out` must be near-free when absent (no recorder, no registry,
//! no wire trailers — the step loop is byte-identical to an untraced
//! build) and cheap when present (per-order events go through a channel
//! to a dedicated writer thread, counters are relaxed atomics). This
//! bench measures both modes end-to-end on the local transport and
//! reports the relative step-loop overhead; CI tracks the JSON so a
//! regression that makes tracing expensive (or worse, makes *untraced*
//! runs pay for it) shows up as a diff in `BENCH_obs.json`.
//!
//! Run: `cargo bench --bench obs_overhead [-- --smoke] [-- --json PATH]`

use std::time::Duration;

use usec::config::types::RunConfig;
use usec::placement::PlacementKind;
use usec::util::benchkit::Bench;

/// The measured workload: a local 6-worker elastic run, throttled so the
/// per-step schedule (not raw kernel speed) dominates — the regime where
/// per-order tracing costs would surface.
fn run_cfg(steps: usize, trace_out: &str) -> RunConfig {
    RunConfig {
        q: 96,
        r: 96,
        g: 6,
        j: 3,
        n: 6,
        placement: PlacementKind::Cyclic,
        steps,
        speeds: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        seed: 31,
        trace_out: trace_out.to_string(),
        ..Default::default()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_obs.json")
        .to_string();
    let (steps, budget, iters) = if smoke {
        (8, Duration::from_millis(100), 1)
    } else {
        (30, Duration::from_secs(2), 8)
    };
    let mut bench = Bench::with_budget(budget, iters);

    let mut off_wall = Duration::ZERO;
    bench.run_units(
        &format!("power iteration E2E tracing off ({steps} steps)"),
        steps as f64,
        || {
            let res =
                usec::apps::run_power_iteration(&run_cfg(steps, "")).expect("untraced run");
            off_wall = res.timeline.total_wall();
            res.final_nmse
        },
    );

    let journal = std::env::temp_dir().join(format!(
        "usec_bench_obs_{}.jsonl",
        std::process::id()
    ));
    let journal_path = journal.to_str().expect("utf-8 temp path");
    let mut on_wall = Duration::ZERO;
    let mut events = 0usize;
    bench.run_units(
        &format!("power iteration E2E tracing on ({steps} steps)"),
        steps as f64,
        || {
            let res =
                usec::apps::run_power_iteration(&run_cfg(steps, journal_path))
                    .expect("traced run");
            on_wall = res.timeline.total_wall();
            events = usec::obs::load_journal(journal_path)
                .expect("journal readable")
                .len();
            res.final_nmse
        },
    );
    let _ = std::fs::remove_file(&journal);

    // journal hot path in isolation: cost of one emitted span event
    {
        let dir = std::env::temp_dir().join(format!(
            "usec_bench_obs_emit_{}.jsonl",
            std::process::id()
        ));
        let journal =
            usec::obs::Journal::create(dir.to_str().unwrap()).expect("journal");
        let rec = journal.recorder();
        let mut i = 0u64;
        bench.run("journal emit (one order span event)", || {
            i += 1;
            rec.emit(
                usec::obs::Event::new(usec::obs::EventKind::Order, 0, rec.now_ns())
                    .worker((i % 6) as usize)
                    .order(i)
                    .rows(16)
                    .dur(1_000),
            );
            i
        });
        journal.finish().expect("journal flush");
        let _ = std::fs::remove_file(&dir);
    }

    println!("{}", bench.table());
    let overhead = if off_wall.as_secs_f64() > 0.0 {
        (on_wall.as_secs_f64() - off_wall.as_secs_f64()) / off_wall.as_secs_f64() * 100.0
    } else {
        f64::NAN
    };
    println!(
        "last run: untraced wall {off_wall:?} vs traced wall {on_wall:?} \
         ({overhead:+.2}% step-loop overhead, {events} journal events)"
    );

    match Bench::write_json(&[&bench], &json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
