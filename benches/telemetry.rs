//! Telemetry-plane overhead benchmark: the live exporter must be
//! near-free for the process being watched.
//!
//! Three angles, coarsest first:
//!
//! * **End-to-end**: the same serve-session drain with the telemetry
//!   plane off vs on — "on" means a real [`MetricsServer`] bound on
//!   loopback with a background scraper hammering `/metrics` the whole
//!   time, so the number includes both the publish stores on the step
//!   loop and any scrape-side contention on the snapshot mutexes. The
//!   acceptance bar is <1% step-loop overhead.
//! * **Publish hot path**: one per-step counter publication
//!   (`Registry::add_order` + whole-snapshot republish into
//!   [`Telemetry::set_counters`]) and one rolling-histogram latency
//!   push — the two writes a serving step actually performs.
//! * **Scrape render**: one `/metrics` text exposition render of a
//!   populated telemetry handle (readers pay this, not the step loop).
//!
//! Run: `cargo bench --bench telemetry [-- --smoke] [-- --json PATH]`
//! Results land as machine-readable JSON (default `BENCH_telemetry.json`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use usec::config::types::RunConfig;
use usec::engine::EngineState;
use usec::metrics::RollingHistogram;
use usec::obs::{http_get, render_prometheus, MetricsServer, Registry, Telemetry};
use usec::serve::{Query, ServeSession, SessionOpts};
use usec::util::benchkit::Bench;

const Q: usize = 96;

fn cfg() -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 2,
        n: 3,
        steps: 1,
        speeds: vec![1.0, 2.0, 3.0],
        seed: 31,
        ..Default::default()
    }
}

/// Drain `m` requests (each riding `steps_per_req` steps) through a
/// resident session, optionally publishing into a telemetry handle.
fn run_once(m: usize, steps_per_req: usize, tel: Option<Arc<Telemetry>>) -> Duration {
    let opts = SessionOpts {
        queue_cap: m.max(64),
        quantum: 1,
        max_width: 8,
        ..Default::default()
    };
    let mut session = ServeSession::build(&cfg(), &opts).unwrap();
    if tel.is_some() {
        session.set_telemetry(tel);
    }
    for i in 0..m {
        session
            .submit(
                &format!("tenant{}", i % 3),
                Query::Pagerank {
                    seed_node: (7 * i) % Q,
                    damping: 0.85,
                },
                0.0, // never converges early: every request rides the full budget
                steps_per_req,
            )
            .unwrap();
    }
    let t0 = Instant::now();
    let responses = session.run_until_drained(2 * m * steps_per_req + 16).unwrap();
    let wall = t0.elapsed();
    assert_eq!(responses.len(), m);
    wall
}

/// A telemetry handle populated the way a live 3-worker serve looks,
/// so the render benchmark emits every metric family.
fn populated_telemetry() -> Arc<Telemetry> {
    let tel = Arc::new(Telemetry::new(3, 2));
    tel.set_state(EngineState::Stepping);
    tel.set_coverage_ok(true);
    tel.set_alive(&[true, true, false]);
    for w in 0..3 {
        tel.set_speed(w, 1.0 + w as f64);
    }
    tel.set_resident(&[4096, 4096, 4096]);
    let reg = Registry::new(3);
    for i in 0..50usize {
        reg.add_order(i % 3, 32);
    }
    tel.set_counters(reg.snapshot(&[]));
    tel
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_telemetry.json")
        .to_string();
    let (m, steps_per_req, budget, iters) = if smoke {
        (6, 4, Duration::from_millis(100), 1)
    } else {
        (24, 12, Duration::from_secs(2), 5)
    };
    let mut bench = Bench::with_budget(budget, iters);

    let mut off_wall = Duration::MAX;
    bench.run_units(
        &format!("serve drain exporter off ({m} reqs x {steps_per_req} steps)"),
        m as f64,
        || {
            let wall = run_once(m, steps_per_req, None);
            if wall < off_wall {
                off_wall = wall;
            }
            wall.as_secs_f64()
        },
    );

    // exporter on: real scrape endpoint plus a background scraper
    // polling it as fast as it can for the whole measured window
    let tel = Arc::new(Telemetry::new(cfg().n, cfg().j));
    let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let srv = MetricsServer::spawn(listener, Arc::clone(&tel)).expect("metrics server");
    let addr = srv.addr().to_string();
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = Arc::clone(&stop);
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut scrapes = 0u64;
            while !stop.load(Ordering::Relaxed) {
                if http_get(&addr, "/metrics", Duration::from_secs(1)).is_ok() {
                    scrapes += 1;
                }
            }
            scrapes
        })
    };
    let mut on_wall = Duration::MAX;
    bench.run_units(
        &format!("serve drain exporter on+scraped ({m} reqs x {steps_per_req} steps)"),
        m as f64,
        || {
            let wall = run_once(m, steps_per_req, Some(Arc::clone(&tel)));
            if wall < on_wall {
                on_wall = wall;
            }
            wall.as_secs_f64()
        },
    );
    stop.store(true, Ordering::Relaxed);
    let scrapes = scraper.join().unwrap_or(0);
    srv.stop();

    // publish hot path: what one serving step writes into the plane
    {
        let tel = populated_telemetry();
        let reg = Registry::new(3);
        let mut i = 0usize;
        bench.run("counter publish (add_order + set_counters)", || {
            reg.add_order(i % 3, 32);
            tel.set_counters(reg.snapshot(&[]));
            i += 1;
            i
        });
    }
    {
        let mut hist = RollingHistogram::new(Duration::from_secs(10), 10);
        let mut i = 0u64;
        bench.run("rolling histogram push (one latency sample)", || {
            i += 1;
            hist.push((i % 997) as f64 * 1e4);
            hist.count()
        });
    }

    // scrape render: the full /metrics text of a populated handle
    {
        let tel = populated_telemetry();
        bench.run("render /metrics exposition", || render_prometheus(&tel).len());
    }

    println!("{}", bench.table());
    let overhead = if off_wall < Duration::MAX && off_wall.as_secs_f64() > 0.0 {
        (on_wall.as_secs_f64() - off_wall.as_secs_f64()) / off_wall.as_secs_f64() * 100.0
    } else {
        f64::NAN
    };
    println!(
        "best drain: exporter off {off_wall:?} vs on {on_wall:?} \
         ({overhead:+.2}% overhead under {scrapes} concurrent scrapes)"
    );

    match Bench::write_json(&[&bench], &json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
