//! EXP-F3 bench: regenerate paper Fig. 3 (straggler-tolerant assignment)
//! and measure the solve + filling pipeline latency.
//!
//! Run: `cargo bench --bench fig3_straggler`

use std::time::Duration;

use usec::exp::fig3;
use usec::linalg::partition::submatrix_ranges;
use usec::optim::{build_assignment, SolveParams};
use usec::placement::{Placement, PlacementKind};
use usec::util::benchkit::Bench;

fn main() {
    println!("{}", fig3::report().expect("fig3"));

    let p = Placement::build(PlacementKind::Repetition, 6, 6, 3).unwrap();
    let avail: Vec<usize> = (0..6).collect();
    let speeds = vec![1.0; 6];
    let sub_rows: Vec<usize> = submatrix_ranges(3600, 6)
        .unwrap()
        .iter()
        .map(|r| r.len())
        .collect();
    let mut bench = Bench::with_budget(Duration::from_millis(400), 5000);
    for s in 0..3usize {
        let params = SolveParams::with_stragglers(s);
        bench.run(&format!("solve+fill+quantize S={s}"), || {
            build_assignment(&p, &avail, &speeds, &params, &sub_rows).unwrap()
        });
    }
    println!("{}", bench.table());
}
