//! Rebalance benchmark: static vs adapted placement under speed drift.
//!
//! The drift scenario mirrors the paper's premise inverted: the cluster's
//! *true* speeds are strongly skewed while the master's prior is uniform,
//! so the frozen placement keeps sub-matrices stranded on slow machines.
//! The `static` run lives with it; the `adapted` run (`--rebalance`)
//! re-optimizes the placement from the live EWMA estimates and migrates
//! shard rows between steps. Both are full elastic power-iteration runs
//! on the local transport with the speed throttle on, so wall-clock
//! reflects the schedule the placement allows.
//!
//! Run: `cargo bench --bench rebalance [-- --smoke] [-- --json PATH]`
//!
//! Results are written as machine-readable JSON (default
//! `BENCH_rebalance.json`) like the other benchkit targets, so the
//! adapted-vs-static gap is tracked across commits.

use std::time::Duration;

use usec::config::types::RunConfig;
use usec::placement::PlacementKind;
use usec::rebalance::RebalanceConfig;
use usec::util::benchkit::Bench;

/// A drift-trace run config: true speeds skewed 16:1, uniform prior.
fn drift_cfg(steps: usize, adapted: bool) -> RunConfig {
    RunConfig {
        q: 96,
        r: 96,
        g: 6,
        j: 3,
        n: 6,
        placement: PlacementKind::Cyclic,
        steps,
        speeds: vec![16.0, 1.0, 1.0, 1.0, 1.0, 8.0],
        row_cost_ns: 200_000,
        seed: 23,
        rebalance: if adapted {
            RebalanceConfig::enabled()
        } else {
            RebalanceConfig::default()
        },
        ..Default::default()
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_rebalance.json")
        .to_string();
    let (steps, budget, iters) = if smoke {
        (8, Duration::from_millis(100), 1)
    } else {
        (20, Duration::from_secs(2), 8)
    };
    let mut bench = Bench::with_budget(budget, iters);

    let mut static_wall = Duration::ZERO;
    bench.run_units(
        &format!("power iteration E2E static placement ({steps} steps, drift)"),
        steps as f64,
        || {
            let res = usec::apps::run_power_iteration(&drift_cfg(steps, false))
                .expect("static run");
            static_wall = res.timeline.total_wall();
            res.final_nmse
        },
    );

    let mut adapted_wall = Duration::ZERO;
    let mut migrations = 0usize;
    let mut migrated_bytes = 0u64;
    bench.run_units(
        &format!("power iteration E2E adapted placement ({steps} steps, drift)"),
        steps as f64,
        || {
            let res = usec::apps::run_power_iteration(&drift_cfg(steps, true))
                .expect("adapted run");
            adapted_wall = res.timeline.total_wall();
            migrations = res.timeline.total_migrations();
            migrated_bytes = res.timeline.total_migrated_bytes();
            res.final_nmse
        },
    );

    // the drift monitor alone (no execution): what a quiet per-step check
    // costs the master
    {
        use usec::linalg::partition::submatrix_ranges;
        use usec::optim::SolveParams;
        use usec::placement::Placement;
        use usec::rebalance::DriftMonitor;
        let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
        let sub_ranges = submatrix_ranges(96, 6).unwrap();
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![1.0; 6];
        let mut monitor = DriftMonitor::new(0.15, 120, 7);
        bench.run("drift check (quiet cluster, 120 search iters)", || {
            monitor
                .check(&placement, &avail, &speeds, &SolveParams::default(), &sub_ranges)
                .unwrap()
                .is_none()
        });
    }

    println!("{}", bench.table());
    println!(
        "last run: static wall {static_wall:?} vs adapted wall {adapted_wall:?} \
         ({migrations} migrations, {migrated_bytes} bytes moved)"
    );

    match Bench::write_json(&[&bench], &json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
