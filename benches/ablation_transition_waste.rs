//! EXP-A5 ablation: transition waste ([2], paper §I) across elastic
//! transitions — naive per-step re-solve vs the stabilized assignment.
//!
//! Run: `cargo bench --bench ablation_transition_waste`

use usec::linalg::partition::submatrix_ranges;
use usec::optim::transition::{stabilize, transition_waste};
use usec::optim::{build_assignment, SolveParams};
use usec::placement::{Placement, PlacementKind};
use usec::sched::ElasticityTrace;
use usec::util::fmt::render_table;

fn main() {
    let p = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
    let sub_rows: Vec<usize> = submatrix_ranges(6000, 6)
        .unwrap()
        .iter()
        .map(|r| r.len())
        .collect();
    let params = SolveParams::default();
    let steps = 200;

    let mut rows = Vec::new();
    for (label, stabilized) in [("naive re-solve", false), ("stabilized", true)] {
        let mut trace = ElasticityTrace::bernoulli(6, 0.25, 0.5, 3, 99);
        let mut prev: Option<usec::optim::Assignment> = None;
        let mut total_waste = 0usize;
        let mut transitions = 0usize;
        for _ in 0..steps {
            let avail = trace.next_step();
            if p.check_feasible(&avail, 0).is_err() {
                continue;
            }
            let mut a = build_assignment(&p, &avail, &speeds, &params, &sub_rows).unwrap();
            if let Some(old) = &prev {
                if stabilized {
                    stabilize(old, &mut a);
                }
                total_waste += transition_waste(old, &a);
                transitions += 1;
            }
            a.validate(&sub_rows).unwrap();
            prev = Some(a);
        }
        rows.push(vec![
            label.to_string(),
            transitions.to_string(),
            total_waste.to_string(),
            format!("{:.1}", total_waste as f64 / transitions.max(1) as f64),
        ]);
    }
    println!(
        "EXP-A5: transition waste over {steps} elastic steps (q=6000, cyclic, \
         preempt 0.25 / arrive 0.5)\n"
    );
    println!(
        "{}",
        render_table(
            &["policy", "transitions", "total waste (rows)", "waste/transition"],
            &rows
        )
    );
    println!("(waste = rows moved between machines beyond the load-change minimum [2])");
}
