//! Pipeline benchmark: synchronous vs pipelined master over a real
//! 3-worker TCP cluster.
//!
//! The pipelined loop (`--pipeline`) overlaps the previous step's
//! combine metric with the next step's dispatch + worker compute, so
//! its payoff grows with the weight of the combine. Each variant runs
//! the same block power iteration with a throttled ~2 ms compute phase
//! per step and a combine whose cost scales with the block width B:
//! at B=1 the combine is nearly free and the two loops tie; at B=16
//! the combine rivals the compute and the pipeline should deliver the
//! ≥1.3× steps/s the roadmap targets.
//!
//! Run: `cargo bench --bench pipeline [-- --smoke] [-- --json PATH]`
//!
//! Results are written as machine-readable JSON (default
//! `BENCH_pipeline.json`): the `sync`/`pipelined` pairs at each B share
//! a unit count (steps), so `units_per_s` ratios are the speedup.

use std::net::TcpListener;
use std::time::{Duration, Instant};

use usec::apps::harness::Harness;
use usec::apps::power_iteration::{PLANT_EIGVAL, PLANT_GAP};
use usec::config::types::RunConfig;
use usec::linalg::{ops, Block};
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::net::WorkloadSpec;
use usec::placement::PlacementKind;
use usec::util::benchkit::Bench;

const Q: usize = 120;
const SEED: u64 = 29;
/// ~2 ms of throttled compute per worker per step (40 rows × 50 µs):
/// the window the pipelined combine hides inside.
const ROW_COST_NS: u64 = 50_000;
/// Extra orthonormalization passes in the combine, making it heavy
/// enough at wide B to rival the compute phase.
const COMBINE_REPS: usize = 60;

/// Spawn `n` worker daemons on ephemeral loopback ports. The threads
/// are detached (unlimited sessions): every benchmark iteration dials a
/// fresh session and the daemons die with the process.
fn start_workers(n: usize) -> Vec<String> {
    let mut addrs = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        std::thread::spawn(move || serve_worker(listener, DaemonOpts::default()));
    }
    addrs
}

fn spec() -> WorkloadSpec {
    WorkloadSpec::PlantedSymmetric {
        q: Q,
        eigval: PLANT_EIGVAL,
        gap: PLANT_GAP,
        seed: SEED,
    }
}

fn cfg(steps: usize, batch: usize, pipeline: bool, workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 3,
        n: 3,
        placement: PlacementKind::Cyclic,
        stragglers: 1,
        steps,
        batch,
        speeds: vec![1.0, 1.0, 1.0],
        row_cost_ns: ROW_COST_NS,
        seed: SEED,
        pipeline,
        workers,
        ..Default::default()
    }
}

/// One full run: build the harness (TCP handshake included), drive
/// `steps` block power-iteration steps with a combine-heavy finish, and
/// return the wall-clock of the step loop alone.
fn run_once(cfg: &RunConfig) -> Duration {
    let spec = spec();
    let matrix = spec.materialize().unwrap();
    let mut h = Harness::build_with_workload(cfg, matrix, Some(spec)).unwrap();
    let b = cfg.batch;
    let cols: Vec<Vec<f32>> = (0..b)
        .map(|k| {
            (0..Q)
                .map(|i| ((i * (k + 2)) % 7) as f32 * 0.3 - 0.9)
                .collect()
        })
        .collect();
    let w0 = Block::from_columns(&cols).unwrap();
    let t0 = Instant::now();
    let out = h
        .run_block_split(
            w0,
            cfg.steps,
            |_combine, _w, mut y| {
                ops::mgs_orthonormalize(y.data_mut(), Q, b);
                Ok(y)
            },
            |_combine, next| {
                // combine-heavy metric: repeated orthonormalization
                // passes over a scratch copy, cost ∝ Q·B²
                let mut scratch = next.data().to_vec();
                let mut acc = 0.0f64;
                for _ in 0..COMBINE_REPS {
                    let norms = ops::mgs_orthonormalize(&mut scratch, Q, b);
                    acc += norms.iter().sum::<f64>();
                }
                Ok(acc)
            },
        )
        .unwrap();
    let wall = t0.elapsed();
    assert!(out.data().iter().all(|v| v.is_finite()));
    wall
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_pipeline.json")
        .to_string();
    let (steps, budget, iters) = if smoke {
        (6, Duration::from_millis(100), 1)
    } else {
        (24, Duration::from_secs(2), 6)
    };
    let mut bench = Bench::with_budget(budget, iters);

    let mut speedups = Vec::new();
    for batch in [1usize, 4, 16] {
        let mut walls = [Duration::ZERO; 2];
        for (slot, pipeline) in [(0, false), (1, true)] {
            let addrs = start_workers(3);
            let run_cfg = cfg(steps, batch, pipeline, addrs);
            let mut best = Duration::MAX;
            let label = if pipeline { "pipelined" } else { "sync" };
            bench.run_units(
                &format!("tcp power iteration {label} B={batch} ({steps} steps)"),
                steps as f64,
                || {
                    let wall = run_once(&run_cfg);
                    if wall < best {
                        best = wall;
                    }
                    wall.as_secs_f64()
                },
            );
            walls[slot] = best;
        }
        let speedup = walls[0].as_secs_f64() / walls[1].as_secs_f64();
        speedups.push((batch, walls[0], walls[1], speedup));
    }

    println!("{}", bench.table());
    for (batch, sync, piped, speedup) in &speedups {
        println!(
            "B={batch}: sync {sync:?} vs pipelined {piped:?} -> {speedup:.2}x steps/s \
             (step-loop wall, best of {iters})"
        );
    }

    match Bench::write_json(&[&bench], &json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
