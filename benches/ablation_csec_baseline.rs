//! EXP-A4 ablation: USEC (this paper) vs the CSEC baseline it argues
//! against — computation time, storage, decode overhead, and numerical
//! error, across random heterogeneous speeds and elastic availability.
//!
//! Run: `cargo bench --bench ablation_csec_baseline`

use std::time::{Duration, Instant};

use usec::csec::{csec_optimal_time, CsecSystem};
use usec::linalg::gen;
use usec::optim::{solve_load_matrix, SolveParams};
use usec::placement::{Placement, PlacementKind};
use usec::util::fmt::render_table;
use usec::util::Rng;

fn main() {
    let n = 6;
    let l = 3; // CSEC recovery threshold = USEC replication J
    let trials = 300;
    let mut rng = Rng::new(33);

    let usec_placements = [
        ("usec repetition", Placement::build(PlacementKind::Repetition, n, 6, 3).unwrap()),
        ("usec cyclic", Placement::build(PlacementKind::Cyclic, n, 6, 3).unwrap()),
        ("usec man", Placement::build(PlacementKind::Man, n, 20, 3).unwrap()),
    ];

    // --- computation-time comparison (normalized per-X units) ---
    let mut mean_c = vec![0.0f64; usec_placements.len() + 1];
    for _ in 0..trials {
        let sigma: Vec<f64> = (0..n).map(|_| rng.exponential(1.0).max(0.01)).collect();
        let avail: Vec<usize> = (0..n).collect();
        for (i, (_, p)) in usec_placements.iter().enumerate() {
            let g = p.submatrices() as f64;
            let s: Vec<f64> = sigma.iter().map(|&x| x * g).collect();
            let sol = solve_load_matrix(p, &avail, &s, &SolveParams::default()).unwrap();
            mean_c[i] += sol.time / trials as f64;
        }
        // CSEC per-X: coded block = q/L rows, coverage L, speed per block
        let s_blocks: Vec<f64> = sigma.iter().map(|&x| x * l as f64).collect();
        let c = csec_optimal_time(&avail, &s_blocks, l).unwrap() / 1.0;
        mean_c[usec_placements.len()] += c / trials as f64;
    }
    let mut rows: Vec<Vec<String>> = usec_placements
        .iter()
        .enumerate()
        .map(|(i, (name, p))| {
            vec![
                name.to_string(),
                format!("{:.4}", mean_c[i]),
                format!("{:.2}", p.storage_fraction(0) * p.machines() as f64),
                "none".into(),
            ]
        })
        .collect();
    rows.push(vec![
        "csec (L=3)".into(),
        format!("{:.4}", mean_c[usec_placements.len()]),
        format!("{:.2}", 6.0 / l as f64),
        "LxL solve / row".into(),
    ]);
    println!("EXP-A4: USEC vs CSEC over {trials} exponential speed draws (N=6)\n");
    println!(
        "{}",
        render_table(
            &["system", "mean c (per-X)", "total storage (X units)", "decode"],
            &rows
        )
    );

    // --- end-to-end coded step: wall time + decode share + accuracy ---
    let q = 1200;
    let x = gen::random_dense(q, q, 9);
    let sys = CsecSystem::encode(&x, n, l).unwrap();
    let w: Vec<f32> = (0..q).map(|i| ((i % 17) as f32 - 8.0) * 0.1).collect();
    let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
    let avail: Vec<usize> = (0..n).collect();

    let t0 = Instant::now();
    let (y, _) = sys.step(&avail, &speeds, &w).unwrap();
    let coded_wall = t0.elapsed();

    let t1 = Instant::now();
    let want = x.matvec(&w).unwrap();
    let plain_wall = t1.elapsed();

    let mut max_rel = 0.0f64;
    for (a, e) in y.iter().zip(&want) {
        let rel = ((a - e).abs() / (1.0 + e.abs())) as f64;
        max_rel = max_rel.max(rel);
    }
    println!(
        "end-to-end q={q}: coded step {} vs plain matvec {} (single-thread); \
         max relative decode error {max_rel:.2e}",
        usec::util::fmt::dur(coded_wall),
        usec::util::fmt::dur(plain_wall),
    );
    println!(
        "(CSEC matches/beats USEC on time with 1/L storage, but pays an L×L \
         decode per row and f32 conditioning error — and only supports \
         computations that commute with linear coding, which is the paper's \
         core motivation for USEC)"
    );
    let _ = Duration::from_secs(0);
}
