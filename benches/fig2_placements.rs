//! EXP-F2/T1 bench: regenerate paper Fig. 2 histograms and Table I moments
//! over 5000 exponential speed realizations (override with `FIG2_N`).
//!
//! Run: `cargo bench --bench fig2_placements`

use usec::exp::fig2::{report, Fig2Params};

fn main() {
    let realizations = std::env::var("FIG2_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5000);
    let t0 = std::time::Instant::now();
    let out = report(&Fig2Params {
        realizations,
        ..Default::default()
    })
    .expect("fig2");
    println!("{out}");
    println!(
        "({} realizations x 3 placements solved in {:.2?}; {:.2} solves/ms)",
        realizations,
        t0.elapsed(),
        (realizations * 3) as f64 / t0.elapsed().as_millis().max(1) as f64
    );
}
