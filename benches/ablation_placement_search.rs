//! EXP-A7 ablation: searched placements (paper §III notes no named
//! placement is optimal in general) — local search over J-replica
//! placements vs repetition / cyclic / MAN under the Fig. 2 speed regime.
//!
//! Run: `cargo bench --bench ablation_placement_search`

use usec::placement::optimizer::{expected_time, local_search, sample_speeds, SearchParams};
use usec::placement::{Placement, PlacementKind};
use usec::util::fmt::render_table;

fn main() {
    let sp = SearchParams {
        samples: 60,
        iters: 250,
        lambda: 1.0,
        seed: 321,
    };
    let named = [
        ("repetition", Placement::build(PlacementKind::Repetition, 6, 6, 3).unwrap()),
        ("cyclic", Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap()),
    ];
    // shared evaluation sample (G=6 normalization)
    let samples = sample_speeds(6, 6, &sp);

    let mut rows = Vec::new();
    for (name, p) in &named {
        rows.push(vec![
            name.to_string(),
            format!("{:.4}", expected_time(p, &samples).unwrap()),
        ]);
    }
    // MAN needs its own G=20 normalization; evaluate on matching samples
    let man = Placement::build(PlacementKind::Man, 6, 20, 3).unwrap();
    let man_samples: Vec<Vec<f64>> = samples
        .iter()
        .map(|s| s.iter().map(|x| x * 20.0 / 6.0).collect())
        .collect();
    rows.push(vec![
        "man".into(),
        format!("{:.4}", expected_time(&man, &man_samples).unwrap()),
    ]);

    let t0 = std::time::Instant::now();
    let (found, t_found) = local_search(&named[0].1, &sp).unwrap();
    rows.push(vec![
        format!("searched ({} iters)", sp.iters),
        format!("{t_found:.4}"),
    ]);
    println!("EXP-A7: expected optimal c over {} exponential draws (N=6, J=3)\n", sp.samples);
    println!("{}", render_table(&["placement", "E[c*]"], &rows));
    println!("search wall time: {:?}", t0.elapsed());
    println!("\nsearched placement replica map:");
    for g in 0..found.submatrices() {
        println!("  X_{} → machines {:?}", g + 1, found.machines_storing(g));
    }
}
