//! EXP-A3 ablation: the EWMA factor γ (Algorithm 1 line 4) trades
//! adaptation speed against stability. Simulates a drifting-speed fleet
//! with noisy measurements and reports the regret of the γ-tracked
//! assignment vs an oracle that knows true speeds.
//!
//! Run: `cargo bench --bench ablation_gamma`

use usec::optim::{solve_load_matrix, SolveParams};
use usec::placement::{Placement, PlacementKind};
use usec::sched::SpeedEstimator;
use usec::util::fmt::render_table;
use usec::util::Rng;

fn main() {
    let p = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let avail: Vec<usize> = (0..6).collect();
    let steps = 120;
    let noise = 0.25; // multiplicative measurement noise (lognormal-ish)

    let mut rows = Vec::new();
    for gamma in [0.1, 0.3, 0.5, 0.7, 0.9, 1.0] {
        let mut rng = Rng::new(4242);
        let mut est = SpeedEstimator::uniform(gamma, 6);
        let mut regret = 0.0f64;
        let mut worst = 0.0f64;
        for t in 0..steps {
            // true speeds drift: slow sinusoid + a step change at t=60
            let truth: Vec<f64> = (0..6)
                .map(|n| {
                    let base = 1.0 + n as f64;
                    let drift = 1.0 + 0.5 * ((t as f64 / 20.0) + n as f64).sin();
                    let kick = if t >= 60 && n == 0 { 3.0 } else { 1.0 };
                    base * drift * kick
                })
                .collect();
            // assignment computed with the *estimate*
            let est_sol =
                solve_load_matrix(&p, &avail, est.estimate(), &SolveParams::default()).unwrap();
            // realized time: estimated loads executed at TRUE speeds
            let realized = est_sol.load.computation_time(&truth, &avail);
            // oracle time
            let oracle = solve_load_matrix(&p, &avail, &truth, &SolveParams::default())
                .unwrap()
                .time;
            let step_regret = realized / oracle - 1.0;
            regret += step_regret / steps as f64;
            worst = worst.max(step_regret);
            // noisy measurements of the true speed
            for n in 0..6 {
                let eps = 1.0 + noise * (rng.f64() - 0.5) * 2.0;
                est.update(n, truth[n] * eps);
            }
        }
        rows.push(vec![
            format!("{gamma:.1}"),
            format!("{:.2}%", regret * 100.0),
            format!("{:.2}%", worst * 100.0),
        ]);
    }
    println!("EXP-A3: EWMA gamma sweep, drifting speeds + {noise:.0?} measurement noise\n");
    println!(
        "{}",
        render_table(&["gamma", "mean regret", "worst-step regret"], &rows)
    );
    println!("(regret = realized step time / oracle-optimal step time − 1)");
}
