//! EXP-A1 ablation: the two independent exact solvers (dense simplex vs
//! parametric max-flow bisection) must agree on random instances; compare
//! their latencies across placement families and problem sizes.
//!
//! Run: `cargo bench --bench ablation_solvers`

use std::time::Duration;

use usec::optim::{solve_load_matrix, SolveParams, SolverKind};
use usec::placement::{Placement, PlacementKind};
use usec::util::benchkit::Bench;
use usec::util::Rng;

fn main() {
    let mut rng = Rng::new(77);
    let cases = [
        ("rep N=6 G=6 J=3", Placement::build(PlacementKind::Repetition, 6, 6, 3).unwrap()),
        ("cyc N=6 G=6 J=3", Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap()),
        ("man N=6 G=20 J=3", Placement::build(PlacementKind::Man, 6, 20, 3).unwrap()),
        ("cyc N=12 G=24 J=4", Placement::build(PlacementKind::Cyclic, 12, 24, 4).unwrap()),
        ("man N=8 G=56 J=3", Placement::build(PlacementKind::Man, 8, 56, 3).unwrap()),
    ];

    // agreement sweep
    let mut max_gap = 0.0f64;
    let mut checked = 0usize;
    for (_, p) in &cases {
        let avail: Vec<usize> = (0..p.machines()).collect();
        for s_cnt in 0..2usize {
            for _ in 0..50 {
                let speeds: Vec<f64> = (0..p.machines())
                    .map(|_| rng.exponential(1.0).max(0.02))
                    .collect();
                let a = solve_load_matrix(
                    p,
                    &avail,
                    &speeds,
                    &SolveParams {
                        stragglers: s_cnt,
                        solver: SolverKind::Simplex,
                        ..Default::default()
                    },
                )
                .unwrap();
                let b = solve_load_matrix(
                    p,
                    &avail,
                    &speeds,
                    &SolveParams {
                        stragglers: s_cnt,
                        solver: SolverKind::ParametricFlow,
                        ..Default::default()
                    },
                )
                .unwrap();
                let gap = (a.time - b.time).abs() / a.time.max(1e-12);
                max_gap = max_gap.max(gap);
                checked += 1;
            }
        }
    }
    println!("solver agreement: {checked} random instances, max relative gap {max_gap:.2e}");
    assert!(max_gap < 1e-5, "solvers disagree");

    // latency comparison
    let mut bench = Bench::with_budget(Duration::from_millis(300), 3000);
    for (label, p) in &cases {
        let avail: Vec<usize> = (0..p.machines()).collect();
        let speeds: Vec<f64> = (0..p.machines())
            .map(|i| 1.0 + (i % 5) as f64)
            .collect();
        for (sname, solver) in [
            ("simplex", SolverKind::Simplex),
            ("flow", SolverKind::ParametricFlow),
        ] {
            let params = SolveParams {
                solver,
                ..Default::default()
            };
            bench.run(&format!("{label} [{sname}]"), || {
                solve_load_matrix(p, &avail, &speeds, &params).unwrap().time
            });
        }
    }
    println!("{}", bench.table());
}
