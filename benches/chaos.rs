//! Chaos benchmark: what the fault-injection layer costs when idle, and
//! how fast the master recovers when it is not.
//!
//! Two questions, answered over the in-process transport:
//!
//! * **Idle overhead** — the `ChaosTransport` wrapper with an armed but
//!   never-firing schedule (a partition window far past the last step)
//!   sits on every frame of the hot path. Its steps/s must be within
//!   noise of the unwrapped run.
//! * **Time-to-recover** — a `crash=W@S+K` schedule kills a worker for
//!   `K` steps with `--recovery` armed; the per-crashed-step wall-clock
//!   beyond the fault-free baseline is the end-to-end recovery latency
//!   (overdue detection + re-plan + supplementary orders).
//!
//! Run: `cargo bench --bench chaos [-- --smoke] [-- --json PATH]`
//!
//! Results land as machine-readable JSON (default `BENCH_chaos.json`).

use std::time::{Duration, Instant};

use usec::apps::run_power_iteration;
use usec::config::types::RunConfig;
use usec::sched::RecoveryPolicy;
use usec::util::benchkit::Bench;

const Q: usize = 96;
const SEED: u64 = 31;

fn cfg(steps: usize) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 6,
        j: 3,
        n: 6,
        steps,
        speeds: vec![1.0; 6],
        seed: SEED,
        recovery: RecoveryPolicy {
            enabled: true,
            overdue_factor: 0.05, // 100ms of the 2s chaos coverage timeout
        },
        ..Default::default()
    }
}

/// Wall-clock of one full run (build + step loop), plus its fault count.
fn run_once(cfg: &RunConfig) -> (Duration, u64) {
    let t0 = Instant::now();
    let res = run_power_iteration(cfg).expect("bench run");
    let wall = t0.elapsed();
    let faults = res.timeline.steps().iter().map(|s| s.faults).sum();
    (wall, faults)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_chaos.json")
        .to_string();
    let (steps, budget, iters) = if smoke {
        (6, Duration::from_millis(100), 1)
    } else {
        (40, Duration::from_secs(2), 6)
    };
    let mut bench = Bench::with_budget(budget, iters);

    // --- idle overhead: armed-but-silent wrapper vs no wrapper ---
    let clean = cfg(steps);
    let mut armed = clean.clone();
    // the partition window opens far past the last step: the wrapper
    // inspects every frame but never injects — zero faults, pure tax
    armed.chaos = format!("partition=0@{}..{}", steps + 1000, steps + 1001);
    let mut clean_best = Duration::MAX;
    bench.run_units(&format!("power iteration, no chaos ({steps} steps)"), steps as f64, || {
        let (wall, faults) = run_once(&clean);
        assert_eq!(faults, 0);
        clean_best = clean_best.min(wall);
        wall.as_secs_f64()
    });
    let mut armed_best = Duration::MAX;
    bench.run_units(
        &format!("power iteration, idle chaos wrapper ({steps} steps)"),
        steps as f64,
        || {
            let (wall, faults) = run_once(&armed);
            assert_eq!(faults, 0, "the armed window must never fire");
            armed_best = armed_best.min(wall);
            wall.as_secs_f64()
        },
    );

    // --- time-to-recover: crash a worker for 2 steps, recovery on ---
    let crash_steps = 2u32;
    let mut crashed = clean.clone();
    crashed.chaos = format!("crash=1@2+{crash_steps}");
    let mut crash_best = Duration::MAX;
    bench.run_units(
        &format!("power iteration, crash-restart ({steps} steps)"),
        steps as f64,
        || {
            let (wall, faults) = run_once(&crashed);
            assert!(faults > 0, "the crash window never fired");
            crash_best = crash_best.min(wall);
            wall.as_secs_f64()
        },
    );

    println!("{}", bench.table());
    let overhead =
        armed_best.as_secs_f64() / clean_best.as_secs_f64() - 1.0;
    println!(
        "idle wrapper overhead: {:+.1}% ({clean_best:?} -> {armed_best:?}, best of {iters})",
        overhead * 100.0
    );
    let recover =
        crash_best.saturating_sub(clean_best).as_secs_f64() / crash_steps as f64;
    println!(
        "time-to-recover: {:.1} ms per crashed step \
         ({crash_best:?} total vs {clean_best:?} fault-free)",
        recover * 1e3
    );

    match Bench::write_json(&[&bench], &json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
}
