//! Hot-path microbenchmarks (the §Perf numbers in EXPERIMENTS.md):
//!
//! * assignment solve (simplex/flow), filling, quantization — the master's
//!   per-step control path;
//! * tile mat-vec / block mat-mat on the host backend and (when artifacts
//!   exist) the PJRT backend — the worker's per-tile data path. The
//!   `matmat B=k` rows measure the block data plane: one tile traversal
//!   amortized over `k` vectors, against `k` sequential B=1 matvecs over
//!   the same tile;
//! * one full master/worker step end-to-end.
//!
//! Run: `cargo bench --bench hotpath [-- --smoke] [-- --json PATH]`
//!
//! Results are also written as machine-readable JSON (default
//! `BENCH_hotpath.json`: name, ns/iter, percentiles, rows·vectors/s) so
//! the perf trajectory has data points across commits. `--smoke` shrinks
//! the measurement budget to a CI-friendly sanity run.

use std::sync::Arc;
use std::time::Duration;

use usec::config::types::AssignPolicy;
use usec::linalg::partition::submatrix_ranges;
use usec::linalg::{gen, ops, Block};
use usec::optim::{build_assignment, solve_load_matrix, SolveParams, SolverKind};
use usec::placement::{Placement, PlacementKind};
use usec::runtime::BackendSpec;
use usec::sched::cluster::Cluster;
use usec::sched::master::{Master, MasterConfig};
use usec::sched::worker::{WorkerConfig, WorkerStorage};
use usec::util::benchkit::Bench;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(String::as_str)
        .unwrap_or("BENCH_hotpath.json")
        .to_string();
    let (budget, max_iters, e2e_budget, e2e_iters) = if smoke {
        (Duration::from_millis(40), 200, Duration::from_millis(200), 10)
    } else {
        (Duration::from_millis(500), 20_000, Duration::from_millis(1500), 200)
    };
    let mut bench = Bench::with_budget(budget, max_iters);

    // ---- control path ----
    let p = Placement::build(PlacementKind::Man, 6, 20, 3).unwrap();
    let avail: Vec<usize> = (0..6).collect();
    let speeds = vec![1.3, 2.1, 0.7, 4.0, 1.1, 2.9];
    for (name, solver) in [
        ("solve MAN G=20 (simplex)", SolverKind::Simplex),
        ("solve MAN G=20 (flow)", SolverKind::ParametricFlow),
    ] {
        let params = SolveParams {
            solver,
            ..Default::default()
        };
        bench.run(name, || {
            solve_load_matrix(&p, &avail, &speeds, &params).unwrap().time
        });
    }
    let sub_rows: Vec<usize> =
        submatrix_ranges(6000, 20).unwrap().iter().map(|r| r.len()).collect();
    let params = SolveParams::with_stragglers(1);
    bench.run("solve+fill+quantize MAN S=1 q=6000", || {
        build_assignment(&p, &avail, &speeds, &params, &sub_rows).unwrap()
    });

    // ---- data path: tile matvec (B=1 reference) ----
    let cols = 1536usize;
    let tile = 128usize;
    let x: Vec<f32> = (0..tile * cols).map(|i| (i % 13) as f32 * 0.1).collect();
    let w: Vec<f32> = (0..cols).map(|i| (i % 7) as f32 * 0.01).collect();
    let host = BackendSpec::Host.instantiate().unwrap();
    bench.run_units("matvec tile 128x1536 (host)", tile as f64, || {
        host.matvec_tile(&x, tile, cols, &w).unwrap()
    });

    // ---- data path: block matmat at B ∈ {1, 4, 8, 16} ----
    // units are rows·vectors so the amortization is visible as throughput
    for b in [1usize, 4, 8, 16] {
        let panel: Vec<f32> = (0..cols * b).map(|i| (i % 9) as f32 * 0.02 - 0.08).collect();
        let mut out = vec![0.0f32; tile * b];
        bench.run_units(
            &format!("matmat tile 128x1536 B={b} (host)"),
            (tile * b) as f64,
            || {
                ops::matmat_into(&x, tile, cols, &panel, b, &mut out);
                out[0]
            },
        );
    }
    // the baseline the acceptance criterion compares against: 8
    // sequential B=1 matvecs over the same tile (8 tile traversals)
    {
        let cols8: Vec<Vec<f32>> = (0..8)
            .map(|k| (0..cols).map(|i| ((i + k) % 9) as f32 * 0.02 - 0.08).collect())
            .collect();
        let mut out = vec![0.0f32; tile];
        bench.run_units("8x sequential matvec tile 128x1536 (host)", (tile * 8) as f64, || {
            for c in &cols8 {
                ops::matvec_into(&x, tile, cols, c, &mut out);
            }
            out[0]
        });
    }

    let artifact_dir = usec::apps::harness::artifact_dir();
    if artifact_dir.join("manifest.json").exists() {
        let pjrt = BackendSpec::Pjrt { dir: artifact_dir }.instantiate().unwrap();
        if pjrt.tile_rows() == Some(tile) {
            bench.run_units("matvec tile 128x1536 (pjrt)", tile as f64, || {
                pjrt.matvec_tile(&x, tile, cols, &w).unwrap()
            });
            let y: Vec<f32> = (0..cols).map(|i| (i % 5) as f32).collect();
            bench.run("normalize q=1536 (pjrt)", || pjrt.normalize(&y).unwrap());
            bench.run("normalize q=1536 (host)", || host.normalize(&y).unwrap());
        }
    }

    // ---- end-to-end master step (host backend, 6 workers) ----
    let q = 960;
    let g = 6;
    let placement = Placement::build(PlacementKind::Cyclic, 6, g, 3).unwrap();
    let ranges = submatrix_ranges(q, g).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 1));
    let arc_ranges = Arc::new(ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| WorkerConfig {
            id,
            backend: BackendSpec::Host,
            speed: 1.0 + id as f64,
            tile_rows: 128,
            threads: 1,
            storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&arc_ranges)),
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut master = Master::new(MasterConfig {
        placement,
        sub_ranges: ranges,
        params: SolveParams::default(),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: (0..6).map(|i| 1.0 + i as f64).collect(),
        row_cost_ns: 0,
        recovery_timeout: Duration::from_secs(30),
        recovery: usec::sched::RecoveryPolicy::default(),
    })
    .unwrap();
    let mut e2e = Bench::with_budget(e2e_budget, e2e_iters);
    {
        let w_vec = Arc::new(Block::single(vec![0.01f32; q]));
        let mut step = 0usize;
        e2e.run_units("master step E2E q=960 B=1 (host, 6 workers)", q as f64, || {
            let out = master.step(&cluster, step, &w_vec, &avail, &[]).unwrap();
            step += 1;
            out.y.len()
        });
        // the same step shipping an 8-vector block end-to-end
        let w_block = Arc::new(
            Block::from_interleaved(
                q,
                8,
                (0..q * 8).map(|i| (i % 17) as f32 * 0.003).collect(),
            )
            .unwrap(),
        );
        e2e.run_units("master step E2E q=960 B=8 (host, 6 workers)", (q * 8) as f64, || {
            let out = master.step(&cluster, step, &w_block, &avail, &[]).unwrap();
            step += 1;
            out.y.len()
        });
    }

    println!("{}", bench.table());
    println!("{}", e2e.table());

    match Bench::write_json(&[&bench, &e2e], &json_path) {
        Ok(()) => println!("wrote {json_path}"),
        Err(e) => eprintln!("could not write {json_path}: {e}"),
    }
    cluster.shutdown();
}
