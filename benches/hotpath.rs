//! Hot-path microbenchmarks (the §Perf numbers in EXPERIMENTS.md):
//!
//! * assignment solve (simplex/flow), filling, quantization — the master's
//!   per-step control path;
//! * tile mat-vec on the host backend and (when artifacts exist) the PJRT
//!   backend — the worker's per-tile data path;
//! * one full master/worker step end-to-end.
//!
//! Run: `cargo bench --bench hotpath`

use std::sync::Arc;
use std::time::Duration;

use usec::config::types::AssignPolicy;
use usec::linalg::partition::submatrix_ranges;
use usec::linalg::gen;
use usec::optim::{build_assignment, solve_load_matrix, SolveParams, SolverKind};
use usec::placement::{Placement, PlacementKind};
use usec::runtime::BackendSpec;
use usec::sched::cluster::Cluster;
use usec::sched::master::{Master, MasterConfig};
use usec::sched::worker::{WorkerConfig, WorkerStorage};
use usec::util::benchkit::Bench;

fn main() {
    let mut bench = Bench::with_budget(Duration::from_millis(500), 20_000);

    // ---- control path ----
    let p = Placement::build(PlacementKind::Man, 6, 20, 3).unwrap();
    let avail: Vec<usize> = (0..6).collect();
    let speeds = vec![1.3, 2.1, 0.7, 4.0, 1.1, 2.9];
    for (name, solver) in [
        ("solve MAN G=20 (simplex)", SolverKind::Simplex),
        ("solve MAN G=20 (flow)", SolverKind::ParametricFlow),
    ] {
        let params = SolveParams {
            solver,
            ..Default::default()
        };
        bench.run(name, || {
            solve_load_matrix(&p, &avail, &speeds, &params).unwrap().time
        });
    }
    let sub_rows: Vec<usize> = submatrix_ranges(6000, 20).unwrap().iter().map(|r| r.len()).collect();
    let params = SolveParams::with_stragglers(1);
    bench.run("solve+fill+quantize MAN S=1 q=6000", || {
        build_assignment(&p, &avail, &speeds, &params, &sub_rows).unwrap()
    });

    // ---- data path: tile matvec ----
    let cols = 1536usize;
    let tile = 128usize;
    let x: Vec<f32> = (0..tile * cols).map(|i| (i % 13) as f32 * 0.1).collect();
    let w: Vec<f32> = (0..cols).map(|i| (i % 7) as f32 * 0.01).collect();
    let host = BackendSpec::Host.instantiate().unwrap();
    bench.run("matvec tile 128x1536 (host)", || {
        host.matvec_tile(&x, tile, cols, &w).unwrap()
    });
    let artifact_dir = usec::apps::harness::artifact_dir();
    if artifact_dir.join("manifest.json").exists() {
        let pjrt = BackendSpec::Pjrt { dir: artifact_dir }.instantiate().unwrap();
        if pjrt.tile_rows() == Some(tile) {
            bench.run("matvec tile 128x1536 (pjrt)", || {
                pjrt.matvec_tile(&x, tile, cols, &w).unwrap()
            });
            let y: Vec<f32> = (0..cols).map(|i| (i % 5) as f32).collect();
            bench.run("normalize q=1536 (pjrt)", || pjrt.normalize(&y).unwrap());
            bench.run("normalize q=1536 (host)", || host.normalize(&y).unwrap());
        }
    }

    // ---- end-to-end master step (host backend, 6 workers) ----
    let q = 960;
    let g = 6;
    let placement = Placement::build(PlacementKind::Cyclic, 6, g, 3).unwrap();
    let ranges = submatrix_ranges(q, g).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 1));
    let arc_ranges = Arc::new(ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| WorkerConfig {
            id,
            backend: BackendSpec::Host,
            speed: 1.0 + id as f64,
            tile_rows: 128,
            storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&arc_ranges)),
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut master = Master::new(MasterConfig {
        placement,
        sub_ranges: ranges,
        params: SolveParams::default(),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: (0..6).map(|i| 1.0 + i as f64).collect(),
        row_cost_ns: 0,
        recovery_timeout: Duration::from_secs(30),
    })
    .unwrap();
    let w_vec = Arc::new(vec![0.01f32; q]);
    let mut step = 0usize;
    let mut e2e = Bench::with_budget(Duration::from_millis(1500), 200);
    e2e.run("master step E2E q=960 (host, 6 workers)", || {
        let out = master.step(&cluster, step, &w_vec, &avail, &[]).unwrap();
        step += 1;
        out.y.len()
    });

    println!("{}", bench.table());
    println!("{}", e2e.table());
    cluster.shutdown();
}
