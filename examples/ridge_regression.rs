//! Ridge regression on the elastic substrate: the same USEC mat-vec
//! machinery solving `(A + λI) w = b` by Richardson iteration, with
//! preemptions happening mid-solve.
//!
//! Run: `cargo run --release --example ridge_regression`

use usec::apps::ridge::run_ridge;
use usec::config::types::RunConfig;

fn main() -> Result<(), usec::Error> {
    let cfg = RunConfig {
        q: 512,
        r: 512,
        steps: 100,
        preempt_prob: 0.15,
        arrive_prob: 0.4,
        min_available: 3,
        speeds: vec![1.0, 1.8, 0.7, 2.2, 1.3, 2.6],
        seed: 99,
        ..Default::default()
    };
    println!(
        "elastic ridge regression: q={}, {} Richardson steps, preemptions on\n",
        cfg.q, cfg.steps
    );
    let res = run_ridge(&cfg, 3.0, 0.13)?;
    for s in res.timeline.steps().iter().step_by(10) {
        println!(
            "step {:>3}: avail {}  residual {:.3e}",
            s.step, s.available, s.metric
        );
    }
    println!(
        "\nfinal relative residual {:.3e} in {:?}",
        res.final_residual,
        res.timeline.total_wall()
    );
    Ok(())
}
