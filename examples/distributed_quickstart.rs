//! Distributed quickstart: 1 master + 3 TCP worker daemons on loopback,
//! in one process for convenience.
//!
//! In production the workers are separate processes (or machines):
//!
//! ```text
//! usec worker --listen 127.0.0.1:7701     # terminal 1
//! usec worker --listen 127.0.0.1:7702     # terminal 2
//! usec worker --listen 127.0.0.1:7703     # terminal 3
//! usec master --workers 127.0.0.1:7701,127.0.0.1:7702,127.0.0.1:7703 \
//!     --q 1536 --g 3 --j 2 --placement cyclic --json-out run.json
//! ```
//!
//! Each worker materializes only its placed J-out-of-G share (here 2/3 of
//! the matrix), generated **row by row** from the workload spec in the
//! handshake — peak worker memory is the share itself, never the full
//! matrix. Add `--stream-data` and the master instead streams each
//! worker's rows as checksummed `Data` frames — the path for external
//! data that no seed can regenerate (ridge/pagerank over real inputs):
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --stream-data --json-out run.json
//! ```
//!
//! Add `--batch 4` and every step ships a block of 4 iterate vectors —
//! the workers run the batched mat-mat kernel (one traversal of their
//! stored rows serves all 4 vectors) and the run becomes block power
//! iteration, estimating the top of the spectrum instead of one
//! eigenpair. `--threads T` additionally fans each worker's tiles across
//! `T` compute threads:
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --batch 4 --threads 2 --json-out run.json
//! ```
//!
//! Add `--recovery` and a worker that dies *mid-step* (socket kill,
//! preemption, silent drop past `--overdue-factor` of the recovery
//! timeout) no longer stalls the step: the master re-plans its uncovered
//! rows onto the surviving replicas — uncoded storage means any replica
//! can compute them, no decoding — ships supplementary orders for the
//! same step, and records the event under `timeline[i].recoveries` in
//! the `--json-out` dump:
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --stragglers 0 --recovery --overdue-factor 0.5 --json-out run.json
//! ```
//!
//! Add `--rebalance` and the placement stops being frozen at job start:
//! between steps the master compares the current placement's expected
//! time under its *live* EWMA speed estimates against the best placement
//! a local search finds, and past `--rebalance-threshold` regret it
//! migrates shard rows to the new layout — make-before-break over the
//! wire (`PlacementUpdate`/`MigrateAck` + checksummed `Data` chunks),
//! metered by `--migration-budget` bytes per step, with every move under
//! `timeline[i].migrations` in the `--json-out` dump:
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --rebalance --rebalance-threshold 0.15 \
//!     --migration-budget 8388608 --row-cost-ns 200000 --json-out run.json
//! ```
//!
//! Add `--pipeline` and the master's step loop stops being synchronous:
//! the previous step's combine metric (MGS norms, NMSE) runs while the
//! workers already compute the next step, and migration bytes stream on
//! a dedicated transfer lane concurrently with compute. The iterate
//! trajectory is unchanged — only metric work crosses the step boundary
//! — and each step reports the hidden time as `timeline[i].overlap_ns`
//! in the `--json-out` dump:
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --batch 16 --pipeline --json-out run.json
//! ```
//!
//! Add `--trace-out trace.jsonl` and the run journals every span — the
//! master's per-step and per-order timings plus the worker-side
//! decode/compute/idle breakdowns piggybacked on each `Report` (wire v5)
//! — which `usec trace` then converts for `chrome://tracing`, or
//! summarizes as a time-sink table with `--summary`:
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --trace-out trace.jsonl --json-out run.json
//! usec trace trace.jsonl --out trace.json   # load in chrome://tracing
//! usec trace trace.jsonl --summary          # top time sinks, as text
//! ```
//!
//! Add `--chaos <spec>` and the transport starts injecting faults from a
//! deterministic seed (`--chaos-seed`, default derived from `--seed`):
//! frame drops, delivery delays, duplication, corruption, asymmetric
//! partitions, slow-worker throttles, crash-restart windows. The same
//! spec + seed replays the same fault schedule byte-for-byte — a failing
//! soak run is a replayable bug report. Every injected fault lands in
//! the journal and in `timeline[i].faults`; pair it with `--recovery` so
//! dropped orders are re-planned instead of timing the step out:
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --chaos "drop=0.05,delay=10:0.2,crash=1@5+3" --chaos-seed 42 \
//!     --recovery --json-out run.json
//! ```
//!
//! Add `--checkpoint-out run.ckpt` and the master snapshots its resumable
//! state (iterate bits, EWMA speeds, live placement) at every step
//! boundary — written off the critical path by a writer thread, atomic
//! temp-file + rename, FNV-checksummed and digest-bound to this exact
//! workload. If the master host dies, restart it with `--resume`: it
//! fast-forwards to the checkpointed step and lands on the same answer
//! the uninterrupted run would have produced. A truncated, corrupted, or
//! wrong-job checkpoint is rejected with a typed error:
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --checkpoint-out run.ckpt --json-out run.json
//! # ...master killed at step k; same job, new master:
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --resume run.ckpt --json-out rest.json
//! ```
//!
//! The cluster doesn't have to be one-job-and-exit. `usec serve` keeps
//! it resident behind a socket and serves a stream of tenant-tagged
//! requests — personalized-PageRank seeds, raw mat-vecs, ridge solves —
//! continuously batched into one block per elastic step (columns join
//! and retire at step boundaries), with deficit-round-robin fairness
//! across tenants and a bounded admission queue that rejects with a
//! typed `busy` error when full:
//!
//! ```text
//! usec serve --listen 127.0.0.1:7700 --workers ... --stream-data \
//!     --q 1536 --g 3 --j 2 --placement cyclic \
//!     --max-width 8 --queue-cap 64 --idle-ms 5000 --json-out serve.json
//! # two tenants, concurrently:
//! usec serve --connect 127.0.0.1:7700 --tenant alice --seed-node 3 --tol 1e-8
//! usec serve --connect 127.0.0.1:7700 --tenant bob   --seed-node 7 --tol 1e-8
//! ```
//!
//! The serve `--json-out` adds request-plane keys on top of the
//! timeline: `requests`, `latency_p50_ns`/`latency_p99_ns`,
//! `queue_depth`, `rows_per_s`.
//!
//! Either way `--json-out` reports the actual per-worker resident bytes
//! under `timeline.storage`. Here we spawn the same daemons on threads
//! and drive the same master code path (`RunConfig.workers` →
//! `TcpTransport`), so `cargo run --example distributed_quickstart` works
//! anywhere.

use std::net::TcpListener;
use std::time::Duration;

use usec::apps::run_power_iteration;
use usec::config::types::RunConfig;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::placement::PlacementKind;
use usec::rebalance::RebalanceConfig;
use usec::sched::RecoveryPolicy;
use usec::serve::{serve_listen, Query, ServeClient, ServeOpts, SessionOpts};

fn main() {
    usec::util::log::init();

    // --- "terminals 1-3": three worker daemons on ephemeral ports ---
    // (each serves ten master sessions: the generator-backed run, the
    // streamed run, the batched block run, the pipelined run, the
    // rebalanced run, the chaos run, the checkpointed run + its resume,
    // the serving session, and the traced run below)
    let mut addrs = Vec::new();
    let mut daemons = Vec::new();
    for _ in 0..3 {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().unwrap().to_string());
        daemons.push(std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 10,
                    ..Default::default()
                },
            )
        }));
    }
    println!("workers listening on {addrs:?}");

    // --- "terminal 4": the master dials the workers over TCP ---
    // cyclic J=2 of G=3: each worker stores 2/3 of the matrix, and that is
    // all it materializes — storage cost is real, not simulated.
    let cfg = RunConfig {
        q: 480,
        r: 480,
        g: 3,
        j: 2,
        n: 3,
        placement: PlacementKind::Cyclic,
        steps: 30,
        speeds: vec![1.0, 2.0, 4.0],
        seed: 7,
        workers: addrs.clone(),
        ..Default::default()
    };
    let res = run_power_iteration(&cfg).expect("distributed run");
    println!(
        "generator-backed shard run: final NMSE {:.3e}, eigenvalue {:.4} (truth {:.4})",
        res.final_nmse, res.eigval, res.truth_eigval
    );
    println!(
        "per-worker resident storage: {:?} bytes (full matrix would be {})",
        res.timeline.storage_bytes(),
        cfg.q * cfg.r * 4
    );

    // --- same run with --stream-data: rows travel as Data frames ---
    let streamed_cfg = RunConfig {
        stream_data: true,
        workers: addrs.clone(),
        ..cfg.clone()
    };
    let streamed = run_power_iteration(&streamed_cfg).expect("streamed run");
    println!(
        "streamed-data run:          final NMSE {:.3e} (matches: {})",
        streamed.final_nmse,
        (streamed.final_nmse - res.final_nmse).abs() < 1e-9
    );

    // --- block data plane: --batch 4 --threads 2 over the same daemons ---
    // four iterate vectors per step (tags 10/11 on the wire); the workers
    // traverse their stored rows once per step for all four vectors.
    // --recovery arms mid-step re-dispatch: had a worker died inside a
    // step, its uncovered rows would have been re-planned onto the
    // surviving replicas instead of stalling the step.
    let batched_cfg = RunConfig {
        batch: 4,
        worker_threads: 2,
        recovery: RecoveryPolicy::enabled(),
        workers: addrs.clone(),
        ..cfg.clone()
    };
    let batched = run_power_iteration(&batched_cfg).expect("batched run");
    println!(
        "batched run (B=4):          final NMSE {:.3e}, spectrum estimate {:?}",
        batched.final_nmse, batched.eigvals
    );
    println!(
        "mid-step recoveries needed: {} (healthy run)",
        batched.timeline.total_recoveries()
    );

    // --- pipelined master: --pipeline over the same daemons ---
    // the previous step's MGS/NMSE combine runs while the workers compute
    // the next step; the trajectory is identical to the batched run above,
    // and every step reports the hidden combine time as overlap_ns.
    let pipelined_cfg = RunConfig {
        pipeline: true,
        workers: addrs.clone(),
        ..batched_cfg.clone()
    };
    let pipelined = run_power_iteration(&pipelined_cfg).expect("pipelined run");
    let hidden_ms: f64 = pipelined
        .timeline
        .steps()
        .iter()
        .map(|s| s.overlap_ns as f64 / 1e6)
        .sum();
    println!(
        "pipelined run (B=4):        final NMSE {:.3e} (matches batched: {}), \
         {hidden_ms:.2} ms of combine hidden inside compute",
        pipelined.final_nmse,
        (pipelined.final_nmse - batched.final_nmse).abs() < 1e-9
    );

    // --- live placement adaptation: --rebalance over the same daemons ---
    // the true speeds are strongly skewed (machine 2 is 6x the others) but
    // the master starts from a uniform prior; once the EWMA learns the
    // skew, the drift monitor fires and shard rows migrate between steps
    // (PlacementUpdate/MigrateAck + checksummed Data chunks on the wire).
    let rebalanced_cfg = RunConfig {
        speeds: vec![1.0, 1.0, 6.0],
        row_cost_ns: 200_000, // throttle makes the skew measurable
        rebalance: RebalanceConfig::enabled(),
        workers: addrs.clone(),
        ..cfg.clone()
    };
    let rebalanced = run_power_iteration(&rebalanced_cfg).expect("rebalanced run");
    println!(
        "rebalanced run:             final NMSE {:.3e}, {} replica move(s), \
         {} bytes migrated",
        rebalanced.final_nmse,
        rebalanced.timeline.total_migrations(),
        rebalanced.timeline.total_migrated_bytes()
    );
    println!(
        "post-migration per-worker storage: {:?} bytes",
        rebalanced.timeline.storage_bytes()
    );

    // --- chaos-tested run: --chaos over the same daemons ---
    // the transport injects seeded faults (delays + duplicate frames here
    // — lossless classes, so the run always completes); the dedup/reorder
    // tolerance of the collect loop absorbs them and the trajectory is
    // unchanged. Same spec + seed ⇒ same fault schedule, byte-for-byte.
    let chaos_cfg = RunConfig {
        chaos: "delay=2:0.2,dup=0.05".to_string(),
        chaos_seed: 42,
        recovery: RecoveryPolicy::enabled(),
        workers: addrs.clone(),
        ..cfg.clone()
    };
    let chaotic = run_power_iteration(&chaos_cfg).expect("chaos run");
    let faults: u64 = chaotic.timeline.steps().iter().map(|s| s.faults).sum();
    println!(
        "chaos run:                  final NMSE {:.3e} (matches: {}), \
         {faults} fault(s) injected",
        chaotic.final_nmse,
        (chaotic.final_nmse - res.final_nmse).abs() < 1e-9
    );

    // --- checkpoint + resume: kill the master at step 15, restart ---
    // first life checkpoints every boundary and "dies" (returns) at step
    // 15; the second life resumes from the snapshot, runs the remaining
    // 15 steps, and lands on the uninterrupted run's answer.
    let ckpt_path = std::env::temp_dir().join("usec_quickstart.ckpt");
    let first_life = RunConfig {
        steps: 15,
        checkpoint_out: ckpt_path.to_str().expect("utf-8 temp path").to_string(),
        workers: addrs.clone(),
        ..cfg.clone()
    };
    run_power_iteration(&first_life).expect("first life");
    let second_life = RunConfig {
        resume: first_life.checkpoint_out.clone(),
        workers: addrs.clone(),
        ..cfg.clone()
    };
    let resumed = run_power_iteration(&second_life).expect("resumed run");
    println!(
        "resumed run:                final NMSE {:.3e} (matches: {}), \
         {} step(s) replayed after the crash",
        resumed.final_nmse,
        (resumed.final_nmse - res.final_nmse).abs() < 1e-9,
        resumed.timeline.len()
    );
    let _ = std::fs::remove_file(&ckpt_path);

    // --- multi-tenant serving: `usec serve` over the same daemons ---
    // the cluster stays resident behind a socket; two tenants submit
    // personalized-PageRank requests concurrently, the batcher coalesces
    // their iterate columns into one block per elastic step, and each
    // column retires when its own residual converges. Rows stream to the
    // workers as Data frames (serve matrices have no generator seed).
    let serve_listener = TcpListener::bind("127.0.0.1:0").expect("bind serve port");
    let serve_addr = serve_listener.local_addr().unwrap().to_string();
    let serve_cfg = RunConfig {
        stream_data: true,
        workers: addrs.clone(),
        ..cfg.clone()
    };
    let server = std::thread::spawn(move || {
        serve_listen(
            serve_listener,
            &serve_cfg,
            &ServeOpts {
                exit_after: 2,
                idle_ms: 0,
                session: SessionOpts::default(),
                ..Default::default()
            },
        )
    });
    let tenants: Vec<_> = ["alice", "bob"]
        .iter()
        .enumerate()
        .map(|(t, name)| {
            let addr = serve_addr.clone();
            std::thread::spawn(move || {
                let mut client = ServeClient::connect(&addr).expect("dial serve");
                let id = client
                    .submit(
                        name,
                        Query::Pagerank {
                            seed_node: 2 * t + 1,
                            damping: 0.85,
                        },
                        1e-8,
                        200,
                    )
                    .expect("submit");
                let resp = client
                    .wait(id, Duration::from_secs(60))
                    .expect("serve answer");
                client.bye();
                (name.to_string(), resp)
            })
        })
        .collect();
    for t in tenants {
        let (name, resp) = t.join().expect("client thread");
        println!(
            "serve request ({name}):     converged in {} step(s), residual {:.2e}, \
             latency {:.2} ms",
            resp.steps,
            resp.residual,
            resp.latency_ns as f64 / 1e6
        );
    }
    let served = server.join().expect("server thread").expect("serve session");
    let summary = served.serve().expect("serve summary");
    println!(
        "serve session:              {} request(s), p99 latency {:.2} ms, \
         peak queue depth {}",
        summary.requests,
        summary.latency_p99_ns / 1e6,
        summary.queue_depth
    );

    // --- end-to-end tracing: --trace-out over the same daemons ---
    // every order ships with the trace bit set (wire v5), every report
    // comes back with the worker-side timing breakdown, and the journal
    // lands as JSONL — `usec trace` turns it into a Chrome trace, or a
    // time-sink table with --summary (printed inline here).
    let journal_path = std::env::temp_dir().join("usec_quickstart_trace.jsonl");
    let traced_cfg = RunConfig {
        trace_out: journal_path.to_str().expect("utf-8 temp path").to_string(),
        workers: addrs,
        ..cfg
    };
    let traced = run_power_iteration(&traced_cfg).expect("traced run");
    let events = usec::obs::load_journal(traced_cfg.trace_out.as_str()).expect("load journal");
    println!(
        "traced run:                 final NMSE {:.3e}, {} journal events \
         (convert with `usec trace {}`)",
        traced.final_nmse,
        events.len(),
        traced_cfg.trace_out
    );
    println!("top time sinks (`usec trace --summary`):");
    print!("{}", usec::obs::summarize(&events));
    let _ = std::fs::remove_file(&journal_path);

    // the master's harness sent Shutdown on drop; reap the daemons
    for d in daemons {
        d.join().expect("daemon thread").expect("daemon exit");
    }
    println!("workers shut down cleanly");
}
