//! Distributed quickstart: 1 master + 3 TCP worker daemons on loopback,
//! in one process for convenience.
//!
//! In production the workers are separate processes (or machines):
//!
//! ```text
//! usec worker --listen 127.0.0.1:7701     # terminal 1
//! usec worker --listen 127.0.0.1:7702     # terminal 2
//! usec worker --listen 127.0.0.1:7703     # terminal 3
//! usec master --workers 127.0.0.1:7701,127.0.0.1:7702,127.0.0.1:7703 \
//!     --q 1536 --g 3 --j 2 --placement cyclic --json-out run.json
//! ```
//!
//! Each worker materializes only its placed J-out-of-G share (here 2/3 of
//! the matrix), regenerated from the workload spec in the handshake. Add
//! `--stream-data` and the master instead streams each worker's rows as
//! checksummed `Data` frames — the path for external data that no seed
//! can regenerate (ridge/pagerank over real inputs):
//!
//! ```text
//! usec master --workers ... --q 1536 --g 3 --j 2 --placement cyclic \
//!     --stream-data --json-out run.json
//! ```
//!
//! Either way `--json-out` reports the actual per-worker resident bytes
//! under `timeline.storage`. Here we spawn the same daemons on threads
//! and drive the same master code path (`RunConfig.workers` →
//! `TcpTransport`), so `cargo run --example distributed_quickstart` works
//! anywhere.

use std::net::TcpListener;

use usec::apps::run_power_iteration;
use usec::config::types::RunConfig;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::placement::PlacementKind;

fn main() {
    usec::util::log::init();

    // --- "terminals 1-3": three worker daemons on ephemeral ports ---
    // (each serves two master sessions: the generator-backed run and the
    // streamed run below)
    let mut addrs = Vec::new();
    let mut daemons = Vec::new();
    for _ in 0..3 {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().unwrap().to_string());
        daemons.push(std::thread::spawn(move || {
            serve_worker(listener, DaemonOpts { max_sessions: 2 })
        }));
    }
    println!("workers listening on {addrs:?}");

    // --- "terminal 4": the master dials the workers over TCP ---
    // cyclic J=2 of G=3: each worker stores 2/3 of the matrix, and that is
    // all it materializes — storage cost is real, not simulated.
    let cfg = RunConfig {
        q: 480,
        r: 480,
        g: 3,
        j: 2,
        n: 3,
        placement: PlacementKind::Cyclic,
        steps: 30,
        speeds: vec![1.0, 2.0, 4.0],
        seed: 7,
        workers: addrs.clone(),
        ..Default::default()
    };
    let res = run_power_iteration(&cfg).expect("distributed run");
    println!(
        "generator-backed shard run: final NMSE {:.3e}, eigenvalue {:.4} (truth {:.4})",
        res.final_nmse, res.eigval, res.truth_eigval
    );
    println!(
        "per-worker resident storage: {:?} bytes (full matrix would be {})",
        res.timeline.storage_bytes(),
        cfg.q * cfg.r * 4
    );

    // --- same run with --stream-data: rows travel as Data frames ---
    let streamed_cfg = RunConfig {
        stream_data: true,
        workers: addrs,
        ..cfg
    };
    let streamed = run_power_iteration(&streamed_cfg).expect("streamed run");
    println!(
        "streamed-data run:          final NMSE {:.3e} (matches: {})",
        streamed.final_nmse,
        (streamed.final_nmse - res.final_nmse).abs() < 1e-9
    );

    // the master's harness sent Shutdown on drop; reap the daemons
    for d in daemons {
        d.join().expect("daemon thread").expect("daemon exit");
    }
    println!("workers shut down cleanly");
}
