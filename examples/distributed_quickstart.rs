//! Distributed quickstart: 1 master + 3 TCP worker daemons on loopback,
//! in one process for convenience.
//!
//! In production the workers are separate processes (or machines):
//!
//! ```text
//! usec worker --listen 127.0.0.1:7701     # terminal 1
//! usec worker --listen 127.0.0.1:7702     # terminal 2
//! usec worker --listen 127.0.0.1:7703     # terminal 3
//! usec master --workers 127.0.0.1:7701,127.0.0.1:7702,127.0.0.1:7703 \
//!     --q 1536 --g 3 --j 3 --placement cyclic --stragglers 1
//! ```
//!
//! Here we spawn the same daemons on threads and drive the same master
//! code path (`RunConfig.workers` → `TcpTransport`), so
//! `cargo run --example distributed_quickstart` works anywhere.

use std::net::TcpListener;

use usec::apps::run_power_iteration;
use usec::config::types::RunConfig;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::placement::PlacementKind;

fn main() {
    usec::util::log::init();

    // --- "terminals 1-3": three worker daemons on ephemeral ports ---
    let mut addrs = Vec::new();
    let mut daemons = Vec::new();
    for _ in 0..3 {
        let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        addrs.push(listener.local_addr().unwrap().to_string());
        daemons.push(std::thread::spawn(move || {
            serve_worker(listener, DaemonOpts { once: true })
        }));
    }
    println!("workers listening on {addrs:?}");

    // --- "terminal 4": the master dials the workers over TCP ---
    let cfg = RunConfig {
        q: 480,
        r: 480,
        g: 3,
        j: 3,
        n: 3,
        placement: PlacementKind::Cyclic,
        stragglers: 1, // tolerate one preempted/slow worker per step
        steps: 30,
        speeds: vec![1.0, 2.0, 4.0],
        seed: 7,
        workers: addrs,
        ..Default::default()
    };
    let res = run_power_iteration(&cfg).expect("distributed run");

    println!(
        "distributed power iteration over {} TCP workers: final NMSE {:.3e}, \
         eigenvalue {:.4} (truth {:.4})",
        cfg.n, res.final_nmse, res.eigval, res.truth_eigval
    );
    println!(
        "total wall {:?} across {} steps",
        res.timeline.total_wall(),
        res.timeline.len()
    );

    // the master's harness sent Shutdown on drop; reap the daemons
    for d in daemons {
        d.join().expect("daemon thread").expect("daemon exit");
    }
    println!("workers shut down cleanly");
}
