//! Remark 1 demo: computation time vs straggler tolerance.
//!
//! Sweeps S = 0..3 over the three placements, printing the optimal
//! `c(M*)` (theory, LP) and a measured elastic run with S injected
//! stragglers per step (practice). Time grows with S — the paper's
//! robustness trade-off.
//!
//! Run: `cargo run --release --example straggler_tradeoff`

use usec::config::types::RunConfig;
use usec::optim::{solve_load_matrix, SolveParams};
use usec::placement::{Placement, PlacementKind};
use usec::util::fmt::render_table;

fn main() -> Result<(), usec::Error> {
    // --- theory: optimal c vs S (paper Remark 1) ---
    let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
    let avail: Vec<usize> = (0..6).collect();
    let mut rows = Vec::new();
    for (name, kind, g) in [
        ("repetition", PlacementKind::Repetition, 6),
        ("cyclic", PlacementKind::Cyclic, 6),
        ("man", PlacementKind::Man, 20),
    ] {
        let p = Placement::build(kind, 6, g, 3)?;
        let mut cells = vec![name.to_string()];
        for s in 0..3usize {
            let sol = solve_load_matrix(&p, &avail, &speeds, &SolveParams::with_stragglers(s))?;
            cells.push(format!("{:.4}", sol.time));
        }
        rows.push(cells);
    }
    println!("optimal computation time c* vs straggler tolerance (s = [1,2,4,8,16,32]):\n");
    println!("{}", render_table(&["placement", "S=0", "S=1", "S=2"], &rows));

    // --- practice: measured elastic runs with injected stragglers ---
    println!("\nmeasured elastic power iteration (q=384, 15 steps, stragglers injected = S):\n");
    let mut rows = Vec::new();
    for s in 0..3usize {
        let cfg = RunConfig {
            q: 384,
            r: 384,
            steps: 15,
            stragglers: s,
            injected_stragglers: s,
            row_cost_ns: 100_000,
            speeds: speeds.clone(),
            seed: 7,
            ..Default::default()
        };
        let res = usec::apps::run_power_iteration(&cfg)?;
        rows.push(vec![
            format!("S={s}"),
            format!("{:.3}s", res.timeline.total_wall().as_secs_f64()),
            format!("{:.2e}", res.final_nmse),
        ]);
    }
    println!("{}", render_table(&["tolerance", "total wall", "final NMSE"], &rows));
    println!("(wall time grows with S: every row is computed 1+S times)");
    Ok(())
}
