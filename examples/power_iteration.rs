//! End-to-end driver (deliverable (b) / DESIGN.md §10): the paper's §V
//! evaluation on the full three-layer stack.
//!
//! Loads the AOT-compiled PJRT artifacts when present (workers then execute
//! the Pallas-kernel-lowered HLO on the request path — Python is not
//! involved), simulates the paper's heterogeneous EC2 fleet, and compares
//! the heterogeneous (Algorithm 1) assignment against the uniform
//! baseline, with and without stragglers. The run is recorded in
//! EXPERIMENTS.md.
//!
//! Run: `make artifacts && cargo run --release --example power_iteration`
//! Flags: `--q 1536 --steps 30 --backend pjrt|host --stragglers 2`

use usec::cli::{ArgSpec, Args};
use usec::config::types::BackendKind;
use usec::exp::fig4::{run, Fig4Params};

fn main() -> Result<(), usec::Error> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let specs = vec![
        ArgSpec::opt("q", "1536", "matrix dimension (paper: 6000)"),
        ArgSpec::opt("steps", "30", "power-iteration steps"),
        ArgSpec::opt("backend", "auto", "auto|host|pjrt"),
        ArgSpec::opt("stragglers", "0", "injected stragglers per step (tolerance matches)"),
        ArgSpec::opt("row-cost-ns", "100000", "simulated ns/row at speed 1"),
        ArgSpec::opt("seed", "2021", "workload seed"),
    ];
    let args = Args::parse(&argv, &specs)?;

    let q = args.get_usize("q")?;
    let artifact_dir = usec::apps::harness::artifact_dir();
    let backend = match args.get("backend").unwrap_or("auto") {
        "auto" => {
            // PJRT artifacts are shape-baked; use them when they match q.
            let ok = usec::runtime::Manifest::load(&artifact_dir)
                .map(|m| m.cols == q && m.q == q)
                .unwrap_or(false);
            if ok {
                BackendKind::Pjrt
            } else {
                eprintln!(
                    "note: artifacts missing or baked for a different shape; using host \
                     backend (run `make artifacts COLS={q} Q={q}` for PJRT)"
                );
                BackendKind::Host
            }
        }
        other => BackendKind::parse(other)?,
    };

    let s = args.get_usize("stragglers")?;
    let params = Fig4Params {
        q,
        steps: args.get_usize("steps")?,
        injected: s,
        // paper §V reading: stragglers are fixed slow instances the master
        // waits for (S = 0) and the EWMA learns
        tolerance: 0,
        slowdown: if s > 0 { 3.0 } else { 0.0 },
        fixed_victims: s > 0,
        row_cost_ns: args.get_u64("row-cost-ns")?,
        seed: args.get_u64("seed")?,
        backend,
    };
    println!(
        "elastic power iteration: q={q}, backend={}, S={s}, {} steps",
        backend.name(),
        params.steps
    );

    let r = run(&params)?;
    println!(
        "\nheterogeneous (Algorithm 1): wall {:.3}s, final NMSE {:.3e}",
        r.hetero.total_wall_s, r.hetero.final_nmse
    );
    println!(
        "uniform baseline:            wall {:.3}s, final NMSE {:.3e}",
        r.uniform.total_wall_s, r.uniform.final_nmse
    );
    println!(
        "heterogeneous gain: {:.1}% (paper reports ≈20%)",
        r.gain * 100.0
    );

    println!("\nNMSE-vs-time series (CSV, heterogeneous):");
    print!("{}", r.hetero.timeline.to_csv());
    println!("\nNMSE-vs-time series (CSV, uniform):");
    print!("{}", r.uniform.timeline.to_csv());
    Ok(())
}
