//! Quickstart: the USEC public API in ~60 lines.
//!
//! 1. Build an uncoded storage placement.
//! 2. Solve the heterogeneous computation-assignment problem (eq. 6/8).
//! 3. Materialize per-machine tasks with the filling algorithm.
//! 4. Run a small elastic power iteration on a simulated cluster.
//!
//! Run: `cargo run --release --example quickstart`

use usec::config::types::RunConfig;
use usec::linalg::partition::submatrix_ranges;
use usec::optim::{build_assignment, solve_load_matrix, SolveParams};
use usec::placement::{Placement, PlacementKind};

fn main() -> Result<(), usec::Error> {
    // --- 1. placement: 6 machines, 6 sub-matrices, replication 3 ---
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3)?;
    println!("cyclic placement: X_1 stored on machines {:?}\n", placement.machines_storing(0));

    // --- 2. optimal load matrix for heterogeneous speeds (paper Fig. 1b) ---
    let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
    let avail: Vec<usize> = (0..6).collect();
    let sol = solve_load_matrix(&placement, &avail, &speeds, &SolveParams::default())?;
    println!("optimal computation time c* = {:.4} (paper: 0.1429)", sol.time);
    println!("{}", usec::util::fmt::render_load_matrix(&sol.load.to_rows(), "X", "m"));

    // --- 3. concrete tasks for a 6000-row matrix, straggler tolerance 1 ---
    let sub_rows: Vec<usize> = submatrix_ranges(6000, 6)?.iter().map(|r| r.len()).collect();
    let assignment = build_assignment(
        &placement,
        &avail,
        &speeds,
        &SolveParams::with_stragglers(1),
        &sub_rows,
    )?;
    for n in 0..6 {
        println!(
            "machine {n}: {} rows across {} tasks",
            assignment.rows_for(n),
            assignment.tasks_for(n).len()
        );
    }

    // --- 4. elastic power iteration on a simulated heterogeneous cluster ---
    let cfg = RunConfig {
        q: 384,
        r: 384,
        steps: 40,
        speeds,
        ..Default::default()
    };
    let res = usec::apps::run_power_iteration(&cfg)?;
    println!(
        "\npower iteration: final NMSE {:.3e}, eigenvalue {:.3} (truth {:.1}), wall {:?}",
        res.final_nmse,
        res.eigval,
        res.truth_eigval,
        res.timeline.total_wall()
    );
    Ok(())
}
