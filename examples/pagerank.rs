//! PageRank on the elastic substrate: damped rank iteration with the
//! link-matrix mat-vec distributed per the USEC assignment.
//!
//! Run: `cargo run --release --example pagerank`

use usec::apps::pagerank::run_pagerank;
use usec::config::types::RunConfig;

fn main() -> Result<(), usec::Error> {
    let cfg = RunConfig {
        q: 600,
        r: 600,
        steps: 50,
        speeds: vec![1.0, 2.2, 0.9, 2.0, 1.1, 2.4],
        seed: 17,
        ..Default::default()
    };
    println!("elastic PageRank: {} pages, {} iterations\n", cfg.q, cfg.steps);
    let res = run_pagerank(&cfg, 0.85)?;
    // top pages
    let mut idx: Vec<usize> = (0..cfg.q).collect();
    idx.sort_by(|&a, &b| res.ranks[b].partial_cmp(&res.ranks[a]).unwrap());
    println!("top 5 pages by rank:");
    for &i in idx.iter().take(5) {
        println!("  page {:>4}: {:.5}", i, res.ranks[i]);
    }
    println!(
        "\nfinal step-to-step L1 delta {:.3e} in {:?}",
        res.final_delta,
        res.timeline.total_wall()
    );
    Ok(())
}
