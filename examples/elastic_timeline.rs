//! Elasticity demo: machines come and go mid-computation (the phenomenon
//! the paper is named after) while power iteration keeps converging.
//!
//! Uses a Bernoulli preemption/arrival trace and prints a per-step
//! timeline: which machines were up, who reported, how the master's speed
//! estimates adapted, and the convergence metric.
//!
//! Run: `cargo run --release --example elastic_timeline`

use usec::config::types::RunConfig;

fn main() -> Result<(), usec::Error> {
    let cfg = RunConfig {
        q: 768,
        r: 768,
        steps: 60,
        preempt_prob: 0.25,
        arrive_prob: 0.45,
        min_available: 3, // trace keeps ≥ J machines so every step is feasible
        row_cost_ns: 50_000,
        seed: 42,
        speeds: vec![1.0, 2.4, 0.8, 2.0, 1.2, 2.8],
        ..Default::default()
    };
    println!(
        "elastic power iteration: q={}, {} steps, preempt p={}, arrive p={}\n",
        cfg.q, cfg.steps, cfg.preempt_prob, cfg.arrive_prob
    );

    let res = usec::apps::run_power_iteration(&cfg)?;
    println!("step  avail  reported  wall(ms)  solve(us)  pred-c     NMSE");
    println!("{}", "-".repeat(66));
    for s in res.timeline.steps() {
        println!(
            "{:>4}  {:>5}  {:>8}  {:>8.1}  {:>9.0}  {:>6.3}  {:>9.2e}",
            s.step,
            s.available,
            s.reported,
            s.wall.as_secs_f64() * 1e3,
            s.solve.as_secs_f64() * 1e6,
            s.predicted_c,
            s.metric
        );
    }
    println!(
        "\nfinal NMSE {:.3e} after {:?} total wall",
        res.final_nmse,
        res.timeline.total_wall()
    );
    println!(
        "(availability varied across steps; every transition re-solved the \
         assignment, no computation was lost)"
    );
    Ok(())
}
