//! Integration tests for placement-shaped storage over TCP:
//!
//! * shard workers (each holding only its J-out-of-G share) reproduce the
//!   local full-storage run within 1e-5 and report the placed resident
//!   byte counts in the timeline (the `--json-out` numbers);
//! * `--stream-data` ships the rows as checksummed `Data` frames and
//!   matches the generator-backed run exactly;
//! * a worker daemon that comes back after a socket-level preemption is
//!   re-admitted and serves again at the next step.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use usec::apps::power_iteration::{run_power_iteration, PLANT_EIGVAL, PLANT_GAP};
use usec::config::types::{AssignPolicy, BackendKind, RunConfig};
use usec::error::Result;
use usec::linalg::gen::planted_symmetric;
use usec::linalg::partition::{submatrix_ranges, RowRange};
use usec::linalg::{ops, Block};
use usec::storage::StorageView;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::net::{
    Hello, TcpOptions, TcpPeer, TcpTransport, Transport, WorkloadSpec, WIRE_VERSION,
};
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::sched::master::{Master, MasterConfig};

const Q: usize = 120;
const SEED: u64 = 19;

fn start_workers(sessions: &[usize]) -> (Vec<String>, Vec<JoinHandle<Result<()>>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for &max_sessions in sessions {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve_worker(listener, DaemonOpts { max_sessions, ..Default::default() })
        }));
    }
    (addrs, handles)
}

fn cfg(n: usize, g: usize, j: usize, workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g,
        j,
        n,
        placement: PlacementKind::Cyclic,
        stragglers: 0,
        steps: 20,
        speeds: vec![1.0; n],
        seed: SEED,
        workers,
        ..Default::default()
    }
}

fn assert_eigvec_close(a: &[f32], b: &[f32]) {
    assert_eq!(a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= 1e-5, "eigvec[{i}] diverged: {x} vs {y}");
    }
}

/// The ISSUE acceptance case: `--placement cyclic --g 5 --j 3` must leave
/// each TCP worker with exactly 3/5 of the full matrix resident, while the
/// distributed result still matches the local full-storage run.
#[test]
fn shard_workers_hold_three_fifths_and_match_local() {
    let (addrs, handles) = start_workers(&[1; 5]);
    let tcp = run_power_iteration(&cfg(5, 5, 3, addrs)).unwrap();
    let local = run_power_iteration(&cfg(5, 5, 3, vec![])).unwrap();

    assert_eigvec_close(&tcp.eigvec, &local.eigvec);
    assert!((tcp.final_nmse - local.final_nmse).abs() <= 1e-7);

    // cyclic N=5, G=5, J=3: each machine stores 3 sub-matrices of 24 rows
    let full = (Q * Q * 4) as u64;
    let share = full * 3 / 5;
    let storage = tcp.timeline.storage_bytes();
    assert_eq!(storage.len(), 5);
    for (n, &b) in storage.iter().enumerate() {
        assert_eq!(b, share, "worker {n} resident bytes {b}, want {share}");
    }
    // local mode: every worker reads the shared full view
    assert!(local.timeline.storage_bytes().iter().all(|&b| b == full));

    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// Satellite: a 3-worker TCP run with proper-subset shard storage (J=2 of
/// G=3) matches the local full-storage run within 1e-5.
#[test]
fn three_worker_shard_run_matches_local() {
    let (addrs, handles) = start_workers(&[1; 3]);
    let tcp = run_power_iteration(&cfg(3, 3, 2, addrs)).unwrap();
    let local = run_power_iteration(&cfg(3, 3, 2, vec![])).unwrap();

    assert_eigvec_close(&tcp.eigvec, &local.eigvec);
    let share = (Q * Q * 4) as u64 * 2 / 3;
    assert!(tcp.timeline.storage_bytes().iter().all(|&b| b == share));

    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// `--stream-data`: the master streams each worker's placed rows instead
/// of shipping a generator spec — the path for external data. Results and
/// resident bytes must be identical to the generator-backed shard run.
#[test]
fn streamed_rows_match_local_run() {
    let (addrs, handles) = start_workers(&[1; 3]);
    let mut streamed_cfg = cfg(3, 3, 2, addrs);
    streamed_cfg.stream_data = true;
    let tcp = run_power_iteration(&streamed_cfg).unwrap();
    let local = run_power_iteration(&cfg(3, 3, 2, vec![])).unwrap();

    assert_eigvec_close(&tcp.eigvec, &local.eigvec);
    assert!((tcp.final_nmse - local.final_nmse).abs() <= 1e-7);
    let share = (Q * Q * 4) as u64 * 2 / 3;
    assert!(tcp.timeline.storage_bytes().iter().all(|&b| b == share));

    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// ROADMAP item (per-row-seeded generators): a shard worker's storage is
/// produced row by row, so *peak* resident bytes during materialization
/// equal the placed share — the full `q×r` matrix is never built, not
/// even transiently — while every generated row stays bit-identical to
/// the full generator's.
#[test]
fn row_seeded_generator_materializes_only_the_placed_share() {
    let spec = WorkloadSpec::PlantedSymmetric {
        q: Q,
        eigval: PLANT_EIGVAL,
        gap: PLANT_GAP,
        seed: SEED,
    };
    // a 3-of-5 cyclic share: sub-matrices {0, 2, 4} of G=5
    let sub_ranges = submatrix_ranges(Q, 5).unwrap();
    let placed = vec![sub_ranges[0], sub_ranges[2], sub_ranges[4]];
    let shard = spec.materialize_shard(&placed).unwrap();

    // peak == steady state == the placed share: materialize_shard builds
    // the shard directly from the row-seeded generator, so the only f32
    // payload ever allocated is the share itself (plus O(q) generator
    // state) — assert the share is exact
    let share_rows: usize = placed.iter().map(|r| r.len()).sum();
    assert_eq!(shard.resident_rows(), share_rows);
    assert_eq!(shard.resident_bytes(), share_rows * Q * 4);
    assert_eq!(shard.resident_bytes(), Q * Q * 4 * 3 / 5);

    // and the rows are bit-identical to the full materialization
    let full = spec.materialize().unwrap();
    for r in &placed {
        for row in r.lo..r.hi {
            assert_eq!(
                shard.row_slice(RowRange::new(row, row + 1)).unwrap(),
                full.row(row),
                "row {row} differs between shard and full generator"
            );
        }
    }
}

/// Block data plane end-to-end over TCP: a `--batch 4` distributed run
/// (tags 10/11 on the wire, shard storage, block mat-mat on the workers)
/// matches the local block run exactly.
#[test]
fn batched_tcp_run_matches_local_block_run() {
    let (addrs, handles) = start_workers(&[1; 3]);
    let mut tcp_cfg = cfg(3, 3, 2, addrs);
    tcp_cfg.batch = 4;
    let mut local_cfg = cfg(3, 3, 2, vec![]);
    local_cfg.batch = 4;

    let tcp = run_power_iteration(&tcp_cfg).unwrap();
    let local = run_power_iteration(&local_cfg).unwrap();

    assert_eigvec_close(&tcp.eigvec, &local.eigvec);
    assert!((tcp.final_nmse - local.final_nmse).abs() <= 1e-7);
    assert_eq!(tcp.eigvals.len(), 4);
    for (a, e) in tcp.eigvals.iter().zip(&local.eigvals) {
        assert!((a - e).abs() <= 1e-5, "block eigenvalue diverged: {a} vs {e}");
    }
    // shard storage is unchanged by batching
    let share = (Q * Q * 4) as u64 * 2 / 3;
    assert!(tcp.timeline.storage_bytes().iter().all(|&b| b == share));

    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// ROADMAP item: a reconnecting `usec worker` with a matching `Hello`
/// rejoins the availability set at the next step instead of being
/// preempted forever.
#[test]
fn reconnecting_worker_rejoins_at_next_step() {
    let q = 60;
    // worker 2 survives two master sessions: the killed one + re-admission
    let (addrs, handles) = start_workers(&[1, 1, 2]);

    let plant = planted_symmetric(q, PLANT_EIGVAL, PLANT_GAP, SEED);
    let peers: Vec<TcpPeer> = addrs
        .iter()
        .enumerate()
        .map(|(id, addr)| TcpPeer {
            addr: addr.clone(),
            hello: Hello {
                version: WIRE_VERSION,
                worker: id,
                speed: 1.0,
                tile_rows: 16,
                backend: BackendKind::Host,
                g: 3,
                heartbeat_ms: 100,
                threads: 1,
                workload: WorkloadSpec::PlantedSymmetric {
                    q,
                    eigval: PLANT_EIGVAL,
                    gap: PLANT_GAP,
                    seed: SEED,
                },
                stored: vec![], // full replication
            },
            stream_ranges: vec![],
        })
        .collect();
    let transport = TcpTransport::connect(peers, TcpOptions::default()).unwrap();
    let full = (q * q * 4) as u64;
    assert_eq!(transport.resident_bytes(), vec![full; 3]);

    let placement = Placement::build(PlacementKind::Cyclic, 3, 3, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, 3).unwrap();
    let mut master = Master::new(MasterConfig {
        placement,
        sub_ranges,
        params: SolveParams::with_stragglers(0),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: vec![1.0; 3],
        row_cost_ns: 0,
        recovery_timeout: Duration::from_secs(20),
        recovery: usec::sched::RecoveryPolicy::default(),
    })
    .unwrap();

    let mut b = vec![1.0f32; q];
    ops::normalize(&mut b);
    let oracle = |w: &[f32]| plant.matrix.matvec(w).unwrap();

    // step 0: all three workers
    let w = Arc::new(Block::single(b.clone()));
    let out = master.step(&transport, 0, &w, &[0, 1, 2], &[]).unwrap();
    assert_eq!(out.y, oracle(w.data()));

    // preempt worker 2 at the socket level
    transport.kill(2);
    assert_eq!(transport.alive(), vec![true, true, false]);

    // step 1 still completes through the surviving replicas
    let out = master.step(&transport, 1, &w, &[0, 1], &[]).unwrap();
    assert_eq!(out.y, oracle(w.data()));

    // the daemon looped back to accept: re-admission brings worker 2 back
    assert_eq!(transport.readmit(), 1, "worker 2 should rejoin");
    assert_eq!(transport.alive(), vec![true, true, true]);
    assert_eq!(transport.resident_bytes(), vec![full; 3]);

    // and it serves work again: with only worker 2 available, every row
    // must come from the re-admitted connection
    let out = master.step(&transport, 2, &w, &[2], &[]).unwrap();
    assert_eq!(out.y, oracle(w.data()));
    assert_eq!(out.reporters, vec![2], "re-admitted worker must serve alone");

    let mut transport = transport;
    transport.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}
