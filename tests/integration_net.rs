//! Integration tests for the TCP transport: a real master and real worker
//! daemons on loopback sockets, including a scripted socket-level
//! preemption mid-run.
//!
//! The distributed run must match the in-process (`LocalTransport`) run
//! within 1e-5 — with deterministic workload regeneration and the exact
//! host kernels on both sides the trajectories are in fact bit-identical,
//! preemption or not, because every row of `y_t = X w_t` has the same
//! value whichever worker computes it.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use usec::apps::power_iteration::{run_power_iteration, PLANT_EIGVAL, PLANT_GAP};
use usec::config::types::{AssignPolicy, BackendKind, RunConfig};
use usec::error::Result;
use usec::linalg::{ops, Block};
use usec::linalg::partition::submatrix_ranges;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::net::{
    Hello, TcpOptions, TcpPeer, TcpTransport, Transport, WorkloadSpec, WIRE_VERSION,
};
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::runtime::BackendSpec;
use usec::sched::master::{Master, MasterConfig};

const Q: usize = 120;
const STEPS: usize = 24;
const SEED: u64 = 11;
const KILL_STEP: usize = 8;

/// Spawn `n` worker daemons on ephemeral loopback ports.
fn start_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<Result<()>>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve_worker(listener, DaemonOpts { max_sessions: 1, ..Default::default() })
        }));
    }
    (addrs, handles)
}

/// 3 machines, full replication (cyclic J=3), S=1 — one worker can vanish
/// mid-step and every row still has a live replica.
fn base_cfg(workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 3,
        n: 3,
        placement: PlacementKind::Cyclic,
        stragglers: 1,
        steps: STEPS,
        speeds: vec![1.0, 1.0, 1.0],
        seed: SEED,
        workers,
        ..Default::default()
    }
}

fn workload_spec() -> WorkloadSpec {
    WorkloadSpec::PlantedSymmetric {
        q: Q,
        eigval: PLANT_EIGVAL,
        gap: PLANT_GAP,
        seed: SEED,
    }
}

#[test]
fn tcp_cluster_survives_mid_run_socket_preemption() {
    let (addrs, handles) = start_workers(3);

    // --- reference: the whole run in-process over LocalTransport ---
    let local = run_power_iteration(&base_cfg(vec![])).unwrap();

    // --- distributed run, driven manually so we can kill a socket ---
    let peers: Vec<TcpPeer> = addrs
        .iter()
        .enumerate()
        .map(|(id, addr)| TcpPeer {
            addr: addr.clone(),
            hello: Hello {
                version: WIRE_VERSION,
                worker: id,
                speed: 1.0,
                tile_rows: 32,
                backend: BackendKind::Host,
                g: 3,
                heartbeat_ms: 100,
                threads: 1,
                workload: workload_spec(),
                stored: vec![], // full replication: store everything
            },
            stream_ranges: vec![],
        })
        .collect();
    let transport = TcpTransport::connect(peers, TcpOptions::default()).unwrap();

    let placement = Placement::build(PlacementKind::Cyclic, 3, 3, 3).unwrap();
    let sub_ranges = submatrix_ranges(Q, 3).unwrap();
    let mut master = Master::new(MasterConfig {
        placement,
        sub_ranges,
        params: SolveParams::with_stragglers(1),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: vec![1.0; 3],
        row_cost_ns: 0,
        recovery_timeout: Duration::from_secs(20),
    })
    .unwrap();
    let host = BackendSpec::Host.instantiate().unwrap();

    let mut b = vec![1.0f32; Q];
    ops::normalize(&mut b);
    let mut eigval = 0.0f64;
    let mut avail_sizes = Vec::new();
    for step in 0..STEPS {
        let alive = transport.alive();
        let avail: Vec<usize> = (0..3).filter(|&n| alive[n]).collect();
        avail_sizes.push(avail.len());
        if step == KILL_STEP {
            // Socket-level preemption *after* this step's availability was
            // read: the master will dispatch to a dead worker and must
            // recover through the S=1 redundancy.
            transport.kill(2);
        }
        let w = Arc::new(Block::single(b.clone()));
        let out = master
            .step(&transport, step, &w, &avail, &[])
            .unwrap_or_else(|e| panic!("step {step} failed: {e}"));
        let (next, norm) = host.normalize(&out.y).unwrap();
        eigval = norm;
        b = next;
    }

    // the dropped worker is reflected in the availability set from the
    // following step onward
    assert!(
        avail_sizes[..=KILL_STEP].iter().all(|&a| a == 3),
        "pre-kill availability wrong: {avail_sizes:?}"
    );
    assert!(
        avail_sizes[KILL_STEP + 1..].iter().all(|&a| a == 2),
        "post-kill availability wrong: {avail_sizes:?}"
    );

    // distributed result matches the single-process run within 1e-5
    assert_eq!(b.len(), local.eigvec.len());
    for (i, (a, e)) in b.iter().zip(&local.eigvec).enumerate() {
        assert!(
            (a - e).abs() <= 1e-5,
            "eigvec[{i}] diverged: tcp {a} vs local {e}"
        );
    }
    assert!(
        (eigval - local.eigval).abs() <= 1e-5,
        "eigenvalue estimate diverged: tcp {eigval} vs local {}",
        local.eigval
    );

    let mut transport = transport;
    transport.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn tcp_harness_matches_local_through_runconfig() {
    let (addrs, handles) = start_workers(3);

    let tcp = run_power_iteration(&base_cfg(addrs)).unwrap();
    let local = run_power_iteration(&base_cfg(vec![])).unwrap();

    assert_eq!(tcp.timeline.len(), STEPS);
    assert!(tcp
        .timeline
        .steps()
        .iter()
        .all(|s| s.available == 3 && s.reported >= 2));
    for (i, (a, e)) in tcp.eigvec.iter().zip(&local.eigvec).enumerate() {
        assert!(
            (a - e).abs() <= 1e-5,
            "eigvec[{i}] diverged: tcp {a} vs local {e}"
        );
    }
    assert!((tcp.final_nmse - local.final_nmse).abs() <= 1e-7);
    assert!(tcp.final_nmse < 0.05, "did not converge: {}", tcp.final_nmse);

    // run_power_iteration dropped its harness (and thus the transport),
    // which sends Shutdown — the once-mode daemons exit cleanly.
    for h in handles {
        h.join().unwrap().unwrap();
    }
}
