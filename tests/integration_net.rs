//! Integration tests for the TCP transport: a real master and real worker
//! daemons on loopback sockets, including a scripted socket-level
//! preemption mid-run.
//!
//! The distributed run must match the in-process (`LocalTransport`) run
//! within 1e-5 — with deterministic workload regeneration and the exact
//! host kernels on both sides the trajectories are in fact bit-identical,
//! preemption or not, because every row of `y_t = X w_t` has the same
//! value whichever worker computes it.

use std::cell::Cell;
use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use usec::apps::power_iteration::{run_power_iteration, PLANT_EIGVAL, PLANT_GAP};
use usec::config::types::{AssignPolicy, BackendKind, RunConfig};
use usec::error::Result;
use usec::linalg::{ops, Block};
use usec::linalg::partition::submatrix_ranges;
use usec::metrics::{StepRecord, Timeline};
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::net::{
    Hello, TcpOptions, TcpPeer, TcpTransport, Transport, TransportEvent, WorkloadSpec,
    WIRE_VERSION,
};
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::runtime::BackendSpec;
use usec::sched::master::{Master, MasterConfig};
use usec::sched::protocol::WorkOrder;
use usec::sched::{RecoveryPolicy, RecoveryReason};

const Q: usize = 120;
const STEPS: usize = 24;
const SEED: u64 = 11;
const KILL_STEP: usize = 8;

/// Spawn `n` worker daemons on ephemeral loopback ports.
fn start_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<Result<()>>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve_worker(listener, DaemonOpts { max_sessions: 1, ..Default::default() })
        }));
    }
    (addrs, handles)
}

/// 3 machines, full replication (cyclic J=3), S=1 — one worker can vanish
/// mid-step and every row still has a live replica.
fn base_cfg(workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 3,
        n: 3,
        placement: PlacementKind::Cyclic,
        stragglers: 1,
        steps: STEPS,
        speeds: vec![1.0, 1.0, 1.0],
        seed: SEED,
        workers,
        ..Default::default()
    }
}

fn workload_spec() -> WorkloadSpec {
    WorkloadSpec::PlantedSymmetric {
        q: Q,
        eigval: PLANT_EIGVAL,
        gap: PLANT_GAP,
        seed: SEED,
    }
}

#[test]
fn tcp_cluster_survives_mid_run_socket_preemption() {
    let (addrs, handles) = start_workers(3);

    // --- reference: the whole run in-process over LocalTransport ---
    let local = run_power_iteration(&base_cfg(vec![])).unwrap();

    // --- distributed run, driven manually so we can kill a socket ---
    let peers: Vec<TcpPeer> = addrs
        .iter()
        .enumerate()
        .map(|(id, addr)| TcpPeer {
            addr: addr.clone(),
            hello: Hello {
                version: WIRE_VERSION,
                worker: id,
                speed: 1.0,
                tile_rows: 32,
                backend: BackendKind::Host,
                g: 3,
                heartbeat_ms: 100,
                threads: 1,
                workload: workload_spec(),
                stored: vec![], // full replication: store everything
            },
            stream_ranges: vec![],
        })
        .collect();
    let transport = TcpTransport::connect(peers, TcpOptions::default()).unwrap();

    let placement = Placement::build(PlacementKind::Cyclic, 3, 3, 3).unwrap();
    let sub_ranges = submatrix_ranges(Q, 3).unwrap();
    let mut master = Master::new(MasterConfig {
        placement,
        sub_ranges,
        params: SolveParams::with_stragglers(1),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: vec![1.0; 3],
        row_cost_ns: 0,
        recovery_timeout: Duration::from_secs(20),
        recovery: RecoveryPolicy::default(),
    })
    .unwrap();
    let host = BackendSpec::Host.instantiate().unwrap();

    let mut b = vec![1.0f32; Q];
    ops::normalize(&mut b);
    let mut eigval = 0.0f64;
    let mut avail_sizes = Vec::new();
    for step in 0..STEPS {
        let alive = transport.alive();
        let avail: Vec<usize> = (0..3).filter(|&n| alive[n]).collect();
        avail_sizes.push(avail.len());
        if step == KILL_STEP {
            // Socket-level preemption *after* this step's availability was
            // read: the master will dispatch to a dead worker and must
            // recover through the S=1 redundancy.
            transport.kill(2);
        }
        let w = Arc::new(Block::single(b.clone()));
        let out = master
            .step(&transport, step, &w, &avail, &[])
            .unwrap_or_else(|e| panic!("step {step} failed: {e}"));
        let (next, norm) = host.normalize(&out.y).unwrap();
        eigval = norm;
        b = next;
    }

    // the dropped worker is reflected in the availability set from the
    // following step onward
    assert!(
        avail_sizes[..=KILL_STEP].iter().all(|&a| a == 3),
        "pre-kill availability wrong: {avail_sizes:?}"
    );
    assert!(
        avail_sizes[KILL_STEP + 1..].iter().all(|&a| a == 2),
        "post-kill availability wrong: {avail_sizes:?}"
    );

    // distributed result matches the single-process run within 1e-5
    assert_eq!(b.len(), local.eigvec.len());
    for (i, (a, e)) in b.iter().zip(&local.eigvec).enumerate() {
        assert!(
            (a - e).abs() <= 1e-5,
            "eigvec[{i}] diverged: tcp {a} vs local {e}"
        );
    }
    assert!(
        (eigval - local.eigval).abs() <= 1e-5,
        "eigenvalue estimate diverged: tcp {eigval} vs local {}",
        local.eigval
    );

    let mut transport = transport;
    transport.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// Transport wrapper that severs one worker's socket at the first receive
/// of the step — i.e. right after every order shipped, genuinely
/// mid-step. The reader thread surfaces `Disconnected` and the master's
/// recovery path must finish the step from surviving replicas.
struct KillOnFirstRecv<'a> {
    inner: &'a TcpTransport,
    victim: usize,
    killed: Cell<bool>,
}

impl Transport for KillOnFirstRecv<'_> {
    fn size(&self) -> usize {
        self.inner.size()
    }
    fn alive(&self) -> Vec<bool> {
        self.inner.alive()
    }
    fn send(&self, worker: usize, order: WorkOrder) -> Result<()> {
        self.inner.send(worker, order)
    }
    fn recv_timeout(&self, timeout: Duration) -> Result<TransportEvent> {
        if !self.killed.replace(true) {
            self.inner.kill(self.victim);
        }
        self.inner.recv_timeout(timeout)
    }
    fn drain(&self) -> Vec<TransportEvent> {
        self.inner.drain()
    }
    fn shutdown(&mut self) {}
}

/// The flagship recovery scenario: a cyclic `g=6 j=3 S=0` shard cluster
/// over TCP loses one worker to a socket kill *after* the step's orders
/// shipped. Without recovery only the coverage timeout could end such a
/// step; with `--recovery` the master re-plans the victim's rows onto the
/// surviving replicas and the step completes exactly.
fn run_mid_step_kill_scenario(nvec: usize) {
    const Q6: usize = 120;
    const VICTIM: usize = 1;
    let (addrs, handles) = start_workers(6);
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let spec = WorkloadSpec::RandomDense {
        q: Q6,
        r: Q6,
        seed: 17,
    };
    let peers: Vec<TcpPeer> = addrs
        .iter()
        .enumerate()
        .map(|(id, addr)| TcpPeer {
            addr: addr.clone(),
            hello: Hello {
                version: WIRE_VERSION,
                worker: id,
                speed: 1.0,
                tile_rows: 16,
                backend: BackendKind::Host,
                g: 6,
                heartbeat_ms: 100,
                threads: 1,
                workload: spec.clone(),
                // placement-shaped shards: each daemon stores only its
                // J/G share, so rescuers must be genuine replicas
                stored: placement.stored_by(id).collect(),
            },
            stream_ranges: vec![],
        })
        .collect();
    let transport = TcpTransport::connect(peers, TcpOptions::default()).unwrap();
    let sub_ranges = submatrix_ranges(Q6, 6).unwrap();
    let mut master = Master::new(MasterConfig {
        placement: placement.clone(),
        sub_ranges,
        params: SolveParams::with_stragglers(0),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: vec![1.0; 6],
        // ~200 ms of throttled compute per worker: no report can race
        // ahead of the scripted kill
        row_cost_ns: 10_000_000,
        recovery_timeout: Duration::from_secs(30),
        recovery: RecoveryPolicy {
            enabled: true,
            overdue_factor: 0.9,
        },
    })
    .unwrap();

    let cols: Vec<Vec<f32>> = (0..nvec)
        .map(|k| {
            (0..Q6)
                .map(|i| ((i * (k + 2)) % 11) as f32 * 0.1 - 0.5)
                .collect()
        })
        .collect();
    let w = Arc::new(Block::from_columns(&cols).unwrap());
    let chaos = KillOnFirstRecv {
        inner: &transport,
        victim: VICTIM,
        killed: Cell::new(false),
    };
    let avail: Vec<usize> = (0..6).collect();
    let out = master.step(&chaos, 0, &w, &avail, &[]).unwrap();

    assert_eq!(out.nvec, nvec);
    assert!(!out.reporters.contains(&VICTIM), "the victim cannot report");
    assert_eq!(out.recoveries.len(), 1, "{:?}", out.recoveries);
    let ev = &out.recoveries[0];
    assert_eq!(ev.victim, VICTIM);
    assert_eq!(ev.reason, RecoveryReason::Disconnected);
    assert!(ev.rows > 0);
    assert!(!ev.rescuers.is_empty() && !ev.rescuers.contains(&VICTIM));

    // the assembled product is exact vs the regenerated oracle
    let oracle = spec.materialize().unwrap();
    for (k, col) in cols.iter().enumerate() {
        let want = oracle.matvec(col).unwrap();
        for (row, e) in want.iter().enumerate() {
            let a = out.y[row * nvec + k];
            assert!(
                (a - e).abs() <= 1e-5,
                "B={nvec} col {k} row {row}: {a} vs {e}"
            );
        }
    }

    // and the event is machine-readable through Timeline::to_json
    // (what `--json-out` writes)
    let mut tl = Timeline::new();
    tl.push(StepRecord {
        step: 0,
        available: 6,
        reported: out.reporters.len(),
        stragglers: 0,
        wall: out.wall,
        solve: out.solve,
        predicted_c: out.predicted_c,
        metric: 0.0,
        recoveries: out.recoveries.clone(),
        migrations: Vec::new(),
        counters: Vec::new(),
        rtt_p50_ms: f64::NAN,
        rtt_p99_ms: f64::NAN,
        compute_p50_ms: f64::NAN,
        compute_p99_ms: f64::NAN,
        overlap_ns: 0,
        faults: 0,
        retries: 0,
        checkpoint: false,
    });
    let back = usec::util::json::Json::parse(&tl.to_json().to_string()).unwrap();
    assert_eq!(back.get_usize("recoveries_total"), Some(1));
    let steps = back.get("timeline").unwrap().items().unwrap();
    let evs = steps[0].get("recoveries").unwrap().items().unwrap();
    assert_eq!(evs[0].get_usize("victim"), Some(VICTIM));
    assert_eq!(evs[0].get_str("reason"), Some("disconnected"));

    let mut transport = transport;
    transport.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn tcp_recovery_survives_mid_step_socket_kill_at_s0() {
    run_mid_step_kill_scenario(1);
}

#[test]
fn tcp_recovery_survives_mid_step_socket_kill_at_s0_batched() {
    run_mid_step_kill_scenario(3);
}

/// End-to-end tracing over a real 3-worker TCP cluster: the journal's
/// span tree must be consistent (every order span matches its dispatch on
/// the same worker track, worker-reported compute bounded by the
/// master-observed RTT), the counters must surface per step, and the
/// Chrome export must carry the spans. The journal is left on disk at
/// `artifacts/integration_trace.jsonl` so CI can upload it.
#[test]
fn traced_tcp_run_produces_a_consistent_journal() {
    use usec::obs::{chrome_trace, load_journal, EventKind};

    let (addrs, handles) = start_workers(3);
    std::fs::create_dir_all("artifacts").unwrap();
    let path = "artifacts/integration_trace.jsonl";
    let mut cfg = base_cfg(addrs);
    cfg.trace_out = path.to_string();
    let res = run_power_iteration(&cfg).unwrap();
    assert_eq!(res.timeline.len(), STEPS);

    let events = load_journal(path).unwrap();
    let dispatches: Vec<_> = events
        .iter()
        .filter(|e| e.kind == EventKind::Dispatch)
        .collect();
    let orders: Vec<_> = events
        .iter()
        .filter(|e| e.kind == EventKind::Order)
        .collect();
    assert!(dispatches.len() >= 3 * STEPS, "3 workers × {STEPS} steps");
    // with S=1 over-provisioning a fully-covered step can drop its last
    // report, so spans ⊆ dispatches; every span must close a real dispatch
    assert!(orders.len() >= STEPS, "at least one closed span per step");
    for o in &orders {
        let d = dispatches
            .iter()
            .find(|d| d.order == o.order)
            .expect("order span without a matching dispatch");
        assert_eq!(o.worker, d.worker, "span on the wrong worker track");
        assert_eq!(o.rows, d.rows, "span rows diverge from the dispatch");
        // worker-side compute can never exceed the master-observed RTT
        let bd = o.breakdown.expect("traced order span missing breakdown");
        let rtt = o.dur_ns.expect("order span missing duration");
        assert!(
            bd.compute_ns <= rtt,
            "compute {} ns exceeds RTT {} ns",
            bd.compute_ns,
            rtt
        );
        // the span nests inside its step's span
        let step = events
            .iter()
            .find(|e| e.kind == EventKind::Step && e.step == o.step)
            .expect("order without an enclosing step span");
        let (s0, s1) = (step.t_ns, step.t_ns + step.dur_ns.unwrap());
        assert!(s0 <= o.t_ns && o.t_ns + rtt <= s1, "span escapes its step");
    }
    assert_eq!(
        events.iter().filter(|e| e.kind == EventKind::Step).count(),
        STEPS
    );
    // the daemon-side phases landed: at least one breakdown carries a
    // non-zero decode or idle measurement
    assert!(orders
        .iter()
        .any(|o| o.breakdown.is_some_and(|b| b.decode_ns > 0 || b.idle_ns > 0)));

    // per-step counter snapshots surfaced into the timeline, monotone in
    // dispatched orders and carrying real wire traffic
    let steps = res.timeline.steps();
    assert!(steps.iter().all(|s| s.counters.len() == 3));
    let last = steps.last().unwrap();
    let total_orders: u64 = last.counters.iter().map(|c| c.orders).sum();
    assert_eq!(total_orders as usize, dispatches.len());
    assert!(last.counters.iter().all(|c| c.bytes_tx > 0 && c.bytes_rx > 0));
    for w in steps.windows(2) {
        for (a, b) in w[0].counters.iter().zip(&w[1].counters) {
            assert!(a.orders <= b.orders && a.bytes_rx <= b.bytes_rx);
        }
    }
    assert!(steps.iter().any(|s| s.rtt_p50_ms.is_finite()));

    // the Chrome export carries every span on its worker track
    let trace = chrome_trace(&events);
    let items = trace.items().unwrap();
    let spans = items
        .iter()
        .filter(|e| e.get_str("ph") == Some("X") && e.get_str("name") == Some("order"))
        .count();
    assert_eq!(spans, orders.len());
    assert!(items.iter().any(|e| {
        e.get_str("ph") == Some("M")
            && e.get("args").and_then(|a| a.get_str("name")) == Some("worker 2")
    }));

    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn tcp_harness_matches_local_through_runconfig() {
    let (addrs, handles) = start_workers(3);

    let tcp = run_power_iteration(&base_cfg(addrs)).unwrap();
    let local = run_power_iteration(&base_cfg(vec![])).unwrap();

    assert_eq!(tcp.timeline.len(), STEPS);
    assert!(tcp
        .timeline
        .steps()
        .iter()
        .all(|s| s.available == 3 && s.reported >= 2));
    for (i, (a, e)) in tcp.eigvec.iter().zip(&local.eigvec).enumerate() {
        assert!(
            (a - e).abs() <= 1e-5,
            "eigvec[{i}] diverged: tcp {a} vs local {e}"
        );
    }
    assert!((tcp.final_nmse - local.final_nmse).abs() <= 1e-7);
    assert!(tcp.final_nmse < 0.05, "did not converge: {}", tcp.final_nmse);

    // run_power_iteration dropped its harness (and thus the transport),
    // which sends Shutdown — the once-mode daemons exit cleanly.
    for h in handles {
        h.join().unwrap().unwrap();
    }
}
