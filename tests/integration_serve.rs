//! Acceptance: a resident 3-worker TCP cluster serves two tenants'
//! concurrent requests — every batched answer matches a dedicated
//! single-job oracle, a worker killed mid-serve is absorbed (recovery
//! armed, full replication), and the `--json-out` style dump carries
//! the per-request latency quantiles.

use std::net::TcpListener;
use std::thread::JoinHandle;

use usec::config::types::RunConfig;
use usec::error::Result;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::net::AnyTransport;
use usec::placement::PlacementKind;
use usec::sched::RecoveryPolicy;
use usec::serve::{Query, ServeSession, SessionOpts};

const Q: usize = 48;
const SEED: u64 = 17;

/// Spawn `n` worker daemons on ephemeral loopback ports.
fn start_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<Result<()>>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 1,
                    ..Default::default()
                },
            )
        }));
    }
    (addrs, handles)
}

/// Full replication (cyclic J=3 of G=3) with S=1: one worker can die
/// mid-serve and every serve-matrix row keeps a live replica. The serve
/// matrix has no generator seed, so distributed sessions stream rows.
fn serve_cfg(workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 3,
        n: 3,
        placement: PlacementKind::Cyclic,
        stragglers: 1,
        steps: 1,
        speeds: vec![1.0, 1.0, 1.0],
        seed: SEED,
        stream_data: !workers.is_empty(),
        recovery: RecoveryPolicy::enabled(),
        workers,
        ..Default::default()
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(&x, &y)| (x as f64 - y as f64).abs())
        .fold(0.0, f64::max)
}

#[test]
fn tcp_cluster_serves_two_tenants_and_absorbs_a_mid_serve_kill() {
    let (addrs, handles) = start_workers(3);

    let cfg = serve_cfg(addrs);
    let mut session = ServeSession::build(&cfg, &SessionOpts::default()).unwrap();

    // two tenants, three concurrent requests across all query kinds
    let queries = [
        (
            "alice",
            Query::Pagerank {
                seed_node: 3,
                damping: 0.85,
            },
            1e-9,
            200,
        ),
        (
            "bob",
            Query::Matvec {
                v: (0..Q).map(|i| (i as f32).sin()).collect(),
            },
            1e-6,
            1,
        ),
        (
            "bob",
            Query::Ridge {
                b: (0..Q).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
                lambda: 3.0,
                eta: 0.13,
            },
            1e-7,
            300,
        ),
    ];
    let mut ids = Vec::new();
    for (tenant, query, tol, max_steps) in &queries {
        ids.push(
            session
                .submit(tenant, query.clone(), *tol, *max_steps)
                .unwrap(),
        );
    }

    // serve a few steps healthy, then kill a worker's socket mid-serve
    let mut responses = Vec::new();
    for _ in 0..3 {
        responses.extend(session.step_once().unwrap());
    }
    match &session.engine().transport {
        AnyTransport::Tcp(t) => t.kill(2),
        _ => panic!("expected a TCP transport"),
    }
    responses.extend(session.run_until_drained(2000).unwrap());
    assert_eq!(responses.len(), queries.len());

    // every answer matches a dedicated single-job oracle: the same
    // request, alone, on its own single-process cluster
    for ((tenant, query, tol, max_steps), id) in queries.iter().zip(&ids) {
        let got = responses.iter().find(|r| r.id == *id).unwrap();
        assert_eq!(&got.tenant, tenant);
        let solo_cfg = serve_cfg(vec![]);
        let mut solo = ServeSession::build(&solo_cfg, &SessionOpts::default()).unwrap();
        solo.submit(tenant, query.clone(), *tol, *max_steps).unwrap();
        let solo_resp = solo.run_until_drained(2000).unwrap();
        solo.finish().unwrap();
        assert_eq!(solo_resp.len(), 1);
        let diff = max_abs_diff(&got.answer, &solo_resp[0].answer);
        assert!(
            diff <= 1e-5,
            "{} answer diverged from its dedicated oracle after the kill: {diff}",
            query.kind()
        );
    }

    // the kill is visible in the timeline: availability drops to 2 and
    // serving continued regardless
    let tl = session.finish().unwrap();
    let avail: Vec<usize> = tl.steps().iter().map(|s| s.available).collect();
    assert_eq!(avail[0], 3, "healthy steps saw all three workers");
    assert_eq!(
        *avail.last().unwrap(),
        2,
        "post-kill steps run on the survivors: {avail:?}"
    );

    // the --json-out style dump carries the request-plane quantiles
    let summary = tl.serve().expect("serve summary attached");
    assert_eq!(summary.requests, queries.len() as u64);
    assert!(summary.latency_p99_ns >= summary.latency_p50_ns);
    let path = std::env::temp_dir().join(format!(
        "usec-serve-int-{}.json",
        std::process::id()
    ));
    std::fs::write(&path, format!("{}\n", tl.to_json())).unwrap();
    let dump = std::fs::read_to_string(&path).unwrap();
    for key in [
        "\"requests\":",
        "\"latency_p50_ns\":",
        "\"latency_p99_ns\":",
        "\"queue_depth\":",
        "\"rows_per_s\":",
    ] {
        assert!(dump.contains(key), "dump is missing {key}");
    }
    let _ = std::fs::remove_file(&path);

    // daemons: worker 2's session died with the kill, 0 and 1 were shut
    // down by the engine drain — all three daemon threads exit
    for h in handles {
        h.join().unwrap().unwrap();
    }
}
