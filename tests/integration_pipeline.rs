//! Integration tests for the pipelined master (`--pipeline`): the
//! event-driven step loop that overlaps the previous step's combine
//! metric with the next step's dispatch+compute, streams migration
//! bytes on the transfer lane concurrently with compute, and recovers
//! from a worker lost while orders are in flight.
//!
//! Uncoded rows have one value whoever (and whenever) computes them, so
//! every pipelined run must match the synchronous oracle within 1e-5 —
//! the pipeline may only move *metric* work across step boundaries,
//! never the trajectory itself.

use std::net::TcpListener;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use usec::apps::power_iteration::run_power_iteration;
use usec::config::types::{AssignPolicy, BackendKind, RunConfig};
use usec::error::Result;
use usec::linalg::partition::submatrix_ranges;
use usec::linalg::Block;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::net::{Hello, TcpOptions, TcpPeer, TcpTransport, Transport, WorkloadSpec, WIRE_VERSION};
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::rebalance::RebalanceConfig;
use usec::sched::master::{Master, MasterConfig};
use usec::sched::{RecoveryPolicy, RecoveryReason};

const Q: usize = 120;
const STEPS: usize = 24;
const SEED: u64 = 11;

/// Spawn `n` worker daemons on ephemeral loopback ports.
fn start_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<Result<()>>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 1,
                    ..Default::default()
                },
            )
        }));
    }
    (addrs, handles)
}

/// 3 machines, full replication (cyclic J=3), S=1 — same cluster shape
/// as the synchronous TCP integration tests.
fn base_cfg(workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 3,
        n: 3,
        placement: PlacementKind::Cyclic,
        stragglers: 1,
        steps: STEPS,
        speeds: vec![1.0, 1.0, 1.0],
        seed: SEED,
        workers,
        ..Default::default()
    }
}

/// Tentpole correctness: at B=1 (vector power iteration) and B=16
/// (block power iteration, combine-heavy MGS) the pipelined loop —
/// in-process *and* over a real 3-worker TCP cluster — reproduces the
/// synchronous oracle, and every pipelined step records the overlap it
/// bought while the synchronous run records none.
#[test]
fn pipelined_local_and_tcp_match_the_synchronous_oracle() {
    for batch in [1usize, 16] {
        let sync_cfg = RunConfig {
            batch,
            ..base_cfg(vec![])
        };
        let oracle = run_power_iteration(&sync_cfg).unwrap();
        assert!(
            oracle.timeline.steps().iter().all(|s| s.overlap_ns == 0),
            "B={batch}: a synchronous step claimed pipeline overlap"
        );

        // --- pipelined, in-process ---
        let piped = run_power_iteration(&RunConfig {
            pipeline: true,
            ..sync_cfg.clone()
        })
        .unwrap();
        assert_eq!(piped.timeline.len(), STEPS);
        assert!(
            piped.timeline.steps().iter().all(|s| s.overlap_ns > 0),
            "B={batch}: a pipelined step lost its overlap measurement"
        );

        // --- pipelined, over TCP ---
        let (addrs, handles) = start_workers(3);
        let tcp = run_power_iteration(&RunConfig {
            pipeline: true,
            ..RunConfig {
                batch,
                ..base_cfg(addrs)
            }
        })
        .unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
        assert!(tcp.timeline.steps().iter().all(|s| s.overlap_ns > 0));

        for run in [&piped, &tcp] {
            assert_eq!(run.eigvec.len(), oracle.eigvec.len());
            for (i, (a, e)) in run.eigvec.iter().zip(&oracle.eigvec).enumerate() {
                assert!(
                    (a - e).abs() <= 1e-5,
                    "B={batch} eigvec[{i}] diverged: pipelined {a} vs oracle {e}"
                );
            }
            assert!(
                (run.final_nmse - oracle.final_nmse).abs() <= 1e-5,
                "B={batch}: nmse diverged"
            );
            for (a, e) in run.eigvals.iter().zip(&oracle.eigvals) {
                assert!((a - e).abs() <= 1e-5, "B={batch}: eigenvalue diverged");
            }
        }
        // the deferred finish still produced a per-step metric for every
        // step, in the same order as the synchronous run
        for (p, o) in piped
            .timeline
            .steps()
            .iter()
            .zip(oracle.timeline.steps())
        {
            assert_eq!(p.step, o.step);
            assert!(p.metric.is_finite(), "step {} metric never finished", p.step);
        }
        assert!(oracle.final_nmse < 0.05, "oracle did not converge");
    }
}

/// Recovery inside the overlap window: the pipelined loop's defining
/// hazard is a worker dying *after* `begin_step` shipped its orders but
/// *before* `collect_step` runs — exactly when the master is busy
/// finishing the previous step's combine. Drive the begin/collect
/// primitive over a cyclic `g=6 j=3 S=0` TCP shard cluster, kill a
/// worker inside the window, and require the recovery plan to finish
/// the step exactly — then keep pipelining on the survivors.
#[test]
fn recovery_covers_a_kill_inside_the_overlap_window() {
    const Q6: usize = 120;
    const NVEC: usize = 3;
    const VICTIM: usize = 1;
    const KILL_STEP: usize = 1;
    let (addrs, handles) = start_workers(6);
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let spec = WorkloadSpec::RandomDense {
        q: Q6,
        r: Q6,
        seed: 17,
    };
    let peers: Vec<TcpPeer> = addrs
        .iter()
        .enumerate()
        .map(|(id, addr)| TcpPeer {
            addr: addr.clone(),
            hello: Hello {
                version: WIRE_VERSION,
                worker: id,
                speed: 1.0,
                tile_rows: 16,
                backend: BackendKind::Host,
                g: 6,
                heartbeat_ms: 100,
                threads: 1,
                workload: spec.clone(),
                stored: placement.stored_by(id).collect(),
            },
            stream_ranges: vec![],
        })
        .collect();
    let transport = TcpTransport::connect(peers, TcpOptions::default()).unwrap();
    let sub_ranges = submatrix_ranges(Q6, 6).unwrap();
    let mut master = Master::new(MasterConfig {
        placement: placement.clone(),
        sub_ranges,
        params: SolveParams::with_stragglers(0),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: vec![1.0; 6],
        // ~200 ms of throttled compute per worker: no report can race
        // ahead of the in-window kill
        row_cost_ns: 10_000_000,
        recovery_timeout: Duration::from_secs(30),
        recovery: RecoveryPolicy {
            enabled: true,
            overdue_factor: 0.9,
        },
    })
    .unwrap();

    let oracle = spec.materialize().unwrap();
    let cols: Vec<Vec<f32>> = (0..NVEC)
        .map(|k| {
            (0..Q6)
                .map(|i| ((i * (k + 2)) % 11) as f32 * 0.1 - 0.5)
                .collect()
        })
        .collect();
    let mut w = Arc::new(Block::from_columns(&cols).unwrap());

    for step in 0..3 {
        let alive = transport.alive();
        let avail: Vec<usize> = (0..6).filter(|&n| alive[n]).collect();
        let fl = master
            .begin_step(&transport, step, &w, &avail, &[])
            .unwrap_or_else(|e| panic!("begin_step {step} failed: {e}"));
        // === the overlap window: orders are in flight, the pipelined
        // loop is off finishing step-1's combine. Strike now. ===
        if step == KILL_STEP {
            transport.kill(VICTIM);
        }
        let out = master
            .collect_step(&transport, fl)
            .unwrap_or_else(|e| panic!("collect_step {step} failed: {e}"));

        assert_eq!(out.nvec, NVEC);
        if step == KILL_STEP {
            assert!(!out.reporters.contains(&VICTIM), "the victim cannot report");
            assert_eq!(out.recoveries.len(), 1, "{:?}", out.recoveries);
            let ev = &out.recoveries[0];
            assert_eq!(ev.victim, VICTIM);
            assert_eq!(ev.reason, RecoveryReason::Disconnected);
            assert!(ev.rows > 0);
            assert!(!ev.rescuers.is_empty() && !ev.rescuers.contains(&VICTIM));
        } else {
            assert!(out.recoveries.is_empty(), "step {step}: spurious recovery");
            if step > KILL_STEP {
                assert_eq!(avail.len(), 5, "the kill must stick");
            }
        }

        // every step — before, during and after the kill — is exact
        // against the regenerated oracle
        for k in 0..NVEC {
            let want = oracle.matvec(&w.column(k)).unwrap();
            for (row, e) in want.iter().enumerate() {
                let a = out.y[row * NVEC + k];
                assert!(
                    (a - e).abs() <= 1e-5,
                    "step {step} col {k} row {row}: {a} vs {e}"
                );
            }
        }
        w = Arc::new(Block::from_interleaved(Q6, NVEC, out.y).unwrap());
    }

    let mut transport = transport;
    transport.shutdown();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

/// Migration racing compute: with `--pipeline --rebalance` on a TCP
/// shard cluster whose speed prior is wrong by 8×, migration bytes
/// stream on the transfer lane while steps keep dispatching — and the
/// run still fires migrations, keeps every step feasible, and matches
/// the in-process oracle.
#[test]
fn pipelined_rebalance_races_compute_and_matches_the_oracle() {
    const TRUE_SPEEDS: [f64; 3] = [8.0, 1.0, 1.0];
    // 2 ms/row at speed 1 makes the skew visible to the EWMA and leaves
    // the transfer lane a real compute window to race against.
    const ROW_COST_NS: u64 = 2_000_000;
    // Cyclic J=2 of G=3: sub-matrix 1 starts with both replicas on slow
    // machines — the placement the drift monitor must fix mid-run.
    let shard_cfg = |workers: Vec<String>| RunConfig {
        j: 2,
        speeds: TRUE_SPEEDS.to_vec(),
        row_cost_ns: ROW_COST_NS,
        stragglers: 0,
        seed: 19,
        ..base_cfg(workers)
    };

    let (addrs, handles) = start_workers(3);
    let adapted = run_power_iteration(&RunConfig {
        pipeline: true,
        rebalance: RebalanceConfig {
            enabled: true,
            threshold: 0.1,
            budget_bytes: 1 << 20,
            ..Default::default()
        },
        ..shard_cfg(addrs)
    })
    .unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    let oracle = run_power_iteration(&RunConfig {
        row_cost_ns: 0,
        ..shard_cfg(vec![])
    })
    .unwrap();

    // the wrong prior fired at least one migration, shipped real bytes,
    // and every move improved the rescheduled expected time
    assert!(
        adapted.timeline.total_migrations() >= 1,
        "no migration fired under an 8x-wrong prior"
    );
    assert!(adapted.timeline.total_migrated_bytes() > 0);
    for step in adapted.timeline.steps() {
        for m in &step.migrations {
            assert!(
                m.expected_after < m.expected_before,
                "move did not improve the schedule: {} -> {}",
                m.expected_before,
                m.expected_after
            );
        }
    }

    // migration raced compute without ever costing coverage: every step
    // completed at full availability with its overlap intact
    assert_eq!(adapted.timeline.len(), STEPS);
    for s in adapted.timeline.steps() {
        assert_eq!(s.available, 3, "step {} lost availability", s.step);
        assert!(s.reported > 0, "step {} was skipped as infeasible", s.step);
        assert!(s.overlap_ns > 0, "step {} lost its overlap", s.step);
    }

    // correctness: whoever holds a row computes the same row
    for (i, (a, e)) in adapted.eigvec.iter().zip(&oracle.eigvec).enumerate() {
        assert!(
            (a - e).abs() <= 1e-5,
            "eigvec[{i}] diverged: adapted {a} vs oracle {e}"
        );
    }
    assert!((adapted.final_nmse - oracle.final_nmse).abs() <= 1e-7);
    assert!(
        adapted.final_nmse < 0.05,
        "adapted run did not converge: {}",
        adapted.final_nmse
    );
}
