//! Chaos soak matrix: every canned fault class crossed with batch width
//! and straggler tolerance, with recovery + rebalancing + pipelining on.
//! Every cell must terminate before its deadline and either match the
//! fault-free oracle or return a typed error — no hangs, no panics, no
//! silently wrong answers.

use std::time::Duration;

use usec::config::types::RunConfig;
use usec::error::Error;
use usec::testing::chaos::{run_with_deadline, soak_config, soak_schedules};

/// Generous per-cell ceiling: a clean cell takes well under a second;
/// recovery adds ~1s per dropped order under the chaos-shortened
/// coverage timeout.
const DEADLINE: Duration = Duration::from_secs(120);

fn oracle(cfg: &RunConfig) -> Vec<f32> {
    let mut clean = cfg.clone();
    clean.chaos.clear();
    run_with_deadline(&clean, DEADLINE)
        .expect("fault-free oracle must run")
        .eigvec
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

#[test]
fn soak_matrix_terminates_and_matches_the_oracle() {
    for batch in [1usize, 8] {
        for stragglers in [0usize, 1] {
            let base = soak_config(batch, stragglers);
            let truth = oracle(&base);
            for (name, sched) in soak_schedules() {
                let mut cfg = base.clone();
                cfg.chaos = sched.to_string();
                let cell = format!("{name} B={batch} S={stragglers}");
                match run_with_deadline(&cfg, DEADLINE) {
                    Ok(res) => {
                        // the product y = Xw is assignment-invariant, so a
                        // recovered run must land on the oracle trajectory
                        let diff = max_abs_diff(&res.eigvec, &truth);
                        assert!(
                            diff <= 1e-5,
                            "{cell}: eigvec drifted {diff} from the oracle"
                        );
                        // faults were actually injected and surfaced
                        let faults: u64 =
                            res.timeline.steps().iter().map(|s| s.faults).sum();
                        assert!(faults > 0, "{cell}: schedule injected no faults");
                    }
                    // a typed error under the deadline is an accepted
                    // outcome (e.g. coverage lost beyond what recovery
                    // can replan); a hang or panic is not
                    Err(e) => {
                        let m = e.to_string();
                        assert!(!m.contains("deadline"), "{cell}: hung — {m}");
                        assert!(!m.contains("panicked"), "{cell}: {m}");
                    }
                }
            }
        }
    }
}

#[test]
fn chaos_runs_are_reproducible_in_the_seed() {
    let mut cfg = soak_config(1, 0);
    cfg.chaos = "drop=0.15,delay=3:0.2".into();
    cfg.chaos_seed = 42;
    let a = run_with_deadline(&cfg, DEADLINE).expect("seeded run");
    let b = run_with_deadline(&cfg, DEADLINE).expect("seeded rerun");
    assert_eq!(a.eigvec, b.eigvec, "trajectory must replay exactly");
    let fa: Vec<u64> = a.timeline.steps().iter().map(|s| s.faults).collect();
    let fb: Vec<u64> = b.timeline.steps().iter().map(|s| s.faults).collect();
    assert_eq!(fa, fb, "per-step fault schedule must replay exactly");
    assert!(fa.iter().sum::<u64>() > 0, "schedule injected no faults");
}

#[test]
fn total_blackout_fails_fast_with_a_typed_error() {
    // every order dropped and recovery off: the run must surface a typed
    // coverage error within the chaos-shortened timeout, not hang
    let mut cfg = soak_config(1, 0);
    cfg.recovery.enabled = false;
    cfg.rebalance.enabled = false;
    cfg.pipeline = false;
    cfg.chaos = "drop=1.0".into();
    let err = run_with_deadline(&cfg, Duration::from_secs(60))
        .expect_err("a fully partitioned run cannot succeed");
    match err {
        Error::Cluster(m) => assert!(!m.contains("deadline"), "hang: {m}"),
        other => panic!("expected a typed cluster error, got {other}"),
    }
}

#[test]
fn throttle_chaos_preserves_the_trajectory() {
    // a throttled worker is slow, not wrong — the run must match the
    // oracle exactly while still journaling the injected faults
    let mut cfg = soak_config(1, 0);
    cfg.row_cost_ns = 10_000;
    let truth = oracle(&cfg);
    cfg.chaos = "throttle=0:8".into();
    let res = run_with_deadline(&cfg, DEADLINE).expect("throttled run");
    assert!(max_abs_diff(&res.eigvec, &truth) <= 1e-5);
    assert!(res.timeline.steps().iter().map(|s| s.faults).sum::<u64>() > 0);
}
