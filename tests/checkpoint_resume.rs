//! Checkpoint/resume: serialization round-trips bit-exactly (property),
//! a master killed at step k resumes to the uninterrupted oracle's
//! answer, and damaged or mismatched checkpoints fail fast with typed
//! errors instead of producing a silently different run.

use std::path::PathBuf;

use usec::config::types::RunConfig;
use usec::error::Error;
use usec::net::WorkloadSpec;
use usec::sched::checkpoint::workload_digest;
use usec::sched::Checkpoint;
use usec::testing::prop::{run, Config};

fn tmp_ckpt(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("usec-resume-{tag}-{}.ckpt", std::process::id()))
}

/// A deterministic mid-size run with no random preemption, so the
/// resumed half sees the exact world the killed master would have seen.
/// Injected stragglers are fine too: victims are drawn from an RNG
/// derived from `(seed, step)`, so a resume replays the same schedule
/// (see `injected_straggler_schedule_replays_across_a_resume`).
fn base_config() -> RunConfig {
    RunConfig {
        q: 96,
        r: 96,
        g: 6,
        j: 3,
        n: 6,
        steps: 8,
        speeds: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        seed: 23,
        ..Default::default()
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

// ---- serialization round-trip (property) ----

#[test]
fn encode_decode_round_trips_bit_exactly() {
    run(Config::default().cases(80).name("ckpt-roundtrip"), |rng| {
        let spec = WorkloadSpec::PlantedSymmetric {
            q: rng.range(4, 512),
            eigval: rng.range_f64(1.0, 20.0),
            gap: rng.range_f64(0.05, 0.9),
            seed: rng.next_u64(),
        };
        let nvec = rng.range(1, 5);
        let rows = rng.range(1, 64);
        // arbitrary bit patterns: subnormals, infs, and NaNs must all
        // survive the hex round-trip with their exact payload bits
        let w: Vec<f32> = (0..rows * nvec)
            .map(|_| f32::from_bits(rng.next_u64() as u32))
            .collect();
        let n = rng.range(1, 8);
        let speeds: Vec<f64> = (0..n).map(|_| f64::from_bits(rng.next_u64())).collect();
        let stored: Vec<Vec<usize>> = (0..n)
            .map(|_| (0..rng.range(1, 5)).map(|_| rng.below(12)).collect())
            .collect();
        let ckpt = Checkpoint {
            next_step: rng.below(1000),
            nvec,
            w,
            speeds,
            last_metric: f64::from_bits(rng.next_u64()),
            stored,
            pending: Vec::new(),
        };
        let back = Checkpoint::decode(&ckpt.encode(&spec), &spec).unwrap();
        assert_eq!(back.next_step, ckpt.next_step);
        assert_eq!(back.nvec, ckpt.nvec);
        assert_eq!(back.stored, ckpt.stored);
        for (a, b) in ckpt.w.iter().zip(&back.w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in ckpt.speeds.iter().zip(&back.speeds) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(ckpt.last_metric.to_bits(), back.last_metric.to_bits());
        // a snapshot with migrations in flight must be refused on load
        let mut midway = ckpt;
        midway.pending = vec![rng.next_u64() >> 12];
        let err = Checkpoint::decode(&midway.encode(&spec), &spec).unwrap_err();
        assert!(matches!(err, Error::Checkpoint(_)), "{err}");
    });
}

#[test]
fn digest_is_sensitive_to_every_workload_field() {
    let base = WorkloadSpec::PlantedSymmetric {
        q: 96,
        eigval: 10.0,
        gap: 0.35,
        seed: 23,
    };
    let variants = [
        WorkloadSpec::PlantedSymmetric { q: 97, eigval: 10.0, gap: 0.35, seed: 23 },
        WorkloadSpec::PlantedSymmetric { q: 96, eigval: 10.5, gap: 0.35, seed: 23 },
        WorkloadSpec::PlantedSymmetric { q: 96, eigval: 10.0, gap: 0.36, seed: 23 },
        WorkloadSpec::PlantedSymmetric { q: 96, eigval: 10.0, gap: 0.35, seed: 24 },
    ];
    for v in &variants {
        assert_ne!(workload_digest(&base), workload_digest(v), "{v:?}");
    }
}

// ---- kill-at-step-k resume vs the uninterrupted oracle ----

fn kill_and_resume(tag: &str, batch: usize, pipeline: bool) {
    let path = tmp_ckpt(tag);
    let total = 8;
    let kill_at = 4;

    let mut oracle_cfg = base_config();
    oracle_cfg.batch = batch;
    oracle_cfg.pipeline = pipeline;
    let oracle = usec::apps::run_power_iteration(&oracle_cfg).unwrap();

    // first life: checkpoint every boundary, die (= return) after step k
    let mut first = oracle_cfg.clone();
    first.steps = kill_at;
    first.checkpoint_out = path.display().to_string();
    usec::apps::run_power_iteration(&first).unwrap();

    // second life: resume from the step-k snapshot, run to completion
    let mut second = oracle_cfg.clone();
    second.resume = path.display().to_string();
    let resumed = usec::apps::run_power_iteration(&second).unwrap();

    // the resumed run executes exactly the missing steps…
    assert_eq!(resumed.timeline.len(), total - kill_at, "{tag}");
    assert_eq!(resumed.timeline.steps()[0].step, kill_at, "{tag}");
    // …and lands on the oracle's answer
    let diff = max_abs_diff(&resumed.eigvec, &oracle.eigvec);
    assert!(diff <= 1e-5, "{tag}: resumed eigvec drifted {diff}");
    // per-step metrics of the second half line up with the oracle's
    for (r, o) in resumed
        .timeline
        .steps()
        .iter()
        .zip(&oracle.timeline.steps()[kill_at..])
    {
        assert_eq!(r.step, o.step, "{tag}");
        assert!((r.metric - o.metric).abs() <= 1e-9, "{tag} step {}", r.step);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn killed_master_resumes_to_the_oracle_answer() {
    kill_and_resume("classic", 1, false);
}

#[test]
fn killed_block_master_resumes_to_the_oracle_answer() {
    kill_and_resume("block", 4, false);
}

#[test]
fn killed_pipelined_master_resumes_to_the_oracle_answer() {
    kill_and_resume("pipelined", 1, true);
}

/// Regression: the injected-straggler RNG is keyed by `(seed, step)`,
/// not by a mutable stream, so a resumed master picks the exact victims
/// the uninterrupted run would have picked — metrics and the answer
/// line up step for step even with stragglers injected every step.
#[test]
fn injected_straggler_schedule_replays_across_a_resume() {
    let path = tmp_ckpt("stragglers");
    let kill_at = 4;

    let mut oracle_cfg = base_config();
    oracle_cfg.stragglers = 1;
    oracle_cfg.injected_stragglers = 1;
    let oracle = usec::apps::run_power_iteration(&oracle_cfg).unwrap();

    let mut first = oracle_cfg.clone();
    first.steps = kill_at;
    first.checkpoint_out = path.display().to_string();
    usec::apps::run_power_iteration(&first).unwrap();

    let mut second = oracle_cfg.clone();
    second.resume = path.display().to_string();
    let resumed = usec::apps::run_power_iteration(&second).unwrap();

    let diff = max_abs_diff(&resumed.eigvec, &oracle.eigvec);
    assert!(diff <= 1e-5, "straggler schedule diverged on resume: {diff}");
    for (r, o) in resumed
        .timeline
        .steps()
        .iter()
        .zip(&oracle.timeline.steps()[kill_at..])
    {
        assert_eq!(r.step, o.step);
        assert_eq!(r.stragglers, o.stragglers, "step {}", r.step);
        assert!((r.metric - o.metric).abs() <= 1e-9, "step {}", r.step);
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn checkpoint_file_marks_the_kill_boundary() {
    let path = tmp_ckpt("boundary");
    let mut cfg = base_config();
    cfg.steps = 3;
    cfg.checkpoint_out = path.display().to_string();
    let res = usec::apps::run_power_iteration(&cfg).unwrap();
    // every boundary checkpointed (checkpoint_every defaults to 1)
    assert!(res.timeline.steps().iter().all(|s| s.checkpoint));
    let spec = WorkloadSpec::PlantedSymmetric {
        q: cfg.q,
        eigval: usec::apps::power_iteration::PLANT_EIGVAL,
        gap: usec::apps::power_iteration::PLANT_GAP,
        seed: cfg.seed,
    };
    let ckpt = Checkpoint::load(&path, &spec).unwrap();
    assert_eq!(ckpt.next_step, 3);
    assert_eq!(ckpt.nvec, 1);
    assert_eq!(ckpt.w.len(), cfg.r);
    assert_eq!(ckpt.stored.len(), cfg.n);
    assert!(ckpt.pending.is_empty());
    let _ = std::fs::remove_file(&path);
}

// ---- damaged / mismatched checkpoints fail fast, typed ----

fn write_checkpoint(tag: &str, steps: usize) -> PathBuf {
    let path = tmp_ckpt(tag);
    let mut cfg = base_config();
    cfg.steps = steps;
    cfg.checkpoint_out = path.display().to_string();
    usec::apps::run_power_iteration(&cfg).unwrap();
    path
}

#[test]
fn resuming_a_different_job_is_a_typed_error() {
    let path = write_checkpoint("wrongjob", 2);
    let mut other = base_config();
    other.seed = 99; // different planted matrix
    other.resume = path.display().to_string();
    let err = usec::apps::run_power_iteration(&other).unwrap_err();
    assert!(matches!(err, Error::Checkpoint(_)), "{err}");
    assert!(err.to_string().contains("digest"), "{err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn resuming_with_a_different_batch_is_a_typed_error() {
    let path = write_checkpoint("wrongbatch", 2);
    let mut wider = base_config();
    wider.batch = 2; // checkpoint was nvec = 1
    wider.resume = path.display().to_string();
    let err = usec::apps::run_power_iteration(&wider).unwrap_err();
    assert!(matches!(err, Error::Checkpoint(_)), "{err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn resuming_a_corrupted_file_is_a_typed_error() {
    let path = write_checkpoint("corrupt", 2);
    // flip one hex digit inside the iterate payload
    let text = std::fs::read_to_string(&path).unwrap();
    let idx = text.find("\"w\":\"").unwrap() + 6;
    let mut bytes = text.into_bytes();
    bytes[idx] = if bytes[idx] == b'0' { b'1' } else { b'0' };
    std::fs::write(&path, bytes).unwrap();
    let mut cfg = base_config();
    cfg.resume = path.display().to_string();
    let err = usec::apps::run_power_iteration(&cfg).unwrap_err();
    assert!(matches!(err, Error::Checkpoint(_)), "{err}");
    assert!(err.to_string().contains("checksum"), "{err}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn resuming_a_missing_file_is_a_typed_error() {
    let mut cfg = base_config();
    cfg.resume = tmp_ckpt("never-written").display().to_string();
    let err = usec::apps::run_power_iteration(&cfg).unwrap_err();
    assert!(matches!(err, Error::Checkpoint(_)), "{err}");
}
