//! Behavioral freeze of the classic one-job paths across the
//! engine/session split: the `Harness` the apps used to own is now a
//! type alias for [`usec::engine::ClusterEngine`], the app drivers are
//! `Workload` shims over `run_job`, and none of that may change what a
//! classic run computes. Each app runs twice with the same config and
//! must produce bit-identical iterates and step metrics, and the
//! classic timeline dump must stay free of the serve-only JSON keys.

use usec::apps::harness::Harness;
use usec::config::types::RunConfig;
use usec::engine::ClusterEngine;

fn base_cfg() -> RunConfig {
    RunConfig {
        q: 96,
        r: 96,
        g: 6,
        j: 3,
        n: 6,
        steps: 10,
        speeds: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        seed: 23,
        ..Default::default()
    }
}

fn assert_bits_equal(a: &[f32], b: &[f32], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length changed between runs");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(
            x.to_bits(),
            y.to_bits(),
            "{tag}: element {i} not bit-identical ({x} vs {y})"
        );
    }
}

/// The shim is the engine: assignable without conversion, so every
/// pre-split call site keeps the exact code path.
#[test]
fn harness_alias_is_the_cluster_engine() {
    fn same_type(h: Harness) -> ClusterEngine {
        h
    }
    let _ = same_type; // compile-time identity is the assertion
}

#[test]
fn power_iteration_is_deterministic_across_runs() {
    let cfg = base_cfg();
    let a = usec::apps::run_power_iteration(&cfg).unwrap();
    let b = usec::apps::run_power_iteration(&cfg).unwrap();
    assert_bits_equal(&a.eigvec, &b.eigvec, "power iteration eigvec");
    assert_eq!(a.final_nmse.to_bits(), b.final_nmse.to_bits());
    for (ra, rb) in a.timeline.steps().iter().zip(b.timeline.steps()) {
        assert_eq!(ra.step, rb.step);
        assert_eq!(ra.metric.to_bits(), rb.metric.to_bits());
    }
}

#[test]
fn block_power_iteration_is_deterministic_across_runs() {
    let mut cfg = base_cfg();
    cfg.batch = 4;
    let a = usec::apps::run_power_iteration(&cfg).unwrap();
    let b = usec::apps::run_power_iteration(&cfg).unwrap();
    assert_bits_equal(&a.eigvec, &b.eigvec, "block eigvec");
    for (va, vb) in a.eigvals.iter().zip(&b.eigvals) {
        assert_eq!(va.to_bits(), vb.to_bits(), "block spectrum estimate");
    }
}

#[test]
fn pagerank_is_deterministic_across_runs() {
    let cfg = base_cfg();
    let a = usec::apps::pagerank::run_pagerank(&cfg, 0.85).unwrap();
    let b = usec::apps::pagerank::run_pagerank(&cfg, 0.85).unwrap();
    assert_bits_equal(&a.ranks, &b.ranks, "pagerank ranks");
    assert_eq!(a.final_delta.to_bits(), b.final_delta.to_bits());
}

#[test]
fn ridge_is_deterministic_across_runs() {
    let cfg = base_cfg();
    let a = usec::apps::ridge::run_ridge(&cfg, 1.0, 0.1).unwrap();
    let b = usec::apps::ridge::run_ridge(&cfg, 1.0, 0.1).unwrap();
    assert_bits_equal(&a.solution, &b.solution, "ridge solution");
    assert_eq!(a.final_residual.to_bits(), b.final_residual.to_bits());
}

/// Classic dumps stay byte-identical: no request-plane keys unless a
/// serve summary was explicitly attached.
#[test]
fn classic_timeline_dump_has_no_serve_keys() {
    let cfg = base_cfg();
    let res = usec::apps::run_power_iteration(&cfg).unwrap();
    let dump = format!("{}", res.timeline.to_json());
    for key in [
        "\"requests\":",
        "\"latency_p50_ns\":",
        "\"latency_p99_ns\":",
        "\"queue_depth\":",
        "\"rows_per_s\":",
    ] {
        assert!(!dump.contains(key), "classic dump grew a serve key {key}");
    }
}
