//! Property tests over the optimization pipeline (own prop framework —
//! DESIGN.md §8): solver optimality certificates, filling-algorithm
//! invariants, quantizer conservation, and recovery guarantees, across
//! randomly generated placements / speeds / availability.

use usec::linalg::partition::quantize_fractions;
use usec::optim::{
    assignment_from_load, build_assignment, lower_bound, solve_load_matrix, SolveParams,
    SolverKind,
};
use usec::testing::prop::{gen, run, Config};

/// The LP solution must be feasible, meet the work-conservation lower
/// bound, and agree with the independent parametric-flow solver.
#[test]
fn solver_certificates_on_random_instances() {
    run(Config::default().cases(60).name("solver-certificates"), |rng| {
        let p = gen::placement(rng);
        let n = p.machines();
        let speeds = gen::speeds(rng, n);
        let avail = gen::availability(rng, n);
        let s_cnt = rng.below(3);
        let params = SolveParams {
            stragglers: s_cnt,
            solver: SolverKind::Simplex,
            ..Default::default()
        };
        if p.check_feasible(&avail, s_cnt).is_err() {
            return; // infeasible instance — covered by the error tests
        }
        let sol = solve_load_matrix(&p, &avail, &speeds, &params).unwrap();
        // structural feasibility
        sol.load.validate(&p, &avail, s_cnt, 1e-6).unwrap();
        // optimality certificate 1: meets the lower bound
        let lb = lower_bound(&p, &avail, &speeds, s_cnt);
        assert!(
            sol.time >= lb - 1e-7 * (1.0 + lb),
            "time {} below lower bound {lb}",
            sol.time
        );
        // optimality certificate 2: the independent solver agrees
        let flow = solve_load_matrix(
            &p,
            &avail,
            &speeds,
            &SolveParams {
                stragglers: s_cnt,
                solver: SolverKind::ParametricFlow,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            (sol.time - flow.time).abs() < 1e-5 * (1.0 + sol.time),
            "simplex {} vs flow {}",
            sol.time,
            flow.time
        );
    });
}

/// Filling + quantization preserves coverage exactly: every row of every
/// sub-matrix is covered by exactly `1+S` distinct machines.
#[test]
fn assignment_coverage_on_random_instances() {
    run(Config::default().cases(40).name("assignment-coverage"), |rng| {
        let p = gen::placement(rng);
        let n = p.machines();
        let speeds = gen::speeds(rng, n);
        let avail = gen::availability(rng, n);
        let s_cnt = rng.below(3);
        if p.check_feasible(&avail, s_cnt).is_err() {
            return;
        }
        let rows = 60 + rng.below(500);
        let sub_rows: Vec<usize> = (0..p.submatrices()).map(|_| rows).collect();
        let params = SolveParams {
            stragglers: s_cnt,
            ..Default::default()
        };
        let a = build_assignment(&p, &avail, &speeds, &params, &sub_rows).unwrap();
        a.validate(&sub_rows).unwrap();

        // exact coverage count per row
        for g in 0..p.submatrices() {
            let mut hits = vec![0usize; rows];
            for &m in &avail {
                for t in a.tasks_for(m).iter().filter(|t| t.g == g) {
                    for r in t.rows.lo..t.rows.hi {
                        hits[r] += 1;
                    }
                }
            }
            for (r, &h) in hits.iter().enumerate() {
                assert_eq!(h, 1 + s_cnt, "g={g} row={r} covered {h} times");
            }
        }

        // recovery: any S reporters missing still covers everything
        if s_cnt > 0 && avail.len() > s_cnt {
            let victims = rng.sample_indices(avail.len(), s_cnt);
            let reporters: Vec<usize> = avail
                .iter()
                .enumerate()
                .filter(|(i, _)| !victims.contains(i))
                .map(|(_, &m)| m)
                .collect();
            for g in 0..p.submatrices() {
                let covered: usize = a
                    .recovered_rows(g, &reporters)
                    .iter()
                    .map(|x| x.len())
                    .sum();
                assert_eq!(covered, rows, "g={g} not recoverable");
            }
        }
    });
}

/// The heterogeneous optimum is never worse than the uniform baseline
/// (it is the LP optimum; uniform is one feasible point).
#[test]
fn optimum_dominates_uniform_baseline() {
    run(Config::default().cases(50).name("optimum-dominates"), |rng| {
        let p = gen::placement(rng);
        let n = p.machines();
        let speeds = gen::speeds(rng, n);
        let avail = gen::availability(rng, n);
        if p.check_feasible(&avail, 0).is_err() {
            return;
        }
        let sol = solve_load_matrix(&p, &avail, &speeds, &SolveParams::default()).unwrap();
        let uniform =
            usec::optim::homogeneous::uniform_load_matrix(&p, &avail, 0).unwrap();
        let uniform_time = uniform.computation_time(&speeds, &avail);
        assert!(
            sol.time <= uniform_time + 1e-9,
            "optimal {} worse than uniform {uniform_time}",
            sol.time
        );
    });
}

/// Quantization conserves rows for arbitrary fraction vectors.
#[test]
fn quantizer_conservation() {
    run(Config::default().cases(200).name("quantizer"), |rng| {
        let k = 1 + rng.below(12);
        let mut fr: Vec<f64> = (0..k).map(|_| rng.f64().max(1e-9)).collect();
        let sum: f64 = fr.iter().sum();
        for f in fr.iter_mut() {
            *f /= sum;
        }
        let rows = 1 + rng.below(5000);
        let ranges = quantize_fractions(&fr, rows).unwrap();
        assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), rows);
        assert_eq!(ranges.last().unwrap().hi, rows);
        for (r, f) in ranges.iter().zip(&fr) {
            assert!(
                (r.len() as f64 - f * rows as f64).abs() < 1.0 + 1e-9,
                "range {} vs exact {}",
                r.len(),
                f * rows as f64
            );
        }
    });
}

/// Monotonicity (Remark 1): c*(S) is non-decreasing in S.
#[test]
fn straggler_tolerance_monotone() {
    run(Config::default().cases(40).name("tradeoff-monotone"), |rng| {
        let p = gen::placement(rng);
        let n = p.machines();
        let speeds = gen::speeds(rng, n);
        let avail: Vec<usize> = (0..n).collect();
        let mut last = 0.0f64;
        for s in 0..p.replication().min(3) {
            if p.check_feasible(&avail, s).is_err() {
                break;
            }
            let sol =
                solve_load_matrix(&p, &avail, &speeds, &SolveParams::with_stragglers(s))
                    .unwrap();
            assert!(
                sol.time >= last - 1e-9,
                "c*({s}) = {} < c*({}) = {last}",
                sol.time,
                s as i64 - 1
            );
            last = sol.time;
        }
    });
}

/// Elastic transition safety: re-solving after any feasible preemption
/// pattern still yields a valid assignment (no work is lost).
#[test]
fn elastic_transition_safety() {
    run(Config::default().cases(40).name("elastic-transitions"), |rng| {
        let p = gen::placement(rng);
        let n = p.machines();
        let speeds = gen::speeds(rng, n);
        let sub_rows: Vec<usize> = (0..p.submatrices()).map(|_| 120).collect();
        // random walk over availability sets
        let mut avail: Vec<usize> = (0..n).collect();
        for _ in 0..6 {
            // preempt or restore one machine
            if rng.chance(0.5) && avail.len() > 1 {
                let i = rng.below(avail.len());
                avail.remove(i);
            } else {
                let missing: Vec<usize> =
                    (0..n).filter(|m| !avail.contains(m)).collect();
                if !missing.is_empty() {
                    avail.push(missing[rng.below(missing.len())]);
                    avail.sort_unstable();
                }
            }
            if p.check_feasible(&avail, 0).is_err() {
                continue;
            }
            let a =
                build_assignment(&p, &avail, &speeds, &SolveParams::default(), &sub_rows)
                    .unwrap();
            a.validate(&sub_rows).unwrap();
            // only available machines get work
            for m in 0..n {
                if !avail.contains(&m) {
                    assert!(a.tasks_for(m).is_empty(), "preempted machine {m} got work");
                }
            }
        }
    });
}

/// Load fidelity: the filling algorithm reproduces the LP loads exactly
/// (before quantization).
#[test]
fn filling_load_fidelity() {
    run(Config::default().cases(60).name("filling-fidelity"), |rng| {
        let p = gen::placement(rng);
        let n = p.machines();
        let speeds = gen::speeds(rng, n);
        let avail: Vec<usize> = (0..n).collect();
        let s_cnt = rng.below(p.replication().min(3));
        if p.check_feasible(&avail, s_cnt).is_err() {
            return;
        }
        let params = SolveParams {
            stragglers: s_cnt,
            ..Default::default()
        };
        let sol = solve_load_matrix(&p, &avail, &speeds, &params).unwrap();
        // huge row count ⇒ quantization error → 0; compare fractional loads
        let sub_rows: Vec<usize> = (0..p.submatrices()).map(|_| 1_000_000).collect();
        let a = assignment_from_load(&p, &sol.load, s_cnt, &sub_rows).unwrap();
        let realized = a.realized_load_matrix(&sub_rows);
        for g in 0..p.submatrices() {
            for m in 0..n {
                let want = sol.load.get(g, m);
                let got = realized.get(g, m);
                assert!(
                    (want - got).abs() < 1e-4,
                    "μ[{g},{m}]: filling {got} vs LP {want}"
                );
            }
        }
    });
}
