//! Acceptance: the live telemetry plane under load. A resident
//! 3-worker TCP serve cluster exposes `/metrics`, `/healthz`, `/readyz`
//! while two tenants' requests drain; concurrent scrapes parse as valid
//! Prometheus text format with monotone counters and stable tenant
//! label sets, the final latency gauges agree with the `--json-out`
//! quantiles, and `/readyz` observes both the Stepping→Draining
//! transition and a `--chaos` crash window (503 while the crashed
//! worker is down, 200 again once the window expires and the engine's
//! backed-off readmit revives it).

use std::net::TcpListener;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use usec::config::types::RunConfig;
use usec::error::Result;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::obs::expose::sample_value;
use usec::obs::{http_get, parse_prometheus, MetricsServer, Sample, Telemetry};
use usec::placement::PlacementKind;
use usec::sched::RecoveryPolicy;
use usec::serve::{Query, ServeSession, SessionOpts};

const Q: usize = 48;
const SEED: u64 = 17;

fn start_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<Result<()>>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 1,
                    ..Default::default()
                },
            )
        }));
    }
    (addrs, handles)
}

/// Full replication (cyclic J=3 of G=3) with S=1: the cluster stays
/// dispatchable with one worker down, so chaos crash windows can expire.
fn serve_cfg(workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 3,
        n: 3,
        placement: PlacementKind::Cyclic,
        stragglers: 1,
        steps: 1,
        speeds: vec![1.0, 1.0, 1.0],
        seed: SEED,
        stream_data: !workers.is_empty(),
        recovery: RecoveryPolicy::enabled(),
        workers,
        ..Default::default()
    }
}

/// Sorted distinct tenant labels of `name` in one scrape.
fn tenant_set(samples: &[Sample], name: &str) -> Vec<String> {
    let mut vals: Vec<String> = samples
        .iter()
        .filter(|s| s.name == name)
        .filter_map(|s| s.label("tenant").map(str::to_string))
        .collect();
    vals.sort();
    vals.dedup();
    vals
}

#[test]
fn concurrent_scrapes_of_a_tcp_serve_cluster_are_valid_and_monotone() {
    let (addrs, handles) = start_workers(3);
    let cfg = serve_cfg(addrs);
    let mut session = ServeSession::build(&cfg, &SessionOpts::default()).unwrap();
    let tel = Arc::new(Telemetry::new(cfg.n, cfg.j));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let srv = MetricsServer::spawn(listener, Arc::clone(&tel)).unwrap();
    let addr = srv.addr().to_string();
    session.set_telemetry(Some(Arc::clone(&tel)));

    // two tenants; alice's pagerank rides many steps so the scraper
    // overlaps a stepping cluster, not an already-drained one
    session
        .submit(
            "alice",
            Query::Pagerank {
                seed_node: 3,
                damping: 0.85,
            },
            0.0,
            40,
        )
        .unwrap();
    session
        .submit(
            "bob",
            Query::Matvec {
                v: (0..Q).map(|i| (i as f32).sin()).collect(),
            },
            1e-6,
            1,
        )
        .unwrap();
    session
        .submit(
            "bob",
            Query::Ridge {
                b: (0..Q).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
                lambda: 3.0,
                eta: 0.13,
            },
            0.0,
            30,
        )
        .unwrap();

    // scraper thread: hammer /metrics and /readyz while the main
    // thread drains the session
    let stop = Arc::new(AtomicBool::new(false));
    let scraper = {
        let stop = Arc::clone(&stop);
        let addr = addr.clone();
        std::thread::spawn(move || {
            let mut scrapes: Vec<Vec<Sample>> = Vec::new();
            let mut ready_codes: Vec<u16> = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                let (code, body) = http_get(&addr, "/metrics", Duration::from_secs(2))
                    .expect("scrape reaches the endpoint");
                assert_eq!(code, 200);
                scrapes.push(parse_prometheus(&body).expect("valid exposition text"));
                let (code, _) = http_get(&addr, "/readyz", Duration::from_secs(2))
                    .expect("probe reaches the endpoint");
                ready_codes.push(code);
                std::thread::sleep(Duration::from_millis(2));
            }
            (scrapes, ready_codes)
        })
    };

    let responses = session.run_until_drained(2000).unwrap();
    stop.store(true, Ordering::Relaxed);
    let (scrapes, ready_codes) = scraper.join().unwrap();
    assert_eq!(responses.len(), 3);
    assert!(
        scrapes.len() >= 3,
        "expected several concurrent scrapes, got {}",
        scrapes.len()
    );

    // counters are monotone across consecutive scrapes
    let series = |name: &str, label: Option<(&str, &str)>| -> Vec<f64> {
        scrapes
            .iter()
            .filter_map(|s| sample_value(s, name, label))
            .collect()
    };
    for (name, label) in [
        ("usec_steps_total", None),
        ("usec_worker_orders_total", Some(("worker", "0"))),
        ("usec_worker_rows_total", Some(("worker", "1"))),
        ("usec_tenant_requests_total", Some(("tenant", "bob"))),
    ] {
        let vals = series(name, label);
        assert!(
            vals.windows(2).all(|w| w[1] >= w[0]),
            "{name} went backwards across scrapes: {vals:?}"
        );
    }
    let steps_seen = series("usec_steps_total", None);
    assert!(
        steps_seen.last().copied().unwrap_or(0.0) > 0.0,
        "no step ever surfaced in a scrape"
    );

    // tenant label sets are stable: empty before the first SLO tick,
    // exactly {alice, bob} from then on — never a partial set
    for s in &scrapes {
        let tenants = tenant_set(s, "usec_tenant_requests_total");
        assert!(
            tenants.is_empty() || tenants == ["alice", "bob"],
            "unstable tenant label set: {tenants:?}"
        );
    }

    // the cluster was ready the whole time it served
    assert!(!ready_codes.is_empty());
    assert!(
        ready_codes.iter().all(|&c| c == 200),
        "healthy serving flapped /readyz: {ready_codes:?}"
    );

    // final per-tenant latency gauges agree with the published snapshot
    // and bracket the --json-out quantiles (same latencies, rolling vs
    // exact quantile — generous resolution bounds, not equality)
    let (code, body) = http_get(&addr, "/metrics", Duration::from_secs(2)).unwrap();
    assert_eq!(code, 200);
    let last = parse_prometheus(&body).unwrap();
    let snap = tel.tenants();
    assert_eq!(snap.len(), 2);
    for (tenant, stats) in &snap {
        for (q, v) in [("0.5", stats.latency_p50_ns), ("0.99", stats.latency_p99_ns)] {
            let gauge = last
                .iter()
                .find(|s| {
                    s.name == "usec_tenant_latency_ns"
                        && s.label("tenant") == Some(tenant.as_str())
                        && s.label("quantile") == Some(q)
                })
                .unwrap_or_else(|| panic!("{tenant} missing latency quantile {q}"))
                .value;
            assert!(
                (gauge - v).abs() <= 1e-3 * v.abs().max(1.0),
                "{tenant} p{q} gauge {gauge} drifted from snapshot {v}"
            );
        }
    }
    let tl = session.finish().unwrap();
    let summary = tl.serve().expect("serve summary attached");
    assert_eq!(summary.requests, 3);
    let p50s: Vec<f64> = snap.values().map(|s| s.latency_p50_ns).collect();
    let p99s: Vec<f64> = snap.values().map(|s| s.latency_p99_ns).collect();
    let lo = 0.25 * p50s.iter().fold(f64::MAX, |a, &b| a.min(b));
    let hi = 4.0 * p99s.iter().fold(0.0f64, |a, &b| a.max(b));
    assert!(
        summary.latency_p50_ns >= lo && summary.latency_p99_ns <= hi,
        "summary quantiles [{}, {}] escaped the tenant gauge envelope [{lo}, {hi}]",
        summary.latency_p50_ns,
        summary.latency_p99_ns,
    );

    // Stepping→Draining observed: the drain flipped /readyz to 503
    let (code, body) = http_get(&addr, "/readyz", Duration::from_secs(2)).unwrap();
    assert_eq!(code, 503, "drained engine still reports ready");
    assert!(body.contains("draining"), "{body}");
    srv.stop();
    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn chaos_crash_window_flips_readyz_to_503_and_back() {
    // local transport; worker 2 crashes at step 2 and stays down for 2
    // chaos-observed steps. S=1 over full replication keeps the cluster
    // dispatchable meanwhile, so the window can expire and the engine's
    // backed-off readmit auto-revives the worker.
    let mut cfg = serve_cfg(vec![]);
    cfg.chaos = "crash=2@2+2".to_string();
    // fast overdue detection: the crashed step recovers in ~100ms
    cfg.recovery = RecoveryPolicy {
        enabled: true,
        overdue_factor: 0.05,
    };
    let mut session = ServeSession::build(&cfg, &SessionOpts::default()).unwrap();
    let tel = Arc::new(Telemetry::new(cfg.n, cfg.j));
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let srv = MetricsServer::spawn(listener, Arc::clone(&tel)).unwrap();
    let addr = srv.addr().to_string();
    session.set_telemetry(Some(Arc::clone(&tel)));

    // one long-riding request keeps the step loop busy across the
    // crash, the down window, and the revival
    session
        .submit(
            "alice",
            Query::Pagerank {
                seed_node: 1,
                damping: 0.85,
            },
            0.0,
            400,
        )
        .unwrap();

    let mut codes = Vec::new();
    for _ in 0..400 {
        let done = session.step_once().unwrap();
        let (code, _) = http_get(&addr, "/readyz", Duration::from_secs(2)).unwrap();
        codes.push(code);
        if !done.is_empty() {
            break;
        }
        // revived after the crash window: the probe sequence is complete
        if codes.contains(&503) && codes.last() == Some(&200) {
            break;
        }
        // give the ~50ms dial backoff wall-clock room to expire
        std::thread::sleep(Duration::from_millis(5));
    }

    assert_eq!(codes.first(), Some(&200), "cluster not ready before the crash");
    assert!(
        codes.contains(&503),
        "crash window never flipped /readyz: {codes:?}"
    );
    assert_eq!(
        codes.last(),
        Some(&200),
        "worker never auto-revived within the step budget: {codes:?}"
    );
    // the 503s form one contiguous window between the two ready phases
    let first = codes.iter().position(|&c| c == 503).unwrap();
    let last = codes.iter().rposition(|&c| c == 503).unwrap();
    assert!(
        codes[first..=last].iter().all(|&c| c == 503),
        "readiness flapped inside the crash window: {codes:?}"
    );
    assert!(tel.faults.get() >= 1, "the crash was never counted as a fault");
    assert!(
        tel.worker_alive(2),
        "telemetry still reports the revived worker dead"
    );

    srv.stop();
    session.finish().unwrap();
}
