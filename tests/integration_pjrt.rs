//! Integration tests over the PJRT backend: the full three-layer stack
//! (Pallas kernel → JAX model → HLO text → PJRT execution from the Rust
//! hot path).
//!
//! These tests require `make artifacts` (they self-skip otherwise so a
//! fresh checkout still passes `cargo test`). Artifact shapes are baked at
//! tile_rows=128, cols=q=1536 by the default Makefile.

use std::sync::Arc;
use std::time::Duration;

use usec::config::types::{AssignPolicy, BackendKind, RunConfig};
use usec::linalg::partition::submatrix_ranges;
use usec::linalg::{gen, Block};
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::runtime::{BackendSpec, Manifest};
use usec::sched::cluster::Cluster;
use usec::sched::master::{Master, MasterConfig};
use usec::sched::worker::{WorkerConfig, WorkerStorage};

fn artifacts() -> Option<(std::path::PathBuf, Manifest)> {
    let dir = usec::apps::harness::artifact_dir();
    let m = Manifest::load(&dir).ok()?;
    Some((dir, m))
}

#[test]
fn pjrt_worker_cluster_matches_host_oracle() {
    let Some((dir, manifest)) = artifacts() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    let q = manifest.cols; // square workload at the baked shape
    let g = 6;
    let n = 6;
    let placement = Placement::build(PlacementKind::Repetition, n, g, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, g).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 77));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..n)
        .map(|id| WorkerConfig {
            id,
            backend: BackendSpec::Pjrt { dir: dir.clone() },
            speed: 1.0 + id as f64 * 0.5,
            tile_rows: manifest.tile_rows,
            threads: 1,
            storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&ranges)),
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut master = Master::new(MasterConfig {
        placement,
        sub_ranges,
        params: SolveParams::default(),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: (0..n).map(|i| 1.0 + i as f64 * 0.5).collect(),
        row_cost_ns: 0,
        recovery_timeout: Duration::from_secs(120),
        recovery: usec::sched::RecoveryPolicy::default(),
    })
    .unwrap();

    let w = Arc::new(Block::single(vec![0.01f32; q]));
    let avail: Vec<usize> = (0..n).collect();
    let out = master.step(&cluster, 0, &w, &avail, &[]).unwrap();

    // oracle: host matvec
    let want = matrix.matvec(w.data()).unwrap();
    let mut max_err = 0.0f32;
    for (a, e) in out.y.iter().zip(&want) {
        max_err = max_err.max((a - e).abs());
    }
    assert!(max_err < 1e-2, "PJRT vs host max err {max_err}");
    cluster.shutdown();
}

#[test]
fn pjrt_power_iteration_converges() {
    let Some((_, manifest)) = artifacts() else {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    };
    if manifest.cols != manifest.q {
        eprintln!("skipping: artifacts not square");
        return;
    }
    let cfg = RunConfig {
        q: manifest.q,
        r: manifest.cols,
        steps: 8,
        backend: BackendKind::Pjrt,
        tile_rows: manifest.tile_rows,
        speeds: vec![1.0, 2.0, 1.5, 2.5, 1.2, 2.2],
        seed: 55,
        ..Default::default()
    };
    let res = usec::apps::run_power_iteration(&cfg).unwrap();
    // 8 steps is enough for NMSE to fall well below the random start
    let series = res.timeline.metric_series();
    assert!(
        series.last().unwrap().1 < series[0].1 * 0.5,
        "no convergence on PJRT: {series:?}"
    );
    // the eigenvalue estimate is already in the right neighbourhood
    assert!((res.eigval - res.truth_eigval).abs() < 2.0);
}
