//! Integration tests: the full master/worker pipeline on the host backend
//! under elasticity, stragglers, and failure injection.

use std::sync::Arc;
use std::time::Duration;

use usec::config::types::{AssignPolicy, RunConfig};
use usec::linalg::partition::submatrix_ranges;
use usec::linalg::gen;
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::runtime::BackendSpec;
use usec::sched::cluster::Cluster;
use usec::sched::master::{Master, MasterConfig};
use usec::sched::straggler::StraggleMode;
use usec::linalg::Block;
use usec::sched::worker::{WorkerConfig, WorkerStorage};

fn spawn(
    q: usize,
    g: usize,
    n: usize,
    j: usize,
    speeds: &[f64],
    policy: AssignPolicy,
    s: usize,
) -> (Master, Cluster, Arc<usec::linalg::Matrix>) {
    let placement = Placement::build(PlacementKind::Cyclic, n, g, j).unwrap();
    let sub_ranges = submatrix_ranges(q, g).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 21));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..n)
        .map(|id| WorkerConfig {
            id,
            backend: BackendSpec::Host,
            speed: speeds[id],
            tile_rows: 32,
            threads: 1,
            storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&ranges)),
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let master = Master::new(MasterConfig {
        placement,
        sub_ranges,
        params: SolveParams::with_stragglers(s),
        policy,
        gamma: 0.5,
        initial_speeds: speeds.to_vec(),
        row_cost_ns: 0,
        recovery_timeout: Duration::from_secs(15),
        recovery: usec::sched::RecoveryPolicy::default(),
    })
    .unwrap();
    (master, cluster, matrix)
}

#[test]
fn many_steps_remain_exact() {
    let speeds = vec![1.0, 3.0, 2.0, 5.0, 1.5, 4.0];
    let (mut master, cluster, matrix) =
        spawn(192, 6, 6, 3, &speeds, AssignPolicy::Heterogeneous, 0);
    let avail: Vec<usize> = (0..6).collect();
    let mut w = Arc::new(Block::single(vec![0.5f32; 192]));
    for step in 0..20 {
        let out = master.step(&cluster, step, &w, &avail, &[]).unwrap();
        let want = matrix.matvec(w.data()).unwrap();
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 2e-3 * (1.0 + e.abs()), "step {step}");
        }
        // feed a fresh normalized iterate
        let mut next = out.y.clone();
        usec::linalg::ops::normalize(&mut next);
        w = Arc::new(Block::single(next));
    }
    cluster.shutdown();
}

#[test]
fn churn_between_steps_is_safe() {
    // availability changes every step; results stay exact
    let speeds = vec![1.0; 6];
    let (mut master, cluster, matrix) =
        spawn(120, 6, 6, 3, &speeds, AssignPolicy::Heterogeneous, 0);
    let w = Arc::new(Block::single(vec![1.0f32; 120]));
    let want = matrix.matvec(w.data()).unwrap();
    let avail_sets: Vec<Vec<usize>> = vec![
        (0..6).collect(),
        vec![0, 1, 2, 3],
        vec![1, 2, 3, 4, 5],
        vec![0, 2, 4],     // cyclic J=3: every sub-matrix still has a replica
        (0..6).collect(),
    ];
    for (step, avail) in avail_sets.iter().enumerate() {
        let out = master.step(&cluster, step, &w, avail, &[]).unwrap();
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-3, "step {step} avail {avail:?}");
        }
    }
    cluster.shutdown();
}

#[test]
fn two_stragglers_with_s2_tolerance() {
    let speeds = vec![2.0, 1.0, 3.0, 1.0, 2.0, 1.0];
    let (mut master, cluster, matrix) =
        spawn(120, 6, 6, 3, &speeds, AssignPolicy::Heterogeneous, 2);
    let avail: Vec<usize> = (0..6).collect();
    let w = Arc::new(Block::single(vec![0.25f32; 120]));
    let want = matrix.matvec(w.data()).unwrap();
    let out = master
        .step(
            &cluster,
            0,
            &w,
            &avail,
            &[(1, StraggleMode::Drop), (4, StraggleMode::Drop)],
        )
        .unwrap();
    assert!(!out.reporters.contains(&1) && !out.reporters.contains(&4));
    for (a, e) in out.y.iter().zip(&want) {
        assert!((a - e).abs() < 1e-3);
    }
    cluster.shutdown();
}

#[test]
fn slow_stragglers_delay_but_do_not_break() {
    let speeds = vec![1.0; 6];
    let (mut master, cluster, matrix) = spawn(60, 6, 6, 3, &speeds, AssignPolicy::Heterogeneous, 1);
    let avail: Vec<usize> = (0..6).collect();
    let w = Arc::new(Block::single(vec![1.0f32; 60]));
    let want = matrix.matvec(w.data()).unwrap();
    // Slow straggler: with S=1 the master can finish without it
    let out = master
        .step(&cluster, 0, &w, &avail, &[(2, StraggleMode::Slow(50.0))])
        .unwrap();
    for (a, e) in out.y.iter().zip(&want) {
        assert!((a - e).abs() < 1e-3);
    }
    cluster.shutdown();
}

#[test]
fn stale_reports_from_previous_step_ignored() {
    // a slow straggler's report for step t arrives during step t+1 and
    // must not pollute it
    let speeds = vec![1.0; 6];
    let (mut master, cluster, matrix) = spawn(60, 6, 6, 3, &speeds, AssignPolicy::Heterogeneous, 1);
    let avail: Vec<usize> = (0..6).collect();
    let w1 = Arc::new(Block::single(vec![1.0f32; 60]));
    let w2 = Arc::new(Block::single(vec![-2.0f32; 60]));
    master
        .step(&cluster, 0, &w1, &avail, &[(0, StraggleMode::Slow(30.0))])
        .unwrap();
    // step 1 runs while worker 0 may still be sleeping on step 0's order
    let out = master.step(&cluster, 1, &w2, &avail, &[]).unwrap();
    let want = matrix.matvec(w2.data()).unwrap();
    for (a, e) in out.y.iter().zip(&want) {
        assert!((a - e).abs() < 1e-3, "stale data leaked into step 1");
    }
    cluster.shutdown();
}

#[test]
fn uniform_vs_hetero_loads_differ_under_skew() {
    let speeds = vec![1.0, 1.0, 1.0, 10.0, 10.0, 10.0];
    let (master_h, cluster_h, _) = spawn(120, 6, 6, 3, &speeds, AssignPolicy::Heterogeneous, 0);
    let (master_u, cluster_u, _) = spawn(120, 6, 6, 3, &speeds, AssignPolicy::Uniform, 0);
    let avail: Vec<usize> = (0..6).collect();
    let a_h = master_h.plan(&avail).unwrap();
    let a_u = master_u.plan(&avail).unwrap();
    let rows_h: Vec<usize> = (0..6).map(|n| a_h.rows_for(n)).collect();
    let rows_u: Vec<usize> = (0..6).map(|n| a_u.rows_for(n)).collect();
    // hetero gives the fast class (machines 3-5) strictly more rows overall
    let fast_h: usize = rows_h[3..].iter().sum();
    let fast_u: usize = rows_u[3..].iter().sum();
    assert!(fast_h > fast_u, "hetero {rows_h:?} vs uniform {rows_u:?}");
    assert!(rows_h[0] < rows_u[0]);
    cluster_h.shutdown();
    cluster_u.shutdown();
}

#[test]
fn full_run_config_pipeline_with_all_features() {
    // end-to-end through the public RunConfig API: elasticity + stragglers
    // + heterogeneous speeds + EWMA adaptation, all at once
    let cfg = RunConfig {
        q: 240,
        r: 240,
        steps: 30,
        stragglers: 1,
        injected_stragglers: 1,
        preempt_prob: 0.2,
        arrive_prob: 0.5,
        min_available: 4,
        row_cost_ns: 30_000,
        gamma: 0.6,
        speeds: vec![1.0, 2.5, 0.8, 3.0, 1.4, 2.0],
        seed: 31,
        ..Default::default()
    };
    let res = usec::apps::run_power_iteration(&cfg).unwrap();
    assert_eq!(res.timeline.len(), 30);
    assert!(
        res.final_nmse < 0.2,
        "did not converge under churn: {}",
        res.final_nmse
    );
}
