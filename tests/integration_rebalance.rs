//! Integration tests for live placement adaptation (`--rebalance`):
//! a real TCP shard cluster whose speed prior is deliberately wrong must
//! re-optimize its placement online, migrate shard rows between steps,
//! beat the static placement's wall-clock, and still match the oracle —
//! while rebalancing disabled (or numerically observed) changes nothing.

use std::net::TcpListener;
use std::thread::JoinHandle;

use usec::apps::power_iteration::run_power_iteration;
use usec::config::types::RunConfig;
use usec::error::Result;
use usec::net::daemon::{serve_worker, DaemonOpts};
use usec::placement::PlacementKind;
use usec::rebalance::RebalanceConfig;

const Q: usize = 120;
const STEPS: usize = 24;
const SEED: u64 = 19;
/// The workers' true speeds; the master starts from a uniform prior and
/// must learn the 8× skew before the drift monitor can fire.
const TRUE_SPEEDS: [f64; 3] = [8.0, 1.0, 1.0];
/// Throttle cost making the skew visible in wall-clock (2 ms/row at
/// speed 1), so the adapted placement's smaller slow-machine load shows.
const ROW_COST_NS: u64 = 2_000_000;

fn start_workers(n: usize) -> (Vec<String>, Vec<JoinHandle<Result<()>>>) {
    let mut addrs = Vec::new();
    let mut handles = Vec::new();
    for _ in 0..n {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        addrs.push(listener.local_addr().unwrap().to_string());
        handles.push(std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 1,
                    ..Default::default()
                },
            )
        }));
    }
    (addrs, handles)
}

/// Cyclic `J=2` of `G=3` over 3 workers: every worker stores 2/3 of the
/// matrix, and sub-matrix 1 starts with both replicas on slow machines —
/// the placement the drift monitor must fix.
fn base_cfg(workers: Vec<String>) -> RunConfig {
    RunConfig {
        q: Q,
        r: Q,
        g: 3,
        j: 2,
        n: 3,
        placement: PlacementKind::Cyclic,
        steps: STEPS,
        speeds: TRUE_SPEEDS.to_vec(),
        row_cost_ns: ROW_COST_NS,
        seed: SEED,
        workers,
        ..Default::default()
    }
}

#[test]
fn tcp_drift_triggers_migration_beats_static_and_matches_oracle() {
    // --- static placement over TCP (the baseline to beat) ---
    let (addrs, handles) = start_workers(3);
    let static_run = run_power_iteration(&base_cfg(addrs)).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    // --- adapted run over TCP: same wrong prior, rebalancing armed ---
    let (addrs, handles) = start_workers(3);
    let adapted_cfg = RunConfig {
        rebalance: RebalanceConfig {
            enabled: true,
            threshold: 0.1,
            budget_bytes: 1 << 20,
            ..Default::default()
        },
        ..base_cfg(addrs)
    };
    let adapted = run_power_iteration(&adapted_cfg).unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    // --- oracle: the same workload in-process, throttle off ---
    let oracle = run_power_iteration(&RunConfig {
        row_cost_ns: 0,
        workers: vec![],
        ..base_cfg(vec![])
    })
    .unwrap();

    // the wrong prior drifted far enough to fire at least one migration,
    // and every recorded move improved the rescheduled expected time
    let migrations = adapted.timeline.total_migrations();
    assert!(migrations >= 1, "no migration fired");
    assert!(adapted.timeline.total_migrated_bytes() > 0);
    let sub_bytes = (Q / 3 * Q * 4) as u64;
    for step in adapted.timeline.steps() {
        for m in &step.migrations {
            assert_eq!(m.bytes, sub_bytes, "a move ships one sub-matrix");
            assert_eq!(m.rows, Q / 3);
            assert!(
                m.expected_after < m.expected_before,
                "move did not improve the schedule: {} -> {}",
                m.expected_before,
                m.expected_after
            );
        }
    }

    // no sub-matrix ever dropped below its replica requirement: every
    // step stayed feasible and completed with full availability
    assert_eq!(adapted.timeline.len(), STEPS);
    for s in adapted.timeline.steps() {
        assert_eq!(s.available, 3, "step {} lost availability", s.step);
        assert!(s.reported > 0, "step {} was skipped as infeasible", s.step);
    }

    // storage was re-reported after the move(s): total resident bytes are
    // conserved (J replicas of every sub-matrix, wherever they live) but
    // the per-worker split left the uniform 2/3 shares
    let storage = adapted.timeline.storage_bytes().to_vec();
    assert_eq!(storage.len(), 3);
    assert_eq!(storage.iter().sum::<u64>(), (2 * Q * Q * 4) as u64);
    let uniform = (2 * Q / 3 * Q * 4) as u64;
    assert!(
        storage.iter().any(|&b| b != uniform),
        "per_worker_bytes was not re-reported after migration: {storage:?}"
    );

    // correctness: whoever computes a row computes the same row — the
    // adapted run matches the in-process oracle
    for (i, (a, e)) in adapted.eigvec.iter().zip(&oracle.eigvec).enumerate() {
        assert!(
            (a - e).abs() <= 1e-5,
            "eigvec[{i}] diverged: adapted {a} vs oracle {e}"
        );
    }
    assert!((adapted.final_nmse - oracle.final_nmse).abs() <= 1e-7);
    assert!(
        adapted.final_nmse < 0.05,
        "adapted run did not converge: {}",
        adapted.final_nmse
    );

    // the payoff: adapting storage to the measured 8x skew beats the
    // static placement's wall-clock (static strands sub-matrix 1 on the
    // two slow machines forever; the throttle makes that visible)
    let static_wall = static_run.timeline.total_wall();
    let adapted_wall = adapted.timeline.total_wall();
    assert!(
        adapted_wall < static_wall,
        "adaptation did not pay off: adapted {adapted_wall:?} vs static {static_wall:?} \
         ({migrations} migrations)"
    );
}

#[test]
fn local_rebalance_is_numerically_invisible_at_any_batch() {
    // Uncoded rows have one value whoever computes them: an adapted run
    // must reproduce the frozen-placement run bit for bit, at B=1 and
    // B>1. (Rebalance *off* is structurally identical to the pre-feature
    // code path — no monitor, no tags — so this also pins the adapted
    // path against the classic baseline.)
    for batch in [1usize, 3] {
        let classic = RunConfig {
            q: 120,
            r: 120,
            g: 6,
            j: 3,
            n: 6,
            placement: PlacementKind::Cyclic,
            steps: 16,
            batch,
            speeds: vec![16.0, 1.0, 1.0, 1.0, 1.0, 8.0],
            row_cost_ns: 0,
            seed: 5,
            ..Default::default()
        };
        let adapted_cfg = RunConfig {
            // throttle on so the EWMA learns the true 16:1 skew and the
            // monitor genuinely fires (numerics are throttle-independent)
            row_cost_ns: 300_000,
            rebalance: RebalanceConfig::enabled(),
            ..classic.clone()
        };
        let baseline = run_power_iteration(&classic).unwrap();
        let adapted = run_power_iteration(&adapted_cfg).unwrap();
        assert!(
            adapted.timeline.total_migrations() >= 1,
            "B={batch}: the 16x skew never fired a local migration"
        );
        assert_eq!(
            adapted.eigvec, baseline.eigvec,
            "B={batch}: rebalancing changed the numerics"
        );
        assert_eq!(adapted.final_nmse, baseline.final_nmse, "B={batch}");
    }
}
