//! Failure injection: dead workers, broken backends, coverage timeouts,
//! stale traffic — the unhappy paths of the coordinator.

use std::sync::Arc;
use std::time::{Duration, Instant};

use usec::config::types::AssignPolicy;
use usec::linalg::partition::submatrix_ranges;
use usec::linalg::gen;
use usec::linalg::Block;
use usec::optim::SolveParams;
use usec::placement::{Placement, PlacementKind};
use usec::runtime::BackendSpec;
use usec::sched::cluster::Cluster;
use usec::sched::master::{Master, MasterConfig};
use usec::sched::worker::{WorkerConfig, WorkerStorage};
use usec::sched::RecoveryPolicy;

fn worker_cfg(
    id: usize,
    backend: BackendSpec,
    matrix: &Arc<usec::linalg::Matrix>,
    ranges: &Arc<Vec<usec::linalg::partition::RowRange>>,
) -> WorkerConfig {
    WorkerConfig {
        id,
        backend,
        speed: 1.0,
        tile_rows: 16,
        threads: 1,
        storage: WorkerStorage::full(Arc::clone(matrix), Arc::clone(ranges)),
    }
}

fn master_cfg(
    placement: Placement,
    sub_ranges: Vec<usec::linalg::partition::RowRange>,
    s: usize,
    timeout_ms: u64,
) -> MasterConfig {
    MasterConfig {
        placement,
        sub_ranges,
        params: SolveParams::with_stragglers(s),
        policy: AssignPolicy::Heterogeneous,
        gamma: 0.5,
        initial_speeds: vec![1.0; 6],
        row_cost_ns: 0,
        recovery_timeout: Duration::from_millis(timeout_ms),
        recovery: RecoveryPolicy::default(),
    }
}

/// One worker's backend fails to initialize (bad artifact dir). With S=1
/// redundancy the step still completes from the survivors.
#[test]
fn dead_backend_survived_with_redundancy() {
    let q = 60;
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, 6).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 1));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| {
            let backend = if id == 2 {
                // nonexistent artifact dir → backend init fails → worker dies
                BackendSpec::Pjrt {
                    dir: "/nonexistent/artifacts".into(),
                }
            } else {
                BackendSpec::Host
            };
            worker_cfg(id, backend, &matrix, &ranges)
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut master = Master::new(master_cfg(placement, sub_ranges, 1, 10_000)).unwrap();
    let w = Arc::new(Block::single(vec![1.0f32; q]));
    let avail: Vec<usize> = (0..6).collect();
    let out = master.step(&cluster, 0, &w, &avail, &[]).unwrap();
    assert!(!out.reporters.contains(&2), "dead worker cannot report");
    let want = matrix.matvec(w.data()).unwrap();
    for (a, e) in out.y.iter().zip(&want) {
        assert!((a - e).abs() < 1e-3);
    }
    cluster.shutdown();
}

/// Same dead backend without redundancy: the step times out with a
/// coverage error instead of hanging or returning wrong data.
#[test]
fn dead_backend_times_out_without_redundancy() {
    let q = 60;
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, 6).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 2));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| {
            let backend = if id == 0 {
                BackendSpec::Pjrt {
                    dir: "/nonexistent/artifacts".into(),
                }
            } else {
                BackendSpec::Host
            };
            worker_cfg(id, backend, &matrix, &ranges)
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut master = Master::new(master_cfg(placement, sub_ranges, 0, 500)).unwrap();
    let w = Arc::new(Block::single(vec![1.0f32; q]));
    let avail: Vec<usize> = (0..6).collect();
    let err = master.step(&cluster, 0, &w, &avail, &[]).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("timeout"), "unexpected error: {msg}");
    cluster.shutdown();
}

/// The same dead backend without redundancy, but with mid-step recovery
/// enabled: the dead worker's rows are re-dispatched to surviving replicas
/// and the `S = 0` step completes exactly — no timeout, no decode.
#[test]
fn dead_backend_recovered_without_redundancy() {
    let q = 60;
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, 6).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 2));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| {
            let backend = if id == 0 {
                BackendSpec::Pjrt {
                    dir: "/nonexistent/artifacts".into(),
                }
            } else {
                BackendSpec::Host
            };
            worker_cfg(id, backend, &matrix, &ranges)
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut cfg = master_cfg(placement, sub_ranges, 0, 10_000);
    cfg.recovery = RecoveryPolicy::enabled();
    let mut master = Master::new(cfg).unwrap();
    let w = Arc::new(Block::single(vec![1.0f32; q]));
    let avail: Vec<usize> = (0..6).collect();
    let out = master.step(&cluster, 0, &w, &avail, &[]).unwrap();
    assert!(!out.reporters.contains(&0), "dead worker cannot report");
    assert!(!out.recoveries.is_empty());
    let ev = &out.recoveries[0];
    assert_eq!(ev.victim, 0);
    assert!(ev.rows > 0);
    assert!(!ev.rescuers.contains(&0));
    let want = matrix.matvec(w.data()).unwrap();
    for (a, e) in out.y.iter().zip(&want) {
        assert!((a - e).abs() < 1e-3);
    }
    cluster.shutdown();
}

/// When *all* replicas of some sub-matrix are dead, recovery must fail
/// fast with a clear infeasibility error instead of waiting out the full
/// coverage timeout.
#[test]
fn all_replicas_dead_recovery_fails_fast() {
    let q = 60;
    // cyclic J=3: X_0 lives exactly on machines {0, 1, 2} — kill them all
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, 6).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 5));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| {
            let backend = if id <= 2 {
                BackendSpec::Pjrt {
                    dir: "/nonexistent/artifacts".into(),
                }
            } else {
                BackendSpec::Host
            };
            worker_cfg(id, backend, &matrix, &ranges)
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut cfg = master_cfg(placement, sub_ranges, 0, 30_000);
    cfg.recovery = RecoveryPolicy::enabled();
    let mut master = Master::new(cfg).unwrap();
    let w = Arc::new(Block::single(vec![1.0f32; q]));
    let avail: Vec<usize> = (0..6).collect();
    let t0 = Instant::now();
    let err = master.step(&cluster, 0, &w, &avail, &[]).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(20),
        "did not fail fast: {:?}",
        t0.elapsed()
    );
    assert!(matches!(err, usec::Error::Infeasible(_)), "{err}");
    assert!(err.to_string().contains("no surviving replica"), "{err}");
    cluster.shutdown();
}

/// Every worker dead: the master reports a clean error.
#[test]
fn all_workers_dead_is_clean_error() {
    let q = 36;
    let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, 6).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 3));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| {
            worker_cfg(
                id,
                BackendSpec::Pjrt {
                    dir: "/nonexistent".into(),
                },
                &matrix,
                &ranges,
            )
        })
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut master = Master::new(master_cfg(placement, sub_ranges, 0, 400)).unwrap();
    let w = Arc::new(Block::single(vec![1.0f32; q]));
    let avail: Vec<usize> = (0..6).collect();
    assert!(master.step(&cluster, 0, &w, &avail, &[]).is_err());
    cluster.shutdown();
}

/// Infeasible availability (a sub-matrix with zero replicas up) is caught
/// by the solver before any work ships.
#[test]
fn infeasible_availability_rejected_up_front() {
    let q = 36;
    let placement = Placement::build(PlacementKind::Repetition, 6, 6, 3).unwrap();
    let sub_ranges = submatrix_ranges(q, 6).unwrap();
    let matrix = Arc::new(gen::random_dense(q, q, 4));
    let ranges = Arc::new(sub_ranges.clone());
    let configs: Vec<WorkerConfig> = (0..6)
        .map(|id| worker_cfg(id, BackendSpec::Host, &matrix, &ranges))
        .collect();
    let cluster = Cluster::spawn(configs).unwrap();
    let mut master = Master::new(master_cfg(placement, sub_ranges, 0, 5_000)).unwrap();
    let w = Arc::new(Block::single(vec![1.0f32; q]));
    // machines 0-2 are the only replicas of X_1..X_3; preempt all of them
    let avail = vec![3, 4, 5];
    let err = master.step(&cluster, 0, &w, &avail, &[]).unwrap_err();
    assert!(matches!(err, usec::Error::Infeasible(_)), "{err}");
    cluster.shutdown();
}

/// The harness-level run skips infeasible steps and keeps going.
#[test]
fn harness_skips_infeasible_steps() {
    use usec::config::types::RunConfig;
    let cfg = RunConfig {
        q: 120,
        r: 120,
        steps: 30,
        // aggressive preemption, min_available below feasibility sometimes
        preempt_prob: 0.6,
        arrive_prob: 0.6,
        min_available: 3,
        speeds: vec![1.0; 6],
        seed: 77,
        placement: PlacementKind::Cyclic,
        ..Default::default()
    };
    let res = usec::apps::run_power_iteration(&cfg).unwrap();
    assert_eq!(res.timeline.len(), 30);
    // with min_available = J = 3, cyclic keeps ≥1 replica per sub-matrix
    // only when the *right* 3 machines are up; some steps may be skipped
    // (reported = 0) without failing the run
    assert!(res.final_nmse.is_finite());
}

/// A worker that reports garbage speed (0/NaN) must not poison the EWMA.
#[test]
fn garbage_speed_measurements_ignored() {
    use usec::sched::SpeedEstimator;
    let mut e = SpeedEstimator::new(0.9, vec![2.0; 3]);
    e.update_all(&[(0, f64::NAN), (1, 0.0), (2, -5.0)]);
    assert_eq!(e.estimate(), &[2.0, 2.0, 2.0]);
}
