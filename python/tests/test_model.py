"""L2 correctness: tile decomposition + combine == undistributed step."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-scale, scale, size=shape), dtype=jnp.float32)


def test_tile_matvec_returns_tuple():
    (y,) = model.tile_matvec(_rand((32, 64), 0), _rand((64,), 1))
    assert y.shape == (32,)


def test_combine_normalize_unit_norm():
    y = _rand((128,), 2)
    bn, n = model.combine_normalize(y)
    np.testing.assert_allclose(float(jnp.linalg.norm(bn)), 1.0, rtol=1e-6)
    np.testing.assert_allclose(float(n), float(jnp.linalg.norm(y)), rtol=1e-6)


def test_combine_normalize_zero_vector_safe():
    bn, n = model.combine_normalize(jnp.zeros((16,), jnp.float32))
    assert float(n) == 0.0
    assert np.all(np.isfinite(np.asarray(bn)))


def test_rayleigh_dot():
    a, b = _rand((64,), 3), _rand((64,), 4)
    (d,) = model.rayleigh_dot(a, b)
    np.testing.assert_allclose(float(d), float(jnp.dot(a, b)), rtol=1e-5)


@settings(max_examples=20, deadline=None)
@given(
    q=st.sampled_from([60, 128, 384]),
    tiles=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_tiled_step_equals_local_step(q, tiles, seed):
    """Row-tiled distributed computation == one-shot local power step."""
    x = _rand((q, q), seed)
    b = _rand((q,), seed + 1)

    # distributed: split rows into `tiles` contiguous chunks (uneven ok)
    bounds = np.linspace(0, q, tiles + 1).astype(int)
    parts = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi > lo:
            (y_part,) = model.tile_matvec(x[lo:hi], b)
            parts.append(np.asarray(y_part))
    y = jnp.asarray(np.concatenate(parts))
    bn_dist, n_dist = model.combine_normalize(y)

    bn_ref, n_ref = model.power_step_local(x, b)
    np.testing.assert_allclose(np.asarray(bn_dist), np.asarray(bn_ref),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(n_dist), float(n_ref), rtol=1e-5)


def test_power_iteration_converges_on_planted_matrix():
    """End-to-end L2 check: power iteration finds a planted eigenpair."""
    rng = np.random.default_rng(7)
    q = 96
    u = rng.normal(size=q)
    u /= np.linalg.norm(u)
    lam = 10.0
    noise = rng.uniform(-0.5, 0.5, size=(q, q))
    noise = 0.05 * (noise + noise.T)
    x = jnp.asarray(lam * np.outer(u, u) + noise, dtype=jnp.float32)

    b = jnp.ones((q,), jnp.float32) / np.sqrt(q)
    for _ in range(100):
        (y,) = model.tile_matvec(x, b)
        b, n = model.combine_normalize(y)
    err = min(np.linalg.norm(np.asarray(b) - u),
              np.linalg.norm(np.asarray(b) + u))
    assert err < 0.05, f"eigvec error {err}"
    np.testing.assert_allclose(float(n), lam, rtol=0.05)


def test_ref_power_step_is_normalized():
    x = _rand((32, 32), 11)
    b = _rand((32,), 12)
    bn, _ = ref.power_step(x, b)
    np.testing.assert_allclose(float(jnp.linalg.norm(bn)), 1.0, rtol=1e-6)
