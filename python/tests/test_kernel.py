"""L1 correctness: the Pallas matvec kernel vs the pure-jnp oracle.

This is the core correctness signal for the kernel that every USEC worker
executes. Hypothesis sweeps tile shapes (divisible and ragged), value
scales, and block-size overrides; fixed cases pin the artifact shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matvec as mk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-scale, scale, size=shape), dtype=jnp.float32)


def assert_matches_ref(x, w, **kw):
    got = mk.matvec(x, w, **kw)
    want = ref.matvec(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


class TestFixedShapes:
    def test_artifact_shape_128x1536(self):
        assert_matches_ref(_rand((128, 1536), 0), _rand((1536,), 1))

    def test_single_block(self):
        assert_matches_ref(_rand((8, 16), 2), _rand((16,), 3))

    def test_one_row(self):
        assert_matches_ref(_rand((1, 64), 4), _rand((64,), 5))

    def test_one_col(self):
        assert_matches_ref(_rand((64, 1), 6), _rand((1,), 7))

    def test_zero_matrix(self):
        x = jnp.zeros((32, 32), jnp.float32)
        w = _rand((32,), 8)
        np.testing.assert_array_equal(np.asarray(mk.matvec(x, w)), np.zeros(32))

    def test_identity(self):
        x = jnp.eye(16, dtype=jnp.float32)
        w = _rand((16,), 9)
        np.testing.assert_allclose(np.asarray(mk.matvec(x, w)),
                                   np.asarray(w), rtol=1e-6)


class TestBlocking:
    def test_pick_blocks_divides(self):
        br, bc = mk.pick_blocks(128, 1536)
        assert 128 % br == 0 and 1536 % bc == 0

    def test_pick_blocks_prime_dims(self):
        br, bc = mk.pick_blocks(127, 6007)
        assert br >= 1 and bc >= 1
        assert 127 % br == 0 and 6007 % bc == 0

    def test_paper_scale_cols_6000(self):
        # 6000 is not a multiple of 256; blocking must still be exact
        br, bc = mk.pick_blocks(128, 6000)
        assert 6000 % bc == 0
        assert_matches_ref(_rand((128, 6000), 10, 0.1), _rand((6000,), 11, 0.1))

    def test_explicit_block_override(self):
        assert_matches_ref(_rand((64, 128), 12), _rand((128,), 13),
                           block_r=16, block_c=32)


@settings(max_examples=40, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=192),
    cols=st.integers(min_value=1, max_value=384),
    seed=st.integers(min_value=0, max_value=2**31),
    scale=st.sampled_from([0.01, 1.0, 100.0]),
)
def test_matvec_matches_ref_sweep(rows, cols, seed, scale):
    x = _rand((rows, cols), seed, scale)
    w = _rand((cols,), seed + 1, scale)
    got = mk.matvec(x, w)
    want = ref.matvec(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-4 * scale * scale)


@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([32, 64, 128]),
    cols=st.sampled_from([256, 512, 1536]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_matvec_artifact_family(rows, cols, seed):
    """The shapes the AOT pipeline actually bakes."""
    assert_matches_ref(_rand((rows, cols), seed), _rand((cols,), seed + 1))


def test_special_values_finite():
    """Large-but-finite values must not overflow the f32 accumulation."""
    x = jnp.full((16, 16), 1e20, jnp.float32)
    w = jnp.full((16,), 1e20, jnp.float32)
    y = mk.matvec(x, w)
    assert np.all(np.isinf(np.asarray(y)))  # documents saturation behaviour

    x = jnp.full((16, 16), 1e3, jnp.float32)
    w = jnp.full((16,), 1e3, jnp.float32)
    y = mk.matvec(x, w)
    np.testing.assert_allclose(np.asarray(y), np.full(16, 16e6), rtol=1e-6)
