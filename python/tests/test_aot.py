"""AOT pipeline: artifacts lower to loadable HLO text with stable shapes."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot


@pytest.fixture(scope="module")
def artifacts():
    return list(aot.lower_artifacts(tile_rows=32, cols=64, q=48))


def test_three_artifacts(artifacts):
    names = [n for n, _, _ in artifacts]
    assert names == ["matvec_t32_c64", "normalize_q48", "dot_q48"]


def test_hlo_text_structure(artifacts):
    for name, _meta, text in artifacts:
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        # tuple return convention (rust side unwraps with to_tuple*)
        assert "tuple" in text, name


def test_matvec_artifact_shapes(artifacts):
    name, meta, text = artifacts[0]
    assert meta["inputs"] == [[32, 64], [64]]
    assert meta["outputs"] == [[32]]
    assert "f32[32,64]" in text
    assert "f32[64]" in text


def test_normalize_artifact_has_two_outputs(artifacts):
    _, meta, text = artifacts[1]
    assert meta["outputs"] == [[48], []]
    assert "f32[48]" in text


def test_cli_writes_manifest(tmp_path):
    out = tmp_path / "arts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--outdir", str(out),
         "--tile-rows", "16", "--cols", "32", "--q", "24"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__))),
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["tile_rows"] == 16
    assert len(manifest["artifacts"]) == 3
    for a in manifest["artifacts"]:
        assert (out / a["path"]).exists()
        assert (out / a["path"]).read_text().startswith("HloModule")
