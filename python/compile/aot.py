"""AOT pipeline: lower the L2/L1 functions to HLO *text* artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
text with ``HloModuleProto::from_text_file`` and compiles it on the PJRT
CPU client. HLO text — NOT ``.serialize()`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Artifacts (shapes baked in, recorded in ``manifest.json``):

* ``matvec_t{T}_c{C}.hlo.txt``   — tile_matvec(f32[T,C], f32[C]) -> (f32[T],)
* ``normalize_q{Q}.hlo.txt``     — combine_normalize(f32[Q]) -> (f32[Q], f32)
* ``dot_q{Q}.hlo.txt``           — rayleigh_dot(f32[Q], f32[Q]) -> (f32,)
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_artifacts(tile_rows: int, cols: int, q: int):
    """Yield (name, metadata, hlo_text) for every artifact."""
    specs = [
        (
            f"matvec_t{tile_rows}_c{cols}",
            {
                "kind": "matvec",
                "tile_rows": tile_rows,
                "cols": cols,
                "inputs": [[tile_rows, cols], [cols]],
                "outputs": [[tile_rows]],
            },
            jax.jit(model.tile_matvec).lower(f32(tile_rows, cols), f32(cols)),
        ),
        (
            f"normalize_q{q}",
            {
                "kind": "normalize",
                "q": q,
                "inputs": [[q]],
                "outputs": [[q], []],
            },
            jax.jit(model.combine_normalize).lower(f32(q)),
        ),
        (
            f"dot_q{q}",
            {
                "kind": "dot",
                "q": q,
                "inputs": [[q], [q]],
                "outputs": [[]],
            },
            jax.jit(model.rayleigh_dot).lower(f32(q), f32(q)),
        ),
    ]
    for name, meta, lowered in specs:
        yield name, meta, to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts", help="artifact directory")
    ap.add_argument("--tile-rows", type=int, default=128,
                    help="rows per worker execution tile")
    ap.add_argument("--cols", type=int, default=1536, help="matrix columns r")
    ap.add_argument("--q", type=int, default=1536, help="matrix rows q")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    manifest = {
        "tile_rows": args.tile_rows,
        "cols": args.cols,
        "q": args.q,
        "artifacts": [],
    }
    for name, meta, text in lower_artifacts(args.tile_rows, args.cols, args.q):
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, path), "w") as f:
            f.write(text)
        manifest["artifacts"].append({"name": name, "path": path, **meta})
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
