"""L2: the JAX compute graph of the USEC worker and master.

Three build-time-lowered functions (all AOT-compiled to HLO text by
`aot.py`; Rust loads them via PJRT and Python never runs at request time):

* ``tile_matvec`` — the worker hot path: one assigned row tile times the
  iterate, through the L1 Pallas kernel.
* ``combine_normalize`` — the master step: normalize the assembled
  ``y = X b`` and report its norm (the power-iteration eigenvalue
  estimate as iterates converge).
* ``rayleigh_dot`` — optional eigenvalue refinement ``<b, X b>``.

``power_step_local`` is a pure-JAX reference of one *whole* step over the
full matrix, used by pytest to check that tile decomposition + combine is
exactly equivalent to the undistributed computation.
"""

import jax.numpy as jnp

from compile.kernels import matvec as matvec_kernel
from compile.kernels import ref


def tile_matvec(x_tile, w):
    """Worker: y_tile = X_tile @ w (L1 Pallas kernel). Returns a 1-tuple."""
    return (matvec_kernel.matvec(x_tile, w),)


def combine_normalize(y):
    """Master: unit-normalize the assembled product; return (b_next, norm)."""
    bn, n = ref.normalize(y)
    return (bn, n)


def rayleigh_dot(a, b):
    """Master: <a, b> for the Rayleigh-quotient eigenvalue estimate."""
    return (ref.dot(a, b),)


def power_step_local(x, b):
    """Reference: one full power-iteration step on one host (tests only)."""
    y = ref.matvec(x, b)
    bn, n = ref.normalize(y)
    return (bn, n)
