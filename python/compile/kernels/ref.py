"""Pure-jnp reference oracles for the Pallas kernels.

Every Pallas kernel in this package must agree with the corresponding
function here (pytest enforces it across a hypothesis sweep of shapes).
These references are also what the L2 model would compute without the
custom kernel, so they double as the "fusion baseline" for the perf notes.
"""

import jax.numpy as jnp


def matvec(x, w):
    """y = X @ w for a row tile X[tile_rows, cols], w[cols] -> y[tile_rows]."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def normalize(y):
    """Unit-normalize; returns (y/||y||, ||y||). Zero-safe (returns y, 0)."""
    n = jnp.linalg.norm(y)
    safe = jnp.where(n > 0.0, n, 1.0)
    return y / safe, n


def dot(a, b):
    """Rayleigh-quotient numerator <a, b>."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def power_step(x, b):
    """One full power-iteration step b <- X b / ||X b|| (test oracle)."""
    y = matvec(x, b)
    bn, n = normalize(y)
    return bn, n
