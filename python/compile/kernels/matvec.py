"""L1 Pallas kernel: tiled mat-vec `y = X @ w` for one row tile.

The kernel is the compute hot-spot of the USEC worker: each worker executes
it once per assigned row tile (`TILE_R` rows of a stored sub-matrix).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid streams
`(BLOCK_R, BLOCK_C)` blocks of the tile through VMEM and reduces over the
column dimension with an accumulation pattern (`@pl.when(k == 0)` zero-init,
`+=` thereafter). `BLOCK_R × BLOCK_C` is sized for the VMEM budget; the
`jnp.dot` inside the block maps to the MXU. `interpret=True` is mandatory on
this CPU-only image — real-TPU lowering emits a Mosaic custom-call the CPU
PJRT plugin cannot execute (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default execution-tile height (rows per PJRT execution). Must match the
#: Rust side's `tile_rows` (artifacts record it in the manifest).
DEFAULT_TILE_ROWS = 128

#: VMEM block budget: BLOCK_R×BLOCK_C f32 ≈ 64×256×4 B = 64 KiB per x-block.
DEFAULT_BLOCK_R = 64
DEFAULT_BLOCK_C = 256


def _largest_divisor_leq(n: int, cap: int) -> int:
    """Largest divisor of `n` that is ≤ cap (≥ 1)."""
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def pick_blocks(tile_rows: int, cols: int,
                block_r: int = DEFAULT_BLOCK_R,
                block_c: int = DEFAULT_BLOCK_C):
    """Choose block sizes that exactly divide the tile (no masking needed)."""
    return (_largest_divisor_leq(tile_rows, block_r),
            _largest_divisor_leq(cols, block_c))


def _matvec_kernel(x_ref, w_ref, o_ref):
    """Grid point (i, k): accumulate X[i-block] @ w[k-block] into y[i-block].

    Column blocks (`k`) form the reduction; the output block is revisited
    once per `k`, so it is zero-initialized at `k == 0`.
    """
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_r", "block_c"))
def matvec(x, w, *, block_r: int = DEFAULT_BLOCK_R, block_c: int = DEFAULT_BLOCK_C):
    """`y = x @ w` via the Pallas kernel.

    x: f32[tile_rows, cols], w: f32[cols] -> f32[tile_rows].
    Block sizes are clamped to divisors of the shape, so any shape works;
    powers of two get the intended blocking.
    """
    tile_rows, cols = x.shape
    br, bc = pick_blocks(tile_rows, cols, block_r, block_c)
    grid = (tile_rows // br, cols // bc)
    return pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, k: (i, k)),
            pl.BlockSpec((bc,), lambda i, k: (k,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, k: (i,)),
        out_shape=jax.ShapeDtypeStruct((tile_rows,), jnp.float32),
        interpret=True,  # CPU-only image; see module docstring
    )(x, w)
