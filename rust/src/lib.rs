//! # USEC — Heterogeneous Uncoded Storage Elastic Computing
//!
//! A production-quality implementation of the USEC framework of
//! Ji, Zhang & Wan (2021): elastic master/worker matrix computation over
//! *uncoded* replicated storage, with exact heterogeneous computation
//! assignment and optional straggler tolerance.
//!
//! The crate is the Layer-3 (Rust) coordinator of a three-layer stack:
//!
//! * **L1** — a Pallas tiled mat-vec kernel (build-time Python, see
//!   `python/compile/kernels/`), lowered together with
//! * **L2** — the JAX power-iteration step graph (`python/compile/model.py`)
//!   into HLO text artifacts under `artifacts/`, which
//! * **L3** — this crate loads via the PJRT CPU client ([`runtime`]) and
//!   drives from the elastic scheduler ([`sched`]). Python never runs on
//!   the request path.
//!
//! ## Core concepts
//!
//! * [`placement`] — how the `q×r` data matrix `X`, row-partitioned into
//!   `G` sub-matrices, is replicated uncoded onto `J` of `N` machines
//!   (repetition / cyclic / MAN / custom placements).
//! * [`optim`] — the paper's optimization framework: the relaxed convex
//!   program (eq. 6 / eq. 8) solved exactly (simplex + parametric-flow
//!   cross-check) and the *filling algorithm* (Algorithm 2) that converts
//!   the optimal load matrix `M*` into a concrete `1+S`-redundant
//!   computation assignment.
//! * [`sched`] — Algorithm 1: the adaptive master/worker loop with EWMA
//!   speed estimation, elasticity traces and straggler injection. The
//!   data plane is **block-batched**: a step ships `B` iterate vectors as
//!   one [`linalg::Block`] (`--batch B`), workers run the cache-blocked
//!   mat-mat kernel ([`linalg::ops::matmat_into`]) over a zero-allocation
//!   per-worker scratch arena, and `--threads T` fans each worker's tiles
//!   across a scoped thread pool (bit-identical to serial). `B = 1` is
//!   byte- and bit-identical to the classic single-vector plane. With
//!   `--recovery` ([`sched::RecoveryPolicy`]) the master also survives
//!   *mid-step* worker loss at `S = 0`: a victim's still-uncovered rows
//!   are re-planned onto surviving uncoded replicas
//!   ([`optim::recovery`]) and shipped as supplementary orders for the
//!   same step, with per-step events in [`metrics::Timeline`] /
//!   `--json-out`.
//! * [`rebalance`] — live placement adaptation: a drift monitor compares
//!   the current placement's expected time under the *live* EWMA
//!   estimates against a searched placement
//!   ([`placement::optimizer::local_search_from_samples`]) and, past a
//!   regret threshold (`--rebalance`), migrates shard rows between steps
//!   over the wire (protocol v4 `PlacementUpdate`/`MigrateAck` + the
//!   checksummed `Data` chunks) — make-before-break and byte-budgeted
//!   (`--migration-budget`), with every move recorded in
//!   [`metrics::Timeline`] / `--json-out`.
//! * [`storage`] — placement-shaped storage: the [`storage::StorageView`]
//!   trait kernels read through, implemented by both the full
//!   [`linalg::Matrix`] (local simulator mode, zero-copy shared `Arc`)
//!   and [`storage::RowShard`] (a worker's actual J-out-of-G share, with
//!   global↔local row mapping). Per-worker resident bytes surface in
//!   [`metrics::Timeline`] and `--json-out`, so the paper's storage cost
//!   is measured, not assumed.
//! * [`net`] — the pluggable master↔worker transport: in-process mpsc
//!   channels ([`net::LocalTransport`], zero-copy `Arc` data plane) or
//!   length-prefixed little-endian TCP frames ([`net::TcpTransport`] +
//!   the `usec worker` daemon) with a versioned handshake and
//!   heartbeat-based liveness, so one power-iteration run can span
//!   separate worker processes. A dropped connection is a preemption and
//!   a reconnecting daemon is re-admitted at the next step, and a master
//!   host that vanishes without FIN/RST is timed out daemon-side
//!   (`DaemonOpts::idle_timeout`) so the worker is never wedged. Workers
//!   materialize only their placed rows — generated row by row from the
//!   workload spec's row-seeded generators (peak memory = the placed
//!   share), or streamed via checksummed `Data` frames (`--stream-data`)
//!   for workloads without a deterministic generator.
//! * [`runtime`] — PJRT artifact loading/execution plus a pure-Rust host
//!   backend so everything is testable without artifacts.
//! * [`obs`] — end-to-end observability: `--trace-out PATH` writes a
//!   JSONL **event journal** (spans and instants — `step`, `solve`,
//!   `dispatch`, `order`, `recovery`, `migration`, `heartbeat_lapse` —
//!   with monotonic timestamps and step/worker/order causal ids) through
//!   a channel-fed writer thread that costs nothing when disabled.
//!   Traced orders ask workers for a **timing breakdown**
//!   ([`obs::OrderBreakdown`]: decode / compute / throttle / assemble /
//!   encode / idle), shipped back as an optional trailing section of
//!   `Report` (wire v5 — byte-identical to v4 when absent), so the
//!   journal holds both the master's observed RTT and the worker's
//!   account of it. Per-worker counters (orders, rows, bytes/frames
//!   tx/rx, reconnects, recoveries, migrations) and per-step order-RTT /
//!   compute p50/p99 land in [`metrics::Timeline`] / `--json-out`, and
//!   `usec trace` converts a journal to Chrome Trace Event Format (one
//!   track per worker) for `chrome://tracing` / Perfetto, with
//!   `--summary` printing the top time sinks. The *live* side of the
//!   same story is the telemetry plane below ([`obs::Telemetry`] /
//!   [`obs::MetricsServer`]).
//! * [`apps`] — power iteration, ridge regression and PageRank built on the
//!   elastic substrate.
//!
//! ## Pipelining
//!
//! `--pipeline` turns the master's synchronous step loop into an
//! event-driven pipeline ([`engine::ClusterEngine::run_block_split`]):
//! the combine *metric* of step `i` (MGS norms, NMSE — everything that
//! does not feed the next iterate) runs while the workers already
//! compute step `i+1`, migration bytes from `--rebalance` plans stream
//! on a dedicated transfer lane concurrently with compute (still
//! byte-budgeted, still make-before-break, swapped in at the next
//! inter-step harvest point), and one
//! [`sched::TimerWheel`] drives the heartbeat, overdue-recovery and
//! migration-ack deadlines off a single bounded `recv_timeout`. The
//! iterate trajectory is bit-identical to the synchronous loop — only
//! metric work moves across the step boundary — and each step's bought
//! overlap is reported as `timeline[i].overlap_ns` in `--json-out`.
//! With the flag off the loop, the wire traffic and the output are
//! byte-identical to the classic synchronous master.
//!
//! ## Robustness
//!
//! Three layers make elasticity *chaos-tested* rather than assumed:
//!
//! * **Seeded fault injection** ([`net::ChaosTransport`], `--chaos`) — a
//!   transport wrapper that composes over both the local and the TCP
//!   backend and injects faults from a deterministic seed
//!   (`--chaos-seed`, default `seed ^ 0xC4A0`): frame drops, delivery
//!   delays, duplication, payload corruption (caught by the codec's
//!   checksums), asymmetric partitions (`partition=W@A..B[:tx|:rx]`),
//!   slow-worker throttles (`throttle=W:F`) and crash-then-restart
//!   windows (`crash=W@S+K`). The same spec + seed replays the same
//!   fault schedule byte-for-byte; every injected fault is journaled as
//!   an [`obs`] event and counted into `timeline[i].faults`. Under
//!   chaos the coverage timeout is shortened so a lost step surfaces as
//!   a typed error in seconds, never a silent hang.
//! * **Retry with capped backoff** ([`util::retry`]) — one shared
//!   policy (capped exponential backoff, deterministic jitter) behind
//!   both TCP dial retries and the master's re-admission probes of dead
//!   workers, so a host that stays down costs `O(log)` dial attempts
//!   instead of one per step. Attempts and successes surface in the
//!   per-worker counters and `timeline[i].retries`.
//! * **Checkpoint/resume** ([`sched::checkpoint`], `--checkpoint-out` /
//!   `--resume`) — at every `--checkpoint-every`-th step boundary the
//!   master snapshots the iterate (exact `f32`/`f64` bit patterns), the
//!   EWMA speeds, and the possibly-rebalanced placement into a
//!   versioned, FNV-checksummed, workload-digested file through a
//!   journal-style writer thread (atomic temp-file + rename). A killed
//!   master restarts with `--resume <ckpt>` and — because `y_t = X w_t`
//!   is assignment-invariant — lands on the uninterrupted run's answer;
//!   damaged, truncated or wrong-job checkpoints are rejected with a
//!   typed [`Error::Checkpoint`]. Injected-straggler victims are drawn
//!   from an RNG derived from `(seed, step)` — like the chaos rolls —
//!   so a resumed run replays the uninterrupted straggler schedule
//!   exactly, `--injected-stragglers` included.
//!
//! All three flags default off and are byte-identical to the
//! pre-robustness master when off — same wire traffic, same
//! `--json-out`.
//!
//! ## Serving
//!
//! `usec serve` ([`serve`]) turns the one-job batch harness into a
//! resident multi-tenant query plane over the same elastic substrate.
//! The cluster lifecycle lives in [`engine::ClusterEngine`] (an explicit
//! `Idle → Stepping → Migrating → Draining` state machine; the classic
//! apps are [`engine::Workload`] implementations driven by
//! [`engine::ClusterEngine::run_job`]). On top, [`serve::ServeSession`]
//! runs **continuous batching**: tenant-tagged requests (personalized
//! PageRank seeds, raw mat-vec queries, ridge solves) wait in a bounded
//! admission queue ([`serve::AdmissionQueue`], typed
//! [`Error::Busy`] backpressure when full), a deficit-round-robin
//! scheduler ([`serve::DrrScheduler`]) picks fairly across tenants, and
//! picked requests' vectors coalesce into one `B`-wide iterate
//! [`linalg::Block`] per step. Requests join and leave the block at step
//! boundaries only — each column retires the moment its own residual
//! converges — so one worker dispatch serves many tenants while
//! elasticity (preemption, recovery, rebalance, chaos) keeps working
//! untouched underneath. `usec serve --listen` exposes submit/poll over
//! the framed TCP codec ([`serve::ServeClient`]); per-request latency
//! quantiles (`latency_p50_ns`/`latency_p99_ns`), request counts,
//! peak queue depth and rows/s land in [`metrics::Timeline`] /
//! `--json-out` (and its CSV twin), and per-tenant SLOs feed the
//! telemetry plane below.
//!
//! ## Observability (live)
//!
//! Where `--trace-out` is the *post-mortem* record, the telemetry plane
//! is the *live* one — and it is pure published state, not a second
//! metrics pipeline:
//!
//! * [`obs::Telemetry`] — a process-wide `Arc` of atomics and snapshot
//!   mutexes. The engine publishes its state machine, J-coverage,
//!   per-worker liveness/speed/resident bytes and counter snapshots;
//!   the serve session publishes queue depth, batch width and per-tenant
//!   SLO stats. Nothing is sampled on scrape — readers only render what
//!   writers already pushed, so the hot path cost is a handful of
//!   relaxed atomic stores.
//! * [`obs::MetricsServer`] (`--metrics-listen HOST:PORT` on
//!   `usec serve` and `usec worker`) — a minimal HTTP/1.1 listener
//!   serving `/metrics` in Prometheus text exposition format 0.0.4
//!   (counters `usec_steps_total`, `usec_worker_orders_total{worker=}`,
//!   … and gauges `usec_worker_speed`, `usec_tenant_latency_ns{tenant=,
//!   quantile=}`, …), plus the probes `/healthz` (200 while the process
//!   is up) and `/readyz` (200 only while the engine is not draining
//!   *and* the placement's J-coverage holds — i.e. the cluster could
//!   actually complete a step; 503 otherwise, e.g. inside a `--chaos`
//!   crash window).
//! * [`serve::SloTracker`] (`--slo-p99-ms`, `--slo-reject-rate`,
//!   `--slo-min-requests`, `--slo-window-ms`) — per-tenant rolling
//!   windows over answered latencies, admits and Busy rejects. Crossing
//!   a threshold journals an `slo_burn` event, bumps
//!   `usec_slo_burns_total` and flips `usec_slo_healthy{tenant=}`; the
//!   final snapshot lands as the `slo` key of the serve `--json-out`.
//! * `usec top --connect HOST:PORT` — a terminal dashboard polling a
//!   scrape endpoint and rendering per-worker and per-tenant tables,
//!   with rates differenced from consecutive scrapes.
//!
//! All of it defaults off: without `--metrics-listen` or `--slo-*`
//! flags, the wire traffic, journal and `--json-out` are byte-identical
//! to the plane never existing.
//!
//! ## Quickstart
//!
//! ```no_run
//! use usec::placement::{Placement, PlacementKind};
//! use usec::optim::{solve_load_matrix, SolveParams};
//!
//! // 6 machines, 6 sub-matrices, replication factor 3, cyclic placement.
//! let p = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
//! let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
//! let avail: Vec<usize> = (0..6).collect();
//! let sol = solve_load_matrix(&p, &avail, &speeds, &SolveParams::default()).unwrap();
//! println!("optimal computation time: {}", sol.time);
//! ```
//!
//! Watching a live cluster — start a metrics-exposing server and point
//! `usec top` at it:
//!
//! ```text
//! usec serve --listen 127.0.0.1:9000 --metrics-listen 127.0.0.1:9100 \
//!     --slo-p99-ms 50 &
//! usec top --connect 127.0.0.1:9100
//! ```

pub mod apps;
pub mod cli;
pub mod config;
pub mod csec;
pub mod engine;
pub mod error;
pub mod exp;
pub mod linalg;
pub mod metrics;
pub mod net;
pub mod obs;
pub mod optim;
pub mod placement;
pub mod rebalance;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod storage;
pub mod testing;
pub mod util;

pub use error::{Error, Result};
