//! Declarative CLI flag parser (offline replacement for `clap`) and the
//! `usec` binary's subcommand dispatch.

pub mod args;
pub mod top;

pub use args::{ArgSpec, Args};

use crate::error::Result;

/// Top-level subcommand dispatch for the `usec` binary.
pub fn dispatch(argv: &[String]) -> Result<()> {
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { &[] } else { &argv[1..] };
    match cmd {
        "run" => crate::exp::run_cli(rest),
        "master" => crate::exp::master_cli(rest),
        "worker" => crate::net::daemon::worker_cli(rest),
        "exp" => crate::exp::exp_cli(rest),
        "solve" => crate::exp::solve_cli(rest),
        "serve" => crate::serve::serve_cli(rest),
        "trace" => crate::obs::trace_cli(rest),
        "top" => top::top_cli(rest),
        "help" | "--help" | "-h" => {
            println!("{}", top_help());
            Ok(())
        }
        other => Err(crate::error::Error::Config(format!(
            "unknown subcommand '{other}' (try `usec help`)"
        ))),
    }
}

fn top_help() -> String {
    let mut s = String::from(
        "usec — Heterogeneous Uncoded Storage Elastic Computing\n\n\
         USAGE: usec <subcommand> [flags]\n\nSUBCOMMANDS:\n\
         \x20 run     run an elastic power-iteration workload end-to-end\n\
         \x20 master  distributed run over TCP worker daemons (--workers host:port,...)\n\
         \x20 worker  worker daemon serving a master over TCP (--listen host:port)\n\
         \x20 exp     regenerate a paper experiment (fig1|fig2|fig3|fig4)\n\
         \x20 solve   solve one assignment instance and print M*\n\
         \x20 serve   resident multi-tenant request server (--listen) or client (--connect)\n\
         \x20 trace   convert a --trace-out journal to Chrome trace JSON (--summary for sinks)\n\
         \x20 top     refreshing cluster view over a --metrics-listen endpoint (--connect)\n\
         \x20 help    this text\n\n",
    );
    s.push_str(&args::help_text(
        "usec run",
        "elastic run flags",
        &crate::config::RunConfig::arg_specs(),
    ));
    s
}
