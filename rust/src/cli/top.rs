//! `usec top`: a refreshing cluster view over a scrape endpoint.
//!
//! Polls `/metrics` of a `--metrics-listen` endpoint (`usec serve` or
//! `usec worker`) and renders the parsed samples as per-worker and
//! per-tenant tables: engine state, readiness, worker speeds and
//! resident bytes, in-flight orders, latency quantiles, fault counts.
//! Rates (orders/s, steps/s) come from differencing two consecutive
//! scrapes, so the first frame shows totals only.
//!
//! `--iterations N` bounds the refresh loop (tests and one-shot
//! inspection); the default refreshes until interrupted.

use std::time::Duration;

use crate::cli::args::{self, ArgSpec, Args};
use crate::error::{Error, Result};
use crate::obs::expose::sample_value;
use crate::obs::{http_get, parse_prometheus, Sample};
use crate::util::fmt;

fn top_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("connect", "", "scrape endpoint host:port (required)"),
        ArgSpec::opt("interval-ms", "1000", "refresh period"),
        ArgSpec::opt("iterations", "0", "exit after N refreshes (0 = until interrupted)"),
        ArgSpec::flag("no-clear", "append frames instead of clearing the screen"),
    ]
}

fn fmt_val(v: f64) -> String {
    if v.is_nan() {
        "-".to_string()
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{v:.0}")
    } else {
        format!("{v:.2}")
    }
}

fn fmt_ms(ns: f64) -> String {
    if ns.is_nan() {
        "-".to_string()
    } else {
        format!("{:.3}", ns / 1e6)
    }
}

/// Sorted distinct values of `label` across samples named `name`.
fn label_values(samples: &[Sample], name: &str, label: &str) -> Vec<String> {
    let mut vals: Vec<String> = samples
        .iter()
        .filter(|s| s.name == name)
        .filter_map(|s| s.label(label).map(str::to_string))
        .collect();
    vals.sort();
    vals.dedup();
    vals
}

/// One rendered frame. `prev` is the previous scrape (for rates) and
/// `dt_s` the seconds between the two.
fn render_top(samples: &[Sample], prev: Option<&[Sample]>, dt_s: f64) -> String {
    let get = |name: &str| sample_value(samples, name, None).unwrap_or(f64::NAN);
    let rate = |name: &str, label: Option<(&str, &str)>| -> f64 {
        let (Some(p), true) = (prev, dt_s > 0.0) else {
            return f64::NAN;
        };
        match (
            sample_value(samples, name, label),
            sample_value(p, name, label),
        ) {
            (Some(now), Some(before)) => (now - before).max(0.0) / dt_s,
            _ => f64::NAN,
        }
    };

    let state = samples
        .iter()
        .find(|s| s.name == "usec_engine_state" && s.value == 1.0)
        .and_then(|s| s.label("state").map(str::to_string))
        .unwrap_or_else(|| "?".to_string());
    let mut out = format!(
        "state {state}  ready {}  workers {}/{}  steps {} ({}/s)  \
         faults {}  retries {}\n",
        if get("usec_ready") == 1.0 { "yes" } else { "NO" },
        fmt_val(get("usec_workers_alive")),
        fmt_val(get("usec_workers")),
        fmt_val(get("usec_steps_total")),
        fmt_val(rate("usec_steps_total", None)),
        fmt_val(get("usec_faults_total")),
        fmt_val(get("usec_retries_total")),
    );

    let workers = label_values(samples, "usec_worker_alive", "worker");
    if !workers.is_empty() {
        let rows: Vec<Vec<String>> = workers
            .iter()
            .map(|w| {
                let l = Some(("worker", w.as_str()));
                let pick = |name: &str| {
                    sample_value(samples, name, l).unwrap_or(f64::NAN)
                };
                vec![
                    w.clone(),
                    if pick("usec_worker_alive") == 1.0 { "up" } else { "DOWN" }.to_string(),
                    fmt_val(pick("usec_worker_speed")),
                    fmt_val(pick("usec_worker_resident_bytes")),
                    fmt_val(pick("usec_worker_orders_total")),
                    fmt_val(rate("usec_worker_orders_total", l)),
                    fmt_val(pick("usec_worker_rows_total")),
                    fmt_val(pick("usec_worker_recoveries_total")),
                    fmt_val(pick("usec_worker_migrations_total")),
                ]
            })
            .collect();
        out.push('\n');
        out.push_str(&fmt::render_table(
            &[
                "worker", "state", "speed", "resident_b", "orders", "orders/s", "rows",
                "recoveries", "migrations",
            ],
            &rows,
        ));
    }

    let tenants = label_values(samples, "usec_tenant_requests_total", "tenant");
    if !tenants.is_empty() {
        out.push_str(&format!(
            "\nqueue depth {}  batch width {}  slo healthy {}  burns {}\n\n",
            fmt_val(get("usec_queue_depth")),
            fmt_val(get("usec_batch_width")),
            if get("usec_slo_healthy") == 1.0 { "yes" } else { "NO" },
            fmt_val(get("usec_slo_burns_total")),
        ));
        let rows: Vec<Vec<String>> = tenants
            .iter()
            .map(|t| {
                let l = Some(("tenant", t.as_str()));
                let pick = |name: &str| {
                    sample_value(samples, name, l).unwrap_or(f64::NAN)
                };
                let q = |quant: &str| {
                    samples
                        .iter()
                        .find(|s| {
                            s.name == "usec_tenant_latency_ns"
                                && s.label("tenant") == Some(t.as_str())
                                && s.label("quantile") == Some(quant)
                        })
                        .map_or(f64::NAN, |s| s.value)
                };
                vec![
                    t.clone(),
                    fmt_val(pick("usec_tenant_requests_total")),
                    fmt_val(pick("usec_tenant_rejects_total")),
                    fmt_val(pick("usec_tenant_inflight")),
                    fmt_val(pick("usec_tenant_queue_depth")),
                    fmt_ms(q("0.5")),
                    fmt_ms(q("0.99")),
                    fmt_val(pick("usec_tenant_rows_per_s")),
                    if pick("usec_slo_healthy") == 1.0 { "yes" } else { "NO" }.to_string(),
                ]
            })
            .collect();
        out.push_str(&fmt::render_table(
            &[
                "tenant", "requests", "rejects", "inflight", "queued", "p50_ms", "p99_ms",
                "rows/s", "healthy",
            ],
            &rows,
        ));
    }
    out
}

/// `usec top --connect host:port [--interval-ms N] [--iterations N]`.
pub fn top_cli(argv: &[String]) -> Result<()> {
    let a = Args::parse(argv, &top_specs())?;
    let addr = a.get("connect").unwrap_or("").to_string();
    if addr.is_empty() {
        println!(
            "{}",
            args::help_text(
                "usec top --connect host:port",
                "refreshing cluster view over a --metrics-listen endpoint",
                &top_specs(),
            )
        );
        return Err(Error::Config("usec top needs --connect host:port".into()));
    }
    let interval = Duration::from_millis(a.get_u64("interval-ms")?.max(10));
    let iterations = a.get_usize("iterations")?;
    let mut prev: Option<Vec<Sample>> = None;
    let mut frames = 0usize;
    loop {
        let (code, body) = http_get(&addr, "/metrics", Duration::from_secs(5))?;
        if code != 200 {
            return Err(Error::Cluster(format!(
                "scrape of {addr} returned HTTP {code}"
            )));
        }
        let samples = parse_prometheus(&body)?;
        let frame = render_top(&samples, prev.as_deref(), interval.as_secs_f64());
        if !a.has("no-clear") {
            // ANSI clear + home, like watch(1)
            print!("\x1b[2J\x1b[H");
        }
        println!("usec top — {addr}\n{frame}");
        prev = Some(samples);
        frames += 1;
        if iterations > 0 && frames >= iterations {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scrape(text: &str) -> Vec<Sample> {
        parse_prometheus(text).unwrap()
    }

    #[test]
    fn renders_worker_and_tenant_tables() {
        let now = scrape(
            "usec_ready 1\n\
             usec_engine_state{state=\"stepping\"} 1\n\
             usec_workers 3\n\
             usec_workers_alive 2\n\
             usec_steps_total 40\n\
             usec_worker_alive{worker=\"0\"} 1\n\
             usec_worker_alive{worker=\"1\"} 0\n\
             usec_worker_speed{worker=\"0\"} 2.5\n\
             usec_worker_orders_total{worker=\"0\"} 12\n\
             usec_queue_depth 3\n\
             usec_batch_width 2\n\
             usec_slo_healthy 0\n\
             usec_tenant_requests_total{tenant=\"alice\"} 7\n\
             usec_tenant_latency_ns{tenant=\"alice\",quantile=\"0.5\"} 2000000\n\
             usec_slo_healthy{tenant=\"alice\"} 0\n",
        );
        let prev = scrape(
            "usec_steps_total 30\n\
             usec_worker_orders_total{worker=\"0\"} 2\n",
        );
        let s = render_top(&now, Some(&prev), 2.0);
        assert!(s.contains("state stepping"), "{s}");
        assert!(s.contains("workers 2/3"));
        // rates: (40-30)/2 steps/s, (12-2)/2 orders/s
        assert!(s.contains("(5/s)"), "{s}");
        let w0 = s.lines().find(|l| l.starts_with('0')).unwrap();
        assert!(w0.contains("up") && w0.contains("2.5") && w0.contains('5'), "{w0}");
        let w1 = s.lines().find(|l| l.starts_with('1')).unwrap();
        assert!(w1.contains("DOWN"), "{w1}");
        let alice = s.lines().find(|l| l.starts_with("alice")).unwrap();
        assert!(alice.contains('7') && alice.contains("2.000") && alice.contains("NO"), "{alice}");
        assert!(s.contains("queue depth 3"));
    }

    #[test]
    fn first_frame_has_no_rates() {
        let now = scrape("usec_ready 1\nusec_steps_total 5\nusec_workers 1\n");
        let s = render_top(&now, None, 1.0);
        assert!(s.contains("(-/s)"), "rates dashed without a prior scrape: {s}");
    }

    #[test]
    fn cli_requires_connect() {
        assert!(top_cli(&[]).is_err());
    }
}
