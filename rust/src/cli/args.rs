//! A small declarative CLI parser (replaces `clap`, unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! subcommands, defaults, and generated `--help` text.

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// Declaration of one flag.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// `None` ⇒ boolean flag; `Some(default)` ⇒ valued flag.
    pub default: Option<String>,
}

impl ArgSpec {
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            default: None,
        }
    }
    pub fn opt(name: &'static str, default: &str, help: &'static str) -> Self {
        ArgSpec {
            name,
            help,
            default: Some(default.to_string()),
        }
    }
}

/// Parsed arguments.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse `argv` (without the program name) against the spec.
    pub fn parse(argv: &[String], specs: &[ArgSpec]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        for s in specs {
            if let Some(d) = &s.default {
                values.insert(s.name.to_string(), d.clone());
            }
        }
        let find = |name: &str| specs.iter().find(|s| s.name == name);
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = find(name)
                    .ok_or_else(|| Error::Config(format!("unknown flag --{name}")))?;
                match (&spec.default, inline) {
                    (None, None) => flags.push(name.to_string()),
                    (None, Some(v)) => {
                        return Err(Error::Config(format!(
                            "--{name} is a boolean flag (got value '{v}')"
                        )))
                    }
                    (Some(_), Some(v)) => {
                        values.insert(name.to_string(), v);
                    }
                    (Some(_), None) => {
                        i += 1;
                        let v = argv.get(i).ok_or_else(|| {
                            Error::Config(format!("--{name} expects a value"))
                        })?;
                        values.insert(name.to_string(), v.clone());
                    }
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        Ok(Args {
            values,
            flags,
            positional,
        })
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.require(name)?;
        v.parse()
            .map_err(|_| Error::Config(format!("--{name} expects an integer, got '{v}'")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.require(name)?;
        v.parse()
            .map_err(|_| Error::Config(format!("--{name} expects a number, got '{v}'")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64> {
        let v = self.require(name)?;
        v.parse()
            .map_err(|_| Error::Config(format!("--{name} expects an integer, got '{v}'")))
    }

    fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| Error::Config(format!("missing --{name}")))
    }

    /// Comma-separated list of numbers (`--speeds 1,2,4`).
    pub fn get_f64_list(&self, name: &str) -> Result<Vec<f64>> {
        let v = self.require(name)?;
        if v.is_empty() {
            return Ok(Vec::new());
        }
        v.split(',')
            .map(|p| {
                p.trim().parse().map_err(|_| {
                    Error::Config(format!("--{name}: '{p}' is not a number"))
                })
            })
            .collect()
    }
}

/// Render generated help text.
pub fn help_text(prog: &str, about: &str, specs: &[ArgSpec]) -> String {
    let mut out = format!("{prog} — {about}\n\nFLAGS:\n");
    for s in specs {
        let def = s
            .default
            .as_ref()
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        out.push_str(&format!("  --{:<18} {}{}\n", s.name, s.help, def));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec::opt("steps", "100", "number of steps"),
            ArgSpec::opt("speeds", "1,2,4", "speed vector"),
            ArgSpec::flag("verbose", "chatty output"),
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], &specs()).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 100);
        assert!(!a.has("verbose"));
    }

    #[test]
    fn space_and_equals_forms() {
        let a = Args::parse(&sv(&["--steps", "5", "--speeds=9,9"]), &specs()).unwrap();
        assert_eq!(a.get_usize("steps").unwrap(), 5);
        assert_eq!(a.get_f64_list("speeds").unwrap(), vec![9.0, 9.0]);
    }

    #[test]
    fn boolean_flags() {
        let a = Args::parse(&sv(&["--verbose"]), &specs()).unwrap();
        assert!(a.has("verbose"));
        assert!(Args::parse(&sv(&["--verbose=yes"]), &specs()).is_err());
    }

    #[test]
    fn positional_and_unknown() {
        let a = Args::parse(&sv(&["run", "--steps", "2"]), &specs()).unwrap();
        assert_eq!(a.positional(), &["run".to_string()]);
        assert!(Args::parse(&sv(&["--bogus"]), &specs()).is_err());
    }

    #[test]
    fn missing_value_and_bad_types() {
        assert!(Args::parse(&sv(&["--steps"]), &specs()).is_err());
        let a = Args::parse(&sv(&["--steps", "abc"]), &specs()).unwrap();
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn help_lists_flags() {
        let h = help_text("usec", "elastic computing", &specs());
        assert!(h.contains("--steps"));
        assert!(h.contains("[default: 100]"));
    }
}
