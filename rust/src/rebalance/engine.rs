//! The rebalancer: drives monitor → plan → transport between steps.
//!
//! [`Rebalancer::tick`] is the harness's inter-step hook. With no plan in
//! flight it consults the [`DriftMonitor`]; when a proposal fires it
//! diffs the placements into a [`MigrationPlan`] and starts executing it,
//! one budgeted batch of [`ReplicaMove`]s per window, through
//! [`Transport::migrate`]. Each acknowledged move swaps exactly one
//! replica in the returned *effective* placement
//! ([`super::plan::apply_move`]), which the caller installs in the master
//! — so assignments, recovery planning, and feasibility checks always see
//! the storage that is actually resident, and no sub-matrix ever drops
//! below its replica requirement mid-transition. A move that fails
//! (unreachable peer, lost ack) is retried at the head of the plan; after
//! [`MAX_STALLS`] consecutive stalled windows the plan is abandoned and
//! the monitor re-evaluates under whatever the cluster has become.
//!
//! The pipelined harness uses the split [`Rebalancer::tick_async`] /
//! [`Rebalancer::harvest`] pair instead: dispatch puts the budgeted
//! window on the transport's transfer lane (bytes stream concurrently
//! with worker compute) and the replica swap waits for harvest at the
//! next inter-step safe point — same budget metering, same
//! make-before-break invariant.

use crate::error::Result;
use crate::linalg::partition::RowRange;
use crate::net::{MigrationOrder, Transport};
use crate::optim::SolveParams;
use crate::placement::optimizer::expected_time_with;
use crate::placement::Placement;

use super::monitor::DriftMonitor;
use super::plan::{apply_move, MigrationPlan, ReplicaMove};
use super::RebalanceConfig;

/// Abandon an in-flight plan after this many consecutive windows whose
/// head move failed (the cluster has drifted away from the proposal).
const MAX_STALLS: u32 = 3;

/// One executed replica move, as surfaced per step in
/// [`crate::metrics::Timeline`] and `--json-out`
/// (`timeline[i].migrations` — the enclosing step record carries the
/// step number).
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationRecord {
    /// Sub-matrix moved.
    pub g: usize,
    /// Worker that lost the replica.
    pub from: usize,
    /// Worker that gained the replica.
    pub to: usize,
    /// Rows moved.
    pub rows: usize,
    /// Payload bytes moved.
    pub bytes: u64,
    /// Expected optimal time of the placement the plan started from,
    /// under the estimates that fired it.
    pub expected_before: f64,
    /// Expected optimal time of the plan's target placement (the
    /// rescheduled expected time).
    pub expected_after: f64,
}

/// Online placement adaptation driver (one per run).
pub struct Rebalancer {
    cfg: RebalanceConfig,
    monitor: DriftMonitor,
    params: SolveParams,
    sub_ranges: Vec<RowRange>,
    cols: usize,
    pending: MigrationPlan,
    /// `(expected_before, expected_after)` of the in-flight plan.
    plan_times: (f64, f64),
    stalls: u32,
    seq: u64,
    /// Moves handed to [`Transport::migrate_async`] whose completion the
    /// transfer lane has not reported yet (keyed by migration seq).
    in_flight: Vec<(u64, ReplicaMove)>,
}

impl Rebalancer {
    pub fn new(
        cfg: RebalanceConfig,
        sub_ranges: Vec<RowRange>,
        cols: usize,
        params: SolveParams,
        seed: u64,
    ) -> Result<Rebalancer> {
        cfg.validate()?;
        let monitor = DriftMonitor::new(cfg.threshold, cfg.search_iters, seed);
        Ok(Rebalancer {
            cfg,
            monitor,
            params,
            sub_ranges,
            cols,
            pending: MigrationPlan::default(),
            plan_times: (f64::NAN, f64::NAN),
            stalls: 0,
            seq: 0,
            in_flight: Vec::new(),
        })
    }

    /// Whether a migration plan is still executing (queued or on the
    /// transfer lane).
    pub fn in_transition(&self) -> bool {
        !self.pending.is_empty() || !self.in_flight.is_empty()
    }

    /// The inter-step hook: check for drift (only when no plan is in
    /// flight — a transition finishes before the monitor re-fires), then
    /// execute up to one byte-budget of pending moves. Returns the
    /// effective placement after the acknowledged moves plus one record
    /// per executed move; the caller installs the placement in the master
    /// and logs the records in the timeline.
    pub fn tick<T: Transport + ?Sized>(
        &mut self,
        step: usize,
        transport: &T,
        placement: &Placement,
        avail: &[usize],
        speeds: &[f64],
    ) -> Result<(Placement, Vec<MigrationRecord>)> {
        let mut current = placement.clone();
        if self.pending.is_empty() {
            if let Some(p) =
                self.monitor
                    .check(&current, avail, speeds, &self.params, &self.sub_ranges)?
            {
                crate::log_info!(
                    "step {step}: placement drift {:.1}% (expected time {:.4} -> {:.4}, \
                     ~{} assignment rows churn); planning migration",
                    p.regret * 100.0,
                    p.current_time,
                    p.proposed_time,
                    p.transition_rows
                );
                self.pending =
                    MigrationPlan::diff(&current, &p.placement, &self.sub_ranges, self.cols)?;
                // A budget-metered plan spreads over many windows, so ship
                // the moves that buy the most expected-time reduction per
                // shipped byte first — a tight `--migration-budget` then
                // spends its early windows where the regret is.
                let samples = vec![speeds.to_vec()];
                let params = &self.params;
                self.pending.reorder_by(|mv| {
                    move_benefit_per_byte(&current, mv, p.current_time, avail, &samples, params)
                });
                self.plan_times = (p.current_time, p.proposed_time);
                self.stalls = 0;
            }
        }
        let mut records = Vec::new();
        let mut batch: std::collections::VecDeque<_> =
            self.pending.take_batch(self.cfg.budget_bytes).into();
        while let Some(mv) = batch.pop_front() {
            self.seq += 1;
            let order = MigrationOrder {
                seq: self.seq,
                g: mv.g,
                from: mv.from,
                to: mv.to,
                rows: mv.rows,
            };
            // A queued move may outlive the availability it was planned
            // under (budget-metered plans span windows): swapping a
            // replica onto a worker the trace has preempted would shrink
            // the sub-matrix's *available* coverage, so defer it like a
            // transport failure until the worker returns or the stall
            // counter abandons the plan.
            let result = if avail.contains(&mv.to) {
                transport.migrate(&order, &self.sub_ranges)
            } else {
                Err(crate::error::Error::Cluster(format!(
                    "gaining worker {} is not in the availability set",
                    mv.to
                )))
            };
            match result {
                Ok(()) => {
                    // the copy is resident and acknowledged: swapping the
                    // replica now can only *gain* coverage mid-transition
                    current = apply_move(&current, &mv)?;
                    records.push(MigrationRecord {
                        g: mv.g,
                        from: mv.from,
                        to: mv.to,
                        rows: mv.rows.len(),
                        bytes: mv.bytes,
                        expected_before: self.plan_times.0,
                        expected_after: self.plan_times.1,
                    });
                }
                Err(e) => {
                    crate::log_warn!(
                        "step {step}: migration of sub-matrix {} ({} -> {}) failed: {e}",
                        mv.g,
                        mv.from,
                        mv.to
                    );
                    self.stalls += 1;
                    if self.stalls >= MAX_STALLS {
                        crate::log_warn!(
                            "step {step}: abandoning the migration plan after \
                             {MAX_STALLS} stalled windows ({} moves dropped)",
                            self.pending.len() + batch.len() + 1
                        );
                        self.pending = MigrationPlan::default();
                    } else {
                        // failed move first, then the unexecuted tail of
                        // the batch, ahead of whatever was already queued
                        for m in batch.drain(..).rev() {
                            self.pending.requeue_front(m);
                        }
                        self.pending.requeue_front(mv);
                    }
                    break; // don't hammer a struggling cluster this window
                }
            }
        }
        if !records.is_empty() {
            self.stalls = 0;
        }
        Ok((current, records))
    }

    /// Non-blocking variant of [`Rebalancer::tick`] for the pipelined
    /// harness: dispatches up to one byte-budget of moves through
    /// [`Transport::migrate_async`] and returns without waiting. A move
    /// the transport completed inline swaps its replica immediately (the
    /// in-process transports behave exactly like the synchronous tick);
    /// a move accepted onto a transfer lane stays pending until
    /// [`Rebalancer::harvest`] matches its completion. While any move is
    /// on the lane no new batch is dispatched and the drift monitor does
    /// not re-fire — one budgeted window at a time, same metering as the
    /// synchronous path.
    pub fn tick_async<T: Transport + ?Sized>(
        &mut self,
        step: usize,
        transport: &T,
        placement: &Placement,
        avail: &[usize],
        speeds: &[f64],
    ) -> Result<(Placement, Vec<MigrationRecord>)> {
        let mut current = placement.clone();
        if !self.in_flight.is_empty() {
            return Ok((current, Vec::new()));
        }
        if self.pending.is_empty() {
            if let Some(p) =
                self.monitor
                    .check(&current, avail, speeds, &self.params, &self.sub_ranges)?
            {
                crate::log_info!(
                    "step {step}: placement drift {:.1}% (expected time {:.4} -> {:.4}, \
                     ~{} assignment rows churn); planning migration",
                    p.regret * 100.0,
                    p.current_time,
                    p.proposed_time,
                    p.transition_rows
                );
                self.pending =
                    MigrationPlan::diff(&current, &p.placement, &self.sub_ranges, self.cols)?;
                let samples = vec![speeds.to_vec()];
                let params = &self.params;
                self.pending.reorder_by(|mv| {
                    move_benefit_per_byte(&current, mv, p.current_time, avail, &samples, params)
                });
                self.plan_times = (p.current_time, p.proposed_time);
                self.stalls = 0;
            }
        }
        let mut records = Vec::new();
        let mut batch: std::collections::VecDeque<_> =
            self.pending.take_batch(self.cfg.budget_bytes).into();
        while let Some(mv) = batch.pop_front() {
            self.seq += 1;
            let order = MigrationOrder {
                seq: self.seq,
                g: mv.g,
                from: mv.from,
                to: mv.to,
                rows: mv.rows,
            };
            let result = if avail.contains(&mv.to) {
                transport.migrate_async(&order, &self.sub_ranges)
            } else {
                Err(crate::error::Error::Cluster(format!(
                    "gaining worker {} is not in the availability set",
                    mv.to
                )))
            };
            match result {
                Ok(true) => {
                    current = apply_move(&current, &mv)?;
                    records.push(self.record(&mv));
                }
                Ok(false) => {
                    self.in_flight.push((order.seq, mv));
                }
                Err(e) => {
                    self.stall(step, mv, &mut batch, &e);
                    break; // don't hammer a struggling cluster this window
                }
            }
        }
        if !records.is_empty() {
            self.stalls = 0;
        }
        Ok((current, records))
    }

    /// Match transfer-lane completions ([`Transport::poll_migrations`]) to
    /// their in-flight moves. The pipelined harness calls this at its
    /// safe point — after collecting a step and before dispatching the
    /// next, when no orders are outstanding against the old placement —
    /// so the replica swap (and the eviction the transport enqueues
    /// behind a completed gain) never races an order that still expects
    /// the old layout. Failed moves requeue at the head of the plan with
    /// the same stall accounting as the synchronous tick.
    pub fn harvest<T: Transport + ?Sized>(
        &mut self,
        step: usize,
        transport: &T,
        placement: &Placement,
    ) -> Result<(Placement, Vec<MigrationRecord>)> {
        if self.in_flight.is_empty() {
            return Ok((placement.clone(), Vec::new()));
        }
        let mut current = placement.clone();
        let mut records = Vec::new();
        for (seq, res) in transport.poll_migrations() {
            let Some(pos) = self.in_flight.iter().position(|(s, _)| *s == seq) else {
                crate::log_warn!("step {step}: unmatched migration completion (seq {seq})");
                continue;
            };
            let (_, mv) = self.in_flight.remove(pos);
            match res {
                Ok(()) => {
                    current = apply_move(&current, &mv)?;
                    records.push(self.record(&mv));
                }
                Err(e) => {
                    let mut empty = std::collections::VecDeque::new();
                    self.stall(step, mv, &mut empty, &e);
                }
            }
        }
        if !records.is_empty() {
            self.stalls = 0;
        }
        Ok((current, records))
    }

    fn record(&self, mv: &ReplicaMove) -> MigrationRecord {
        MigrationRecord {
            g: mv.g,
            from: mv.from,
            to: mv.to,
            rows: mv.rows.len(),
            bytes: mv.bytes,
            expected_before: self.plan_times.0,
            expected_after: self.plan_times.1,
        }
    }

    /// Shared failure path: count the stall, abandon the plan after
    /// [`MAX_STALLS`], otherwise requeue the failed move (and the
    /// unexecuted tail of its batch) at the head of the plan.
    fn stall(
        &mut self,
        step: usize,
        mv: ReplicaMove,
        batch: &mut std::collections::VecDeque<ReplicaMove>,
        e: &crate::error::Error,
    ) {
        crate::log_warn!(
            "step {step}: migration of sub-matrix {} ({} -> {}) failed: {e}",
            mv.g,
            mv.from,
            mv.to
        );
        self.stalls += 1;
        if self.stalls >= MAX_STALLS {
            crate::log_warn!(
                "step {step}: abandoning the migration plan after \
                 {MAX_STALLS} stalled windows ({} moves dropped)",
                self.pending.len() + batch.len() + 1
            );
            self.pending = MigrationPlan::default();
            batch.clear();
        } else {
            for m in batch.drain(..).rev() {
                self.pending.requeue_front(m);
            }
            self.pending.requeue_front(mv);
        }
    }
}

/// Benefit-per-byte of one replica move in isolation: the expected-time
/// reduction of applying just this move to `current` (against the plan's
/// solved baseline `current_time`), divided by the bytes it ships.
/// Un-evaluable moves score `NEG_INFINITY`, sinking to the back of the
/// plan.
pub(crate) fn move_benefit_per_byte(
    current: &Placement,
    mv: &ReplicaMove,
    current_time: f64,
    avail: &[usize],
    samples: &[Vec<f64>],
    params: &SolveParams,
) -> f64 {
    let next = match apply_move(current, mv) {
        Ok(p) => p,
        Err(_) => return f64::NEG_INFINITY,
    };
    match expected_time_with(&next, avail, samples, params) {
        Ok(t) => (current_time - t) / mv.bytes.max(1) as f64,
        Err(_) => f64::NEG_INFINITY,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::Error;
    use crate::linalg::partition::submatrix_ranges;
    use crate::net::TransportEvent;
    use crate::placement::PlacementKind;
    use crate::sched::protocol::WorkOrder;
    use std::sync::Mutex;
    use std::time::Duration;

    /// Transport double: records migrations, optionally failing some.
    struct FakeTransport {
        n: usize,
        migrated: Mutex<Vec<MigrationOrder>>,
        fail_first: Mutex<u32>,
    }

    impl FakeTransport {
        fn new(n: usize, fail_first: u32) -> FakeTransport {
            FakeTransport {
                n,
                migrated: Mutex::new(Vec::new()),
                fail_first: Mutex::new(fail_first),
            }
        }
    }

    impl Transport for FakeTransport {
        fn size(&self) -> usize {
            self.n
        }
        fn alive(&self) -> Vec<bool> {
            vec![true; self.n]
        }
        fn send(&self, _worker: usize, _order: WorkOrder) -> Result<()> {
            Ok(())
        }
        fn recv_timeout(&self, _timeout: Duration) -> Result<TransportEvent> {
            Err(Error::Cluster("nothing scripted".into()))
        }
        fn drain(&self) -> Vec<TransportEvent> {
            Vec::new()
        }
        fn migrate(&self, order: &MigrationOrder, _sub_ranges: &[RowRange]) -> Result<()> {
            let mut fails = self.fail_first.lock().unwrap();
            if *fails > 0 {
                *fails -= 1;
                return Err(Error::Cluster("scripted migration failure".into()));
            }
            self.migrated.lock().unwrap().push(order.clone());
            Ok(())
        }
        fn shutdown(&mut self) {}
    }

    fn rebalancer(threshold: f64, budget: u64) -> (Rebalancer, Placement, Vec<RowRange>) {
        let placement = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
        let sub_ranges = submatrix_ranges(120, 6).unwrap();
        let rb = Rebalancer::new(
            RebalanceConfig {
                enabled: true,
                threshold,
                budget_bytes: budget,
                search_iters: 250,
            },
            sub_ranges.clone(),
            120,
            SolveParams::default(),
            7,
        )
        .unwrap();
        (rb, placement, sub_ranges)
    }

    #[test]
    fn quiet_cluster_never_migrates() {
        let (mut rb, placement, _) = rebalancer(0.15, 0);
        let t = FakeTransport::new(6, 0);
        let avail: Vec<usize> = (0..6).collect();
        for step in 0..3 {
            let (p, recs) = rb
                .tick(step, &t, &placement, &avail, &[1.0; 6])
                .unwrap();
            assert!(recs.is_empty());
            assert_eq!(p, placement);
        }
        assert!(t.migrated.lock().unwrap().is_empty());
    }

    #[test]
    fn drift_plans_and_executes_within_budget() {
        // budget of one move per window: the transition spreads over
        // several ticks, and every intermediate placement stays feasible
        let per_move = 20 * 120 * 4;
        let (mut rb, placement, _) = rebalancer(0.15, per_move);
        let t = FakeTransport::new(6, 0);
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![24.0, 16.0, 1.0, 1.0, 1.0, 1.0];
        let mut current = placement;
        let mut all = Vec::new();
        let mut converged = false;
        for step in 0..200 {
            let (p, recs) = rb.tick(step, &t, &current, &avail, &speeds).unwrap();
            assert!(recs.len() <= 1, "budget allows one move per window");
            for r in &recs {
                assert_eq!(r.rows, 20);
                assert_eq!(r.bytes, per_move as u64);
                assert!(r.expected_after < r.expected_before);
            }
            current = p;
            current.check_feasible(&avail, 0).unwrap();
            let quiet = recs.is_empty();
            all.extend(recs);
            if !all.is_empty() && quiet && !rb.in_transition() {
                converged = true; // monitor re-checked and found no drift
                break;
            }
        }
        assert!(!all.is_empty(), "strong drift must migrate");
        assert!(converged, "transition never settled");
        assert_eq!(
            all.len(),
            t.migrated.lock().unwrap().len(),
            "records mirror transport calls"
        );
        // sequence numbers are unique and increasing
        let seqs: Vec<u64> = t.migrated.lock().unwrap().iter().map(|o| o.seq).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn queued_moves_never_target_an_unavailable_worker() {
        // a budget-metered plan spans windows; a move queued while its
        // target was available must defer (not apply) if the trace has
        // preempted the target by the time its window comes
        let per_move = 20 * 120 * 4;
        let (mut rb, placement, _) = rebalancer(0.15, per_move);
        let t = FakeTransport::new(6, 0);
        let all: Vec<usize> = (0..6).collect();
        let speeds = vec![24.0, 16.0, 1.0, 1.0, 1.0, 1.0];
        // window 0: the monitor fires and the first move executes
        let (p1, recs1) = rb.tick(0, &t, &placement, &all, &speeds).unwrap();
        assert!(!recs1.is_empty(), "strong drift must fire");
        let mut current = p1;
        if rb.in_transition() {
            // the fast machines (the gains' targets) leave the
            // availability set: remaining moves must defer or, at most,
            // execute onto a still-available worker
            let restricted = vec![2usize, 3, 4, 5];
            let before = t.migrated.lock().unwrap().len();
            let (p2, recs2) = rb.tick(1, &t, &current, &restricted, &speeds).unwrap();
            for r in &recs2 {
                assert!(
                    restricted.contains(&r.to),
                    "move applied onto unavailable worker {}",
                    r.to
                );
            }
            assert_eq!(
                t.migrated.lock().unwrap().len(),
                before + recs2.len(),
                "a deferred move must not reach the transport"
            );
            current = p2;
        }
        // availability restored: the plan (or a re-fired one) completes
        for step in 2..60 {
            let (p, recs) = rb.tick(step, &t, &current, &all, &speeds).unwrap();
            current = p;
            if recs.is_empty() && !rb.in_transition() {
                break;
            }
        }
        current.check_feasible(&all, 0).unwrap();
    }

    #[test]
    fn tight_budget_front_loads_the_highest_benefit_move() {
        use super::super::plan::MigrationPlan;
        // two queued moves of equal size: g=0 hops between two slow
        // machines (≈ no benefit), g=1 lands on the one fast machine (big
        // benefit). The raw diff order ships g=0 first; benefit-per-byte
        // ordering must flip that, so a one-move budget picks g=1.
        let old = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
        let mut replicas: Vec<Vec<usize>> =
            (0..6).map(|g| old.machines_storing(g).to_vec()).collect();
        replicas[0] = vec![1, 2, 3]; // g=0: 0 → 3 (slow → slow)
        replicas[1] = vec![2, 3, 4]; // g=1: 1 → 4 (slow → fast)
        let new = Placement::from_replicas(PlacementKind::Custom, 6, replicas).unwrap();
        let subs = submatrix_ranges(120, 6).unwrap();
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![1.0, 1.0, 1.0, 1.0, 16.0, 1.0];
        let samples = vec![speeds.clone()];
        let params = SolveParams::default();

        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        assert_eq!(plan.take_batch(1)[0].g, 0, "diff order ships g=0 first");

        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        let base = crate::placement::optimizer::expected_time_with(
            &old, &avail, &samples, &params,
        )
        .unwrap();
        plan.reorder_by(|mv| move_benefit_per_byte(&old, mv, base, &avail, &samples, &params));
        let first = plan.take_batch(1);
        assert_eq!(first.len(), 1, "tight budget ships exactly one move");
        assert_eq!(
            (first[0].g, first[0].to),
            (1, 4),
            "the slow→fast move front-loads under a tight budget"
        );
    }

    /// Transport double with a fake transfer lane: `migrate_async`
    /// accepts every move (`Ok(false)`), `poll_migrations` completes
    /// them, optionally failing the first few.
    struct FakeLaneTransport {
        n: usize,
        lane: Mutex<Vec<MigrationOrder>>,
        completed: Mutex<Vec<MigrationOrder>>,
        fail_first: Mutex<u32>,
    }

    impl FakeLaneTransport {
        fn new(n: usize, fail_first: u32) -> FakeLaneTransport {
            FakeLaneTransport {
                n,
                lane: Mutex::new(Vec::new()),
                completed: Mutex::new(Vec::new()),
                fail_first: Mutex::new(fail_first),
            }
        }
    }

    impl Transport for FakeLaneTransport {
        fn size(&self) -> usize {
            self.n
        }
        fn alive(&self) -> Vec<bool> {
            vec![true; self.n]
        }
        fn send(&self, _worker: usize, _order: WorkOrder) -> Result<()> {
            Ok(())
        }
        fn recv_timeout(&self, _timeout: Duration) -> Result<TransportEvent> {
            Err(Error::Cluster("nothing scripted".into()))
        }
        fn drain(&self) -> Vec<TransportEvent> {
            Vec::new()
        }
        fn migrate(&self, _order: &MigrationOrder, _sub_ranges: &[RowRange]) -> Result<()> {
            panic!("async path must not fall back to the blocking migrate");
        }
        fn migrate_async(
            &self,
            order: &MigrationOrder,
            _sub_ranges: &[RowRange],
        ) -> Result<bool> {
            self.lane.lock().unwrap().push(order.clone());
            Ok(false)
        }
        fn poll_migrations(&self) -> Vec<(u64, Result<()>)> {
            let mut fails = self.fail_first.lock().unwrap();
            self.lane
                .lock()
                .unwrap()
                .drain(..)
                .map(|o| {
                    let seq = o.seq;
                    if *fails > 0 {
                        *fails -= 1;
                        (seq, Err(Error::Cluster("scripted lane failure".into())))
                    } else {
                        self.completed.lock().unwrap().push(o);
                        (seq, Ok(()))
                    }
                })
                .collect()
        }
        fn shutdown(&mut self) {}
    }

    #[test]
    fn async_tick_defers_the_swap_to_harvest() {
        let per_move = 20 * 120 * 4;
        let (mut rb, placement, _) = rebalancer(0.15, per_move);
        let t = FakeLaneTransport::new(6, 0);
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![24.0, 16.0, 1.0, 1.0, 1.0, 1.0];
        // dispatch window: the move goes to the lane, the placement is
        // NOT swapped yet (the copy is not resident)
        let (p1, recs1) = rb
            .tick_async(0, &t, &placement, &avail, &speeds)
            .unwrap();
        assert!(recs1.is_empty(), "no record before the lane completes");
        assert_eq!(p1, placement, "no swap before the lane completes");
        assert!(rb.in_transition());
        assert_eq!(t.lane.lock().unwrap().len(), 1, "one budgeted move");
        // another tick while the lane is busy must not dispatch more
        let (p2, recs2) = rb.tick_async(1, &t, &p1, &avail, &speeds).unwrap();
        assert!(recs2.is_empty() && p2 == p1);
        assert_eq!(t.lane.lock().unwrap().len(), 1);
        // harvest: the completed gain swaps exactly one replica
        let (p3, recs3) = rb.harvest(1, &t, &p2).unwrap();
        assert_eq!(recs3.len(), 1);
        assert_eq!(recs3[0].rows, 20);
        assert_ne!(p3, p2, "harvest installs the swap");
        p3.check_feasible(&avail, 0).unwrap();
        // the run keeps draining through dispatch/harvest pairs
        let mut current = p3;
        for step in 2..200 {
            let (p, _) = rb
                .tick_async(step, &t, &current, &avail, &speeds)
                .unwrap();
            let (p, _) = rb.harvest(step, &t, &p).unwrap();
            current = p;
            current.check_feasible(&avail, 0).unwrap();
            if !rb.in_transition() {
                break;
            }
        }
        assert!(!rb.in_transition(), "transition never drained");
        assert!(!t.completed.lock().unwrap().is_empty());
    }

    #[test]
    fn failed_lane_moves_requeue_with_stall_accounting() {
        let per_move = 20 * 120 * 4;
        let (mut rb, placement, _) = rebalancer(0.15, per_move);
        // first completion fails: the move must requeue and succeed on a
        // later window, with no replica swapped for the failure
        let t = FakeLaneTransport::new(6, 1);
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![24.0, 16.0, 1.0, 1.0, 1.0, 1.0];
        let (p1, _) = rb
            .tick_async(0, &t, &placement, &avail, &speeds)
            .unwrap();
        let (p2, recs) = rb.harvest(0, &t, &p1).unwrap();
        assert!(recs.is_empty(), "a failed lane move must not be recorded");
        assert_eq!(p2, p1, "a failed lane move must not swap replicas");
        assert!(rb.in_transition(), "the failed move requeues");
        let (p3, _) = rb.tick_async(1, &t, &p2, &avail, &speeds).unwrap();
        let (p4, recs) = rb.harvest(1, &t, &p3).unwrap();
        assert_eq!(recs.len(), 1, "the retried move completes");
        p4.check_feasible(&avail, 0).unwrap();
    }

    #[test]
    fn async_tick_on_a_sync_transport_completes_inline() {
        // the default migrate_async falls back to the blocking migrate
        // and reports inline completion — tick_async then behaves exactly
        // like tick, so transports without a lane need no changes
        let per_move = 20 * 120 * 4;
        let (mut rb, placement, _) = rebalancer(0.15, per_move);
        let t = FakeTransport::new(6, 0);
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![24.0, 16.0, 1.0, 1.0, 1.0, 1.0];
        let (p1, recs1) = rb
            .tick_async(0, &t, &placement, &avail, &speeds)
            .unwrap();
        assert_eq!(recs1.len(), 1, "inline completion records immediately");
        assert_ne!(p1, placement, "inline completion swaps immediately");
        let (p2, recs2) = rb.harvest(0, &t, &p1).unwrap();
        assert!(recs2.is_empty() && p2 == p1, "nothing left to harvest");
    }

    #[test]
    fn failed_moves_retry_then_abandon() {
        let (mut rb, placement, _) = rebalancer(0.15, 0);
        // every migrate call fails: the plan stalls and is abandoned after
        // MAX_STALLS windows instead of wedging the run
        let t = FakeTransport::new(6, u32::MAX);
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![24.0, 16.0, 1.0, 1.0, 1.0, 1.0];
        let mut fired = false;
        for step in 0..10 {
            let (p, recs) = rb.tick(step, &t, &placement, &avail, &speeds).unwrap();
            assert!(recs.is_empty(), "a failed move must not be recorded");
            assert_eq!(p, placement, "a failed move must not swap replicas");
            fired |= rb.in_transition();
            if fired && !rb.in_transition() {
                return; // abandoned — the monitor may re-fire later
            }
        }
        panic!("plan was never abandoned");
    }
}
