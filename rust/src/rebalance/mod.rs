//! Live placement adaptation: online re-optimization + shard migration.
//!
//! The paper's whole premise is that storage placement should be
//! optimized for *measured* heterogeneous speeds — yet a classic run
//! freezes the placement at job start while the master's EWMA estimator
//! ([`crate::sched::speed`]) keeps learning speeds the placement was
//! never optimized for. Because USEC storage is *uncoded*, adapting
//! online is just copying rows: no re-encoding, no decoding, plain row
//! blocks over the existing chunked `Data` machinery. This module closes
//! the loop from speed estimates back to storage:
//!
//! 1. **Drift monitor** ([`monitor::DriftMonitor`]) — between steps,
//!    evaluates the expected-time *regret* of the current placement under
//!    the live estimates ([`crate::placement::optimizer::expected_time_with`])
//!    against the best placement a local search can find
//!    ([`crate::placement::optimizer::local_search_from_samples`]), and
//!    fires when the relative regret exceeds a threshold.
//! 2. **Migration planner** ([`plan::MigrationPlan`]) — diffs the old and
//!    new [`Placement`](crate::placement::Placement) into minimal
//!    per-sub-matrix replica moves, budgeted per step
//!    (`--migration-budget` bytes) and executed make-before-break so no
//!    sub-matrix ever drops below its replica requirement mid-transition.
//!    The assignment churn the switch causes is measured with the
//!    transition-waste metric ([`crate::optim::transition`]).
//! 3. **Execution** ([`engine::Rebalancer`]) — ships each move through
//!    [`crate::net::Transport::migrate`] (wire v4:
//!    `PlacementUpdate`/`MigrateAck` + checksummed `Data` chunks over
//!    TCP; zero-copy `Arc` swaps over the local transport), swaps the
//!    replica in the master's effective placement only after the move is
//!    acknowledged, and surfaces every move in
//!    [`crate::metrics::Timeline`] / `--json-out`
//!    (`timeline[i].migrations`).
//!
//! Rebalancing off (the default) is bit-identical to the classic
//! behaviour: no monitor runs, no tags are sent, and wire v4 encodes v3
//! traffic byte-identically.

pub mod engine;
pub mod monitor;
pub mod plan;

pub use engine::{MigrationRecord, Rebalancer};
pub use monitor::{DriftMonitor, Proposal};
pub use plan::{MigrationPlan, ReplicaMove};

use crate::error::{Error, Result};

/// Rebalancing knobs (`--rebalance`, `--rebalance-threshold`,
/// `--migration-budget`).
#[derive(Debug, Clone, PartialEq)]
pub struct RebalanceConfig {
    /// Master switch. `false` (the default) is bit-identical to the
    /// classic frozen-placement behaviour.
    pub enabled: bool,
    /// Relative expected-time regret `(t_current − t_best)/t_current`
    /// that triggers a migration plan. The placement-search ablation
    /// (cyclic vs searched under strong heterogeneity) shows regrets well
    /// above 15% when the placement is stale, so the default fires on
    /// genuine drift but not on estimator noise.
    pub threshold: f64,
    /// Migration payload bytes shipped per inter-step window; a plan
    /// larger than the budget spreads over several steps (at least one
    /// move per window makes progress whatever the budget). `0` =
    /// unlimited.
    pub budget_bytes: u64,
    /// Local-search iterations per drift check.
    pub search_iters: usize,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            enabled: false,
            threshold: 0.15,
            budget_bytes: 8 << 20,
            search_iters: 120,
        }
    }
}

impl RebalanceConfig {
    /// Rebalancing on, with the default threshold and budget.
    pub fn enabled() -> Self {
        RebalanceConfig {
            enabled: true,
            ..Default::default()
        }
    }

    /// Structural sanity (checked by
    /// [`crate::config::RunConfig::validate`] and
    /// [`engine::Rebalancer::new`]).
    pub fn validate(&self) -> Result<()> {
        if self.enabled {
            if !(self.threshold > 0.0 && self.threshold < 1.0) {
                return Err(Error::Config(format!(
                    "rebalance threshold {} not in (0, 1)",
                    self.threshold
                )));
            }
            if self.search_iters == 0 {
                return Err(Error::Config(
                    "rebalance needs at least one search iteration".into(),
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        RebalanceConfig::default().validate().unwrap();
        RebalanceConfig::enabled().validate().unwrap();
        for bad in [0.0, -0.1, 1.0, 2.0] {
            let c = RebalanceConfig {
                enabled: true,
                threshold: bad,
                ..Default::default()
            };
            assert!(c.validate().is_err(), "threshold {bad} accepted");
        }
        let c = RebalanceConfig {
            enabled: true,
            search_iters: 0,
            ..Default::default()
        };
        assert!(c.validate().is_err());
        // a disabled config never consults the knobs
        let off = RebalanceConfig {
            enabled: false,
            threshold: 9.0,
            search_iters: 0,
            ..Default::default()
        };
        off.validate().unwrap();
    }
}
