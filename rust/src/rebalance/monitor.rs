//! Drift monitor: when is the current placement stale enough to move?
//!
//! Between steps the master holds two things the placement was never
//! optimized for: the live EWMA speed estimates and the live availability
//! set. The monitor evaluates the *expected-time regret* of keeping the
//! current placement — the relative gap between its optimal computation
//! time under the live estimates and the best placement a replica-move
//! local search can find ([`crate::placement::optimizer`]) — and proposes
//! the searched placement when the regret clears the threshold. The
//! assignment churn the switch would cause is measured up front with the
//! transition-waste metric ([`crate::optim::transition`]) so the caller
//! can weigh (and report) it.

use crate::error::Result;
use crate::linalg::partition::RowRange;
use crate::optim::{self, transition, SolveParams};
use crate::placement::optimizer::{expected_time_with, local_search_from_samples};
use crate::placement::Placement;

/// A placement change worth making, per the drift monitor.
#[derive(Debug, Clone)]
pub struct Proposal {
    /// The searched placement to transition to.
    pub placement: Placement,
    /// Expected optimal time of the *current* placement under the live
    /// estimates.
    pub current_time: f64,
    /// Expected optimal time of the proposed placement.
    pub proposed_time: f64,
    /// Relative regret `(current − proposed)/current` ∈ (0, 1).
    pub regret: f64,
    /// Assignment rows that would churn when adopting the proposal
    /// (transition waste under the live estimates; 0 when it could not be
    /// evaluated).
    pub transition_rows: usize,
}

/// Fires a [`Proposal`] when the live regret exceeds the threshold.
#[derive(Debug, Clone)]
pub struct DriftMonitor {
    threshold: f64,
    iters: usize,
    seed: u64,
}

impl DriftMonitor {
    pub fn new(threshold: f64, iters: usize, seed: u64) -> DriftMonitor {
        DriftMonitor {
            threshold,
            iters,
            seed,
        }
    }

    /// Evaluate the current placement against the live estimates. Returns
    /// `Ok(None)` when the placement is within the threshold of the best
    /// found, when no feasible evaluation exists under `avail` (a skipped
    /// step is not the monitor's to fix), or when search finds nothing
    /// better. Successive checks rotate the search seed so repeated calls
    /// explore different move sequences.
    pub fn check(
        &mut self,
        current: &Placement,
        avail: &[usize],
        speeds: &[f64],
        params: &SolveParams,
        sub_ranges: &[RowRange],
    ) -> Result<Option<Proposal>> {
        let samples = vec![speeds.to_vec()];
        let seed = self.seed;
        self.seed = self.seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let current_time = match expected_time_with(current, avail, &samples, params) {
            Ok(t) => t,
            Err(_) => return Ok(None), // infeasible availability: sit out
        };
        let (best, proposed_time) = local_search_from_samples(
            current,
            avail,
            &samples,
            params,
            self.iters,
            seed,
            Some(current_time), // the baseline is already solved above
        )?;
        if !(current_time.is_finite() && proposed_time.is_finite()) || current_time <= 0.0 {
            return Ok(None);
        }
        let regret = (current_time - proposed_time) / current_time;
        if regret <= self.threshold {
            return Ok(None);
        }
        let transition_rows = transition_churn(current, &best, avail, speeds, params, sub_ranges);
        Ok(Some(Proposal {
            placement: best,
            current_time,
            proposed_time,
            regret,
            transition_rows,
        }))
    }
}

/// Transition waste (in assignment rows) of switching placements under
/// the live estimates — best effort: 0 when either assignment cannot be
/// built (the switch is then justified by regret alone).
fn transition_churn(
    old: &Placement,
    new: &Placement,
    avail: &[usize],
    speeds: &[f64],
    params: &SolveParams,
    sub_ranges: &[RowRange],
) -> usize {
    let sub_rows: Vec<usize> = sub_ranges.iter().map(|r| r.len()).collect();
    let old_a = optim::build_assignment(old, avail, speeds, params, &sub_rows);
    let new_a = optim::build_assignment(new, avail, speeds, params, &sub_rows);
    match (old_a, new_a) {
        (Ok(a), Ok(b)) => transition::transition_waste(&a, &b),
        _ => 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::partition::submatrix_ranges;
    use crate::placement::PlacementKind;

    fn cyclic() -> (Placement, Vec<RowRange>) {
        (
            Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap(),
            submatrix_ranges(120, 6).unwrap(),
        )
    }

    #[test]
    fn uniform_speeds_do_not_fire() {
        let (p, subs) = cyclic();
        let mut m = DriftMonitor::new(0.15, 150, 7);
        let avail: Vec<usize> = (0..6).collect();
        let got = m
            .check(&p, &avail, &[1.0; 6], &SolveParams::default(), &subs)
            .unwrap();
        assert!(got.is_none(), "uniform speeds proposed {got:?}");
    }

    #[test]
    fn strong_skew_fires_with_consistent_numbers() {
        let (p, subs) = cyclic();
        let mut m = DriftMonitor::new(0.15, 250, 7);
        let avail: Vec<usize> = (0..6).collect();
        let speeds = vec![24.0, 16.0, 1.0, 1.0, 1.0, 1.0];
        let prop = m
            .check(&p, &avail, &speeds, &SolveParams::default(), &subs)
            .unwrap()
            .expect("strong drift must fire");
        assert!(prop.proposed_time < prop.current_time);
        assert!(prop.regret > 0.15 && prop.regret < 1.0, "{}", prop.regret);
        assert!(
            (prop.regret - (prop.current_time - prop.proposed_time) / prop.current_time).abs()
                < 1e-12
        );
        // proposal keeps the replication factor and stays feasible
        for g in 0..prop.placement.submatrices() {
            assert_eq!(prop.placement.machines_storing(g).len(), 3);
        }
        prop.placement.check_feasible(&avail, 0).unwrap();
        assert!(prop.transition_rows > 0, "a real switch churns rows");
    }

    #[test]
    fn infeasible_availability_sits_out() {
        let (p, subs) = cyclic();
        let mut m = DriftMonitor::new(0.1, 50, 1);
        // availability so thin the placement is infeasible at S=1
        let got = m
            .check(
                &p,
                &[0, 3],
                &[1.0; 6],
                &SolveParams::with_stragglers(1),
                &subs,
            )
            .unwrap();
        assert!(got.is_none());
    }
}
