//! Migration planner: diff two placements into budgeted replica moves.
//!
//! Because both placements replicate every sub-matrix exactly `J` times,
//! the diff decomposes per sub-matrix into equal-sized *added* and
//! *removed* replica sets, which pair off into [`ReplicaMove`]s: copy the
//! sub-matrix's rows to the gaining machine, then retire the losing
//! machine's copy. A move is executed make-before-break
//! ([`crate::net::Transport::migrate`]) and the effective placement swaps
//! the replica only after the copy is acknowledged
//! ([`apply_move`]), so **every intermediate placement is a valid
//! `J`-replica placement** — no sub-matrix ever has fewer live copies
//! than the replica requirement demands mid-transition.
//!
//! [`MigrationPlan::take_batch`] meters the plan against the per-step
//! byte budget (`--migration-budget`): a plan larger than the budget
//! spreads over several inter-step windows, one batch per window, always
//! making at least one move of progress.

use std::collections::{BTreeSet, VecDeque};

use crate::error::{Error, Result};
use crate::linalg::partition::RowRange;
use crate::placement::{Placement, PlacementKind};

/// One replica move: sub-matrix `g` stops living on `from` and starts
/// living on `to`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReplicaMove {
    pub g: usize,
    pub from: usize,
    pub to: usize,
    /// Global rows of sub-matrix `g`.
    pub rows: RowRange,
    /// Payload bytes the move ships (`rows · cols · 4`).
    pub bytes: u64,
}

/// An ordered queue of replica moves driving one placement to another.
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    moves: VecDeque<ReplicaMove>,
}

impl MigrationPlan {
    /// Diff `old` → `new` into replica moves. Both placements must share
    /// the machine count, sub-matrix count, and replication factor;
    /// `sub_ranges` is the global row partition and `cols` the matrix
    /// width (for the byte accounting).
    pub fn diff(
        old: &Placement,
        new: &Placement,
        sub_ranges: &[RowRange],
        cols: usize,
    ) -> Result<MigrationPlan> {
        if old.machines() != new.machines()
            || old.submatrices() != new.submatrices()
            || old.replication() != new.replication()
        {
            return Err(Error::Shape(format!(
                "placement geometry changed: N {}→{}, G {}→{}, J {}→{}",
                old.machines(),
                new.machines(),
                old.submatrices(),
                new.submatrices(),
                old.replication(),
                new.replication()
            )));
        }
        if sub_ranges.len() != old.submatrices() {
            return Err(Error::Shape(format!(
                "{} sub-ranges for G={}",
                sub_ranges.len(),
                old.submatrices()
            )));
        }
        let mut moves = VecDeque::new();
        for g in 0..old.submatrices() {
            let was: BTreeSet<usize> = old.machines_storing(g).iter().copied().collect();
            let now: BTreeSet<usize> = new.machines_storing(g).iter().copied().collect();
            let added: Vec<usize> = now.difference(&was).copied().collect();
            let removed: Vec<usize> = was.difference(&now).copied().collect();
            debug_assert_eq!(added.len(), removed.len(), "equal J on both sides");
            let rows = sub_ranges[g];
            let bytes = (rows.len() as u64) * (cols as u64) * 4;
            for (&to, &from) in added.iter().zip(&removed) {
                moves.push_back(ReplicaMove {
                    g,
                    from,
                    to,
                    rows,
                    bytes,
                });
            }
        }
        Ok(MigrationPlan { moves })
    }

    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    pub fn len(&self) -> usize {
        self.moves.len()
    }

    /// Total payload bytes still queued.
    pub fn total_bytes(&self) -> u64 {
        self.moves.iter().map(|m| m.bytes).sum()
    }

    /// Pop the next batch: scan the queue front-to-back, taking every move
    /// whose payload still fits the remaining `budget_bytes` and leaving
    /// the rest queued **in their original order** — so when
    /// [`reorder_by`](Self::reorder_by) has front-loaded benefit-per-byte,
    /// a window truncated by one oversized move still ships the later,
    /// smaller moves that fit, in benefit order, instead of stalling
    /// behind the head. The first move of a non-empty plan always ships so
    /// a small budget meters progress instead of deadlocking it. `0` =
    /// unlimited.
    pub fn take_batch(&mut self, budget_bytes: u64) -> Vec<ReplicaMove> {
        let mut batch = Vec::new();
        let mut kept = VecDeque::new();
        let mut spent = 0u64;
        while let Some(next) = self.moves.pop_front() {
            let would = spent.saturating_add(next.bytes);
            if batch.is_empty() || budget_bytes == 0 || would <= budget_bytes {
                spent = would;
                batch.push(next);
            } else {
                kept.push_back(next);
            }
        }
        self.moves = kept;
        batch
    }

    /// Push a failed move back to the head of the queue (retried first in
    /// the next window).
    pub fn requeue_front(&mut self, mv: ReplicaMove) {
        self.moves.push_front(mv);
    }

    /// Reorder the queue by descending `score` (stable, so equally scored
    /// moves keep their diff order). Used to front-load the moves with the
    /// highest benefit-per-byte, so a tight `--migration-budget` spends
    /// its first windows where they buy the most expected-time reduction.
    pub fn reorder_by<F: FnMut(&ReplicaMove) -> f64>(&mut self, mut score: F) {
        let mut scored: Vec<(f64, ReplicaMove)> =
            self.moves.drain(..).map(|m| (score(&m), m)).collect();
        scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        self.moves = scored.into_iter().map(|(_, m)| m).collect();
    }
}

/// The effective placement after one acknowledged move: replica `from` of
/// sub-matrix `g` is swapped for `to`. Validated, so an impossible swap
/// (duplicate replica) surfaces as an error instead of a corrupt state.
pub fn apply_move(p: &Placement, mv: &ReplicaMove) -> Result<Placement> {
    let mut replicas: Vec<Vec<usize>> = (0..p.submatrices())
        .map(|g| p.machines_storing(g).to_vec())
        .collect();
    let reps = replicas.get_mut(mv.g).ok_or_else(|| {
        Error::Shape(format!(
            "move references sub-matrix {} of {}",
            mv.g,
            p.submatrices()
        ))
    })?;
    let slot = reps.iter().position(|&m| m == mv.from).ok_or_else(|| {
        Error::Shape(format!(
            "machine {} stores no replica of sub-matrix {}",
            mv.from, mv.g
        ))
    })?;
    reps[slot] = mv.to;
    reps.sort_unstable();
    Placement::from_replicas(PlacementKind::Custom, p.machines(), replicas)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::partition::submatrix_ranges;

    fn placements() -> (Placement, Placement, Vec<RowRange>) {
        let old = Placement::build(PlacementKind::Cyclic, 6, 6, 3).unwrap();
        // move one replica of g=2 (machines {2,3,4}) to machine 0 and one
        // replica of g=3 (machines {3,4,5}) to machine 1
        let mut replicas: Vec<Vec<usize>> = (0..6)
            .map(|g| old.machines_storing(g).to_vec())
            .collect();
        replicas[2] = vec![0, 2, 3];
        replicas[3] = vec![1, 3, 5];
        let new = Placement::from_replicas(PlacementKind::Custom, 6, replicas).unwrap();
        (old, new, submatrix_ranges(120, 6).unwrap())
    }

    #[test]
    fn diff_pairs_added_with_removed() {
        let (old, new, subs) = placements();
        let plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        assert_eq!(plan.len(), 2);
        assert_eq!(plan.total_bytes(), 2 * 20 * 120 * 4);
        let mut plan = plan;
        let all = plan.take_batch(0);
        assert_eq!(all.len(), 2);
        assert_eq!(
            (all[0].g, all[0].to, all[0].from, all[0].rows),
            (2, 0, 4, subs[2])
        );
        assert_eq!((all[1].g, all[1].to, all[1].from), (3, 1, 4));
        // identical placements diff to an empty plan
        assert!(MigrationPlan::diff(&old, &old, &subs, 120)
            .unwrap()
            .is_empty());
    }

    #[test]
    fn batches_respect_the_byte_budget() {
        let (old, new, subs) = placements();
        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        let per_move = 20 * 120 * 4;
        // budget below one move still ships exactly one (progress), the
        // rest waits for the next window
        let b1 = plan.take_batch(per_move - 1);
        assert_eq!(b1.len(), 1);
        let b2 = plan.take_batch(per_move - 1);
        assert_eq!(b2.len(), 1);
        assert!(plan.take_batch(per_move).is_empty());
        // a budget covering both ships both at once
        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        assert_eq!(plan.take_batch(2 * per_move).len(), 2);
        // requeue puts a failed move back at the head
        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        let first = plan.take_batch(per_move)[0].clone();
        plan.requeue_front(first.clone());
        assert_eq!(plan.take_batch(per_move)[0], first);
    }

    #[test]
    fn truncation_fills_the_budget_past_an_oversized_move() {
        // a benefit-ordered queue with unequal payloads: 60 B (best
        // per byte), then 100 B, then 30 B
        fn mv(g: usize, bytes: u64) -> ReplicaMove {
            ReplicaMove {
                g,
                from: 0,
                to: 1,
                rows: RowRange::new(0, 1),
                bytes,
            }
        }
        let mut plan = MigrationPlan {
            moves: [mv(0, 60), mv(1, 100), mv(2, 30)].into_iter().collect(),
        };
        // budget 90: the 100 B move does not fit after the 60 B head, but
        // the 30 B move behind it does — the window ships both fitting
        // moves in benefit order and leaves the oversized one queued
        let batch = plan.take_batch(90);
        assert_eq!(
            batch.iter().map(|m| m.g).collect::<Vec<_>>(),
            vec![0, 2],
            "window should skip the oversized move and take the later fit"
        );
        assert_eq!(plan.len(), 1);
        // the skipped move kept its place and ships next window
        // (oversized vs the budget, so it rides the progress guarantee)
        let next = plan.take_batch(90);
        assert_eq!(next.iter().map(|m| m.g).collect::<Vec<_>>(), vec![1]);
        assert!(plan.is_empty());
        // skipped moves keep their *relative* order too
        let mut plan = MigrationPlan {
            moves: [mv(0, 50), mv(1, 80), mv(2, 70), mv(3, 40)]
                .into_iter()
                .collect(),
        };
        let batch = plan.take_batch(90);
        assert_eq!(batch.iter().map(|m| m.g).collect::<Vec<_>>(), vec![0, 3]);
        let rest = plan.take_batch(0);
        assert_eq!(rest.iter().map(|m| m.g).collect::<Vec<_>>(), vec![1, 2]);
    }

    #[test]
    fn reorder_by_is_a_stable_descending_sort() {
        let (old, new, subs) = placements();
        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        // score g=3 above g=2 → it jumps to the front of the queue
        plan.reorder_by(|m| m.g as f64);
        let all = plan.take_batch(0);
        assert_eq!((all[0].g, all[1].g), (3, 2));
        // equal scores keep the diff order (stability)
        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        plan.reorder_by(|_| 1.0);
        let all = plan.take_batch(0);
        assert_eq!((all[0].g, all[1].g), (2, 3));
    }

    #[test]
    fn every_intermediate_placement_keeps_the_replica_requirement() {
        // the make-before-break invariant: applying the plan one
        // acknowledged move at a time never leaves any sub-matrix with
        // fewer than J live replicas (here J = 1 + S for S = 2)
        let (old, new, subs) = placements();
        let mut plan = MigrationPlan::diff(&old, &new, &subs, 120).unwrap();
        let avail: Vec<usize> = (0..6).collect();
        let mut current = old.clone();
        while let Some(mv) = plan.take_batch(1).pop() {
            current = apply_move(&current, &mv).unwrap();
            assert_eq!(current.replication(), 3);
            current.check_feasible(&avail, 2).unwrap();
        }
        // the plan lands exactly on the target replica sets
        for g in 0..new.submatrices() {
            assert_eq!(current.machines_storing(g), new.machines_storing(g));
        }
    }

    #[test]
    fn apply_move_rejects_impossible_swaps() {
        let (old, _, subs) = placements();
        // machine 0 stores no replica of g=2 in the old placement
        let bad = ReplicaMove {
            g: 2,
            from: 0,
            to: 5,
            rows: subs[2],
            bytes: 0,
        };
        assert!(apply_move(&old, &bad).is_err());
        // moving onto a machine that already stores g duplicates a replica
        let dup = ReplicaMove {
            g: 2,
            from: 2,
            to: 3,
            rows: subs[2],
            bytes: 0,
        };
        assert!(apply_move(&old, &dup).is_err());
    }

    #[test]
    fn diff_rejects_geometry_changes() {
        let (old, _, subs) = placements();
        let other = Placement::build(PlacementKind::Cyclic, 6, 6, 2).unwrap();
        assert!(MigrationPlan::diff(&old, &other, &subs, 120).is_err());
        assert!(MigrationPlan::diff(&old, &old, &subs[..3], 120).is_err());
    }
}
