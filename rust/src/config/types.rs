//! Typed run configuration, buildable from CLI flags.
//!
//! One [`RunConfig`] fully determines an elastic run: geometry (`q, r, G,
//! J, N`), placement, straggler tolerance, solver, elasticity/straggler
//! randomness, speed model, backend, and seeds. Experiments construct it
//! programmatically; the `usec` binary builds it from flags.

use crate::cli::{ArgSpec, Args};
use crate::error::{Error, Result};
use crate::optim::{SolveParams, SolverKind};
use crate::placement::PlacementKind;
use crate::rebalance::RebalanceConfig;
use crate::sched::recovery::RecoveryPolicy;

/// Which compute backend workers use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Pure-Rust reference kernels (always available; test oracle).
    #[default]
    Host,
    /// PJRT CPU client running the AOT artifacts from `artifacts/`.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "host" | "rust" => Ok(BackendKind::Host),
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            other => Err(Error::Config(format!("unknown backend '{other}'"))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Host => "host",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Assignment policy for the master.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AssignPolicy {
    /// The paper's heterogeneous-optimal assignment (solve (6)/(8) + fill).
    #[default]
    Heterogeneous,
    /// Uniform speed-oblivious split (Fig. 4 baseline).
    Uniform,
    /// Paper's closed-form cyclic design for homogeneous speeds.
    CyclicHomogeneous,
}

impl AssignPolicy {
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "hetero" | "heterogeneous" | "optimal" => Ok(AssignPolicy::Heterogeneous),
            "uniform" | "homo" | "homogeneous" => Ok(AssignPolicy::Uniform),
            "cyclic" | "cyclic-homogeneous" => Ok(AssignPolicy::CyclicHomogeneous),
            other => Err(Error::Config(format!("unknown policy '{other}'"))),
        }
    }
    pub fn name(&self) -> &'static str {
        match self {
            AssignPolicy::Heterogeneous => "heterogeneous",
            AssignPolicy::Uniform => "uniform",
            AssignPolicy::CyclicHomogeneous => "cyclic-homogeneous",
        }
    }
}

/// Full configuration of an elastic run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Matrix rows (`q`) and columns (`r`).
    pub q: usize,
    pub r: usize,
    /// Sub-matrix count `G`, replication `J`, machine count `N`.
    pub g: usize,
    pub j: usize,
    pub n: usize,
    pub placement: PlacementKind,
    /// Straggler tolerance `S`.
    pub stragglers: usize,
    /// Stragglers actually injected per step (Fig. 4 bottom uses 2).
    pub injected_stragglers: usize,
    /// Injected-straggler behaviour: `0.0` ⇒ drop (never report; requires
    /// `stragglers ≥ injected` to make progress), `> 1.0` ⇒ report that
    /// factor slower (the paper's §V EC2 stragglers: slow, not lost).
    pub straggler_slowdown: f64,
    /// `true` ⇒ the same machines straggle every step (an overloaded
    /// instance), letting the EWMA learn them; `false` ⇒ fresh uniform
    /// victims per step.
    pub straggler_fixed: bool,
    pub solver: SolverKind,
    pub policy: AssignPolicy,
    pub backend: BackendKind,
    /// Computation steps `T`.
    pub steps: usize,
    /// EWMA speed-estimate factor `γ` (Algorithm 1 line 4).
    pub gamma: f64,
    /// Per-step preemption / arrival probabilities of the elasticity trace.
    pub preempt_prob: f64,
    pub arrive_prob: f64,
    /// Minimum number of machines the trace keeps available.
    pub min_available: usize,
    /// Worker speed multipliers (relative; length `N`). Empty ⇒ EC2-like
    /// defaults from [`crate::sched::speed`].
    pub speeds: Vec<f64>,
    /// Simulated per-row compute cost used by the speed throttle, in
    /// nanoseconds at speed 1.0 (0 disables throttling).
    pub row_cost_ns: u64,
    /// PJRT tile rows (must match the AOT artifact).
    pub tile_rows: usize,
    /// Iterate vectors per elastic step (block size `B`). 1 is the classic
    /// single-vector plane; larger values run block workloads (subspace /
    /// block power iteration, multi-seed PageRank) on the batched
    /// mat-mat data plane.
    pub batch: usize,
    /// Compute threads per worker for the tile fan-out (intra-worker
    /// parallelism; host backend only). 1 keeps the speed throttle's
    /// ratios meaningful and is bit-identical to the serial worker.
    pub worker_threads: usize,
    pub seed: u64,
    /// TCP worker daemon addresses (`host:port`). Empty ⇒ in-process
    /// worker threads over the zero-copy local transport; non-empty ⇒ the
    /// run dials `usec worker` daemons and `n` must equal the list length
    /// ([`RunConfig::from_args`] aligns `n` automatically).
    pub workers: Vec<String>,
    /// Stream the matrix rows to TCP workers as checksummed `Data` frames
    /// instead of regenerating them from the workload spec — required for
    /// workloads without a deterministic generator (external data), and
    /// available for any workload. Ignored in local mode.
    pub stream_data: bool,
    /// Mid-step recovery (`--recovery` / `--overdue-factor`): re-dispatch
    /// a victim's uncovered rows to surviving replicas instead of relying
    /// on `S ≥ 1` redundancy or the coverage timeout. Disabled by default
    /// (bit-identical to the classic behaviour).
    pub recovery: RecoveryPolicy,
    /// Live placement adaptation (`--rebalance` / `--rebalance-threshold`
    /// / `--migration-budget`): re-optimize the placement online from the
    /// live EWMA speed estimates and migrate shard rows between steps.
    /// Disabled by default (bit-identical to the frozen placement).
    pub rebalance: RebalanceConfig,
    /// Pipelined step loop (`--pipeline`): overlap the master-side
    /// combine/bookkeeping of step `i` with the workers' compute of step
    /// `i+1`, and stream migration bytes concurrently with compute on the
    /// transport's transfer lane. Off by default (the synchronous loop,
    /// byte-identical on the wire to the classic behaviour).
    pub pipeline: bool,
    /// Path for the machine-readable per-step timeline dump (JSON). Empty
    /// ⇒ no dump.
    pub json_out: String,
    /// Path for the JSONL tracing journal ([`crate::obs`]): spans and
    /// point events with worker-side timing breakdowns, convertible with
    /// `usec trace`. Empty ⇒ tracing off (zero overhead).
    pub trace_out: String,
    /// Seeded fault-injection schedule (`--chaos`), parsed by
    /// [`crate::net::ChaosSpec::parse`] — e.g.
    /// `"drop=0.05,delay=20:0.1,crash=2@3+2"`. Empty ⇒ no chaos wrapper,
    /// byte-identical wire traffic to the unwrapped transport.
    pub chaos: String,
    /// Seed for the chaos schedule's deterministic rolls. 0 ⇒ derive from
    /// the run seed (`seed ^ 0xC4A0`), so reruns reproduce faults
    /// byte-for-byte.
    pub chaos_seed: u64,
    /// Path the master checkpoints resumable run state to at step
    /// boundaries (`--checkpoint-out`). Empty ⇒ checkpointing off.
    pub checkpoint_out: String,
    /// Checkpoint cadence in steps (`--checkpoint-every`, with
    /// `--checkpoint-out`); 1 ⇒ every boundary.
    pub checkpoint_every: usize,
    /// Path of a checkpoint to resume from (`--resume`). Empty ⇒ fresh
    /// run. Validated against this run's workload digest at load.
    pub resume: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            q: 1536,
            r: 1536,
            g: 6,
            j: 3,
            n: 6,
            placement: PlacementKind::Repetition,
            stragglers: 0,
            injected_stragglers: 0,
            straggler_slowdown: 0.0,
            straggler_fixed: false,
            solver: SolverKind::Simplex,
            policy: AssignPolicy::Heterogeneous,
            backend: BackendKind::Host,
            steps: 50,
            gamma: 0.5,
            preempt_prob: 0.0,
            arrive_prob: 0.0,
            min_available: 0,
            speeds: Vec::new(),
            row_cost_ns: 0,
            tile_rows: 128,
            batch: 1,
            worker_threads: 1,
            seed: 7,
            workers: Vec::new(),
            stream_data: false,
            recovery: RecoveryPolicy::default(),
            rebalance: RebalanceConfig::default(),
            pipeline: false,
            json_out: String::new(),
            trace_out: String::new(),
            chaos: String::new(),
            chaos_seed: 0,
            checkpoint_out: String::new(),
            checkpoint_every: 1,
            resume: String::new(),
        }
    }
}

impl RunConfig {
    /// CLI flag declarations matching [`RunConfig::from_args`].
    pub fn arg_specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec::opt("q", "1536", "matrix rows"),
            ArgSpec::opt("r", "1536", "matrix cols"),
            ArgSpec::opt("g", "6", "sub-matrix count G"),
            ArgSpec::opt("j", "3", "replication factor J"),
            ArgSpec::opt("n", "6", "machine count N"),
            ArgSpec::opt("placement", "repetition", "repetition|cyclic|man"),
            ArgSpec::opt("stragglers", "0", "straggler tolerance S"),
            ArgSpec::opt("inject-stragglers", "0", "stragglers injected per step"),
            ArgSpec::opt(
                "straggler-slowdown",
                "0",
                "0 = drop stragglers, >1 = slow them by that factor",
            ),
            ArgSpec::flag("straggler-fixed", "same victims every step"),
            ArgSpec::opt("solver", "simplex", "simplex|flow"),
            ArgSpec::opt("policy", "hetero", "hetero|uniform|cyclic"),
            ArgSpec::opt("backend", "host", "host|pjrt"),
            ArgSpec::opt("steps", "50", "computation steps T"),
            ArgSpec::opt("gamma", "0.5", "EWMA speed factor"),
            ArgSpec::opt("preempt-prob", "0", "per-step preemption probability"),
            ArgSpec::opt("arrive-prob", "0", "per-step arrival probability"),
            ArgSpec::opt("min-available", "0", "trace keeps at least this many VMs"),
            ArgSpec::opt("speeds", "", "comma-separated speed multipliers"),
            ArgSpec::opt("row-cost-ns", "0", "simulated ns per row at speed 1"),
            ArgSpec::opt("tile-rows", "128", "PJRT tile rows (match artifacts)"),
            ArgSpec::opt("batch", "1", "iterate vectors per step (block size B)"),
            ArgSpec::opt("threads", "1", "compute threads per worker (host backend)"),
            ArgSpec::opt("seed", "7", "PRNG seed"),
            ArgSpec::opt(
                "workers",
                "",
                "comma-separated worker daemon addresses (host:port); \
                 sets N and switches to the TCP transport",
            ),
            ArgSpec::flag(
                "stream-data",
                "stream matrix rows to TCP workers instead of regenerating \
                 from the workload seed",
            ),
            ArgSpec::flag(
                "recovery",
                "re-dispatch a mid-step victim's uncovered rows to \
                 surviving replicas (finish the step instead of timing out)",
            ),
            ArgSpec::opt(
                "overdue-factor",
                "0.5",
                "declare a silent worker overdue after this fraction of \
                 the recovery timeout (with --recovery)",
            ),
            ArgSpec::flag(
                "rebalance",
                "re-optimize the placement online from live speed \
                 estimates and migrate shard rows between steps",
            ),
            ArgSpec::opt(
                "rebalance-threshold",
                "0.15",
                "relative expected-time regret that triggers a migration \
                 plan (with --rebalance)",
            ),
            ArgSpec::opt(
                "migration-budget",
                "8388608",
                "max bytes of shard rows migrated between consecutive \
                 steps (0 = unlimited; with --rebalance)",
            ),
            ArgSpec::flag(
                "pipeline",
                "overlap master-side combine with the next step's worker \
                 compute (and migrations with compute)",
            ),
            ArgSpec::opt("json-out", "", "write the per-step timeline JSON here"),
            ArgSpec::opt(
                "trace-out",
                "",
                "write the JSONL tracing journal here (convert with `usec trace`)",
            ),
            ArgSpec::opt(
                "chaos",
                "",
                "seeded fault schedule, e.g. drop=0.05,delay=20:0.1,\
                 partition=1@2..5,throttle=0:4,crash=2@3+2",
            ),
            ArgSpec::opt(
                "chaos-seed",
                "0",
                "chaos roll seed (0 = derive from --seed)",
            ),
            ArgSpec::opt(
                "checkpoint-out",
                "",
                "checkpoint resumable master state here at step boundaries",
            ),
            ArgSpec::opt(
                "checkpoint-every",
                "1",
                "steps between checkpoints (with --checkpoint-out)",
            ),
            ArgSpec::opt(
                "resume",
                "",
                "resume a crashed run from this checkpoint file",
            ),
        ]
    }

    /// Build from parsed CLI args.
    pub fn from_args(a: &Args) -> Result<RunConfig> {
        let cfg = RunConfig {
            q: a.get_usize("q")?,
            r: a.get_usize("r")?,
            g: a.get_usize("g")?,
            j: a.get_usize("j")?,
            n: a.get_usize("n")?,
            placement: PlacementKind::parse(a.get("placement").unwrap_or("repetition"))?,
            stragglers: a.get_usize("stragglers")?,
            injected_stragglers: a.get_usize("inject-stragglers")?,
            straggler_slowdown: a.get_f64("straggler-slowdown")?,
            straggler_fixed: a.has("straggler-fixed"),
            solver: SolverKind::parse(a.get("solver").unwrap_or("simplex"))?,
            policy: AssignPolicy::parse(a.get("policy").unwrap_or("hetero"))?,
            backend: BackendKind::parse(a.get("backend").unwrap_or("host"))?,
            steps: a.get_usize("steps")?,
            gamma: a.get_f64("gamma")?,
            preempt_prob: a.get_f64("preempt-prob")?,
            arrive_prob: a.get_f64("arrive-prob")?,
            min_available: a.get_usize("min-available")?,
            speeds: a.get_f64_list("speeds")?,
            row_cost_ns: a.get_u64("row-cost-ns")?,
            tile_rows: a.get_usize("tile-rows")?,
            batch: a.get_usize("batch")?,
            worker_threads: a.get_usize("threads")?,
            seed: a.get_u64("seed")?,
            workers: parse_worker_list(a.get("workers").unwrap_or("")),
            stream_data: a.has("stream-data"),
            recovery: RecoveryPolicy {
                enabled: a.has("recovery"),
                overdue_factor: a.get_f64("overdue-factor")?,
            },
            rebalance: RebalanceConfig {
                enabled: a.has("rebalance"),
                threshold: a.get_f64("rebalance-threshold")?,
                budget_bytes: a.get_u64("migration-budget")?,
                ..Default::default()
            },
            pipeline: a.has("pipeline"),
            json_out: a.get("json-out").unwrap_or("").to_string(),
            trace_out: a.get("trace-out").unwrap_or("").to_string(),
            chaos: a.get("chaos").unwrap_or("").to_string(),
            chaos_seed: a.get_u64("chaos-seed")?,
            checkpoint_out: a.get("checkpoint-out").unwrap_or("").to_string(),
            checkpoint_every: a.get_usize("checkpoint-every")?,
            resume: a.get("resume").unwrap_or("").to_string(),
        };
        let mut cfg = cfg;
        if !cfg.workers.is_empty() {
            // the worker list is authoritative for the machine count
            cfg.n = cfg.workers.len();
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Structural sanity checks.
    pub fn validate(&self) -> Result<()> {
        if self.q == 0 || self.r == 0 {
            return Err(Error::Config("q and r must be positive".into()));
        }
        if self.g == 0 || self.g > self.q {
            return Err(Error::Config(format!(
                "G={} must be in [1, q={}]",
                self.g, self.q
            )));
        }
        if self.j == 0 || self.j > self.n {
            return Err(Error::Config(format!(
                "J={} must be in [1, N={}]",
                self.j, self.n
            )));
        }
        if !self.speeds.is_empty() && self.speeds.len() != self.n {
            return Err(Error::Config(format!(
                "{} speeds given for N={} machines",
                self.speeds.len(),
                self.n
            )));
        }
        if !(0.0..=1.0).contains(&self.gamma) {
            return Err(Error::Config(format!("gamma {} not in [0,1]", self.gamma)));
        }
        for (name, p) in [
            ("preempt-prob", self.preempt_prob),
            ("arrive-prob", self.arrive_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(Error::Config(format!("{name} {p} not in [0,1]")));
            }
        }
        if self.tile_rows == 0 {
            return Err(Error::Config("tile-rows must be positive".into()));
        }
        if self.batch == 0 {
            return Err(Error::Config("batch must be at least 1".into()));
        }
        if self.batch > crate::net::codec::MAX_NVEC {
            // reject up front: past the wire cap every daemon would refuse
            // the tag-10 frame and the run would die opaquely mid-dispatch
            return Err(Error::Config(format!(
                "batch {} exceeds the wire protocol's block-width cap {}",
                self.batch,
                crate::net::codec::MAX_NVEC
            )));
        }
        if self.worker_threads == 0 {
            return Err(Error::Config("threads must be at least 1".into()));
        }
        self.recovery.validate()?;
        self.rebalance.validate()?;
        // reject a malformed chaos schedule up front, not mid-run
        crate::net::ChaosSpec::parse(&self.chaos)?;
        if self.checkpoint_every == 0 {
            return Err(Error::Config("checkpoint-every must be at least 1".into()));
        }
        if !self.workers.is_empty() && self.workers.len() != self.n {
            return Err(Error::Config(format!(
                "{} worker addresses given for N={} machines",
                self.workers.len(),
                self.n
            )));
        }
        if self.injected_stragglers > self.stragglers && self.stragglers > 0 {
            // allowed (the system then misses rows) but suspicious for
            // experiments that expect full recovery
        }
        Ok(())
    }

    /// Whether this run dials remote TCP workers.
    pub fn is_distributed(&self) -> bool {
        !self.workers.is_empty()
    }

    /// Solve parameters derived from this config.
    pub fn solve_params(&self) -> SolveParams {
        SolveParams {
            stragglers: self.stragglers,
            solver: self.solver,
            ..Default::default()
        }
    }
}

/// Split a `host:port,host:port` list, tolerating blanks.
fn parse_worker_list(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(str::to_string)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn worker_list_sets_n_and_validates() {
        let argv: Vec<String> = ["--workers", "h1:1,h2:2,h3:3", "--speeds", "1,2,3"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv, &RunConfig::arg_specs()).unwrap();
        let cfg = RunConfig::from_args(&a).unwrap();
        assert_eq!(cfg.n, 3);
        assert!(cfg.is_distributed());
        assert_eq!(cfg.workers, vec!["h1:1", "h2:2", "h3:3"]);

        // programmatic mismatch rejected
        let bad = RunConfig {
            workers: vec!["h:1".into()], // N stays 6
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_args_roundtrip() {
        let argv: Vec<String> = [
            "--q",
            "6000",
            "--placement",
            "cyclic",
            "--speeds",
            "1,2,4,8,16,32",
            "--stragglers",
            "1",
            "--solver",
            "flow",
            "--policy",
            "uniform",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let a = Args::parse(&argv, &RunConfig::arg_specs()).unwrap();
        let cfg = RunConfig::from_args(&a).unwrap();
        assert_eq!(cfg.q, 6000);
        assert_eq!(cfg.placement, PlacementKind::Cyclic);
        assert_eq!(cfg.speeds, vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0]);
        assert_eq!(cfg.stragglers, 1);
        assert_eq!(cfg.solver, SolverKind::ParametricFlow);
        assert_eq!(cfg.policy, AssignPolicy::Uniform);
    }

    #[test]
    fn validation_rejects_bad_geometry() {
        let c = RunConfig {
            j: 10, // > N
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = RunConfig {
            speeds: vec![1.0, 2.0], // wrong length
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = RunConfig {
            gamma: 1.5,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn batch_and_threads_parse_and_validate() {
        let argv: Vec<String> = ["--batch", "8", "--threads", "4"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv, &RunConfig::arg_specs()).unwrap();
        let cfg = RunConfig::from_args(&a).unwrap();
        assert_eq!(cfg.batch, 8);
        assert_eq!(cfg.worker_threads, 4);

        let c = RunConfig {
            batch: 0,
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = RunConfig {
            batch: crate::net::codec::MAX_NVEC + 1, // past the wire cap
            ..Default::default()
        };
        assert!(c.validate().is_err());
        let c = RunConfig {
            worker_threads: 0,
            ..Default::default()
        };
        assert!(c.validate().is_err());
    }

    #[test]
    fn recovery_flags_parse_and_validate() {
        let argv: Vec<String> = ["--recovery", "--overdue-factor", "0.25"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv, &RunConfig::arg_specs()).unwrap();
        let cfg = RunConfig::from_args(&a).unwrap();
        assert!(cfg.recovery.enabled);
        assert!((cfg.recovery.overdue_factor - 0.25).abs() < 1e-12);

        // default: off, bit-identical to the classic behaviour
        let none = Args::parse(&[], &RunConfig::arg_specs()).unwrap();
        assert!(!RunConfig::from_args(&none).unwrap().recovery.enabled);

        // an enabled policy rejects a degenerate overdue factor
        let bad = RunConfig {
            recovery: RecoveryPolicy {
                enabled: true,
                overdue_factor: 0.0,
            },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn rebalance_flags_parse_and_validate() {
        let argv: Vec<String> = [
            "--rebalance",
            "--rebalance-threshold",
            "0.3",
            "--migration-budget",
            "65536",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let a = Args::parse(&argv, &RunConfig::arg_specs()).unwrap();
        let cfg = RunConfig::from_args(&a).unwrap();
        assert!(cfg.rebalance.enabled);
        assert!((cfg.rebalance.threshold - 0.3).abs() < 1e-12);
        assert_eq!(cfg.rebalance.budget_bytes, 65536);

        // default: off, bit-identical to the frozen-placement behaviour
        let none = Args::parse(&[], &RunConfig::arg_specs()).unwrap();
        assert!(!RunConfig::from_args(&none).unwrap().rebalance.enabled);

        // an enabled config rejects a degenerate threshold
        let bad = RunConfig {
            rebalance: RebalanceConfig {
                enabled: true,
                threshold: 0.0,
                ..Default::default()
            },
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn pipeline_flag_parses_and_defaults_off() {
        let argv: Vec<String> = ["--pipeline"].iter().map(|s| s.to_string()).collect();
        let a = Args::parse(&argv, &RunConfig::arg_specs()).unwrap();
        assert!(RunConfig::from_args(&a).unwrap().pipeline);

        // default: off, the synchronous loop
        let none = Args::parse(&[], &RunConfig::arg_specs()).unwrap();
        assert!(!RunConfig::from_args(&none).unwrap().pipeline);
    }

    #[test]
    fn robustness_flags_parse_and_default_off() {
        let argv: Vec<String> = [
            "--chaos",
            "drop=0.05,crash=2@3+2",
            "--chaos-seed",
            "99",
            "--checkpoint-out",
            "run.ckpt",
            "--checkpoint-every",
            "4",
            "--resume",
            "old.ckpt",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let a = Args::parse(&argv, &RunConfig::arg_specs()).unwrap();
        let cfg = RunConfig::from_args(&a).unwrap();
        assert_eq!(cfg.chaos, "drop=0.05,crash=2@3+2");
        assert_eq!(cfg.chaos_seed, 99);
        assert_eq!(cfg.checkpoint_out, "run.ckpt");
        assert_eq!(cfg.checkpoint_every, 4);
        assert_eq!(cfg.resume, "old.ckpt");

        // defaults: everything off (flags-absent ⇒ classic behaviour)
        let none = Args::parse(&[], &RunConfig::arg_specs()).unwrap();
        let cfg = RunConfig::from_args(&none).unwrap();
        assert!(cfg.chaos.is_empty());
        assert_eq!(cfg.chaos_seed, 0);
        assert!(cfg.checkpoint_out.is_empty());
        assert_eq!(cfg.checkpoint_every, 1);
        assert!(cfg.resume.is_empty());

        // malformed schedules and degenerate cadence rejected at validate
        let bad = RunConfig {
            chaos: "drop=oops".into(),
            ..Default::default()
        };
        assert!(bad.validate().is_err());
        let bad = RunConfig {
            checkpoint_every: 0,
            ..Default::default()
        };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn backend_and_policy_parse() {
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert!(BackendKind::parse("gpu").is_err());
        assert_eq!(
            AssignPolicy::parse("optimal").unwrap(),
            AssignPolicy::Heterogeneous
        );
    }
}
