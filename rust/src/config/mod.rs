//! Run configuration: typed config structs + file/CLI loading — see
//! [`types`].

pub mod types;

pub use types::RunConfig;
