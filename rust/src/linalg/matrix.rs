//! Row-major dense `f32` matrix.

use crate::error::{Error, Result};

/// A dense row-major `f32` matrix (`rows × cols`).
///
/// `f32` matches the PJRT artifact dtype; the reference kernels accumulate
/// in `f64` so the host backend is a high-precision oracle for the
/// artifact path.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Matrix {
    /// Zero-filled matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Build from a flat row-major buffer. Rejects `rows * cols` overflow
    /// explicitly (huge dims from untrusted inputs must not wrap and
    /// silently validate).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        let expect = rows.checked_mul(cols).ok_or_else(|| {
            Error::Shape(format!("{rows}x{cols} matrix dimensions overflow usize"))
        })?;
        if data.len() != expect {
            return Err(Error::Shape(format!(
                "buffer of {} elements cannot be a {rows}x{cols} matrix ({expect} expected)",
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Flat row-major data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable flat data.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Element accessor.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Element setter.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// A view of row `r`.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Borrow rows `[lo, hi)` as a contiguous slice (row-major submatrix).
    pub fn row_block(&self, lo: usize, hi: usize) -> &[f32] {
        assert!(lo <= hi && hi <= self.rows, "row block {lo}..{hi} of {}", self.rows);
        &self.data[lo * self.cols..hi * self.cols]
    }

    /// Fallible [`Matrix::row_block`] for untrusted row ranges (the
    /// [`crate::storage::StorageView`] path): out-of-range rows are a
    /// [`Error::Shape`], not a panic.
    pub fn try_row_block(&self, lo: usize, hi: usize) -> Result<&[f32]> {
        if lo > hi || hi > self.rows {
            return Err(Error::Shape(format!(
                "row block {lo}..{hi} of a {}-row matrix",
                self.rows
            )));
        }
        Ok(&self.data[lo * self.cols..hi * self.cols])
    }

    /// Copy rows `[lo, hi)` into a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Matrix {
        Matrix {
            rows: hi - lo,
            cols: self.cols,
            data: self.row_block(lo, hi).to_vec(),
        }
    }

    /// `self * v` with `f64` accumulation.
    pub fn matvec(&self, v: &[f32]) -> Result<Vec<f32>> {
        if v.len() != self.cols {
            return Err(Error::Shape(format!(
                "matvec: vector length {} vs {} columns",
                v.len(),
                self.cols
            )));
        }
        let mut out = vec![0.0f32; self.rows];
        ops::matvec_into(&self.data, self.rows, self.cols, v, &mut out);
        Ok(out)
    }

    /// Symmetry check (used by generator tests).
    pub fn is_symmetric(&self, tol: f32) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self.at(i, j) - self.at(j, i)).abs() > tol {
                    return false;
                }
            }
        }
        true
    }
}

use super::ops;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(m.at(0, 0), 1.0);
        assert_eq!(m.at(1, 2), 6.0);
        assert_eq!(m.row(1), &[4., 5., 6.]);
    }

    #[test]
    fn bad_shape_rejected() {
        assert!(Matrix::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn overflowing_dims_rejected_not_wrapped() {
        // usize::MAX * 2 wraps to an even value; a wrapping check could
        // falsely accept a tiny buffer — this must be a Shape error.
        let e = Matrix::from_vec(usize::MAX, 2, vec![0.0; 2]).unwrap_err();
        assert!(e.to_string().contains("overflow"), "{e}");
    }

    #[test]
    fn try_row_block_errors_instead_of_panicking() {
        let m = Matrix::from_vec(3, 2, vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(m.try_row_block(1, 3).unwrap(), &[2., 3., 4., 5.]);
        assert!(m.try_row_block(2, 4).is_err());
        assert!(m.try_row_block(2, 1).is_err());
    }

    #[test]
    fn eye_matvec_is_identity() {
        let m = Matrix::eye(4);
        let v = vec![1., 2., 3., 4.];
        assert_eq!(m.matvec(&v).unwrap(), v);
    }

    #[test]
    fn matvec_known() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 3., 4.]).unwrap();
        let y = m.matvec(&[1., 1.]).unwrap();
        assert_eq!(y, vec![3., 7.]);
    }

    #[test]
    fn matvec_shape_mismatch() {
        let m = Matrix::zeros(2, 2);
        assert!(m.matvec(&[1.0]).is_err());
    }

    #[test]
    fn row_blocks() {
        let m = Matrix::from_vec(3, 2, vec![0., 1., 2., 3., 4., 5.]).unwrap();
        assert_eq!(m.row_block(1, 3), &[2., 3., 4., 5.]);
        let s = m.slice_rows(0, 1);
        assert_eq!(s.rows(), 1);
        assert_eq!(s.data(), &[0., 1.]);
    }

    #[test]
    fn symmetry_check() {
        let m = Matrix::from_vec(2, 2, vec![1., 2., 2., 1.]).unwrap();
        assert!(m.is_symmetric(0.0));
        let m2 = Matrix::from_vec(2, 2, vec![1., 2., 3., 1.]).unwrap();
        assert!(!m2.is_symmetric(0.5));
    }
}
