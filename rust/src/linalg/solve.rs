//! Dense linear solves: LU with partial pivoting (`f64`).
//!
//! Substrate for the CSEC baseline's decoder (the master must invert the
//! coding matrix restricted to the reporting machines).

use crate::error::{Error, Result};

/// LU factorization (in place) with partial pivoting of a square matrix.
#[derive(Debug, Clone)]
pub struct Lu {
    n: usize,
    /// Packed L\U factors, row-major.
    lu: Vec<f64>,
    /// Row permutation.
    piv: Vec<usize>,
}

impl Lu {
    /// Factor `a` (row-major `n×n`). Errors on singular (|pivot| < tol).
    pub fn factor(a: &[f64], n: usize, tol: f64) -> Result<Lu> {
        if a.len() != n * n {
            return Err(Error::Shape(format!("{} elements for {n}x{n}", a.len())));
        }
        let mut lu = a.to_vec();
        let mut piv: Vec<usize> = (0..n).collect();
        for k in 0..n {
            // pivot: largest |entry| in column k at/below row k
            let mut p = k;
            let mut best = lu[k * n + k].abs();
            for r in (k + 1)..n {
                let v = lu[r * n + k].abs();
                if v > best {
                    best = v;
                    p = r;
                }
            }
            if best < tol {
                return Err(Error::solver(format!(
                    "singular matrix at pivot {k} (|p| = {best:.3e})"
                )));
            }
            if p != k {
                for c in 0..n {
                    lu.swap(k * n + c, p * n + c);
                }
                piv.swap(k, p);
            }
            let pivot = lu[k * n + k];
            for r in (k + 1)..n {
                let f = lu[r * n + k] / pivot;
                lu[r * n + k] = f;
                for c in (k + 1)..n {
                    lu[r * n + c] -= f * lu[k * n + c];
                }
            }
        }
        Ok(Lu { n, lu, piv })
    }

    /// Solve `A x = b` for one right-hand side.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n {
            return Err(Error::Shape(format!("rhs of {} for n={}", b.len(), self.n)));
        }
        let n = self.n;
        // apply permutation
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // forward substitution (L has unit diagonal)
        for r in 1..n {
            for c in 0..r {
                x[r] -= self.lu[r * n + c] * x[c];
            }
        }
        // back substitution
        for r in (0..n).rev() {
            for c in (r + 1)..n {
                x[r] -= self.lu[r * n + c] * x[c];
            }
            x[r] /= self.lu[r * n + r];
        }
        Ok(x)
    }

    /// Solve for many right-hand sides arranged as columns of a row-major
    /// `n×m` matrix; returns the solution in the same layout.
    pub fn solve_many(&self, b: &[f64], m: usize) -> Result<Vec<f64>> {
        if b.len() != self.n * m {
            return Err(Error::Shape(format!(
                "{} elements for {}x{m}",
                b.len(),
                self.n
            )));
        }
        let mut out = vec![0.0; self.n * m];
        let mut col = vec![0.0; self.n];
        for j in 0..m {
            for i in 0..self.n {
                col[i] = b[i * m + j];
            }
            let x = self.solve(&col)?;
            for i in 0..self.n {
                out[i * m + j] = x[i];
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        // A = [[2,1],[1,3]], b = [5, 10] → x = [1, 3]
        let lu = Lu::factor(&[2.0, 1.0, 1.0, 3.0], 2, 1e-12).unwrap();
        let x = lu.solve(&[5.0, 10.0]).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        // A = [[0,1],[1,0]] needs a row swap
        let lu = Lu::factor(&[0.0, 1.0, 1.0, 0.0], 2, 1e-12).unwrap();
        let x = lu.solve(&[2.0, 3.0]).unwrap();
        assert_eq!(x, vec![3.0, 2.0]);
    }

    #[test]
    fn singular_detected() {
        assert!(Lu::factor(&[1.0, 2.0, 2.0, 4.0], 2, 1e-9).is_err());
    }

    #[test]
    fn random_roundtrip() {
        let n = 8;
        let mut rng = crate::util::Rng::new(5);
        let a: Vec<f64> = (0..n * n).map(|_| rng.f64() - 0.5).collect();
        // diagonal dominance for a well-conditioned test
        let mut a2 = a.clone();
        for i in 0..n {
            a2[i * n + i] += 4.0;
        }
        let x_true: Vec<f64> = (0..n).map(|i| i as f64 - 3.0).collect();
        let b: Vec<f64> = (0..n)
            .map(|r| (0..n).map(|c| a2[r * n + c] * x_true[c]).sum())
            .collect();
        let lu = Lu::factor(&a2, n, 1e-12).unwrap();
        let x = lu.solve(&b).unwrap();
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn solve_many_matches_single() {
        let a = [3.0, 1.0, 1.0, 2.0];
        let lu = Lu::factor(&a, 2, 1e-12).unwrap();
        let b = [1.0, 2.0, 3.0, 4.0]; // two columns
        let xs = lu.solve_many(&b, 2).unwrap();
        let x0 = lu.solve(&[1.0, 3.0]).unwrap();
        let x1 = lu.solve(&[2.0, 4.0]).unwrap();
        assert!((xs[0] - x0[0]).abs() < 1e-12 && (xs[2] - x0[1]).abs() < 1e-12);
        assert!((xs[1] - x1[0]).abs() < 1e-12 && (xs[3] - x1[1]).abs() < 1e-12);
    }
}
