//! [`Block`]: a bundle of `B` equal-length vectors, interleaved row-major.
//!
//! The block data plane ships `B` iterate vectors per elastic step instead
//! of one, so a worker amortizes one traversal of its stored rows over `B`
//! mat-vec products (`linalg::ops::matmat_into`). The layout is
//! *interleaved* (`data[i * nvec + k]` is component `i` of vector `k`),
//! which is exactly the column-panel layout the mat-mat kernel consumes
//! and, for `nvec == 1`, is byte-identical to the plain vector — the B=1
//! wire encoding and the in-memory hot path are unchanged from the
//! single-vector plane.

use crate::error::{Error, Result};

/// `nvec` vectors of length `len`, interleaved row-major:
/// `data[i * nvec + k]` is component `i` of vector `k`.
#[derive(Debug, Clone, PartialEq)]
pub struct Block {
    len: usize,
    nvec: usize,
    data: Vec<f32>,
}

impl Block {
    /// Wrap one vector as a `B = 1` block (zero-copy; the data layout of a
    /// single-vector block *is* the vector).
    pub fn single(v: Vec<f32>) -> Block {
        Block {
            len: v.len(),
            nvec: 1,
            data: v,
        }
    }

    /// Zero-filled block.
    pub fn zeros(len: usize, nvec: usize) -> Block {
        assert!(nvec > 0, "Block with zero vectors");
        Block {
            len,
            nvec,
            data: vec![0.0; len * nvec],
        }
    }

    /// Build from an interleaved buffer; `data.len()` must be
    /// `len * nvec`.
    pub fn from_interleaved(len: usize, nvec: usize, data: Vec<f32>) -> Result<Block> {
        if nvec == 0 {
            return Err(Error::Shape("block must carry at least one vector".into()));
        }
        let expect = len.checked_mul(nvec).ok_or_else(|| {
            Error::Shape(format!("block {len}x{nvec} dimensions overflow usize"))
        })?;
        if data.len() != expect {
            return Err(Error::Shape(format!(
                "buffer of {} elements cannot be a {len}x{nvec} block",
                data.len()
            )));
        }
        Ok(Block { len, nvec, data })
    }

    /// Interleave `columns` (all the same length) into a block.
    pub fn from_columns(columns: &[Vec<f32>]) -> Result<Block> {
        let nvec = columns.len();
        if nvec == 0 {
            return Err(Error::Shape("block must carry at least one vector".into()));
        }
        let len = columns[0].len();
        if columns.iter().any(|c| c.len() != len) {
            return Err(Error::Shape("block columns differ in length".into()));
        }
        let mut data = vec![0.0f32; len * nvec];
        for (k, col) in columns.iter().enumerate() {
            for (i, &v) in col.iter().enumerate() {
                data[i * nvec + k] = v;
            }
        }
        Ok(Block { len, nvec, data })
    }

    /// Vector length (rows of the panel).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Number of vectors `B`.
    pub fn nvec(&self) -> usize {
        self.nvec
    }

    /// Interleaved storage (`len * nvec` values).
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Extract vector `k` as an owned contiguous vector.
    pub fn column(&self, k: usize) -> Vec<f32> {
        assert!(k < self.nvec, "column {k} of {}", self.nvec);
        (0..self.len).map(|i| self.data[i * self.nvec + k]).collect()
    }

    /// Unwrap a `B = 1` block into its vector (zero-copy).
    ///
    /// Panics when the block carries more than one vector — callers on the
    /// single-vector path own that invariant.
    pub fn into_single(self) -> Vec<f32> {
        assert_eq!(self.nvec, 1, "into_single on a B={} block", self.nvec);
        self.data
    }

    /// Borrow the single vector of a `B = 1` block.
    pub fn as_single(&self) -> Option<&[f32]> {
        (self.nvec == 1).then_some(self.data.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_is_zero_copy_layout() {
        let b = Block::single(vec![1.0, 2.0, 3.0]);
        assert_eq!(b.len(), 3);
        assert_eq!(b.nvec(), 1);
        assert_eq!(b.data(), &[1.0, 2.0, 3.0]);
        assert_eq!(b.as_single(), Some(&[1.0, 2.0, 3.0][..]));
        assert_eq!(b.column(0), vec![1.0, 2.0, 3.0]);
        assert_eq!(b.into_single(), vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn columns_round_trip_through_interleaving() {
        let cols = vec![vec![1.0f32, 2.0, 3.0], vec![10.0, 20.0, 30.0]];
        let b = Block::from_columns(&cols).unwrap();
        assert_eq!(b.len(), 3);
        assert_eq!(b.nvec(), 2);
        assert_eq!(b.data(), &[1.0, 10.0, 2.0, 20.0, 3.0, 30.0]);
        assert_eq!(b.column(0), cols[0]);
        assert_eq!(b.column(1), cols[1]);
        assert!(b.as_single().is_none());
    }

    #[test]
    fn from_interleaved_validates_shape() {
        assert!(Block::from_interleaved(2, 2, vec![0.0; 4]).is_ok());
        assert!(Block::from_interleaved(2, 2, vec![0.0; 3]).is_err());
        assert!(Block::from_interleaved(2, 0, vec![]).is_err());
    }

    #[test]
    fn mismatched_columns_rejected() {
        assert!(Block::from_columns(&[vec![1.0], vec![1.0, 2.0]]).is_err());
        assert!(Block::from_columns(&[]).is_err());
    }
}
