//! Reference vector/matrix kernels (host backend + test oracle).
//!
//! These are the CPU hot-path fallbacks: `matvec_into` is what a worker
//! executes per tile when running with the host backend instead of PJRT.
//! Accumulation is in `f64` to serve as a numerics oracle.

/// `out[r] = Σ_c a[r*cols + c] * v[c]` for `r < rows`.
///
/// Unrolled-by-4 inner loop over columns; `f64` accumulators.
pub fn matvec_into(a: &[f32], rows: usize, cols: usize, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(v.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        // 8 independent f64 accumulators: enough ILP to keep the FMA ports
        // busy while preserving the f64-accumulation oracle property
        // (§Perf iteration 3: +29 % over the 4-wide version).
        let mut acc = [0.0f64; 8];
        let mut row_it = row.chunks_exact(8);
        let mut v_it = v.chunks_exact(8);
        for (rc, vc) in (&mut row_it).zip(&mut v_it) {
            for k in 0..8 {
                acc[k] += rc[k] as f64 * vc[k] as f64;
            }
        }
        for (x, y) in row_it.remainder().iter().zip(v_it.remainder()) {
            acc[0] += *x as f64 * *y as f64;
        }
        out[r] = acc.iter().sum::<f64>() as f32;
    }
}

/// `out[r*nvec + k] = Σ_c a[r*cols + c] * x[c*nvec + k]` — a row-major
/// `rows × cols` tile times a `cols × nvec` column panel (interleaved, the
/// [`crate::linalg::Block`] layout), `f64` accumulators throughout.
///
/// This is the block data plane's hot kernel: one traversal of the tile is
/// amortized over `nvec` mat-vec products, turning the memory-bandwidth-
/// bound mat-vec into a compute-dense mat-mat. Vectors are processed in
/// groups of up to 8 so the inner loop keeps 8 independent `f64`
/// accumulators live (the same ILP budget as [`matvec_into`]) while the
/// panel group (`cols × 8` f32s) stays cache-resident across the tile's
/// rows. `nvec == 1` delegates to [`matvec_into`], so the B=1 path is
/// bit-identical to the single-vector plane.
pub fn matmat_into(a: &[f32], rows: usize, cols: usize, x: &[f32], nvec: usize, out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(x.len(), cols * nvec);
    debug_assert_eq!(out.len(), rows * nvec);
    if nvec == 1 {
        return matvec_into(a, rows, cols, x, out);
    }
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        let orow = &mut out[r * nvec..(r + 1) * nvec];
        let mut k0 = 0usize;
        while k0 < nvec {
            let kw = (nvec - k0).min(8);
            let mut acc = [0.0f64; 8];
            if kw == 8 {
                // full 8-wide group: fixed-trip inner loop the compiler can
                // keep entirely in registers
                for (c, &av) in row.iter().enumerate() {
                    let av = av as f64;
                    let xs = &x[c * nvec + k0..c * nvec + k0 + 8];
                    for k in 0..8 {
                        acc[k] += av * xs[k] as f64;
                    }
                }
            } else {
                for (c, &av) in row.iter().enumerate() {
                    let av = av as f64;
                    let xs = &x[c * nvec + k0..c * nvec + k0 + kw];
                    for (k, &xv) in xs.iter().enumerate() {
                        acc[k] += av * xv as f64;
                    }
                }
            }
            for (k, &a_k) in acc.iter().take(kw).enumerate() {
                orow[k0 + k] = a_k as f32;
            }
            k0 += kw;
        }
    }
}

/// Modified Gram–Schmidt over the `nvec` interleaved columns of a
/// `len × nvec` panel (the [`crate::linalg::Block`] layout), in place.
///
/// Returns each column's norm *after* projecting out the previous columns
/// (the `R` diagonal of the thin QR): for block power iteration these are
/// the running eigenvalue estimates. A column that projects to (near)
/// zero is left as-is and reports norm 0, mirroring [`normalize`].
pub fn mgs_orthonormalize(data: &mut [f32], len: usize, nvec: usize) -> Vec<f64> {
    debug_assert_eq!(data.len(), len * nvec);
    let mut norms = Vec::with_capacity(nvec);
    for k in 0..nvec {
        // project out the already-orthonormalized columns j < k
        for j in 0..k {
            let mut d = 0.0f64;
            for i in 0..len {
                d += data[i * nvec + j] as f64 * data[i * nvec + k] as f64;
            }
            for i in 0..len {
                let v = data[i * nvec + k] as f64 - d * data[i * nvec + j] as f64;
                data[i * nvec + k] = v as f32;
            }
        }
        let mut sq = 0.0f64;
        for i in 0..len {
            let v = data[i * nvec + k] as f64;
            sq += v * v;
        }
        let n = sq.sqrt();
        if n > 0.0 {
            let inv = 1.0 / n;
            for i in 0..len {
                data[i * nvec + k] = (data[i * nvec + k] as f64 * inv) as f32;
            }
        }
        norms.push(n);
    }
    norms
}

/// Euclidean norm with `f64` accumulation.
pub fn norm2(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// In-place scale: `v *= s`.
pub fn scale(v: &mut [f32], s: f32) {
    for x in v.iter_mut() {
        *x *= s;
    }
}

/// Normalize to unit norm; returns the original norm. Zero vectors are
/// left untouched (returns 0).
pub fn normalize(v: &mut [f32]) -> f64 {
    let n = norm2(v);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        scale(v, inv);
    }
    n
}

/// Dot product with `f64` accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Normalized mean-square error between an estimate and a reference
/// direction, sign-invariant (eigenvectors are defined up to sign):
/// `min(|e - r|², |e + r|²) / |r|²`.
pub fn nmse_signless(est: &[f32], reference: &[f32]) -> f64 {
    debug_assert_eq!(est.len(), reference.len());
    let mut plus = 0.0f64;
    let mut minus = 0.0f64;
    let mut rnorm = 0.0f64;
    for (&e, &r) in est.iter().zip(reference) {
        let (e, r) = (e as f64, r as f64);
        plus += (e - r) * (e - r);
        minus += (e + r) * (e + r);
        rnorm += r * r;
    }
    if rnorm == 0.0 {
        return f64::INFINITY;
    }
    plus.min(minus) / rnorm
}

/// `y += x` elementwise.
pub fn axpy1(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_naive() {
        let rows = 7;
        let cols = 13; // non-multiple of 4 exercises the tail loop
        let a: Vec<f32> = (0..rows * cols).map(|i| (i % 11) as f32 - 5.0).collect();
        let v: Vec<f32> = (0..cols).map(|i| (i as f32) * 0.5 - 3.0).collect();
        let mut out = vec![0.0; rows];
        matvec_into(&a, rows, cols, &v, &mut out);
        for r in 0..rows {
            let expect: f32 = (0..cols).map(|c| a[r * cols + c] * v[c]).sum();
            assert!((out[r] - expect).abs() < 1e-4, "row {r}");
        }
    }

    #[test]
    fn matmat_matches_independent_matvecs() {
        let rows = 9;
        let cols = 21; // non-multiple of 8 exercises the matvec tail
        for nvec in [1usize, 2, 3, 7, 8, 9, 16, 19] {
            let a: Vec<f32> = (0..rows * cols).map(|i| (i % 17) as f32 * 0.25 - 2.0).collect();
            let x: Vec<f32> = (0..cols * nvec).map(|i| (i % 13) as f32 * 0.1 - 0.6).collect();
            let mut out = vec![0.0f32; rows * nvec];
            matmat_into(&a, rows, cols, &x, nvec, &mut out);
            for k in 0..nvec {
                let col: Vec<f32> = (0..cols).map(|c| x[c * nvec + k]).collect();
                let mut want = vec![0.0f32; rows];
                matvec_into(&a, rows, cols, &col, &mut want);
                for r in 0..rows {
                    let got = out[r * nvec + k];
                    assert!(
                        (got - want[r]).abs() <= 1e-6 * want[r].abs().max(1.0),
                        "B={nvec} col {k} row {r}: {got} vs {}",
                        want[r]
                    );
                }
            }
        }
    }

    #[test]
    fn matmat_b1_is_bit_identical_to_matvec() {
        let (rows, cols) = (5, 13);
        let a: Vec<f32> = (0..rows * cols).map(|i| (i % 7) as f32 - 3.0).collect();
        let v: Vec<f32> = (0..cols).map(|i| (i as f32) * 0.3 - 1.0).collect();
        let mut via_matvec = vec![0.0f32; rows];
        let mut via_matmat = vec![0.0f32; rows];
        matvec_into(&a, rows, cols, &v, &mut via_matvec);
        matmat_into(&a, rows, cols, &v, 1, &mut via_matmat);
        assert_eq!(via_matvec, via_matmat);
    }

    #[test]
    fn mgs_produces_orthonormal_columns() {
        let len = 12;
        let nvec = 3;
        let mut data: Vec<f32> = (0..len * nvec)
            .map(|i| ((i * 31 + 7) % 23) as f32 * 0.17 - 1.9)
            .collect();
        let norms = mgs_orthonormalize(&mut data, len, nvec);
        assert!(norms.iter().all(|&n| n > 0.0));
        for j in 0..nvec {
            for k in 0..nvec {
                let d: f64 = (0..len)
                    .map(|i| data[i * nvec + j] as f64 * data[i * nvec + k] as f64)
                    .sum();
                let want = if j == k { 1.0 } else { 0.0 };
                assert!((d - want).abs() < 1e-5, "<q{j}, q{k}> = {d}");
            }
        }
    }

    #[test]
    fn mgs_leaves_zero_column_untouched() {
        // 3 rows x 2 interleaved columns: col0 = [1, 0, 1], col1 = zeros
        let mut data = vec![1.0f32, 0.0, 0.0, 0.0, 1.0, 0.0];
        let norms = mgs_orthonormalize(&mut data, 3, 2);
        assert!((norms[0] - 2.0f64.sqrt()).abs() < 1e-7);
        assert_eq!(norms[1], 0.0);
        assert_eq!(data[1], 0.0);
        assert_eq!(data[3], 0.0);
        assert_eq!(data[5], 0.0);
    }

    #[test]
    fn norm_and_normalize() {
        let mut v = vec![3.0, 4.0];
        assert_eq!(norm2(&v), 5.0);
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm2(&v) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn normalize_zero_vector_noop() {
        let mut v = vec![0.0; 4];
        assert_eq!(normalize(&mut v), 0.0);
        assert_eq!(v, vec![0.0; 4]);
    }

    #[test]
    fn dot_product() {
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
    }

    #[test]
    fn nmse_sign_invariant() {
        let r = vec![1.0f32, 0.0, 0.0];
        let e_pos = vec![1.0f32, 0.0, 0.0];
        let e_neg = vec![-1.0f32, 0.0, 0.0];
        assert_eq!(nmse_signless(&e_pos, &r), 0.0);
        assert_eq!(nmse_signless(&e_neg, &r), 0.0);
        let e_off = vec![0.0f32, 1.0, 0.0];
        assert!((nmse_signless(&e_off, &r) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0f32, 2.0];
        axpy1(&mut y, &[10.0, 20.0]);
        assert_eq!(y, vec![11.0, 22.0]);
    }
}
