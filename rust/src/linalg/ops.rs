//! Reference vector/matrix kernels (host backend + test oracle).
//!
//! These are the CPU hot-path fallbacks: `matvec_into` is what a worker
//! executes per tile when running with the host backend instead of PJRT.
//! Accumulation is in `f64` to serve as a numerics oracle.

/// `out[r] = Σ_c a[r*cols + c] * v[c]` for `r < rows`.
///
/// Unrolled-by-4 inner loop over columns; `f64` accumulators.
pub fn matvec_into(a: &[f32], rows: usize, cols: usize, v: &[f32], out: &mut [f32]) {
    debug_assert_eq!(a.len(), rows * cols);
    debug_assert_eq!(v.len(), cols);
    debug_assert_eq!(out.len(), rows);
    for r in 0..rows {
        let row = &a[r * cols..(r + 1) * cols];
        // 8 independent f64 accumulators: enough ILP to keep the FMA ports
        // busy while preserving the f64-accumulation oracle property
        // (§Perf iteration 3: +29 % over the 4-wide version).
        let mut acc = [0.0f64; 8];
        let mut row_it = row.chunks_exact(8);
        let mut v_it = v.chunks_exact(8);
        for (rc, vc) in (&mut row_it).zip(&mut v_it) {
            for k in 0..8 {
                acc[k] += rc[k] as f64 * vc[k] as f64;
            }
        }
        for (x, y) in row_it.remainder().iter().zip(v_it.remainder()) {
            acc[0] += *x as f64 * *y as f64;
        }
        out[r] = acc.iter().sum::<f64>() as f32;
    }
}

/// Euclidean norm with `f64` accumulation.
pub fn norm2(v: &[f32]) -> f64 {
    v.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

/// In-place scale: `v *= s`.
pub fn scale(v: &mut [f32], s: f32) {
    for x in v.iter_mut() {
        *x *= s;
    }
}

/// Normalize to unit norm; returns the original norm. Zero vectors are
/// left untouched (returns 0).
pub fn normalize(v: &mut [f32]) -> f64 {
    let n = norm2(v);
    if n > 0.0 {
        let inv = (1.0 / n) as f32;
        scale(v, inv);
    }
    n
}

/// Dot product with `f64` accumulation.
pub fn dot(a: &[f32], b: &[f32]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

/// Normalized mean-square error between an estimate and a reference
/// direction, sign-invariant (eigenvectors are defined up to sign):
/// `min(|e - r|², |e + r|²) / |r|²`.
pub fn nmse_signless(est: &[f32], reference: &[f32]) -> f64 {
    debug_assert_eq!(est.len(), reference.len());
    let mut plus = 0.0f64;
    let mut minus = 0.0f64;
    let mut rnorm = 0.0f64;
    for (&e, &r) in est.iter().zip(reference) {
        let (e, r) = (e as f64, r as f64);
        plus += (e - r) * (e - r);
        minus += (e + r) * (e + r);
        rnorm += r * r;
    }
    if rnorm == 0.0 {
        return f64::INFINITY;
    }
    plus.min(minus) / rnorm
}

/// `y += x` elementwise.
pub fn axpy1(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_matches_naive() {
        let rows = 7;
        let cols = 13; // non-multiple of 4 exercises the tail loop
        let a: Vec<f32> = (0..rows * cols).map(|i| (i % 11) as f32 - 5.0).collect();
        let v: Vec<f32> = (0..cols).map(|i| (i as f32) * 0.5 - 3.0).collect();
        let mut out = vec![0.0; rows];
        matvec_into(&a, rows, cols, &v, &mut out);
        for r in 0..rows {
            let expect: f32 = (0..cols).map(|c| a[r * cols + c] * v[c]).sum();
            assert!((out[r] - expect).abs() < 1e-4, "row {r}");
        }
    }

    #[test]
    fn norm_and_normalize() {
        let mut v = vec![3.0, 4.0];
        assert_eq!(norm2(&v), 5.0);
        let n = normalize(&mut v);
        assert_eq!(n, 5.0);
        assert!((norm2(&v) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn normalize_zero_vector_noop() {
        let mut v = vec![0.0; 4];
        assert_eq!(normalize(&mut v), 0.0);
        assert_eq!(v, vec![0.0; 4]);
    }

    #[test]
    fn dot_product() {
        assert_eq!(dot(&[1., 2., 3.], &[4., 5., 6.]), 32.0);
    }

    #[test]
    fn nmse_sign_invariant() {
        let r = vec![1.0f32, 0.0, 0.0];
        let e_pos = vec![1.0f32, 0.0, 0.0];
        let e_neg = vec![-1.0f32, 0.0, 0.0];
        assert_eq!(nmse_signless(&e_pos, &r), 0.0);
        assert_eq!(nmse_signless(&e_neg, &r), 0.0);
        let e_off = vec![0.0f32, 1.0, 0.0];
        assert!((nmse_signless(&e_off, &r) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_accumulates() {
        let mut y = vec![1.0f32, 2.0];
        axpy1(&mut y, &[10.0, 20.0]);
        assert_eq!(y, vec![11.0, 22.0]);
    }
}
