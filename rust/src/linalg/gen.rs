//! Synthetic matrix generators with controlled spectra.
//!
//! The paper's Fig. 4 uses a dense 6000×6000 symmetric matrix on EC2. We
//! plant a known dominant eigenpair so NMSE against the *true* eigenvector
//! is measurable without an external eigensolver (DESIGN.md §3).

use crate::linalg::{ops, Matrix};
use crate::util::Rng;

/// A symmetric matrix together with its planted dominant eigenpair.
#[derive(Debug, Clone)]
pub struct PlantedMatrix {
    pub matrix: Matrix,
    /// Unit-norm dominant eigenvector.
    pub eigvec: Vec<f32>,
    /// Dominant eigenvalue.
    pub eigval: f64,
}

/// Build `A = λ·u uᵀ + ε·(B + Bᵀ)/2` with `u` a random unit vector and `B`
/// i.i.d. uniform noise. `ε` is sized so the noise spectral radius
/// (≈ `ε·√(3n)` w.h.p.) stays below `gap·λ`, guaranteeing `u` dominates.
///
/// `n` is the dimension; `gap ∈ (0,1)` controls the relative spectral gap
/// (smaller gap ⇒ slower power-iteration convergence).
pub fn planted_symmetric(n: usize, eigval: f64, gap: f64, seed: u64) -> PlantedMatrix {
    assert!(n > 0 && (0.0..1.0).contains(&gap));
    let mut rng = Rng::new(seed);

    // random unit dominant eigenvector
    let mut u: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    ops::normalize(&mut u);

    // noise scale: uniform[-0.5,0.5) entries have variance 1/12; symmetric
    // random matrix spectral norm ≈ 2σ√n = √(n/3); keep it at gap·λ/2.
    let eps = (gap * eigval * 0.5) / (n as f64 / 3.0).sqrt();

    let mut m = Matrix::zeros(n, n);
    let data = m.data_mut();
    // fill upper triangle with symmetric noise + rank-1 plant
    for i in 0..n {
        for j in i..n {
            let noise = (rng.f64() - 0.5) * eps;
            let plant = eigval * u[i] as f64 * u[j] as f64;
            let v = (plant + noise) as f32;
            data[i * n + j] = v;
            data[j * n + i] = v;
        }
    }
    PlantedMatrix {
        matrix: m,
        eigvec: u,
        eigval,
    }
}

/// Uniform random dense matrix in `[-0.5, 0.5)` (generic workloads).
pub fn random_dense(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::zeros(rows, cols);
    rng.fill_f32(m.data_mut());
    m
}

/// Row-stochastic "link" matrix for the PageRank example: random sparse-ish
/// column pattern, rows normalized to sum to 1.
pub fn random_stochastic(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::zeros(n, n);
    for r in 0..n {
        // each "page" links to ~log2(n)+2 others
        let k = ((n as f64).log2() as usize + 2).min(n);
        let targets = rng.sample_indices(n, k);
        let w = 1.0 / k as f32;
        for t in targets {
            m.set(r, t, w);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_matrix_is_symmetric() {
        let p = planted_symmetric(64, 10.0, 0.5, 1);
        assert!(p.matrix.is_symmetric(0.0));
    }

    #[test]
    fn planted_eigvec_is_unit() {
        let p = planted_symmetric(64, 10.0, 0.5, 2);
        assert!((ops::norm2(&p.eigvec) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn power_iteration_recovers_plant() {
        let p = planted_symmetric(128, 8.0, 0.4, 3);
        let mut b: Vec<f32> = vec![1.0; 128];
        ops::normalize(&mut b);
        for _ in 0..200 {
            b = p.matrix.matvec(&b).unwrap();
            ops::normalize(&mut b);
        }
        // The noise term perturbs the true dominant eigenvector away from
        // the plant by O(‖E‖/λ·gap), so a small floor remains.
        let nmse = ops::nmse_signless(&b, &p.eigvec);
        assert!(nmse < 0.05, "nmse = {nmse}");
        // Rayleigh quotient ≈ planted eigenvalue
        let ab = p.matrix.matvec(&b).unwrap();
        let lambda = ops::dot(&ab, &b);
        assert!((lambda - 8.0).abs() < 0.5, "lambda = {lambda}");
    }

    #[test]
    fn random_dense_in_range() {
        let m = random_dense(8, 8, 7);
        assert!(m.data().iter().all(|&x| (-0.5..0.5).contains(&x)));
    }

    #[test]
    fn stochastic_rows_sum_to_one() {
        let m = random_stochastic(32, 9);
        for r in 0..32 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = planted_symmetric(16, 5.0, 0.5, 42);
        let b = planted_symmetric(16, 5.0, 0.5, 42);
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.eigvec, b.eigvec);
    }
}
