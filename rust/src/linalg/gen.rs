//! Synthetic matrix generators with controlled spectra.
//!
//! The paper's Fig. 4 uses a dense 6000×6000 symmetric matrix on EC2. We
//! plant a known dominant eigenpair so NMSE against the *true* eigenvector
//! is measurable without an external eigensolver (DESIGN.md §3).
//!
//! Every generator here is **row-seeded**: each row's entries derive from
//! `(seed, row)` (and, for symmetric matrices, from the unordered entry
//! pair), not from a single sequential stream. A shard worker can therefore
//! materialize exactly its placed `J/G` rows — bit-identical to the
//! corresponding rows of the full matrix — without ever holding the `q×r`
//! matrix transiently ([`crate::net::WorkloadSpec::materialize_shard`]).

use crate::linalg::{ops, Matrix};
use crate::util::Rng;

/// SplitMix64 finalizer: a cheap, high-quality 64-bit mixer used to derive
/// independent per-row / per-entry seeds from `(seed, index)`.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Derived seed for one row of a row-seeded generator.
#[inline]
fn row_seed(seed: u64, row: usize) -> u64 {
    mix64(seed ^ (row as u64).wrapping_mul(0xA076_1D64_78BD_642F))
}

/// Symmetric per-entry uniform noise in `[-0.5, 0.5)`: a hash of the
/// *unordered* index pair, so `pair_uniform(s, i, j) == pair_uniform(s, j,
/// i)` by construction and any row can be generated independently.
#[inline]
fn pair_uniform(seed: u64, i: usize, j: usize) -> f64 {
    let (a, b) = if i <= j { (i as u64, j as u64) } else { (j as u64, i as u64) };
    let z = mix64(
        seed ^ a.wrapping_mul(0xD6E8_FEB8_6659_FD93) ^ b.wrapping_mul(0xCA5A_8268_9512_1157),
    );
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64) - 0.5
}

/// A symmetric matrix together with its planted dominant eigenpair.
#[derive(Debug, Clone)]
pub struct PlantedMatrix {
    pub matrix: Matrix,
    /// Unit-norm dominant eigenvector.
    pub eigvec: Vec<f32>,
    /// Dominant eigenvalue.
    pub eigval: f64,
}

/// Row-seeded generator for the planted symmetric workload: `A = λ·u uᵀ +
/// ε·E` with `u` a random unit vector and `E` symmetric uniform noise.
///
/// Construction is **per-row**: `fill_row(i)` derives every entry from the
/// plant (`O(n)` state, the eigenvector) and a symmetric hash of the entry
/// pair — no sequential stream — so a shard worker generates exactly its
/// placed rows, bit-identical to the same rows of [`planted_symmetric`],
/// with `O(n)` peak memory beyond its shard.
#[derive(Debug, Clone)]
pub struct PlantedRows {
    n: usize,
    eigval: f64,
    /// Noise scale (see [`PlantedRows::new`]).
    eps: f64,
    seed: u64,
    /// Unit-norm planted dominant eigenvector.
    pub eigvec: Vec<f32>,
}

impl PlantedRows {
    /// `n` is the dimension; `gap ∈ (0,1)` controls the relative spectral
    /// gap (smaller gap ⇒ slower power-iteration convergence). `ε` is sized
    /// so the noise spectral radius (≈ `ε·√(3n)` w.h.p.) stays below
    /// `gap·λ`, guaranteeing `u` dominates.
    pub fn new(n: usize, eigval: f64, gap: f64, seed: u64) -> PlantedRows {
        assert!(n > 0 && (0.0..1.0).contains(&gap));
        let mut rng = Rng::new(seed);
        // random unit dominant eigenvector (O(n) shared state)
        let mut u: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        ops::normalize(&mut u);
        // noise scale: uniform[-0.5,0.5) entries have variance 1/12;
        // symmetric random matrix spectral norm ≈ 2σ√n = √(n/3); keep it
        // at gap·λ/2.
        let eps = (gap * eigval * 0.5) / (n as f64 / 3.0).sqrt();
        PlantedRows {
            n,
            eigval,
            eps,
            seed,
            eigvec: u,
        }
    }

    /// Matrix dimension.
    pub fn dim(&self) -> usize {
        self.n
    }

    /// Write row `i` of the matrix into `out` (`n` values).
    pub fn fill_row(&self, i: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n);
        let ui = self.eigval * self.eigvec[i] as f64;
        for (j, o) in out.iter_mut().enumerate() {
            let noise = pair_uniform(self.seed, i, j) * self.eps;
            *o = (ui * self.eigvec[j] as f64 + noise) as f32;
        }
    }
}

/// Build `A = λ·u uᵀ + ε·E` as a full matrix (see [`PlantedRows`], which
/// this fills row by row — the two are bit-identical per row).
pub fn planted_symmetric(n: usize, eigval: f64, gap: f64, seed: u64) -> PlantedMatrix {
    let gen = PlantedRows::new(n, eigval, gap, seed);
    let mut m = Matrix::zeros(n, n);
    for i in 0..n {
        gen.fill_row(i, &mut m.data_mut()[i * n..(i + 1) * n]);
    }
    PlantedMatrix {
        matrix: m,
        eigvec: gen.eigvec,
        eigval,
    }
}

/// Write row `row` of the [`random_dense`] matrix for `(seed, cols)` into
/// `out` — the row-seeded primitive shard workers use to materialize only
/// their placed rows.
pub fn random_dense_row_into(cols: usize, seed: u64, row: usize, out: &mut [f32]) {
    debug_assert_eq!(out.len(), cols);
    let mut rng = Rng::new(row_seed(seed, row));
    rng.fill_f32(out);
}

/// Uniform random dense matrix in `[-0.5, 0.5)` (generic workloads),
/// filled row by row from [`random_dense_row_into`].
pub fn random_dense(rows: usize, cols: usize, seed: u64) -> Matrix {
    let mut m = Matrix::zeros(rows, cols);
    for r in 0..rows {
        random_dense_row_into(cols, seed, r, &mut m.data_mut()[r * cols..(r + 1) * cols]);
    }
    m
}

/// Row-stochastic "link" matrix for the PageRank example: random sparse-ish
/// column pattern, rows normalized to sum to 1.
pub fn random_stochastic(n: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let mut m = Matrix::zeros(n, n);
    for r in 0..n {
        // each "page" links to ~log2(n)+2 others
        let k = ((n as f64).log2() as usize + 2).min(n);
        let targets = rng.sample_indices(n, k);
        let w = 1.0 / k as f32;
        for t in targets {
            m.set(r, t, w);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn planted_matrix_is_symmetric() {
        let p = planted_symmetric(64, 10.0, 0.5, 1);
        assert!(p.matrix.is_symmetric(0.0));
    }

    #[test]
    fn planted_eigvec_is_unit() {
        let p = planted_symmetric(64, 10.0, 0.5, 2);
        assert!((ops::norm2(&p.eigvec) - 1.0).abs() < 1e-5);
    }

    #[test]
    fn power_iteration_recovers_plant() {
        let p = planted_symmetric(128, 8.0, 0.4, 3);
        let mut b: Vec<f32> = vec![1.0; 128];
        ops::normalize(&mut b);
        for _ in 0..200 {
            b = p.matrix.matvec(&b).unwrap();
            ops::normalize(&mut b);
        }
        // The noise term perturbs the true dominant eigenvector away from
        // the plant by O(‖E‖/λ·gap), so a small floor remains.
        let nmse = ops::nmse_signless(&b, &p.eigvec);
        assert!(nmse < 0.05, "nmse = {nmse}");
        // Rayleigh quotient ≈ planted eigenvalue
        let ab = p.matrix.matvec(&b).unwrap();
        let lambda = ops::dot(&ab, &b);
        assert!((lambda - 8.0).abs() < 0.5, "lambda = {lambda}");
    }

    #[test]
    fn random_dense_in_range() {
        let m = random_dense(8, 8, 7);
        assert!(m.data().iter().all(|&x| (-0.5..0.5).contains(&x)));
    }

    #[test]
    fn stochastic_rows_sum_to_one() {
        let m = random_stochastic(32, 9);
        for r in 0..32 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = planted_symmetric(16, 5.0, 0.5, 42);
        let b = planted_symmetric(16, 5.0, 0.5, 42);
        assert_eq!(a.matrix, b.matrix);
        assert_eq!(a.eigvec, b.eigvec);
    }

    #[test]
    fn planted_rows_match_full_matrix_bitwise() {
        let n = 48;
        let full = planted_symmetric(n, 9.0, 0.4, 17);
        let rows = PlantedRows::new(n, 9.0, 0.4, 17);
        assert_eq!(rows.dim(), n);
        assert_eq!(rows.eigvec, full.eigvec);
        let mut buf = vec![0.0f32; n];
        // any row, generated in any order, is bit-identical to the full fill
        for i in [31usize, 0, 47, 12] {
            rows.fill_row(i, &mut buf);
            assert_eq!(buf.as_slice(), full.matrix.row(i), "row {i}");
        }
    }

    #[test]
    fn random_dense_rows_match_full_matrix_bitwise() {
        let (rows, cols) = (20, 11);
        let full = random_dense(rows, cols, 91);
        let mut buf = vec![0.0f32; cols];
        for r in [19usize, 0, 7] {
            random_dense_row_into(cols, 91, r, &mut buf);
            assert_eq!(buf.as_slice(), full.row(r), "row {r}");
        }
    }

    #[test]
    fn pair_noise_is_symmetric() {
        for (i, j) in [(0usize, 5usize), (3, 3), (17, 2)] {
            let a = pair_uniform(99, i, j);
            let b = pair_uniform(99, j, i);
            assert_eq!(a, b);
            assert!((-0.5..0.5).contains(&a));
        }
    }
}
