//! Row partitioning: sub-matrices, fractional-assignment quantization, tiles.
//!
//! Three granularities (DESIGN.md §6):
//!
//! 1. **Sub-matrices** — the paper's `G`-way row partition of `X`.
//! 2. **Assignment rows** — the filling algorithm's fractional intervals
//!    quantized to whole rows (largest-remainder, exactly conservative).
//! 3. **Tiles** — fixed `TILE_R`-row blocks matching the AOT-compiled
//!    PJRT executable shape; a worker runs `ceil(len/TILE_R)` executions
//!    per assigned range, zero-padding the final ragged tile.

use crate::error::{Error, Result};

/// A half-open row interval `[lo, hi)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct RowRange {
    pub lo: usize,
    pub hi: usize,
}

impl RowRange {
    pub fn new(lo: usize, hi: usize) -> Self {
        assert!(lo <= hi, "RowRange {lo}..{hi}");
        RowRange { lo, hi }
    }

    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    pub fn contains(&self, row: usize) -> bool {
        self.lo <= row && row < self.hi
    }

    /// Intersection (possibly empty).
    pub fn intersect(&self, other: &RowRange) -> RowRange {
        let lo = self.lo.max(other.lo);
        let hi = self.hi.min(other.hi).max(lo);
        RowRange { lo, hi }
    }

    /// Shift by a base offset (sub-matrix-local → global rows).
    pub fn offset(&self, base: usize) -> RowRange {
        RowRange {
            lo: self.lo + base,
            hi: self.hi + base,
        }
    }

    /// Overflow-checked [`RowRange::offset`] for untrusted inputs (task
    /// ranges arriving off the wire): a huge `lo`/`hi` plus base is an
    /// [`Error::Shape`], not a wrap or a panic.
    pub fn checked_offset(&self, base: usize) -> Result<RowRange> {
        let overflow = || {
            Error::Shape(format!(
                "row range {}..{} + offset {base} overflows usize",
                self.lo, self.hi
            ))
        };
        Ok(RowRange {
            lo: self.lo.checked_add(base).ok_or_else(overflow)?,
            hi: self.hi.checked_add(base).ok_or_else(overflow)?,
        })
    }
}

/// Balanced partition of `q` rows into `g_count` contiguous sub-matrices.
///
/// When `g_count` divides `q` every part has exactly `q/g_count` rows (the
/// paper's setting); otherwise the first `q % g_count` parts get one extra
/// row. The parts tile `[0, q)` exactly.
pub fn submatrix_ranges(q: usize, g_count: usize) -> Result<Vec<RowRange>> {
    if g_count == 0 || q < g_count {
        return Err(Error::Shape(format!(
            "cannot partition {q} rows into {g_count} sub-matrices"
        )));
    }
    let base = q / g_count;
    let extra = q % g_count;
    let mut out = Vec::with_capacity(g_count);
    let mut lo = 0;
    for g in 0..g_count {
        let len = base + usize::from(g < extra);
        out.push(RowRange::new(lo, lo + len));
        lo += len;
    }
    debug_assert_eq!(lo, q);
    Ok(out)
}

/// Quantize fractional interval sizes to whole rows, conserving the total.
///
/// `fractions` are non-negative and sum to (approximately) 1; the result is
/// a list of contiguous [`RowRange`]s covering `[0, rows)` whose lengths are
/// the largest-remainder rounding of `fractions[i] * rows`. Every length
/// differs from its exact value by less than 1 row.
pub fn quantize_fractions(fractions: &[f64], rows: usize) -> Result<Vec<RowRange>> {
    if fractions.is_empty() {
        return Err(Error::Shape("no fractions to quantize".into()));
    }
    let sum: f64 = fractions.iter().sum();
    if fractions.iter().any(|&f| f < -1e-12) || (sum - 1.0).abs() > 1e-6 {
        return Err(Error::Shape(format!(
            "fractions must be >= 0 and sum to 1 (sum = {sum})"
        )));
    }
    let exact: Vec<f64> = fractions.iter().map(|&f| f.max(0.0) * rows as f64).collect();
    let mut lens: Vec<usize> = exact.iter().map(|&e| e.floor() as usize).collect();
    let assigned: usize = lens.iter().sum();
    let mut deficit = rows - assigned.min(rows);
    // distribute the remaining rows by largest fractional remainder
    let mut order: Vec<usize> = (0..fractions.len()).collect();
    order.sort_by(|&a, &b| {
        let ra = exact[a] - exact[a].floor();
        let rb = exact[b] - exact[b].floor();
        rb.partial_cmp(&ra).unwrap().then(a.cmp(&b))
    });
    for &i in &order {
        if deficit == 0 {
            break;
        }
        lens[i] += 1;
        deficit -= 1;
    }
    let mut out = Vec::with_capacity(lens.len());
    let mut lo = 0;
    for len in lens {
        out.push(RowRange::new(lo, lo + len));
        lo += len;
    }
    if lo != rows {
        return Err(Error::Shape(format!(
            "quantization covered {lo} of {rows} rows"
        )));
    }
    Ok(out)
}

/// Tile planner: splits an assigned range into `TILE_R`-row execution units.
#[derive(Debug, Clone, Copy)]
pub struct TilePlan {
    tile: usize,
}

impl TilePlan {
    pub fn new(tile: usize) -> Self {
        assert!(tile > 0);
        TilePlan { tile }
    }

    pub fn tile_rows(&self) -> usize {
        self.tile
    }

    /// Execution units for a range: all `tile` rows except possibly the
    /// last, which is ragged (the executor zero-pads it).
    pub fn plan(&self, range: RowRange) -> Vec<RowRange> {
        let mut out = Vec::with_capacity(range.len().div_ceil(self.tile));
        let mut lo = range.lo;
        while lo < range.hi {
            let hi = (lo + self.tile).min(range.hi);
            out.push(RowRange::new(lo, hi));
            lo = hi;
        }
        out
    }

    /// Number of PJRT executions for a range.
    pub fn count(&self, range: RowRange) -> usize {
        range.len().div_ceil(self.tile)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn submatrix_even_split() {
        let parts = submatrix_ranges(6000, 6).unwrap();
        assert_eq!(parts.len(), 6);
        assert!(parts.iter().all(|p| p.len() == 1000));
        assert_eq!(parts[0].lo, 0);
        assert_eq!(parts[5].hi, 6000);
    }

    #[test]
    fn submatrix_uneven_split_conserves_rows() {
        let parts = submatrix_ranges(10, 3).unwrap();
        let lens: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        assert_eq!(parts.last().unwrap().hi, 10);
    }

    #[test]
    fn submatrix_rejects_degenerate() {
        assert!(submatrix_ranges(3, 0).is_err());
        assert!(submatrix_ranges(2, 3).is_err());
    }

    #[test]
    fn quantize_exact_thirds() {
        let r = quantize_fractions(&[1.0 / 3.0, 1.0 / 3.0, 1.0 / 3.0], 9).unwrap();
        assert_eq!(r.iter().map(|x| x.len()).collect::<Vec<_>>(), vec![3, 3, 3]);
    }

    #[test]
    fn quantize_conserves_total_rows() {
        let fr = [0.143, 0.262, 0.095, 0.5];
        let r = quantize_fractions(&fr, 1000).unwrap();
        assert_eq!(r.iter().map(|x| x.len()).sum::<usize>(), 1000);
        assert_eq!(r.last().unwrap().hi, 1000);
        // each part within 1 row of exact
        for (range, f) in r.iter().zip(fr) {
            assert!((range.len() as f64 - f * 1000.0).abs() < 1.0);
        }
    }

    #[test]
    fn quantize_handles_zero_fractions() {
        let r = quantize_fractions(&[0.0, 1.0, 0.0], 5).unwrap();
        assert_eq!(r[0].len(), 0);
        assert_eq!(r[1].len(), 5);
        assert_eq!(r[2].len(), 0);
    }

    #[test]
    fn quantize_rejects_bad_sum() {
        assert!(quantize_fractions(&[0.5, 0.2], 10).is_err());
        assert!(quantize_fractions(&[-0.1, 1.1], 10).is_err());
    }

    #[test]
    fn tiles_cover_range() {
        let plan = TilePlan::new(512);
        let tiles = plan.plan(RowRange::new(100, 1700));
        assert_eq!(tiles.len(), 4); // 1600 rows → 3 full + 1 ragged
        assert_eq!(tiles[0], RowRange::new(100, 612));
        assert_eq!(tiles.last().unwrap().hi, 1700);
        let covered: usize = tiles.iter().map(|t| t.len()).sum();
        assert_eq!(covered, 1600);
        assert_eq!(plan.count(RowRange::new(100, 1700)), 4);
    }

    #[test]
    fn tile_empty_range() {
        let plan = TilePlan::new(64);
        assert!(plan.plan(RowRange::new(5, 5)).is_empty());
        assert_eq!(plan.count(RowRange::new(5, 5)), 0);
    }

    #[test]
    fn range_ops() {
        let a = RowRange::new(0, 10);
        let b = RowRange::new(5, 15);
        assert_eq!(a.intersect(&b), RowRange::new(5, 10));
        assert!(a.contains(9));
        assert!(!a.contains(10));
        assert_eq!(a.offset(100), RowRange::new(100, 110));
        let disjoint = RowRange::new(20, 30);
        assert!(a.intersect(&disjoint).is_empty());
    }

    #[test]
    fn checked_offset_rejects_overflow() {
        let a = RowRange::new(0, 10);
        assert_eq!(a.checked_offset(5).unwrap(), RowRange::new(5, 15));
        assert!(RowRange::new(usize::MAX - 3, usize::MAX)
            .checked_offset(10)
            .is_err());
    }
}
