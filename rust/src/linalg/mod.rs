//! Dense linear algebra substrate.
//!
//! The data plane of the USEC system: the row-major data matrix `X`, its
//! row partition into `G` sub-matrices and fixed-size tiles, reference
//! mat-vec / norm kernels (used by the host backend and by tests as the
//! oracle for the PJRT path), and synthetic matrix generators with planted
//! spectra for the power-iteration experiments.

pub mod block;
pub mod gen;
pub mod matrix;
pub mod ops;
pub mod partition;
pub mod solve;

pub use block::Block;
pub use matrix::Matrix;
pub use partition::{RowRange, TilePlan};
