//! `usec` — CLI entrypoint. Subcommands are wired up as the library
//! modules land; see `usec help`.

fn main() {
    usec::util::log::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = usec::cli::dispatch(&argv) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
