//! Master ↔ worker message types (in-process transport over mpsc).
//!
//! The data plane stays cheap: the iterate `w_t` is shared via `Arc`, and
//! workers return only their computed row segments (global row ids), so a
//! step moves `O(q)` floats, not `O(q·J)`.

use std::sync::Arc;

use crate::linalg::partition::RowRange;
use crate::optim::Task;

use super::straggler::StraggleMode;

/// One step's work for one worker.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkOrder {
    pub step: usize,
    /// The iterate `w_t` (shared, read-only).
    pub w: Arc<Vec<f32>>,
    /// Assigned tasks (sub-matrix-local row ranges).
    pub tasks: Vec<Task>,
    /// Speed-throttle target: ns per row at speed 1.0 (0 ⇒ no throttle).
    pub row_cost_ns: u64,
    /// Straggler instruction injected by the master's chaos layer.
    pub straggle: Option<StraggleMode>,
}

/// One computed segment: global rows `[rows.lo, rows.hi)` of `y`.
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    pub rows: RowRange,
    pub values: Vec<f32>,
}

/// A worker's report for one step.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerReport {
    pub worker: usize,
    pub step: usize,
    /// Computed segments in *global* row coordinates.
    pub segments: Vec<Segment>,
    /// Measured speed `ν[n] = μ[n]/(τ₂−τ₁)` in sub-matrix units/s
    /// (Algorithm 1 line 14); `None` when no work was assigned.
    pub measured_speed: Option<f64>,
    /// Worker-side elapsed time.
    pub elapsed: std::time::Duration,
}

/// Master → worker control/data messages.
#[derive(Debug)]
pub enum ToWorker {
    Work(WorkOrder),
    Shutdown,
}

/// Worker → master messages.
#[derive(Debug)]
pub enum ToMaster {
    Report(WorkerReport),
    /// A worker died (panic or backend failure) — failure injection path.
    Failed { worker: usize, step: usize, error: String },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_carries_global_rows() {
        let s = Segment {
            rows: RowRange::new(100, 104),
            values: vec![1.0; 4],
        };
        assert_eq!(s.rows.len(), s.values.len());
    }

    #[test]
    fn work_order_shares_iterate() {
        let w = Arc::new(vec![0.5f32; 8]);
        let o1 = WorkOrder {
            step: 0,
            w: Arc::clone(&w),
            tasks: vec![],
            row_cost_ns: 0,
            straggle: None,
        };
        let o2 = WorkOrder {
            step: 0,
            w: Arc::clone(&w),
            tasks: vec![],
            row_cost_ns: 0,
            straggle: None,
        };
        assert_eq!(Arc::strong_count(&w), 3);
        drop((o1, o2));
        assert_eq!(Arc::strong_count(&w), 1);
    }
}
