//! Master ↔ worker message types (in-process transport over mpsc).
//!
//! The data plane stays cheap: the iterate block `W_t` (B vectors,
//! [`Block`]) is shared via `Arc`, and workers return only their computed
//! row segments (global row ids), so a step moves `O(q·B)` floats, not
//! `O(q·J·B)`. With `B = 1` everything degenerates to the classic
//! single-vector plane — same layout, same bytes.

use std::sync::Arc;

use crate::linalg::partition::RowRange;
use crate::linalg::Block;
use crate::optim::Task;

use super::straggler::StraggleMode;

/// One step's work for one worker.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkOrder {
    pub step: usize,
    /// The iterate block `W_t` (`B` vectors, shared, read-only). `B = 1`
    /// is the classic power-iteration plane.
    pub w: Arc<Block>,
    /// Assigned tasks (sub-matrix-local row ranges).
    pub tasks: Vec<Task>,
    /// Speed-throttle target: ns per row at speed 1.0 (0 ⇒ no throttle).
    pub row_cost_ns: u64,
    /// Straggler instruction injected by the master's chaos layer.
    pub straggle: Option<StraggleMode>,
    /// Tracing request: when set the worker measures a per-phase timing
    /// breakdown and ships it back on the report ([`crate::obs`]). On the
    /// wire this is an optional trailing byte (v5) so untraced orders
    /// keep the v4 layout bit-for-bit.
    pub trace: bool,
}

/// One computed segment: global rows `[rows.lo, rows.hi)` of `Y`,
/// `values[i*B + k]` being row `rows.lo + i` of product vector `k`
/// (`B` = the report's [`WorkerReport::nvec`]).
#[derive(Debug, Clone, PartialEq)]
pub struct Segment {
    pub rows: RowRange,
    pub values: Vec<f32>,
}

/// A worker's report for one step.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkerReport {
    pub worker: usize,
    pub step: usize,
    /// Computed segments in *global* row coordinates, `rows × nvec`
    /// interleaved values each.
    pub segments: Vec<Segment>,
    /// Block width `B` of the order this report answers (1 on the classic
    /// single-vector plane).
    pub nvec: usize,
    /// Measured speed `ν[n] = μ[n]/(τ₂−τ₁)` in sub-matrix units/s
    /// (Algorithm 1 line 14); `None` when no work was assigned.
    pub measured_speed: Option<f64>,
    /// Worker-side elapsed time.
    pub elapsed: std::time::Duration,
    /// Per-phase timing breakdown, present only when the order asked for
    /// tracing ([`WorkOrder::trace`]). Optional trailing section on the
    /// wire (v5); reports without it are byte-identical to v4.
    pub breakdown: Option<crate::obs::OrderBreakdown>,
}

/// Master → worker control/data messages.
#[derive(Debug)]
pub enum ToWorker {
    Work(WorkOrder),
    /// Replace the worker's storage handle in place — the local-transport
    /// half of live shard migration ([`crate::rebalance`]): the new
    /// [`WorkerStorage`](crate::sched::worker::WorkerStorage) arrives as a
    /// zero-copy `Arc` and is swapped in between orders.
    SwapStorage(crate::sched::worker::WorkerStorage),
    Shutdown,
}

/// Worker → master messages.
#[derive(Debug)]
pub enum ToMaster {
    Report(WorkerReport),
    /// A worker died (panic or backend failure) — failure injection path.
    Failed { worker: usize, step: usize, error: String },
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segment_carries_global_rows() {
        let s = Segment {
            rows: RowRange::new(100, 104),
            values: vec![1.0; 4],
        };
        assert_eq!(s.rows.len(), s.values.len());
    }

    #[test]
    fn block_segment_carries_rows_times_nvec() {
        let nvec = 3;
        let s = Segment {
            rows: RowRange::new(10, 14),
            values: vec![0.5; 4 * nvec],
        };
        assert_eq!(s.values.len(), s.rows.len() * nvec);
    }

    #[test]
    fn work_order_shares_iterate() {
        let w = Arc::new(Block::single(vec![0.5f32; 8]));
        let o1 = WorkOrder {
            step: 0,
            w: Arc::clone(&w),
            tasks: vec![],
            row_cost_ns: 0,
            straggle: None,
            trace: false,
        };
        let o2 = WorkOrder {
            step: 0,
            w: Arc::clone(&w),
            tasks: vec![],
            row_cost_ns: 0,
            straggle: None,
            trace: false,
        };
        assert_eq!(Arc::strong_count(&w), 3);
        drop((o1, o2));
        assert_eq!(Arc::strong_count(&w), 1);
    }
}
