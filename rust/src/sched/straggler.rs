//! Straggler injection (Fig. 4 bottom: "2 stragglers each iteration").
//!
//! A straggler is a machine that accepted work but fails to report in
//! time. The injector picks `k` victims uniformly from the available set
//! each step; victims either never report (`Drop`) or report after a
//! multiplicative slowdown (`Slow`). The master must still recover `y_t`
//! from the remaining reports whenever the assignment tolerates `S ≥ k`.
//!
//! Victims are drawn from an RNG derived from `(seed, step)` — not from a
//! stream advanced once per call — so a run resumed from a `--checkpoint`
//! snapshot replays exactly the victim schedule the uninterrupted run
//! would have seen (the same scheme the chaos fault rolls use).

use crate::util::Rng;

/// What an injected straggler does with its work order.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StraggleMode {
    /// Never report this step.
    Drop,
    /// Report, but `factor`× slower than its throttle target.
    Slow(f64),
}

/// Per-step straggler chooser.
#[derive(Debug, Clone)]
pub struct StragglerInjector {
    per_step: usize,
    mode: StraggleMode,
    seed: u64,
    /// When set, the same machines straggle every step (the "overloaded
    /// instance" reading of the paper's EC2 stragglers) instead of fresh
    /// uniform victims per step.
    fixed: Option<Vec<usize>>,
}

impl StragglerInjector {
    pub fn none() -> Self {
        StragglerInjector {
            per_step: 0,
            mode: StraggleMode::Drop,
            seed: 0,
            fixed: None,
        }
    }

    pub fn new(per_step: usize, mode: StraggleMode, seed: u64) -> Self {
        StragglerInjector {
            per_step,
            mode,
            seed,
            fixed: None,
        }
    }

    /// The same `victims` straggle every step.
    pub fn fixed(victims: Vec<usize>, mode: StraggleMode) -> Self {
        StragglerInjector {
            per_step: victims.len(),
            mode,
            seed: 0,
            fixed: Some(victims),
        }
    }

    pub fn per_step(&self) -> usize {
        self.per_step
    }

    /// Choose victims for `step`: a map `machine → mode` (victims only).
    /// Pure in `(seed, step, avail)`, so the schedule is replayable from
    /// any resume point.
    pub fn choose(&self, step: usize, avail: &[usize]) -> Vec<(usize, StraggleMode)> {
        if let Some(victims) = &self.fixed {
            return victims
                .iter()
                .filter(|v| avail.contains(v))
                .map(|&v| (v, self.mode))
                .collect();
        }
        let k = self.per_step.min(avail.len().saturating_sub(1));
        if k == 0 {
            return Vec::new();
        }
        let mut rng = Rng::new(self.seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let picks = rng.sample_indices(avail.len(), k);
        picks.into_iter().map(|i| (avail[i], self.mode)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_injects_nothing() {
        let inj = StragglerInjector::none();
        assert!(inj.choose(0, &[0, 1, 2]).is_empty());
    }

    #[test]
    fn chooses_k_distinct_victims_from_avail() {
        let inj = StragglerInjector::new(2, StraggleMode::Drop, 3);
        for step in 0..50 {
            let v = inj.choose(step, &[1, 3, 5, 7, 9]);
            assert_eq!(v.len(), 2);
            let mut ms: Vec<usize> = v.iter().map(|&(m, _)| m).collect();
            ms.sort_unstable();
            ms.dedup();
            assert_eq!(ms.len(), 2);
            assert!(ms.iter().all(|m| [1, 3, 5, 7, 9].contains(m)));
        }
    }

    #[test]
    fn never_stragglers_everyone() {
        // keeps at least one non-straggler even if per_step >= |avail|
        let inj = StragglerInjector::new(5, StraggleMode::Drop, 4);
        let v = inj.choose(0, &[0, 1, 2]);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn victims_vary_across_steps() {
        let inj = StragglerInjector::new(1, StraggleMode::Drop, 9);
        let mut seen = std::collections::BTreeSet::new();
        for step in 0..60 {
            for (m, _) in inj.choose(step, &[0, 1, 2, 3, 4, 5]) {
                seen.insert(m);
            }
        }
        assert!(seen.len() >= 4, "victims not spread: {seen:?}");
    }

    #[test]
    fn schedule_is_replayable_from_any_step() {
        // choosing step 7 cold gives the same victims as choosing it
        // after a full pass 0..7 — the resume guarantee
        let inj = StragglerInjector::new(2, StraggleMode::Slow(4.0), 21);
        let avail = [0, 1, 2, 3, 4, 5, 6];
        let mut warm = Vec::new();
        for step in 0..8 {
            warm.push(inj.choose(step, &avail));
        }
        let fresh = StragglerInjector::new(2, StraggleMode::Slow(4.0), 21);
        assert_eq!(fresh.choose(7, &avail), warm[7]);
        assert_eq!(fresh.choose(3, &avail), warm[3]);
        // and two injectors with the same seed agree step by step
        for (step, w) in warm.iter().enumerate() {
            assert_eq!(&fresh.choose(step, &avail), w);
        }
    }
}
