//! Mid-step recovery: re-dispatch a victim's uncovered rows to survivors.
//!
//! Uncoded storage makes mid-step failure recoverable *without decoding*:
//! every sub-matrix already sits, plain, on `J` machines, so when a worker
//! dies (or goes silent) after the step's orders shipped, the master can
//! re-plan exactly the rows that worker still owed onto surviving replicas
//! and finish the same step — no `S ≥ 1` redundancy and no coverage
//! timeout needed. This module holds the policy knob
//! ([`RecoveryPolicy`]), the per-step bookkeeping
//! ([`RecoveryTracker`]: who owes which global rows, which orders are
//! still unanswered), and the event record surfaced through
//! [`crate::metrics::Timeline`] / `--json-out` ([`RecoveryEvent`]). The
//! restricted assignment itself is solved in
//! [`crate::optim::recovery::plan_recovery`].
//!
//! Three triggers share one path ([`RecoveryReason`]):
//!
//! * **Disconnected** — the transport reports the worker's channel dead
//!   (socket kill, daemon crash, closed mpsc), including a dispatch-time
//!   send failure.
//! * **Failed** — the worker replied with an execution failure for this
//!   step (backend error, shard residency violation).
//! * **Overdue** — the worker is silent past `overdue_factor` of the
//!   master's recovery timeout; this rescues *silent* droppers that
//!   otherwise could only time the whole step out.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::linalg::partition::RowRange;
use crate::optim::Task;

/// Master-side recovery configuration (static across steps).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RecoveryPolicy {
    /// Re-dispatch a victim's uncovered rows to surviving replicas instead
    /// of letting redundancy or the coverage timeout decide. `false` (the
    /// default) preserves the classic behaviour bit for bit.
    pub enabled: bool,
    /// Fraction of the recovery timeout after which a dispatched-to worker
    /// with an unanswered order is declared overdue and recovered, which
    /// also rescues silent droppers. Must be in `(0, 1]` when enabled.
    pub overdue_factor: f64,
}

impl Default for RecoveryPolicy {
    fn default() -> Self {
        RecoveryPolicy {
            enabled: false,
            overdue_factor: 0.5,
        }
    }
}

impl RecoveryPolicy {
    /// Recovery on, with the default overdue factor.
    pub fn enabled() -> Self {
        RecoveryPolicy {
            enabled: true,
            ..Default::default()
        }
    }

    /// Structural sanity (checked by [`crate::sched::Master::new`] and
    /// [`crate::config::RunConfig::validate`]).
    pub fn validate(&self) -> Result<()> {
        if self.enabled && !(self.overdue_factor > 0.0 && self.overdue_factor <= 1.0) {
            return Err(Error::Config(format!(
                "recovery overdue factor {} not in (0, 1]",
                self.overdue_factor
            )));
        }
        Ok(())
    }

    /// How long an unanswered order may sit before its worker is overdue.
    pub fn overdue_delay(&self, recovery_timeout: Duration) -> Duration {
        recovery_timeout.mul_f64(self.overdue_factor)
    }
}

/// Why a worker's rows were re-dispatched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryReason {
    /// Channel death (socket loss / dispatch failure) mid-step.
    Disconnected,
    /// The worker reported an execution failure for this step.
    Failed,
    /// Silent past the overdue fraction of the recovery timeout.
    Overdue,
}

impl RecoveryReason {
    pub fn name(&self) -> &'static str {
        match self {
            RecoveryReason::Disconnected => "disconnected",
            RecoveryReason::Failed => "failed",
            RecoveryReason::Overdue => "overdue",
        }
    }
}

/// One mid-step recovery, as surfaced per step in
/// [`crate::metrics::Timeline`] and `--json-out`.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryEvent {
    pub step: usize,
    /// The worker whose rows were re-dispatched.
    pub victim: usize,
    pub reason: RecoveryReason,
    /// Uncovered rows re-dispatched (global row count).
    pub rows: usize,
    /// Workers that received supplementary orders, sorted.
    pub rescuers: Vec<usize>,
}

/// Per-step bookkeeping: which global rows each dispatched order implied,
/// and which orders are still unanswered (for overdue detection).
#[derive(Debug)]
pub struct RecoveryTracker {
    /// Per worker: `(g, global rows)` responsibility accumulated over the
    /// original order plus any supplementary recovery orders.
    responsibility: Vec<Vec<(usize, RowRange)>>,
    /// Per worker: dispatch instants of orders not yet answered by any
    /// report (FIFO; a report answers the oldest outstanding order).
    outstanding: Vec<VecDeque<Instant>>,
    /// Workers already recovered this step (never recovered twice, and
    /// excluded from the survivor set).
    victim: Vec<bool>,
    /// Workers whose channel proved dead (dispatch or recovery send
    /// failure, disconnect) — excluded from the survivor set.
    unreachable: Vec<bool>,
}

impl RecoveryTracker {
    pub fn new(machines: usize) -> RecoveryTracker {
        RecoveryTracker {
            responsibility: vec![Vec::new(); machines],
            outstanding: vec![VecDeque::new(); machines],
            victim: vec![false; machines],
            unreachable: vec![false; machines],
        }
    }

    /// Record the global-row responsibility an order's tasks imply
    /// (whether or not the send later succeeds — a failed dispatch still
    /// leaves rows to recover).
    pub fn assign(&mut self, worker: usize, tasks: &[Task], sub_ranges: &[RowRange]) {
        for t in tasks {
            if !t.rows.is_empty() {
                self.responsibility[worker].push((t.g, t.rows.offset(sub_ranges[t.g].lo)));
            }
        }
    }

    /// Record one successfully shipped order (overdue clock starts).
    pub fn note_order_sent(&mut self, worker: usize, at: Instant) {
        self.outstanding[worker].push_back(at);
    }

    /// A report from `worker` answers its oldest outstanding order.
    pub fn note_report(&mut self, worker: usize) {
        self.outstanding[worker].pop_front();
    }

    pub fn mark_victim(&mut self, worker: usize) {
        self.victim[worker] = true;
    }

    pub fn is_victim(&self, worker: usize) -> bool {
        self.victim[worker]
    }

    pub fn mark_unreachable(&mut self, worker: usize) {
        self.unreachable[worker] = true;
    }

    /// Available workers that can still take supplementary orders.
    pub fn survivors(&self, avail: &[usize]) -> Vec<usize> {
        avail
            .iter()
            .copied()
            .filter(|&n| !self.victim[n] && !self.unreachable[n])
            .collect()
    }

    /// The still-uncovered subset of `worker`'s responsibility, as maximal
    /// `(g, global rows)` runs. Overlapping responsibility spans (a rescuer
    /// that later became a victim, `S > 0` row sets) are merged first so no
    /// row is counted or re-dispatched twice.
    pub fn uncovered_rows(&self, worker: usize, covered: &[bool]) -> Vec<(usize, RowRange)> {
        let mut by_sub: BTreeMap<usize, Vec<RowRange>> = BTreeMap::new();
        for &(g, r) in &self.responsibility[worker] {
            by_sub.entry(g).or_default().push(r);
        }
        let mut out = Vec::new();
        for (g, mut spans) in by_sub {
            spans.sort_by_key(|r| r.lo);
            let mut merged: Vec<RowRange> = Vec::new();
            for r in spans {
                match merged.last_mut() {
                    Some(last) if r.lo <= last.hi => last.hi = last.hi.max(r.hi),
                    _ => merged.push(r),
                }
            }
            for span in merged {
                let mut run_lo = None;
                for row in span.lo..span.hi {
                    match (covered[row], run_lo) {
                        (false, None) => run_lo = Some(row),
                        (true, Some(lo)) => {
                            out.push((g, RowRange::new(lo, row)));
                            run_lo = None;
                        }
                        _ => {}
                    }
                }
                if let Some(lo) = run_lo {
                    out.push((g, RowRange::new(lo, span.hi)));
                }
            }
        }
        out
    }

    /// First non-victim worker whose oldest unanswered order is older than
    /// `delay`.
    pub fn overdue_victim(&self, now: Instant, delay: Duration) -> Option<usize> {
        self.outstanding.iter().enumerate().find_map(|(n, q)| {
            match (self.victim[n], q.front()) {
                (false, Some(&sent)) if now.saturating_duration_since(sent) >= delay => Some(n),
                _ => None,
            }
        })
    }

    /// Earliest instant at which some non-victim worker becomes overdue
    /// (bounds the master's receive wait so silence is noticed on time).
    pub fn next_overdue_at(&self, delay: Duration) -> Option<Instant> {
        self.outstanding
            .iter()
            .enumerate()
            .filter(|&(n, _)| !self.victim[n])
            .filter_map(|(_, q)| q.front())
            .min()
            .map(|&sent| sent + delay)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(g: usize, lo: usize, hi: usize) -> Task {
        Task {
            g,
            rows: RowRange::new(lo, hi),
        }
    }

    fn sub_ranges() -> Vec<RowRange> {
        vec![RowRange::new(0, 10), RowRange::new(10, 20)]
    }

    #[test]
    fn policy_validation() {
        RecoveryPolicy::default().validate().unwrap();
        RecoveryPolicy::enabled().validate().unwrap();
        for bad in [0.0, -0.5, 1.5] {
            let p = RecoveryPolicy {
                enabled: true,
                overdue_factor: bad,
            };
            assert!(p.validate().is_err(), "factor {bad} accepted");
        }
        // a disabled policy never consults the factor
        let off = RecoveryPolicy {
            enabled: false,
            overdue_factor: 9.0,
        };
        off.validate().unwrap();
        let d = RecoveryPolicy::enabled().overdue_delay(Duration::from_secs(10));
        assert_eq!(d, Duration::from_secs(5));
    }

    #[test]
    fn uncovered_rows_tracks_coverage_runs() {
        let mut t = RecoveryTracker::new(2);
        t.assign(0, &[task(0, 2, 8), task(1, 0, 4)], &sub_ranges());
        let mut covered = vec![false; 20];
        // cover global rows 4..6 (inside the first span) and 10..12
        for row in 4..6 {
            covered[row] = true;
        }
        for row in 10..12 {
            covered[row] = true;
        }
        let got = t.uncovered_rows(0, &covered);
        assert_eq!(
            got,
            vec![
                (0, RowRange::new(2, 4)),
                (0, RowRange::new(6, 8)),
                (1, RowRange::new(12, 14)),
            ]
        );
        // fully covered ⇒ nothing to recover
        let all = vec![true; 20];
        assert!(t.uncovered_rows(0, &all).is_empty());
        // the other worker owes nothing
        assert!(t.uncovered_rows(1, &covered).is_empty());
    }

    #[test]
    fn overlapping_responsibility_merges() {
        let mut t = RecoveryTracker::new(1);
        t.assign(0, &[task(0, 0, 6)], &sub_ranges());
        t.assign(0, &[task(0, 4, 10)], &sub_ranges()); // supplementary, overlaps
        let covered = vec![false; 20];
        assert_eq!(t.uncovered_rows(0, &covered), vec![(0, RowRange::new(0, 10))]);
    }

    #[test]
    fn overdue_follows_outstanding_orders() {
        let delay = Duration::from_millis(50);
        let mut t = RecoveryTracker::new(2);
        let t0 = Instant::now();
        t.note_order_sent(0, t0);
        t.note_order_sent(1, t0);
        assert_eq!(t.overdue_victim(t0, delay), None);
        assert_eq!(t.next_overdue_at(delay), Some(t0 + delay));
        // worker 0 answers; only worker 1 can go overdue
        t.note_report(0);
        let late = t0 + Duration::from_millis(60);
        assert_eq!(t.overdue_victim(late, delay), Some(1));
        // a marked victim is never reported overdue again
        t.mark_victim(1);
        assert_eq!(t.overdue_victim(late, delay), None);
        assert_eq!(t.next_overdue_at(delay), None);
    }

    #[test]
    fn survivors_exclude_victims_and_unreachable() {
        let mut t = RecoveryTracker::new(4);
        t.mark_victim(1);
        t.mark_unreachable(3);
        assert_eq!(t.survivors(&[0, 1, 2, 3]), vec![0, 2]);
    }
}
