//! Elasticity traces: which machines are available at each step.
//!
//! Substitutes the cloud provider's preemption behaviour (DESIGN.md §3):
//! the algorithms only ever observe the availability set `N_t`, so a trace
//! generator exercising preemptions/arrivals reproduces the paper's
//! environment. Three modes: static, scripted, and a Bernoulli birth-death
//! process with a floor on `|N_t|`.

use crate::util::Rng;

/// Availability-set generator.
#[derive(Debug, Clone)]
pub enum ElasticityTrace {
    /// All `n` machines available every step.
    Static { n: usize },
    /// Explicit per-step availability lists (cycled if shorter than the
    /// run). Useful for regression tests and replaying recorded traces.
    Scripted { steps: Vec<Vec<usize>>, cursor: usize },
    /// Birth-death process: each available machine is preempted with
    /// probability `preempt` per step; each preempted machine returns with
    /// probability `arrive`. `|N_t|` never drops below `min_available`.
    Bernoulli {
        state: Vec<bool>,
        preempt: f64,
        arrive: f64,
        min_available: usize,
        rng: Rng,
    },
}

impl ElasticityTrace {
    pub fn static_all(n: usize) -> Self {
        ElasticityTrace::Static { n }
    }

    pub fn scripted(steps: Vec<Vec<usize>>) -> Self {
        assert!(!steps.is_empty(), "scripted trace needs at least one step");
        ElasticityTrace::Scripted { steps, cursor: 0 }
    }

    pub fn bernoulli(n: usize, preempt: f64, arrive: f64, min_available: usize, seed: u64) -> Self {
        assert!(min_available <= n);
        ElasticityTrace::Bernoulli {
            state: vec![true; n],
            preempt,
            arrive,
            min_available,
            rng: Rng::new(seed),
        }
    }

    /// Availability set for the next step (sorted machine ids, non-empty
    /// unless a scripted step is empty).
    pub fn next_step(&mut self) -> Vec<usize> {
        match self {
            ElasticityTrace::Static { n } => (0..*n).collect(),
            ElasticityTrace::Scripted { steps, cursor } => {
                let s = steps[*cursor % steps.len()].clone();
                *cursor += 1;
                s
            }
            ElasticityTrace::Bernoulli {
                state,
                preempt,
                arrive,
                min_available,
                rng,
            } => {
                // arrivals first (preempted machines may come back)
                for up in state.iter_mut() {
                    if !*up && rng.chance(*arrive) {
                        *up = true;
                    }
                }
                // preemptions, respecting the floor
                let mut up_count = state.iter().filter(|&&u| u).count();
                for i in 0..state.len() {
                    if state[i] && up_count > *min_available && rng.chance(*preempt) {
                        state[i] = false;
                        up_count -= 1;
                    }
                }
                // never return an empty set — resurrect one machine
                if up_count == 0 {
                    let i = rng.below(state.len());
                    state[i] = true;
                }
                (0..state.len()).filter(|&i| state[i]).collect()
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_trace_is_constant() {
        let mut t = ElasticityTrace::static_all(4);
        assert_eq!(t.next_step(), vec![0, 1, 2, 3]);
        assert_eq!(t.next_step(), vec![0, 1, 2, 3]);
    }

    #[test]
    fn scripted_trace_cycles() {
        let mut t = ElasticityTrace::scripted(vec![vec![0, 1], vec![2]]);
        assert_eq!(t.next_step(), vec![0, 1]);
        assert_eq!(t.next_step(), vec![2]);
        assert_eq!(t.next_step(), vec![0, 1]);
    }

    #[test]
    fn bernoulli_respects_floor() {
        let mut t = ElasticityTrace::bernoulli(6, 0.9, 0.0, 3, 42);
        for _ in 0..50 {
            let a = t.next_step();
            assert!(a.len() >= 3, "floor violated: {a:?}");
        }
    }

    #[test]
    fn bernoulli_never_empty_even_without_floor() {
        let mut t = ElasticityTrace::bernoulli(3, 1.0, 0.0, 0, 7);
        for _ in 0..20 {
            assert!(!t.next_step().is_empty());
        }
    }

    #[test]
    fn bernoulli_machines_return() {
        let mut t = ElasticityTrace::bernoulli(4, 0.5, 0.5, 0, 11);
        let mut seen_counts = std::collections::BTreeSet::new();
        for _ in 0..200 {
            seen_counts.insert(t.next_step().len());
        }
        // the process must actually move around
        assert!(seen_counts.len() > 1, "trace never changed: {seen_counts:?}");
    }

    #[test]
    fn bernoulli_deterministic_by_seed() {
        let mut a = ElasticityTrace::bernoulli(6, 0.3, 0.3, 1, 5);
        let mut b = ElasticityTrace::bernoulli(6, 0.3, 0.3, 1, 5);
        for _ in 0..20 {
            assert_eq!(a.next_step(), b.next_step());
        }
    }
}
