//! The elastic coordinator — the paper's Algorithm 1.
//!
//! A master thread drives `T` computation steps. Each step it:
//!
//! 1. reads the availability set `N_t` from an [`elastic::ElasticityTrace`],
//! 2. re-solves the computation assignment for the current speed estimates
//!    (`optim::build_assignment` — LP + filling algorithm),
//! 3. ships `(w_t, tasks)` to the available workers ([`protocol`]),
//! 4. waits until the received reports *cover* every row (at most
//!    `N_t − S` workers needed by construction),
//! 5. assembles `y_t = X w_t`, normalizes, and
//! 6. updates the per-machine speed estimates with an EWMA
//!    ([`speed::SpeedEstimator`], Algorithm 1 line 4) from the measured
//!    speeds the workers report (line 14).
//!
//! Workers are OS threads with a per-machine speed *throttle* simulating
//! the paper's heterogeneous EC2 VMs (DESIGN.md §3), and a
//! [`straggler::StragglerInjector`] can mark workers as dropped/slow per
//! step (Fig. 4 bottom).
//!
//! With [`recovery::RecoveryPolicy`] enabled, step 4 additionally
//! *re-plans mid-step*: a worker that disconnects, fails, or goes overdue
//! has its uncovered rows re-dispatched to surviving replicas
//! ([`recovery`]), so an `S = 0` step survives preemption instead of
//! timing out.

pub mod checkpoint;
pub mod cluster;
pub mod elastic;
pub mod master;
pub mod protocol;
pub mod recovery;
pub mod sim;
pub mod speed;
pub mod straggler;
pub mod timer;
pub mod worker;

pub use checkpoint::{Checkpoint, CheckpointWriter, CHECKPOINT_VERSION};
pub use cluster::Cluster;
pub use elastic::ElasticityTrace;
pub use master::{Master, RunResult};
pub use recovery::{RecoveryEvent, RecoveryPolicy, RecoveryReason};
pub use speed::SpeedEstimator;
pub use timer::{DeadlineKind, TimerWheel};
pub use straggler::StragglerInjector;
