//! Single timer wheel for the master's bounded waits.
//!
//! Before the pipelined master, every wait site computed its own bound:
//! the collect loop re-derived the coverage remainder *and* the next
//! overdue instant on every received event, and the TCP migration path
//! carried its own ack deadline. The wheel replaces those scattered
//! per-wait bounds with one registry of named deadlines: arm or clear a
//! deadline when the state behind it actually changes, then size every
//! blocking `recv_timeout` off [`TimerWheel::wait_from`] — the earliest
//! armed instant decides the sleep. A burst of events cannot starve a
//! deadline, because handling an event no longer re-derives it unless
//! that event mutated the state the deadline watches (see the
//! regression test in [`crate::sched::master`]).

use std::time::{Duration, Instant};

/// The named deadlines a master wait can be bounded by.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeadlineKind {
    /// The step's coverage timeout (`recovery_timeout` from dispatch).
    Coverage,
    /// The earliest unanswered order going overdue — recovery's
    /// silent-dropper clock ([`crate::sched::recovery`]).
    Overdue,
    /// A migration ack the transfer lane is waiting on.
    MigrateAck,
    /// The next heartbeat-liveness check.
    Heartbeat,
    /// The earliest backed-off retry (dial/readmit) becoming eligible —
    /// see [`crate::util::retry`].
    Retry,
}

impl DeadlineKind {
    pub const ALL: [DeadlineKind; 5] = [
        DeadlineKind::Coverage,
        DeadlineKind::Overdue,
        DeadlineKind::MigrateAck,
        DeadlineKind::Heartbeat,
        DeadlineKind::Retry,
    ];

    fn slot(self) -> usize {
        match self {
            DeadlineKind::Coverage => 0,
            DeadlineKind::Overdue => 1,
            DeadlineKind::MigrateAck => 2,
            DeadlineKind::Heartbeat => 3,
            DeadlineKind::Retry => 4,
        }
    }
}

/// Fixed-slot deadline registry. Five named slots — no allocation and no
/// ordering structure needed at this cardinality; `next_due` is a scan.
#[derive(Debug, Default)]
pub struct TimerWheel {
    slots: [Option<Instant>; 5],
}

impl TimerWheel {
    pub fn new() -> TimerWheel {
        TimerWheel { slots: [None; 5] }
    }

    /// Arm (or re-arm) a deadline.
    pub fn set(&mut self, kind: DeadlineKind, at: Instant) {
        self.slots[kind.slot()] = Some(at);
    }

    /// Disarm a deadline.
    pub fn clear(&mut self, kind: DeadlineKind) {
        self.slots[kind.slot()] = None;
    }

    pub fn get(&self, kind: DeadlineKind) -> Option<Instant> {
        self.slots[kind.slot()]
    }

    /// The earliest armed deadline, if any.
    pub fn next_due(&self) -> Option<(DeadlineKind, Instant)> {
        DeadlineKind::ALL
            .iter()
            .filter_map(|&k| self.get(k).map(|at| (k, at)))
            .min_by_key(|&(_, at)| at)
    }

    /// True when `kind` is armed and `now` has reached it.
    pub fn due(&self, kind: DeadlineKind, now: Instant) -> bool {
        self.get(kind).is_some_and(|at| now >= at)
    }

    /// Bound for the next blocking receive: the time from `now` until
    /// the earliest armed deadline, floored at 1 ms so a just-passed
    /// deadline still yields a real (non-busy) wait — callers handle
    /// due deadlines *before* sleeping. `None` when nothing is armed.
    pub fn wait_from(&self, now: Instant) -> Option<Duration> {
        self.next_due().map(|(_, at)| {
            at.saturating_duration_since(now)
                .max(Duration::from_millis(1))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn earliest_armed_deadline_wins() {
        let now = Instant::now();
        let mut wheel = TimerWheel::new();
        assert_eq!(wheel.next_due(), None);
        assert_eq!(wheel.wait_from(now), None);

        wheel.set(DeadlineKind::Coverage, now + Duration::from_secs(10));
        wheel.set(DeadlineKind::Overdue, now + Duration::from_secs(2));
        let (kind, at) = wheel.next_due().unwrap();
        assert_eq!(kind, DeadlineKind::Overdue);
        assert_eq!(at, now + Duration::from_secs(2));

        // the overdue clock disarms ⇒ coverage becomes the bound
        wheel.clear(DeadlineKind::Overdue);
        assert_eq!(wheel.next_due().unwrap().0, DeadlineKind::Coverage);
    }

    #[test]
    fn due_and_wait_floor() {
        let now = Instant::now();
        let mut wheel = TimerWheel::new();
        wheel.set(DeadlineKind::MigrateAck, now);
        assert!(wheel.due(DeadlineKind::MigrateAck, now));
        assert!(!wheel.due(DeadlineKind::Heartbeat, now));
        // a passed deadline still yields a non-busy 1 ms wait
        assert_eq!(
            wheel.wait_from(now + Duration::from_secs(1)),
            Some(Duration::from_millis(1))
        );
        // a future deadline yields its actual remainder
        wheel.set(DeadlineKind::MigrateAck, now + Duration::from_secs(5));
        let w = wheel.wait_from(now).unwrap();
        assert!(w > Duration::from_secs(4) && w <= Duration::from_secs(5));
    }

    #[test]
    fn rearming_replaces_the_slot() {
        let now = Instant::now();
        let mut wheel = TimerWheel::new();
        wheel.set(DeadlineKind::Overdue, now + Duration::from_secs(9));
        wheel.set(DeadlineKind::Overdue, now + Duration::from_secs(1));
        assert_eq!(
            wheel.get(DeadlineKind::Overdue),
            Some(now + Duration::from_secs(1))
        );
    }
}
