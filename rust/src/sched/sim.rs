//! Step-synchronous cluster simulator (virtual time, no threads).
//!
//! The real system ([`super::master`]) is step-synchronous by construction
//! — one assignment, one barrier, one combine per step — so a faithful
//! simulator needs no event queue: per step it solves the assignment with
//! the master's *estimated* speeds, realizes the step time against the
//! *true* (drifting, noisy) speeds, and feeds measurements back into the
//! EWMA. This makes sweeps tractable that threads cannot reach (hundreds
//! of machines × thousands of steps × policy grid), used by
//! `benches/ablation_scale.rs`.

use crate::config::types::AssignPolicy;
use crate::error::Result;
use crate::optim::{self, SolveParams};
use crate::placement::Placement;
use crate::util::Rng;

use super::speed::SpeedEstimator;

/// Simulator parameters.
#[derive(Debug, Clone)]
pub struct SimParams {
    pub placement: Placement,
    /// True base speeds (sub-matrix units / time).
    pub true_speeds: Vec<f64>,
    pub params: SolveParams,
    pub policy: AssignPolicy,
    pub gamma: f64,
    pub steps: usize,
    /// Per-step multiplicative measurement noise half-width (e.g. 0.2 ⇒
    /// measurements in ×[0.8, 1.2]).
    pub measurement_noise: f64,
    /// Per-step probability a machine's true speed is re-drawn ×[0.5, 2).
    pub drift_prob: f64,
    /// Per-step preemption / arrival probabilities.
    pub preempt: f64,
    pub arrive: f64,
    pub min_available: usize,
    pub seed: u64,
}

/// Aggregate simulation output.
#[derive(Debug)]
pub struct SimResult {
    /// Realized per-step times (virtual units), skipped steps excluded.
    pub step_times: Vec<f64>,
    /// Steps skipped as infeasible.
    pub skipped: usize,
    /// Mean wall-clock of the assignment solve (real seconds).
    pub mean_solve_s: f64,
    /// Total virtual time.
    pub total_time: f64,
}

/// Run the simulation.
pub fn simulate(p: &SimParams) -> Result<SimResult> {
    let n = p.placement.machines();
    assert_eq!(p.true_speeds.len(), n);
    let mut rng = Rng::new(p.seed);
    let mut truth = p.true_speeds.clone();
    let mut est = SpeedEstimator::uniform(p.gamma, n);
    let mut up = vec![true; n];
    let mut trace = super::elastic::ElasticityTrace::bernoulli(
        n,
        p.preempt,
        p.arrive,
        p.min_available,
        p.seed ^ 0xE1A5,
    );
    let _ = &mut up;

    let mut step_times = Vec::with_capacity(p.steps);
    let mut skipped = 0usize;
    let mut solve_total = 0.0f64;
    let mut solves = 0usize;

    for _ in 0..p.steps {
        // drift
        for t in truth.iter_mut() {
            if rng.chance(p.drift_prob) {
                *t *= rng.range_f64(0.5, 2.0);
            }
        }
        let avail = if p.preempt > 0.0 || p.arrive > 0.0 {
            trace.next_step()
        } else {
            (0..n).collect()
        };
        if p.placement.check_feasible(&avail, p.params.stragglers).is_err() {
            skipped += 1;
            continue;
        }

        let t0 = std::time::Instant::now();
        let load = match p.policy {
            AssignPolicy::Heterogeneous => {
                optim::solve_load_matrix(&p.placement, &avail, est.estimate(), &p.params)?.load
            }
            AssignPolicy::Uniform | AssignPolicy::CyclicHomogeneous => {
                optim::homogeneous::uniform_load_matrix(
                    &p.placement,
                    &avail,
                    p.params.stragglers,
                )?
            }
        };
        solve_total += t0.elapsed().as_secs_f64();
        solves += 1;

        // realized step time under TRUE speeds
        let step_time = load.computation_time(&truth, &avail);
        step_times.push(step_time);

        // measurements: per available machine with work, noisy true speed
        for &m in &avail {
            if load.machine_load(m) > 0.0 {
                let noise = 1.0 + p.measurement_noise * (rng.f64() * 2.0 - 1.0);
                est.update(m, truth[m] * noise);
            }
        }
    }
    let total_time = step_times.iter().sum();
    Ok(SimResult {
        total_time,
        skipped,
        mean_solve_s: if solves > 0 { solve_total / solves as f64 } else { 0.0 },
        step_times,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::PlacementKind;

    fn base(policy: AssignPolicy, n: usize) -> SimParams {
        SimParams {
            placement: Placement::build(PlacementKind::Cyclic, n, n, 3).unwrap(),
            true_speeds: (0..n).map(|i| 1.0 + (i % 4) as f64).collect(),
            params: SolveParams::default(),
            policy,
            gamma: 0.5,
            steps: 200,
            measurement_noise: 0.1,
            drift_prob: 0.0,
            preempt: 0.0,
            arrive: 0.0,
            min_available: 3,
            seed: 9,
        }
    }

    #[test]
    fn hetero_beats_uniform_in_simulation() {
        let h = simulate(&base(AssignPolicy::Heterogeneous, 6)).unwrap();
        let u = simulate(&base(AssignPolicy::Uniform, 6)).unwrap();
        assert!(
            h.total_time < u.total_time * 0.95,
            "hetero {} vs uniform {}",
            h.total_time,
            u.total_time
        );
    }

    #[test]
    fn converges_to_near_oracle_without_drift() {
        let p = base(AssignPolicy::Heterogeneous, 6);
        let r = simulate(&p).unwrap();
        // oracle time for this placement/speeds
        let avail: Vec<usize> = (0..6).collect();
        let oracle = optim::solve_load_matrix(
            &p.placement,
            &avail,
            &p.true_speeds,
            &p.params,
        )
        .unwrap()
        .time;
        // late steps should be within noise of oracle
        let tail: f64 =
            r.step_times[150..].iter().sum::<f64>() / (r.step_times.len() - 150) as f64;
        assert!(
            tail < oracle * 1.25,
            "tail mean {tail} vs oracle {oracle}"
        );
    }

    #[test]
    fn scales_to_many_machines() {
        let mut p = base(AssignPolicy::Heterogeneous, 30);
        p.steps = 20;
        let r = simulate(&p).unwrap();
        assert_eq!(r.step_times.len(), 20);
        assert!(r.mean_solve_s < 0.5, "solve too slow: {}", r.mean_solve_s);
    }

    #[test]
    fn elastic_simulation_skips_infeasible() {
        let mut p = base(AssignPolicy::Heterogeneous, 6);
        p.preempt = 0.5;
        p.arrive = 0.3;
        p.min_available = 1; // may go infeasible for cyclic J=3
        let r = simulate(&p).unwrap();
        assert_eq!(r.step_times.len() + r.skipped, 200);
    }

    #[test]
    fn drift_is_tracked() {
        let mut p = base(AssignPolicy::Heterogeneous, 6);
        p.drift_prob = 0.05;
        p.steps = 500;
        let r = simulate(&p).unwrap();
        assert_eq!(r.step_times.len(), 500);
        assert!(r.total_time.is_finite());
    }
}
