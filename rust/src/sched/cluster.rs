//! Cluster lifecycle: spawn worker threads, route messages, join on drop.

use std::sync::mpsc::{self, Receiver, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};

use super::protocol::{ToMaster, ToWorker, WorkOrder};
use super::worker::{run_worker, WorkerConfig};

/// A running set of worker threads plus the master-side channel ends.
pub struct Cluster {
    senders: Vec<Sender<ToWorker>>,
    receiver: Receiver<ToMaster>,
    handles: Vec<JoinHandle<()>>,
}

impl Cluster {
    /// Spawn one thread per worker config.
    pub fn spawn(configs: Vec<WorkerConfig>) -> Result<Cluster> {
        if configs.is_empty() {
            return Err(Error::Cluster("no workers to spawn".into()));
        }
        let (tx_master, rx_master) = mpsc::channel();
        let mut senders = Vec::with_capacity(configs.len());
        let mut handles = Vec::with_capacity(configs.len());
        for cfg in configs {
            let (tx_w, rx_w) = mpsc::channel();
            let tx_m = tx_master.clone();
            let id = cfg.id;
            let handle = std::thread::Builder::new()
                .name(format!("usec-worker-{id}"))
                .spawn(move || run_worker(cfg, rx_w, tx_m))
                .map_err(|e| Error::Cluster(format!("spawn worker {id}: {e}")))?;
            senders.push(tx_w);
            handles.push(handle);
        }
        Ok(Cluster {
            senders,
            receiver: rx_master,
            handles,
        })
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Send a work order to one worker.
    pub fn send(&self, worker: usize, order: WorkOrder) -> Result<()> {
        self.senders
            .get(worker)
            .ok_or_else(|| Error::Cluster(format!("no worker {worker}")))?
            .send(ToWorker::Work(order))
            .map_err(|_| Error::Cluster(format!("worker {worker} channel closed")))
    }

    /// Swap one worker's storage handle in place (live migration, local
    /// mode): the new view travels as a zero-copy `Arc` and takes effect
    /// before the worker's next order.
    pub fn swap_storage(
        &self,
        worker: usize,
        storage: crate::sched::worker::WorkerStorage,
    ) -> Result<()> {
        self.senders
            .get(worker)
            .ok_or_else(|| Error::Cluster(format!("no worker {worker}")))?
            .send(ToWorker::SwapStorage(storage))
            .map_err(|_| Error::Cluster(format!("worker {worker} channel closed")))
    }

    /// Blocking receive with timeout.
    pub fn recv_timeout(&self, timeout: std::time::Duration) -> Result<ToMaster> {
        self.receiver
            .recv_timeout(timeout)
            .map_err(|e| Error::Cluster(format!("recv: {e}")))
    }

    /// Drain any pending messages without blocking (late reports).
    pub fn drain(&self) -> Vec<ToMaster> {
        let mut out = Vec::new();
        while let Ok(m) = self.receiver.try_recv() {
            out.push(m);
        }
        out
    }

    /// Ask all workers to exit and join them (idempotent; shared by
    /// [`Cluster::shutdown`], `Drop`, and the [`crate::net::Transport`]
    /// impl).
    pub fn halt(&mut self) {
        for s in &self.senders {
            let _ = s.send(ToWorker::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }

    /// Ask all workers to exit and join them.
    pub fn shutdown(mut self) {
        self.halt();
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.halt();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::partition::{submatrix_ranges, RowRange};
    use crate::linalg::{gen, Block, Matrix};
    use crate::optim::Task;
    use crate::runtime::BackendSpec;
    use crate::sched::worker::WorkerStorage;
    use std::sync::Arc;
    use std::time::Duration;

    fn make_cluster(n: usize) -> Cluster {
        let q = 40;
        let matrix: Arc<Matrix> = Arc::new(gen::random_dense(q, q, 3));
        let ranges = Arc::new(submatrix_ranges(q, 4).unwrap());
        let configs = (0..n)
            .map(|id| WorkerConfig {
                id,
                backend: BackendSpec::Host,
                speed: 1.0,
                tile_rows: 8,
                threads: 1,
                storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&ranges)),
            })
            .collect();
        Cluster::spawn(configs).unwrap()
    }

    #[test]
    fn spawn_and_shutdown() {
        let c = make_cluster(4);
        assert_eq!(c.size(), 4);
        c.shutdown();
    }

    #[test]
    fn routes_work_and_reports() {
        let c = make_cluster(3);
        for id in 0..3 {
            c.send(
                id,
                WorkOrder {
                    step: 7,
                    w: Arc::new(Block::single(vec![1.0; 40])),
                    tasks: vec![Task {
                        g: id,
                        rows: RowRange::new(0, 5),
                    }],
                    row_cost_ns: 0,
                    straggle: None,
                    trace: false,
                },
            )
            .unwrap();
        }
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..3 {
            match c.recv_timeout(Duration::from_secs(5)).unwrap() {
                ToMaster::Report(r) => {
                    assert_eq!(r.step, 7);
                    seen.insert(r.worker);
                }
                ToMaster::Failed { error, .. } => panic!("worker failed: {error}"),
            }
        }
        assert_eq!(seen.len(), 3);
        c.shutdown();
    }

    #[test]
    fn send_to_missing_worker_errors() {
        let c = make_cluster(2);
        let bad = c.send(
            9,
            WorkOrder {
                step: 0,
                w: Arc::new(Block::single(vec![])),
                tasks: vec![],
                row_cost_ns: 0,
                straggle: None,
                trace: false,
            },
        );
        assert!(bad.is_err());
        c.shutdown();
    }

    #[test]
    fn drain_collects_pending() {
        let c = make_cluster(2);
        for id in 0..2 {
            c.send(
                id,
                WorkOrder {
                    step: 1,
                    w: Arc::new(Block::single(vec![1.0; 40])),
                    tasks: vec![],
                    row_cost_ns: 0,
                    straggle: None,
                    trace: false,
                },
            )
            .unwrap();
        }
        std::thread::sleep(Duration::from_millis(200));
        let drained = c.drain();
        assert_eq!(drained.len(), 2);
        c.shutdown();
    }
}
