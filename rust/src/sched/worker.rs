//! Worker VM: owns (a view of) its stored sub-matrices, executes assigned
//! row tiles through a [`crate::runtime::Backend`], throttles to its
//! simulated speed, and reports measured speed back (Algorithm 1 lines
//! 8–15).
//!
//! The per-step hot loop is **zero-allocation**: every tile's `B`-vector
//! product lands in a per-worker [`ExecScratch`] arena that persists
//! across tiles *and* steps ([`Backend::matmat_tile_into`]); the only
//! allocations per order are the final per-task segment buffers that ship
//! to the master. With [`WorkerConfig::threads`] > 1 the tile list fans
//! out across a scoped thread pool (host backend only — PJRT clients are
//! not `Send`); per-row `f64` accumulation is untouched by the split, so
//! a multi-threaded run is bit-identical to the single-threaded one and
//! the host backend stays the numerics oracle.
//!
//! Workers are deliberately **step-agnostic**: each [`WorkOrder`] is
//! executed and reported independently, so a supplementary recovery order
//! for an in-flight step ([`crate::sched::recovery`]) is just another
//! order in the queue — the master dedups by row (coverage bitmap) and by
//! worker id (EWMA) on its side. The pipelined master (`--pipeline`)
//! leans on the same property: orders for step `i+1` may arrive while the
//! master is still finishing step `i`'s combine, and the worker neither
//! knows nor cares — it computes whatever order is next in its queue.
//!
//! The speed throttle is the EC2-heterogeneity substitute (DESIGN.md §3):
//! after computing its tiles, a worker sleeps up to
//! `assigned_rows · row_cost_ns / speed` so wall-clock per step reflects
//! the configured speed ratios. With `row_cost_ns = 0` the throttle is off
//! and true compute speed shows through. `threads` defaults to 1 so the
//! throttle's ratios keep meaning what they say.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::linalg::partition::{RowRange, TilePlan};
use crate::linalg::{ops, Matrix};
use crate::runtime::{Backend, BackendSpec};
use crate::storage::{RowShard, StorageView, StoreHandle};

use super::protocol::{Segment, ToMaster, ToWorker, WorkOrder, WorkerReport};
use super::straggler::StraggleMode;

/// Read-only storage a worker holds, addressed in global row coordinates
/// through the [`StorageView`] trait.
///
/// Local simulator mode shares one full matrix by `Arc`
/// ([`StoreHandle::Full`], zero-copy — the uncoded USEC storage model
/// without duplicating gigabytes per simulated VM). Distributed workers
/// hold a placement-shaped [`StoreHandle::Shard`] with only their placed
/// rows resident, so per-worker memory *is* the storage the placement
/// prescribes.
#[derive(Clone, Debug)]
pub struct WorkerStorage {
    pub store: StoreHandle,
    /// Global row range of each sub-matrix `X_g`.
    pub sub_ranges: Arc<Vec<RowRange>>,
}

impl WorkerStorage {
    /// Zero-copy full-matrix storage (local mode).
    pub fn full(matrix: Arc<Matrix>, sub_ranges: Arc<Vec<RowRange>>) -> Self {
        WorkerStorage {
            store: StoreHandle::Full(matrix),
            sub_ranges,
        }
    }

    /// Placement-shaped shard storage (distributed mode).
    pub fn shard(shard: Arc<RowShard>, sub_ranges: Arc<Vec<RowRange>>) -> Self {
        WorkerStorage {
            store: StoreHandle::Shard(shard),
            sub_ranges,
        }
    }

    /// Matrix payload bytes actually resident on this worker.
    pub fn resident_bytes(&self) -> usize {
        self.store.resident_bytes()
    }
}

/// Static per-worker configuration.
#[derive(Clone)]
pub struct WorkerConfig {
    pub id: usize,
    pub backend: BackendSpec,
    /// True speed multiplier (the master only ever sees estimates).
    pub speed: f64,
    /// Execution-tile height (must match PJRT artifacts when used).
    pub tile_rows: usize,
    /// Compute threads for the tile fan-out (intra-worker parallelism).
    /// 1 (the default everywhere) is bit-identical to the classic serial
    /// worker and keeps the speed throttle's ratios meaningful; > 1 only
    /// takes effect on the host backend.
    pub threads: usize,
    pub storage: WorkerStorage,
}

/// Per-worker scratch arena: one growing buffer reused across tiles and
/// steps, so the compute loop performs no allocation (satisfying the
/// zero-alloc hot-loop contract of the block data plane).
#[derive(Debug, Default)]
pub struct ExecScratch {
    buf: Vec<f32>,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch::default()
    }

    /// Grow (never shrink) to at least `len` f32s and hand out the prefix.
    fn at_least(&mut self, len: usize) -> &mut [f32] {
        if self.buf.len() < len {
            self.buf.resize(len, 0.0);
        }
        &mut self.buf[..len]
    }

    /// Current arena capacity in f32s (steady-state after the first step).
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }
}

/// One tile's compute job: where its rows live globally and where its
/// output lands in the scratch arena (offsets are in f32s; jobs tile the
/// arena prefix contiguously and in order).
struct TileJob {
    global: RowRange,
    off: usize,
}

/// Worker thread body. Runs until `Shutdown` or channel close.
pub fn run_worker(mut cfg: WorkerConfig, rx: Receiver<ToWorker>, tx: Sender<ToMaster>) {
    let backend = match cfg.backend.instantiate() {
        Ok(b) => b,
        Err(e) => {
            let _ = tx.send(ToMaster::Failed {
                worker: cfg.id,
                step: 0,
                error: format!("backend init: {e}"),
            });
            return;
        }
    };
    let tile = TilePlan::new(cfg.tile_rows);
    let mut scratch = ExecScratch::new();
    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Shutdown => break,
            ToWorker::SwapStorage(storage) => {
                // live migration, local mode: the replacement view arrives
                // as an `Arc` — swapping it in is zero-copy and atomic
                // between orders
                cfg.storage = storage;
            }
            ToWorker::Work(order) => {
                let step = order.step;
                match execute_order(&cfg, &backend, &tile, &order, &mut scratch) {
                    Ok(Some(report)) => {
                        let _ = tx.send(ToMaster::Report(report));
                    }
                    Ok(None) => {} // injected Drop straggler: stay silent
                    Err(e) => {
                        let _ = tx.send(ToMaster::Failed {
                            worker: cfg.id,
                            step,
                            error: e.to_string(),
                        });
                    }
                }
            }
        }
    }
}

/// Execute one work order; `Ok(None)` means an injected Drop straggler.
///
/// Public because the TCP worker daemon ([`crate::net::daemon`]) drives the
/// same compute path over a socket instead of an mpsc channel. `scratch`
/// is the worker's persistent arena; passing a fresh one is correct but
/// reintroduces the per-step allocation this path exists to avoid.
pub fn execute_order(
    cfg: &WorkerConfig,
    backend: &Backend,
    tile: &TilePlan,
    order: &WorkOrder,
    scratch: &mut ExecScratch,
) -> Result<Option<WorkerReport>> {
    let start = Instant::now();
    let cols = cfg.storage.store.cols();
    let nvec = order.w.nvec();
    if order.w.len() != cols {
        return Err(Error::Shape(format!(
            "iterate block length {} != matrix cols {cols}",
            order.w.len()
        )));
    }

    // ---- plan: validate task geometry, lay jobs out in the arena ----
    let mut jobs: Vec<TileJob> = Vec::with_capacity(order.tasks.len());
    // (global range, arena offset) per non-empty task — one shipped
    // segment each; consecutive tiles of a task are contiguous in the
    // arena, so segment assembly is one bulk copy per task
    let mut task_spans: Vec<(RowRange, usize)> = Vec::with_capacity(order.tasks.len());
    let mut assigned_rows = 0usize;
    let mut mu = 0.0f64; // load in sub-matrix units
    for task in &order.tasks {
        let sub = *cfg.storage.sub_ranges.get(task.g).ok_or_else(|| {
            Error::Shape(format!(
                "task references sub-matrix {} of {}",
                task.g,
                cfg.storage.sub_ranges.len()
            ))
        })?;
        let global = task.rows.checked_offset(sub.lo)?;
        if global.hi > sub.hi {
            return Err(Error::Shape(format!(
                "task rows {}..{} overrun sub-matrix {} ({} rows)",
                task.rows.lo,
                task.rows.hi,
                task.g,
                sub.len()
            )));
        }
        if global.is_empty() {
            continue;
        }
        task_spans.push((global, assigned_rows * nvec));
        for t in tile.plan(global) {
            jobs.push(TileJob {
                global: t,
                off: assigned_rows * nvec + (t.lo - global.lo) * nvec,
            });
        }
        assigned_rows += global.len();
        mu += task.rows.len() as f64 / sub.len() as f64;
    }

    // ---- compute: zero-alloc hot loop over the arena ----
    let compute_start = Instant::now();
    let buf = scratch.at_least(assigned_rows * nvec);
    let threads = effective_threads(cfg, backend, jobs.len());
    if threads <= 1 {
        for job in &jobs {
            // the view rejects rows outside this worker's placed share —
            // a shard worker cannot silently compute from rows it should
            // not store
            let x = cfg.storage.store.row_slice(job.global)?;
            let out = &mut buf[job.off..job.off + job.global.len() * nvec];
            backend.matmat_tile_into(x, job.global.len(), cols, order.w.data(), nvec, out)?;
        }
    } else {
        compute_parallel(cfg, order, &jobs, cols, nvec, buf, threads)?;
    }
    let compute_ns = compute_start.elapsed().as_nanos() as u64;

    // speed throttle: emulate a machine of speed `cfg.speed`
    let mut target_ns = if cfg.speed > 0.0 {
        (assigned_rows as f64 * order.row_cost_ns as f64 / cfg.speed) as u64
    } else {
        0
    };
    let straggle = order.straggle;
    if let Some(StraggleMode::Slow(f)) = straggle {
        target_ns = (target_ns as f64 * f) as u64;
    }
    let elapsed = start.elapsed();
    let target = Duration::from_nanos(target_ns);
    let throttle_start = Instant::now();
    if elapsed < target {
        std::thread::sleep(target - elapsed);
    }
    let throttle_ns = throttle_start.elapsed().as_nanos() as u64;

    if matches!(straggle, Some(StraggleMode::Drop)) {
        return Ok(None);
    }

    // ---- assemble: one segment (one bulk copy) per task ----
    let assemble_start = Instant::now();
    let segments: Vec<Segment> = task_spans
        .iter()
        .map(|&(global, off)| Segment {
            rows: global,
            values: buf[off..off + global.len() * nvec].to_vec(),
        })
        .collect();
    let assemble_ns = assemble_start.elapsed().as_nanos() as u64;

    let total = start.elapsed();
    let measured_speed = if assigned_rows > 0 && total.as_secs_f64() > 0.0 {
        Some(mu / total.as_secs_f64())
    } else {
        None
    };
    Ok(Some(WorkerReport {
        worker: cfg.id,
        step: order.step,
        segments,
        nvec,
        measured_speed,
        elapsed: total,
        // compute-path phases only; the TCP daemon fills decode/encode/
        // idle before the report leaves the process
        breakdown: order.trace.then(|| crate::obs::OrderBreakdown {
            compute_ns,
            throttle_ns,
            assemble_ns,
            ..Default::default()
        }),
    }))
}

/// How many compute threads this order actually uses. PJRT clients are
/// `Rc`-based (not `Send`), so intra-worker parallelism is a host-backend
/// feature; everything else runs the serial path.
fn effective_threads(cfg: &WorkerConfig, backend: &Backend, jobs: usize) -> usize {
    let t = cfg.threads.max(1);
    if t == 1 || jobs < 2 {
        return 1;
    }
    match backend {
        Backend::Host(_) => t.min(jobs),
        _ => {
            crate::log_debug!(
                "worker {}: threads={t} requested but the {} backend is \
                 single-threaded; running serial",
                cfg.id,
                backend.name()
            );
            1
        }
    }
}

/// Fan the tile jobs out across `threads` scoped threads, each writing its
/// disjoint arena slices through the same host kernel. Work is split into
/// contiguous job groups balanced by row count; per-row f64 accumulation
/// is per-tile-row regardless of the split, so the result is bit-identical
/// to the serial path.
fn compute_parallel(
    cfg: &WorkerConfig,
    order: &WorkOrder,
    jobs: &[TileJob],
    cols: usize,
    nvec: usize,
    buf: &mut [f32],
    threads: usize,
) -> Result<()> {
    // slice the arena prefix into one disjoint &mut per job (jobs tile it
    // contiguously and in order)
    let mut slices: Vec<&mut [f32]> = Vec::with_capacity(jobs.len());
    let mut rest = buf;
    let mut consumed = 0usize;
    for job in jobs {
        debug_assert_eq!(job.off, consumed);
        let take = job.global.len() * nvec;
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        slices.push(head);
        rest = tail;
        consumed += take;
    }

    // contiguous groups with ~equal row counts
    let total_rows: usize = jobs.iter().map(|j| j.global.len()).sum();
    let per_thread = total_rows.div_ceil(threads).max(1);
    let mut groups: Vec<Vec<(&TileJob, &mut [f32])>> = Vec::with_capacity(threads);
    let mut current: Vec<(&TileJob, &mut [f32])> = Vec::new();
    let mut current_rows = 0usize;
    for (job, slice) in jobs.iter().zip(slices) {
        current.push((job, slice));
        current_rows += job.global.len();
        if current_rows >= per_thread {
            groups.push(std::mem::take(&mut current));
            current_rows = 0;
        }
    }
    if !current.is_empty() {
        groups.push(current);
    }

    let store = &cfg.storage.store;
    let w = order.w.data();
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let handles: Vec<_> = groups
            .into_iter()
            .map(|group| {
                s.spawn(move || -> Result<()> {
                    for (job, out) in group {
                        let x = store.row_slice(job.global)?;
                        ops::matmat_into(x, job.global.len(), cols, w, nvec, out);
                    }
                    Ok(())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| {
                    Err(Error::Cluster("worker compute thread panicked".into()))
                })
            })
            .collect()
    });
    for r in results {
        r?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gen, Block};
    use crate::optim::Task;
    use std::sync::mpsc;

    fn storage(q: usize, g: usize) -> WorkerStorage {
        let m = gen::random_dense(q, q, 5);
        let ranges = crate::linalg::partition::submatrix_ranges(q, g).unwrap();
        WorkerStorage::full(Arc::new(m), Arc::new(ranges))
    }

    fn order(tasks: Vec<Task>, q: usize, straggle: Option<StraggleMode>) -> WorkOrder {
        WorkOrder {
            step: 1,
            w: Arc::new(Block::single(vec![0.1f32; q])),
            tasks,
            row_cost_ns: 0,
            straggle,
            trace: false,
        }
    }

    fn spawn_worker(cfg: WorkerConfig) -> (Sender<ToWorker>, Receiver<ToMaster>) {
        let (tx_w, rx_w) = mpsc::channel();
        let (tx_m, rx_m) = mpsc::channel();
        std::thread::spawn(move || run_worker(cfg, rx_w, tx_m));
        (tx_w, rx_m)
    }

    fn cfg(id: usize, speed: f64) -> WorkerConfig {
        WorkerConfig {
            id,
            backend: BackendSpec::Host,
            speed,
            tile_rows: 16,
            threads: 1,
            storage: storage(60, 6),
        }
    }

    #[test]
    fn computes_assigned_rows_correctly() {
        let c = cfg(0, 1.0);
        // same seed as `storage` — the oracle matrix is bit-identical
        let matrix = gen::random_dense(60, 60, 5);
        let (tx, rx) = spawn_worker(c);
        // sub-matrix 2 covers global rows 20..30; assign local rows 3..9
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 2,
                rows: RowRange::new(3, 9),
            }],
            60,
            None,
        )))
        .unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        assert_eq!(r.worker, 0);
        assert_eq!(r.step, 1);
        assert_eq!(r.nvec, 1);
        let total: usize = r.segments.iter().map(|s| s.rows.len()).sum();
        assert_eq!(total, 6);
        // numerics: matches direct matvec on those rows
        let w = vec![0.1f32; 60];
        for seg in &r.segments {
            for (i, row) in (seg.rows.lo..seg.rows.hi).enumerate() {
                let want: f32 = matrix.row(row).iter().zip(&w).map(|(a, b)| a * b).sum();
                assert!((seg.values[i] - want).abs() < 1e-4);
            }
        }
        assert!(r.measured_speed.unwrap() > 0.0);
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn empty_order_reports_no_speed() {
        let (tx, rx) = spawn_worker(cfg(1, 1.0));
        tx.send(ToWorker::Work(order(vec![], 60, None))).unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        assert!(r.segments.is_empty());
        assert!(r.measured_speed.is_none());
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn accepts_supplementary_order_for_in_flight_step() {
        // mid-step recovery ships a second order with the same step id;
        // the worker must execute both and report both
        let (tx, rx) = spawn_worker(cfg(8, 1.0));
        for g in [0usize, 3] {
            tx.send(ToWorker::Work(order(
                vec![Task {
                    g,
                    rows: RowRange::new(0, 5),
                }],
                60,
                None,
            )))
            .unwrap();
        }
        for _ in 0..2 {
            let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
                panic!("expected report");
            };
            assert_eq!(r.step, 1);
            assert_eq!(r.segments.len(), 1);
        }
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn drop_straggler_stays_silent() {
        let (tx, rx) = spawn_worker(cfg(2, 1.0));
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 0,
                rows: RowRange::new(0, 10),
            }],
            60,
            Some(StraggleMode::Drop),
        )))
        .unwrap();
        assert!(rx.recv_timeout(Duration::from_millis(300)).is_err());
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn throttle_slows_reports() {
        let mut c = cfg(3, 1.0);
        c.speed = 0.5; // half speed
        let (tx, rx) = spawn_worker(c);
        let mut o = order(
            vec![Task {
                g: 0,
                rows: RowRange::new(0, 10),
            }],
            60,
            None,
        );
        o.row_cost_ns = 2_000_000; // 2ms/row at speed 1 → 40ms at 0.5
        tx.send(ToWorker::Work(o)).unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        assert!(
            r.elapsed >= Duration::from_millis(35),
            "throttle not applied: {:?}",
            r.elapsed
        );
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn measured_speed_tracks_throttle_ratio() {
        // two workers with 2x speed ratio must report ~2x measured speed
        let run = |speed: f64| {
            let mut c = cfg(4, speed);
            c.speed = speed;
            let (tx, rx) = spawn_worker(c);
            let mut o = order(
                vec![Task {
                    g: 1,
                    rows: RowRange::new(0, 10),
                }],
                60,
                None,
            );
            o.row_cost_ns = 8_000_000; // 80ms at speed 1 — dwarfs sleep jitter
            tx.send(ToWorker::Work(o)).unwrap();
            let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
                panic!("expected report");
            };
            tx.send(ToWorker::Shutdown).unwrap();
            r.measured_speed.unwrap()
        };
        let slow = run(1.0);
        let fast = run(2.0);
        let ratio = fast / slow;
        assert!((1.5..2.6).contains(&ratio), "speed ratio {ratio}");
    }

    #[test]
    fn shard_worker_matches_full_worker_and_rejects_unplaced_rows() {
        let q = 60;
        let matrix = Arc::new(gen::random_dense(q, q, 5));
        let ranges = Arc::new(crate::linalg::partition::submatrix_ranges(q, 6).unwrap());
        // shard worker stores sub-matrices {1, 2} only (global rows 10..30)
        let placed = vec![ranges[1], ranges[2]];
        let shard = Arc::new(RowShard::from_matrix(&matrix, &placed).unwrap());
        assert_eq!(shard.resident_bytes(), 20 * q * 4);
        let c = WorkerConfig {
            id: 7,
            backend: BackendSpec::Host,
            speed: 1.0,
            tile_rows: 16,
            threads: 1,
            storage: WorkerStorage::shard(shard, Arc::clone(&ranges)),
        };
        let (tx, rx) = spawn_worker(c);
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 2,
                rows: RowRange::new(2, 8),
            }],
            q,
            None,
        )))
        .unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        let w = vec![0.1f32; q];
        for seg in &r.segments {
            for (i, row) in (seg.rows.lo..seg.rows.hi).enumerate() {
                let want: f32 = matrix.row(row).iter().zip(&w).map(|(a, b)| a * b).sum();
                assert!((seg.values[i] - want).abs() < 1e-4);
            }
        }
        // a task over rows the shard does not store must fail, not panic
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 4,
                rows: RowRange::new(0, 5),
            }],
            q,
            None,
        )))
        .unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToMaster::Failed { worker, .. } => assert_eq!(worker, 7),
            other => panic!("expected Failed, got {other:?}"),
        }
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn swap_storage_takes_effect_before_the_next_order() {
        let q = 60;
        let matrix = Arc::new(gen::random_dense(q, q, 5));
        let ranges = Arc::new(crate::linalg::partition::submatrix_ranges(q, 6).unwrap());
        let (tx, rx) = spawn_worker(cfg(12, 1.0)); // full storage
        // live migration, local mode: swap to a shard holding only
        // sub-matrix 0 (global rows 0..10)
        let shard = Arc::new(RowShard::from_matrix(&matrix, &[ranges[0]]).unwrap());
        tx.send(ToWorker::SwapStorage(WorkerStorage::shard(
            shard,
            Arc::clone(&ranges),
        )))
        .unwrap();
        // rows outside the swapped-in share must now fail...
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 3,
                rows: RowRange::new(0, 5),
            }],
            q,
            None,
        )))
        .unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToMaster::Failed { worker, .. } => assert_eq!(worker, 12),
            other => panic!("expected Failed after the swap, got {other:?}"),
        }
        // ...while the placed rows still compute correctly
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 0,
                rows: RowRange::new(0, 5),
            }],
            q,
            None,
        )))
        .unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToMaster::Report(r) => {
                assert_eq!(r.segments.len(), 1);
                assert_eq!(r.segments[0].rows, RowRange::new(0, 5));
            }
            other => panic!("expected Report, got {other:?}"),
        }
        tx.send(ToWorker::Shutdown).unwrap();
    }

    /// Direct `execute_order` harness for block/thread matrix tests.
    fn run_order_direct(c: &WorkerConfig, o: &WorkOrder) -> WorkerReport {
        let backend = c.backend.instantiate().unwrap();
        let tile = TilePlan::new(c.tile_rows);
        let mut scratch = ExecScratch::new();
        execute_order(c, &backend, &tile, o, &mut scratch)
            .unwrap()
            .expect("report")
    }

    #[test]
    fn block_order_matches_per_column_matvecs() {
        let q = 60;
        let c = cfg(9, 1.0);
        let matrix = gen::random_dense(q, q, 5);
        let nvec = 5;
        let cols: Vec<Vec<f32>> = (0..nvec)
            .map(|k| (0..q).map(|i| ((i + k) % 7) as f32 * 0.1 - 0.3).collect())
            .collect();
        let block = Block::from_columns(&cols).unwrap();
        let o = WorkOrder {
            step: 3,
            w: Arc::new(block),
            tasks: vec![
                Task {
                    g: 1,
                    rows: RowRange::new(0, 10),
                },
                Task {
                    g: 4,
                    rows: RowRange::new(2, 9),
                },
            ],
            row_cost_ns: 0,
            straggle: None,
            trace: false,
        };
        let r = run_order_direct(&c, &o);
        assert_eq!(r.nvec, nvec);
        assert_eq!(r.segments.len(), 2);
        for seg in &r.segments {
            assert_eq!(seg.values.len(), seg.rows.len() * nvec);
            for (i, row) in (seg.rows.lo..seg.rows.hi).enumerate() {
                for (k, col) in cols.iter().enumerate() {
                    let want: f32 = matrix.row(row).iter().zip(col).map(|(a, b)| a * b).sum();
                    let got = seg.values[i * nvec + k];
                    assert!(
                        (got - want).abs() < 1e-4,
                        "row {row} col {k}: {got} vs {want}"
                    );
                }
            }
        }
    }

    #[test]
    fn multithreaded_order_is_bit_identical_to_serial() {
        let mut serial = cfg(10, 1.0);
        serial.tile_rows = 8; // many tiles → a real fan-out
        let mut threaded = serial.clone();
        threaded.threads = 4;
        let tasks = vec![
            Task {
                g: 0,
                rows: RowRange::new(0, 10),
            },
            Task {
                g: 3,
                rows: RowRange::new(1, 10),
            },
            Task {
                g: 5,
                rows: RowRange::new(0, 7),
            },
        ];
        for nvec in [1usize, 4] {
            let w = Block::from_interleaved(
                60,
                nvec,
                (0..60 * nvec).map(|i| (i % 11) as f32 * 0.07 - 0.35).collect(),
            )
            .unwrap();
            let o = WorkOrder {
                step: 2,
                w: Arc::new(w),
                tasks: tasks.clone(),
                row_cost_ns: 0,
                straggle: None,
                trace: false,
            };
            let a = run_order_direct(&serial, &o);
            let b = run_order_direct(&threaded, &o);
            assert_eq!(a.segments, b.segments, "B={nvec}");
        }
    }

    #[test]
    fn traced_order_carries_a_breakdown_and_untraced_does_not() {
        let c = cfg(13, 1.0);
        let mut o = order(
            vec![Task {
                g: 0,
                rows: RowRange::new(0, 10),
            }],
            60,
            None,
        );
        o.row_cost_ns = 1_000_000; // 10ms target → a visible throttle phase
        assert!(run_order_direct(&c, &o).breakdown.is_none());
        o.trace = true;
        let r = run_order_direct(&c, &o);
        let bd = r.breakdown.expect("traced order must carry a breakdown");
        assert!(bd.compute_ns > 0);
        assert!(bd.throttle_ns >= 5_000_000, "throttle {:?}", bd);
        // daemon-side phases are not the worker's to fill
        assert_eq!(bd.decode_ns, 0);
        assert_eq!(bd.encode_ns, 0);
        assert_eq!(bd.idle_ns, 0);
        // the phases are a decomposition of the reported elapsed time
        assert!(
            bd.total_ns() <= r.elapsed.as_nanos() as u64,
            "phases {:?} exceed elapsed {:?}",
            bd,
            r.elapsed
        );
    }

    #[test]
    fn scratch_arena_is_reused_across_steps() {
        let c = cfg(11, 1.0);
        let backend = c.backend.instantiate().unwrap();
        let tile = TilePlan::new(c.tile_rows);
        let mut scratch = ExecScratch::new();
        let o = order(
            vec![Task {
                g: 0,
                rows: RowRange::new(0, 10),
            }],
            60,
            None,
        );
        execute_order(&c, &backend, &tile, &o, &mut scratch)
            .unwrap()
            .unwrap();
        let cap = scratch.capacity();
        assert_eq!(cap, 10);
        execute_order(&c, &backend, &tile, &o, &mut scratch)
            .unwrap()
            .unwrap();
        assert_eq!(scratch.capacity(), cap, "steady state must not reallocate");
    }
}
