//! Worker VM: owns (a view of) its stored sub-matrices, executes assigned
//! row tiles through a [`crate::runtime::Backend`], throttles to its
//! simulated speed, and reports measured speed back (Algorithm 1 lines
//! 8–15).
//!
//! The speed throttle is the EC2-heterogeneity substitute (DESIGN.md §3):
//! after computing its tiles, a worker sleeps up to
//! `assigned_rows · row_cost_ns / speed` so wall-clock per step reflects
//! the configured speed ratios. With `row_cost_ns = 0` the throttle is off
//! and true compute speed shows through.

use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::linalg::partition::{RowRange, TilePlan};
use crate::linalg::Matrix;
use crate::runtime::BackendSpec;
use crate::storage::{RowShard, StorageView, StoreHandle};

use super::protocol::{Segment, ToMaster, ToWorker, WorkOrder, WorkerReport};
use super::straggler::StraggleMode;

/// Read-only storage a worker holds, addressed in global row coordinates
/// through the [`StorageView`] trait.
///
/// Local simulator mode shares one full matrix by `Arc`
/// ([`StoreHandle::Full`], zero-copy — the uncoded USEC storage model
/// without duplicating gigabytes per simulated VM). Distributed workers
/// hold a placement-shaped [`StoreHandle::Shard`] with only their placed
/// rows resident, so per-worker memory *is* the storage the placement
/// prescribes.
#[derive(Clone)]
pub struct WorkerStorage {
    pub store: StoreHandle,
    /// Global row range of each sub-matrix `X_g`.
    pub sub_ranges: Arc<Vec<RowRange>>,
}

impl WorkerStorage {
    /// Zero-copy full-matrix storage (local mode).
    pub fn full(matrix: Arc<Matrix>, sub_ranges: Arc<Vec<RowRange>>) -> Self {
        WorkerStorage {
            store: StoreHandle::Full(matrix),
            sub_ranges,
        }
    }

    /// Placement-shaped shard storage (distributed mode).
    pub fn shard(shard: Arc<RowShard>, sub_ranges: Arc<Vec<RowRange>>) -> Self {
        WorkerStorage {
            store: StoreHandle::Shard(shard),
            sub_ranges,
        }
    }

    /// Matrix payload bytes actually resident on this worker.
    pub fn resident_bytes(&self) -> usize {
        self.store.resident_bytes()
    }
}

/// Static per-worker configuration.
#[derive(Clone)]
pub struct WorkerConfig {
    pub id: usize,
    pub backend: BackendSpec,
    /// True speed multiplier (the master only ever sees estimates).
    pub speed: f64,
    /// Execution-tile height (must match PJRT artifacts when used).
    pub tile_rows: usize,
    pub storage: WorkerStorage,
}

/// Worker thread body. Runs until `Shutdown` or channel close.
pub fn run_worker(cfg: WorkerConfig, rx: Receiver<ToWorker>, tx: Sender<ToMaster>) {
    let backend = match cfg.backend.instantiate() {
        Ok(b) => b,
        Err(e) => {
            let _ = tx.send(ToMaster::Failed {
                worker: cfg.id,
                step: 0,
                error: format!("backend init: {e}"),
            });
            return;
        }
    };
    let tile = TilePlan::new(cfg.tile_rows);
    while let Ok(msg) = rx.recv() {
        match msg {
            ToWorker::Shutdown => break,
            ToWorker::Work(order) => {
                let step = order.step;
                match execute_order(&cfg, &backend, &tile, &order) {
                    Ok(Some(report)) => {
                        let _ = tx.send(ToMaster::Report(report));
                    }
                    Ok(None) => {} // injected Drop straggler: stay silent
                    Err(e) => {
                        let _ = tx.send(ToMaster::Failed {
                            worker: cfg.id,
                            step,
                            error: e.to_string(),
                        });
                    }
                }
            }
        }
    }
}

/// Execute one work order; `Ok(None)` means an injected Drop straggler.
///
/// Public because the TCP worker daemon ([`crate::net::daemon`]) drives the
/// same compute path over a socket instead of an mpsc channel.
pub fn execute_order(
    cfg: &WorkerConfig,
    backend: &crate::runtime::Backend,
    tile: &TilePlan,
    order: &WorkOrder,
) -> Result<Option<WorkerReport>> {
    let start = Instant::now();
    let cols = cfg.storage.store.cols();
    let mut segments = Vec::new();
    let mut assigned_rows = 0usize;
    let mut mu = 0.0f64; // load in sub-matrix units

    for task in &order.tasks {
        let sub = *cfg.storage.sub_ranges.get(task.g).ok_or_else(|| {
            Error::Shape(format!(
                "task references sub-matrix {} of {}",
                task.g,
                cfg.storage.sub_ranges.len()
            ))
        })?;
        let global = task.rows.checked_offset(sub.lo)?;
        if global.hi > sub.hi {
            return Err(Error::Shape(format!(
                "task rows {}..{} overrun sub-matrix {} ({} rows)",
                task.rows.lo,
                task.rows.hi,
                task.g,
                sub.len()
            )));
        }
        assigned_rows += global.len();
        mu += task.rows.len() as f64 / sub.len() as f64;
        for t in tile.plan(global) {
            // the view rejects rows outside this worker's placed share —
            // a shard worker cannot silently compute from rows it should
            // not store
            let x = cfg.storage.store.row_slice(t)?;
            let y = backend.matvec_tile(x, t.len(), cols, &order.w)?;
            segments.push(Segment { rows: t, values: y });
        }
    }

    // speed throttle: emulate a machine of speed `cfg.speed`
    let mut target_ns = if cfg.speed > 0.0 {
        (assigned_rows as f64 * order.row_cost_ns as f64 / cfg.speed) as u64
    } else {
        0
    };
    let straggle = order.straggle;
    if let Some(StraggleMode::Slow(f)) = straggle {
        target_ns = (target_ns as f64 * f) as u64;
    }
    let elapsed = start.elapsed();
    let target = Duration::from_nanos(target_ns);
    if elapsed < target {
        std::thread::sleep(target - elapsed);
    }

    if matches!(straggle, Some(StraggleMode::Drop)) {
        return Ok(None);
    }

    let total = start.elapsed();
    let measured_speed = if assigned_rows > 0 && total.as_secs_f64() > 0.0 {
        Some(mu / total.as_secs_f64())
    } else {
        None
    };
    Ok(Some(WorkerReport {
        worker: cfg.id,
        step: order.step,
        segments,
        measured_speed,
        elapsed: total,
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gen;
    use crate::optim::Task;
    use std::sync::mpsc;

    fn storage(q: usize, g: usize) -> WorkerStorage {
        let m = gen::random_dense(q, q, 5);
        let ranges = crate::linalg::partition::submatrix_ranges(q, g).unwrap();
        WorkerStorage::full(Arc::new(m), Arc::new(ranges))
    }

    fn order(tasks: Vec<Task>, q: usize, straggle: Option<StraggleMode>) -> WorkOrder {
        WorkOrder {
            step: 1,
            w: Arc::new(vec![0.1f32; q]),
            tasks,
            row_cost_ns: 0,
            straggle,
        }
    }

    fn spawn_worker(cfg: WorkerConfig) -> (Sender<ToWorker>, Receiver<ToMaster>) {
        let (tx_w, rx_w) = mpsc::channel();
        let (tx_m, rx_m) = mpsc::channel();
        std::thread::spawn(move || run_worker(cfg, rx_w, tx_m));
        (tx_w, rx_m)
    }

    fn cfg(id: usize, speed: f64) -> WorkerConfig {
        WorkerConfig {
            id,
            backend: BackendSpec::Host,
            speed,
            tile_rows: 16,
            storage: storage(60, 6),
        }
    }

    #[test]
    fn computes_assigned_rows_correctly() {
        let c = cfg(0, 1.0);
        // same seed as `storage` — the oracle matrix is bit-identical
        let matrix = gen::random_dense(60, 60, 5);
        let (tx, rx) = spawn_worker(c);
        // sub-matrix 2 covers global rows 20..30; assign local rows 3..9
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 2,
                rows: RowRange::new(3, 9),
            }],
            60,
            None,
        )))
        .unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        assert_eq!(r.worker, 0);
        assert_eq!(r.step, 1);
        let total: usize = r.segments.iter().map(|s| s.rows.len()).sum();
        assert_eq!(total, 6);
        // numerics: matches direct matvec on those rows
        let w = vec![0.1f32; 60];
        for seg in &r.segments {
            for (i, row) in (seg.rows.lo..seg.rows.hi).enumerate() {
                let want: f32 = matrix.row(row).iter().zip(&w).map(|(a, b)| a * b).sum();
                assert!((seg.values[i] - want).abs() < 1e-4);
            }
        }
        assert!(r.measured_speed.unwrap() > 0.0);
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn empty_order_reports_no_speed() {
        let (tx, rx) = spawn_worker(cfg(1, 1.0));
        tx.send(ToWorker::Work(order(vec![], 60, None))).unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        assert!(r.segments.is_empty());
        assert!(r.measured_speed.is_none());
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn drop_straggler_stays_silent() {
        let (tx, rx) = spawn_worker(cfg(2, 1.0));
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 0,
                rows: RowRange::new(0, 10),
            }],
            60,
            Some(StraggleMode::Drop),
        )))
        .unwrap();
        assert!(rx.recv_timeout(Duration::from_millis(300)).is_err());
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn throttle_slows_reports() {
        let mut c = cfg(3, 1.0);
        c.speed = 0.5; // half speed
        let (tx, rx) = spawn_worker(c);
        let mut o = order(
            vec![Task {
                g: 0,
                rows: RowRange::new(0, 10),
            }],
            60,
            None,
        );
        o.row_cost_ns = 2_000_000; // 2ms/row at speed 1 → 40ms at 0.5
        tx.send(ToWorker::Work(o)).unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        assert!(
            r.elapsed >= Duration::from_millis(35),
            "throttle not applied: {:?}",
            r.elapsed
        );
        tx.send(ToWorker::Shutdown).unwrap();
    }

    #[test]
    fn measured_speed_tracks_throttle_ratio() {
        // two workers with 2x speed ratio must report ~2x measured speed
        let run = |speed: f64| {
            let mut c = cfg(4, speed);
            c.speed = speed;
            let (tx, rx) = spawn_worker(c);
            let mut o = order(
                vec![Task {
                    g: 1,
                    rows: RowRange::new(0, 10),
                }],
                60,
                None,
            );
            o.row_cost_ns = 8_000_000; // 80ms at speed 1 — dwarfs sleep jitter
            tx.send(ToWorker::Work(o)).unwrap();
            let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
                panic!("expected report");
            };
            tx.send(ToWorker::Shutdown).unwrap();
            r.measured_speed.unwrap()
        };
        let slow = run(1.0);
        let fast = run(2.0);
        let ratio = fast / slow;
        assert!((1.5..2.6).contains(&ratio), "speed ratio {ratio}");
    }

    #[test]
    fn shard_worker_matches_full_worker_and_rejects_unplaced_rows() {
        let q = 60;
        let matrix = Arc::new(gen::random_dense(q, q, 5));
        let ranges = Arc::new(crate::linalg::partition::submatrix_ranges(q, 6).unwrap());
        // shard worker stores sub-matrices {1, 2} only (global rows 10..30)
        let placed = vec![ranges[1], ranges[2]];
        let shard = Arc::new(RowShard::from_matrix(&matrix, &placed).unwrap());
        assert_eq!(shard.resident_bytes(), 20 * q * 4);
        let c = WorkerConfig {
            id: 7,
            backend: BackendSpec::Host,
            speed: 1.0,
            tile_rows: 16,
            storage: WorkerStorage::shard(shard, Arc::clone(&ranges)),
        };
        let (tx, rx) = spawn_worker(c);
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 2,
                rows: RowRange::new(2, 8),
            }],
            q,
            None,
        )))
        .unwrap();
        let ToMaster::Report(r) = rx.recv_timeout(Duration::from_secs(5)).unwrap() else {
            panic!("expected report");
        };
        let w = vec![0.1f32; q];
        for seg in &r.segments {
            for (i, row) in (seg.rows.lo..seg.rows.hi).enumerate() {
                let want: f32 = matrix.row(row).iter().zip(&w).map(|(a, b)| a * b).sum();
                assert!((seg.values[i] - want).abs() < 1e-4);
            }
        }
        // a task over rows the shard does not store must fail, not panic
        tx.send(ToWorker::Work(order(
            vec![Task {
                g: 4,
                rows: RowRange::new(0, 5),
            }],
            q,
            None,
        )))
        .unwrap();
        match rx.recv_timeout(Duration::from_secs(5)).unwrap() {
            ToMaster::Failed { worker, .. } => assert_eq!(worker, 7),
            other => panic!("expected Failed, got {other:?}"),
        }
        tx.send(ToWorker::Shutdown).unwrap();
    }
}
