//! Speed estimation (Algorithm 1 lines 1, 4, 14) and speed profiles.
//!
//! The master never knows true speeds; it maintains `ŝ` and updates it
//! each step from worker-measured `ν[n] = μ[n]/(τ₂−τ₁)` with
//! `ŝ ← γ·ν + (1−γ)·ŝ`. Machines that did not report (preempted or
//! straggling) keep their previous estimate.

/// EWMA speed estimator.
#[derive(Debug, Clone)]
pub struct SpeedEstimator {
    gamma: f64,
    estimate: Vec<f64>,
}

impl SpeedEstimator {
    /// Start from an initial guess `ŝ₀` (Algorithm 1 line 1 initializes all
    /// workers to the same prior).
    pub fn new(gamma: f64, initial: Vec<f64>) -> Self {
        assert!((0.0..=1.0).contains(&gamma), "gamma {gamma} not in [0,1]");
        assert!(initial.iter().all(|&s| s > 0.0), "speeds must be positive");
        SpeedEstimator {
            gamma,
            estimate: initial,
        }
    }

    /// Uniform prior of `1.0` for `n` machines.
    pub fn uniform(gamma: f64, n: usize) -> Self {
        Self::new(gamma, vec![1.0; n])
    }

    /// Current estimate `ŝ`.
    pub fn estimate(&self) -> &[f64] {
        &self.estimate
    }

    /// Fold in one measurement (Algorithm 1 line 4).
    pub fn update(&mut self, machine: usize, measured: f64) {
        if measured > 0.0 && measured.is_finite() {
            let s = &mut self.estimate[machine];
            *s = self.gamma * measured + (1.0 - self.gamma) * *s;
        }
    }

    /// Fold in a batch of `(machine, ν)` measurements.
    pub fn update_all(&mut self, measurements: &[(usize, f64)]) {
        for &(n, v) in measurements {
            self.update(n, v);
        }
    }
}

/// EC2-like speed profiles (DESIGN.md §3). The paper's testbed mixes 3×
/// t2.large and 3× t2.xlarge; measured throughputs differ ~2× between the
/// classes plus significant within-class variation (\[4\]'s observation).
pub fn ec2_mixed_profile(n: usize) -> Vec<f64> {
    // Interleave large (≈1.0) and xlarge (≈2.2) instances with ±15 %
    // deterministic jitter. Interleaving matters: under the repetition
    // placement the replica groups are consecutive machines, and a real
    // EC2 allocation mixes instance classes within a group — that
    // within-group heterogeneity is precisely what the paper's assignment
    // exploits.
    (0..n)
        .map(|i| {
            let base = if i % 2 == 0 { 1.0 } else { 2.2 };
            let jitter = 1.0 + 0.15 * (((i * 7 + 3) as f64) * 2.399).sin();
            base * jitter
        })
        .collect()
}

/// The paper's Fig. 1 example speeds, extended/truncated to `n`.
pub fn geometric_profile(n: usize) -> Vec<f64> {
    (0..n).map(|i| 2f64.powi(i as i32 % 6)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ewma_converges_to_truth() {
        let mut e = SpeedEstimator::uniform(0.5, 1);
        for _ in 0..40 {
            e.update(0, 4.0);
        }
        assert!((e.estimate()[0] - 4.0).abs() < 1e-6);
    }

    #[test]
    fn gamma_zero_never_moves() {
        let mut e = SpeedEstimator::new(0.0, vec![2.0]);
        e.update(0, 100.0);
        assert_eq!(e.estimate()[0], 2.0);
    }

    #[test]
    fn gamma_one_tracks_instantly() {
        let mut e = SpeedEstimator::new(1.0, vec![2.0]);
        e.update(0, 7.0);
        assert_eq!(e.estimate()[0], 7.0);
    }

    #[test]
    fn missing_reports_keep_estimate() {
        let mut e = SpeedEstimator::new(0.5, vec![1.0, 1.0]);
        e.update_all(&[(0, 3.0)]);
        assert!(e.estimate()[0] > 1.0);
        assert_eq!(e.estimate()[1], 1.0);
    }

    #[test]
    fn rejects_garbage_measurements() {
        let mut e = SpeedEstimator::new(0.5, vec![1.0]);
        e.update(0, -1.0);
        e.update(0, f64::NAN);
        e.update(0, f64::INFINITY);
        assert_eq!(e.estimate()[0], 1.0);
    }

    #[test]
    fn profiles_have_expected_shape() {
        let p = ec2_mixed_profile(6);
        assert_eq!(p.len(), 6);
        assert!(p.iter().all(|&s| s > 0.0));
        // interleaved: every xlarge (odd) is faster than every large (even)
        for odd in [1, 3, 5] {
            for even in [0, 2, 4] {
                assert!(p[odd] > p[even], "{p:?}");
            }
        }
        let g = geometric_profile(6);
        assert_eq!(g, vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0]);
    }

    #[test]
    fn tracks_drifting_speed() {
        let mut e = SpeedEstimator::new(0.5, vec![1.0]);
        // speed drifts up; estimate follows within a few steps
        for step in 0..30 {
            let truth = 1.0 + step as f64 * 0.1;
            e.update(0, truth);
        }
        assert!((e.estimate()[0] - 3.9).abs() < 0.2);
    }
}
