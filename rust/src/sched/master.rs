//! The master machine (Algorithm 1 lines 3–7, 16–17).
//!
//! [`Master::step`] performs one elastic computation step: solve the
//! assignment for the current speed estimates, ship work orders, wait
//! until the received segments *cover every row of `y`* (with straggler
//! tolerance `S`, coverage is guaranteed after any `N_t − S` reports),
//! assemble `y_t`, and fold measured speeds into the EWMA estimator.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::types::AssignPolicy;
use crate::error::{Error, Result};
use crate::linalg::partition::RowRange;
use crate::linalg::Block;
use crate::net::{Transport, TransportEvent};
use crate::optim::{self, Assignment, SolveParams};
use crate::placement::Placement;
use crate::util::json::{Json, ObjBuilder};

use super::protocol::WorkOrder;
use super::speed::SpeedEstimator;
use super::straggler::StraggleMode;

/// Master configuration (static across steps).
#[derive(Clone)]
pub struct MasterConfig {
    pub placement: Placement,
    /// Global row range of each sub-matrix.
    pub sub_ranges: Vec<RowRange>,
    pub params: SolveParams,
    pub policy: AssignPolicy,
    /// EWMA factor γ.
    pub gamma: f64,
    /// Initial speed guess `ŝ₀` (uniform prior if empty).
    pub initial_speeds: Vec<f64>,
    /// Simulated per-row cost forwarded to workers (throttle).
    pub row_cost_ns: u64,
    /// How long to wait for coverage before declaring the step lost.
    pub recovery_timeout: Duration,
}

/// What one step produced.
#[derive(Debug)]
pub struct StepOutcome {
    /// Assembled product block `Y_t = X W_t`, `q × nvec` interleaved
    /// (`y[row*nvec + k]` is row `row` of product vector `k`). With
    /// `nvec == 1` this is the plain product vector, unchanged from the
    /// single-vector plane.
    pub y: Vec<f32>,
    /// Block width `B` of this step's iterate.
    pub nvec: usize,
    /// Workers whose reports were used.
    pub reporters: Vec<usize>,
    /// Wall-clock of the whole step (solve + compute + assemble).
    pub wall: Duration,
    /// Time spent in the assignment solver.
    pub solve: Duration,
    /// Predicted computation time `c(M*)` under the *estimated* speeds.
    pub predicted_c: f64,
}

/// Result summary of a full run (filled by the apps layer).
#[derive(Debug)]
pub struct RunResult {
    pub timeline: crate::metrics::Timeline,
    pub final_iterate: Vec<f32>,
    pub eigval_estimate: f64,
}

impl RunResult {
    /// Machine-readable dump for library embedders: eigenvalue estimate,
    /// iterate geometry, and the full per-step timeline. (The `usec` CLI's
    /// `--json-out` builds its own document in [`crate::exp`] with
    /// app/backend/policy metadata around the same
    /// [`crate::metrics::Timeline::to_json`] payload.)
    pub fn to_json(&self) -> Json {
        let norm: f64 = self
            .final_iterate
            .iter()
            .map(|&x| f64::from(x) * f64::from(x))
            .sum::<f64>()
            .sqrt();
        ObjBuilder::new()
            .num("eigval_estimate", self.eigval_estimate)
            .num("iterate_len", self.final_iterate.len() as f64)
            .num("iterate_norm", norm)
            .val("timeline", self.timeline.to_json())
            .build()
    }
}

/// The elastic master.
pub struct Master {
    cfg: MasterConfig,
    estimator: SpeedEstimator,
    q: usize,
    sub_rows: Vec<usize>,
}

impl Master {
    pub fn new(cfg: MasterConfig) -> Result<Master> {
        let n = cfg.placement.machines();
        if cfg.sub_ranges.len() != cfg.placement.submatrices() {
            return Err(Error::Shape(format!(
                "{} sub-ranges for G={}",
                cfg.sub_ranges.len(),
                cfg.placement.submatrices()
            )));
        }
        let estimator = if cfg.initial_speeds.is_empty() {
            SpeedEstimator::uniform(cfg.gamma, n)
        } else {
            if cfg.initial_speeds.len() != n {
                return Err(Error::Shape(format!(
                    "{} initial speeds for N={n}",
                    cfg.initial_speeds.len()
                )));
            }
            SpeedEstimator::new(cfg.gamma, cfg.initial_speeds.clone())
        };
        let q = cfg.sub_ranges.iter().map(|r| r.len()).sum();
        let sub_rows = cfg.sub_ranges.iter().map(|r| r.len()).collect();
        Ok(Master {
            cfg,
            estimator,
            q,
            sub_rows,
        })
    }

    /// Current speed estimates `ŝ`.
    pub fn speed_estimate(&self) -> &[f64] {
        self.estimator.estimate()
    }

    /// Build this step's assignment under the configured policy.
    pub fn plan(&self, avail: &[usize]) -> Result<Assignment> {
        let speeds = self.estimator.estimate();
        match self.cfg.policy {
            AssignPolicy::Heterogeneous => optim::build_assignment(
                &self.cfg.placement,
                avail,
                speeds,
                &self.cfg.params,
                &self.sub_rows,
            ),
            AssignPolicy::Uniform => optim::assignment::build_uniform_assignment(
                &self.cfg.placement,
                avail,
                &self.cfg.params,
                &self.sub_rows,
            ),
            AssignPolicy::CyclicHomogeneous => {
                optim::assignment::build_cyclic_homogeneous_assignment(
                    &self.cfg.placement,
                    avail,
                    self.cfg.params.stragglers,
                    &self.sub_rows,
                )
            }
        }
    }

    /// One elastic computation step (Algorithm 1 lines 3–7 + 16).
    ///
    /// Generic over the [`Transport`]: the same loop drives in-process
    /// worker threads ([`crate::net::LocalTransport`] / the bare
    /// [`crate::sched::Cluster`]) and remote TCP worker daemons
    /// ([`crate::net::TcpTransport`]).
    ///
    /// `stragglers` are the chaos-injected victims for this step (the
    /// master ships the instruction; a real deployment would simply
    /// experience them).
    /// `w` is the iterate *block*: `B` vectors per step
    /// ([`crate::linalg::Block`]); wrap a plain vector with
    /// [`Block::single`] for the classic `B = 1` plane.
    pub fn step<T: Transport + ?Sized>(
        &mut self,
        cluster: &T,
        step: usize,
        w: &Arc<Block>,
        avail: &[usize],
        stragglers: &[(usize, StraggleMode)],
    ) -> Result<StepOutcome> {
        let t0 = Instant::now();
        let nvec = w.nvec();

        // ---- solve ----
        let solve_start = Instant::now();
        let assignment = self.plan(avail)?;
        let solve = solve_start.elapsed();
        let predicted_c = assignment
            .realized_load_matrix(&self.sub_rows)
            .computation_time(self.estimator.estimate(), avail);

        // ---- dispatch ----
        let mut expected = 0usize;
        for &n in avail {
            let tasks = assignment.tasks_for(n);
            if tasks.is_empty() {
                continue;
            }
            let straggle = stragglers
                .iter()
                .find(|&&(m, _)| m == n)
                .map(|&(_, mode)| mode);
            // A dead worker (channel closed — backend init failure or
            // panic) is tolerated like a straggler: redundancy or the
            // coverage timeout decides the step's fate, not the dispatch.
            match cluster.send(
                n,
                WorkOrder {
                    step,
                    w: Arc::clone(w),
                    tasks,
                    row_cost_ns: self.cfg.row_cost_ns,
                    straggle,
                },
            ) {
                Ok(()) => expected += 1,
                Err(e) => {
                    crate::log_warn!("step {step}: dispatch to worker {n} failed: {e}");
                }
            }
        }
        if expected == 0 {
            return Err(Error::infeasible("no worker received any task"));
        }

        // ---- collect until coverage ----
        let mut y = vec![0.0f32; self.q * nvec];
        let mut covered = vec![false; self.q];
        let mut missing = self.q;
        let mut reporters = Vec::new();
        let mut measurements: Vec<(usize, f64)> = Vec::new();
        let deadline = Instant::now() + self.cfg.recovery_timeout;

        while missing > 0 {
            let now = Instant::now();
            if now >= deadline {
                return Err(Error::Cluster(format!(
                    "step {step}: coverage timeout with {missing} rows missing \
                     ({}/{} reports)",
                    reporters.len(),
                    expected
                )));
            }
            match cluster.recv_timeout(deadline - now) {
                Ok(TransportEvent::Report(r)) => {
                    if r.step != step {
                        continue; // stale report from a previous step
                    }
                    if r.worker >= self.cfg.placement.machines() {
                        // defense in depth vs a misbehaving transport: an
                        // unknown id must not index the speed estimator
                        crate::log_warn!(
                            "step {step}: report from unknown worker {}, dropped",
                            r.worker
                        );
                        continue;
                    }
                    if r.nvec != nvec {
                        // a report for a different block width cannot be
                        // spliced into this step's panel
                        crate::log_warn!(
                            "step {step}: worker {} reported B={}, expected B={nvec}, dropped",
                            r.worker,
                            r.nvec
                        );
                        continue;
                    }
                    for seg in &r.segments {
                        debug_assert_eq!(seg.values.len(), seg.rows.len() * nvec);
                        if seg.rows.hi > self.q {
                            // a remote peer must not be able to panic the
                            // master with out-of-range rows
                            crate::log_warn!(
                                "worker {}: segment {}..{} exceeds q={}, dropped",
                                r.worker,
                                seg.rows.lo,
                                seg.rows.hi,
                                self.q
                            );
                            continue;
                        }
                        for (i, row) in (seg.rows.lo..seg.rows.hi).enumerate() {
                            if !covered[row] {
                                covered[row] = true;
                                missing -= 1;
                            }
                            y[row * nvec..(row + 1) * nvec]
                                .copy_from_slice(&seg.values[i * nvec..(i + 1) * nvec]);
                        }
                    }
                    if let Some(v) = r.measured_speed {
                        measurements.push((r.worker, v));
                    }
                    reporters.push(r.worker);
                }
                Ok(TransportEvent::Failed { worker, error, .. }) => {
                    crate::log_warn!("worker {worker} failed in step {step}: {error}");
                }
                Ok(TransportEvent::Disconnected { worker }) => {
                    // Mid-step preemption: redundancy (S ≥ 1 or replica
                    // coverage) or the timeout decides the step; the
                    // transport's liveness view removes the worker from
                    // the availability set at the next step.
                    crate::log_warn!(
                        "worker {worker} disconnected during step {step} \
                         (treated as preemption)"
                    );
                }
                Err(_) => {
                    return Err(Error::Cluster(format!(
                        "step {step}: coverage timeout with {missing} rows missing"
                    )));
                }
            }
        }

        // ---- speed update (Algorithm 1 line 4, next step's estimate) ----
        self.estimator.update_all(&measurements);

        Ok(StepOutcome {
            y,
            nvec,
            reporters,
            wall: t0.elapsed(),
            solve,
            predicted_c,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::types::AssignPolicy;
    use crate::linalg::partition::submatrix_ranges;
    use crate::linalg::{gen, Matrix};
    use crate::placement::PlacementKind;
    use crate::runtime::BackendSpec;
    use crate::sched::cluster::Cluster;
    use crate::sched::worker::{WorkerConfig, WorkerStorage};

    fn build(
        q: usize,
        speeds: &[f64],
        policy: AssignPolicy,
        s: usize,
    ) -> (Master, Cluster, Arc<Matrix>) {
        let n = speeds.len();
        let placement = Placement::build(PlacementKind::Cyclic, n, n, 3).unwrap();
        let sub_ranges = submatrix_ranges(q, n).unwrap();
        let matrix = Arc::new(gen::random_dense(q, q, 9));
        let ranges = Arc::new(sub_ranges.clone());
        let configs: Vec<WorkerConfig> = (0..n)
            .map(|id| WorkerConfig {
                id,
                backend: BackendSpec::Host,
                speed: speeds[id],
                tile_rows: 16,
                threads: 1,
                storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&ranges)),
            })
            .collect();
        let cluster = Cluster::spawn(configs).unwrap();
        let master = Master::new(MasterConfig {
            placement,
            sub_ranges,
            params: SolveParams::with_stragglers(s),
            policy,
            gamma: 0.5,
            initial_speeds: speeds.to_vec(),
            row_cost_ns: 0,
            recovery_timeout: Duration::from_secs(10),
        })
        .unwrap();
        (master, cluster, matrix)
    }

    fn oracle_y(matrix: &Matrix, w: &[f32]) -> Vec<f32> {
        matrix.matvec(w).unwrap()
    }

    #[test]
    fn step_assembles_exact_product() {
        let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        let w = Arc::new(Block::single(vec![0.25f32; 60]));
        let avail: Vec<usize> = (0..6).collect();
        let out = master.step(&cluster, 0, &w, &avail, &[]).unwrap();
        let want = oracle_y(&matrix, w.data());
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
        assert!(!out.reporters.is_empty());
        assert!(out.predicted_c > 0.0);
        cluster.shutdown();
    }

    #[test]
    fn step_assembles_block_product() {
        let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        let nvec = 3;
        let cols: Vec<Vec<f32>> = (0..nvec)
            .map(|k| (0..60).map(|i| ((i * (k + 1)) % 9) as f32 * 0.1 - 0.4).collect())
            .collect();
        let w = Arc::new(Block::from_columns(&cols).unwrap());
        let avail: Vec<usize> = (0..6).collect();
        let out = master.step(&cluster, 0, &w, &avail, &[]).unwrap();
        assert_eq!(out.nvec, nvec);
        assert_eq!(out.y.len(), 60 * nvec);
        for (k, col) in cols.iter().enumerate() {
            let want = oracle_y(&matrix, col);
            for (row, e) in want.iter().enumerate() {
                let a = out.y[row * nvec + k];
                assert!((a - e).abs() < 1e-4, "col {k} row {row}: {a} vs {e}");
            }
        }
        cluster.shutdown();
    }

    #[test]
    fn step_with_preempted_machines() {
        let speeds = vec![1.0; 6];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        let w = Arc::new(Block::single(vec![1.0f32; 60]));
        // cyclic J=3 placement tolerates 2 preemptions for S=0
        let avail = vec![0, 2, 3, 5];
        let out = master.step(&cluster, 1, &w, &avail, &[]).unwrap();
        let want = oracle_y(&matrix, w.data());
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-3);
        }
        assert!(out.reporters.iter().all(|r| avail.contains(r)));
        cluster.shutdown();
    }

    #[test]
    fn straggler_tolerant_step_recovers_with_drop() {
        let speeds = vec![1.0; 6];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 1);
        let w = Arc::new(Block::single(vec![0.5f32; 60]));
        let avail: Vec<usize> = (0..6).collect();
        let out = master
            .step(&cluster, 2, &w, &avail, &[(3, StraggleMode::Drop)])
            .unwrap();
        assert!(!out.reporters.contains(&3));
        let want = oracle_y(&matrix, w.data());
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-3);
        }
        cluster.shutdown();
    }

    #[test]
    fn unprotected_step_times_out_under_drop() {
        let speeds = vec![1.0; 6];
        let (mut master, cluster, _) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        master.cfg.recovery_timeout = Duration::from_millis(400);
        let w = Arc::new(Block::single(vec![0.5f32; 60]));
        let avail: Vec<usize> = (0..6).collect();
        let r = master.step(&cluster, 3, &w, &avail, &[(0, StraggleMode::Drop)]);
        assert!(r.is_err(), "S=0 cannot survive a dropped worker");
        cluster.shutdown();
    }

    #[test]
    fn speed_estimates_adapt_from_reports() {
        let speeds = vec![0.5, 4.0, 1.0, 1.0, 1.0, 1.0];
        let n = speeds.len();
        let placement = Placement::build(PlacementKind::Cyclic, n, n, 3).unwrap();
        let q = 120;
        let sub_ranges = submatrix_ranges(q, n).unwrap();
        let matrix = Arc::new(gen::random_dense(q, q, 11));
        let ranges = Arc::new(sub_ranges.clone());
        let configs: Vec<WorkerConfig> = (0..n)
            .map(|id| WorkerConfig {
                id,
                backend: BackendSpec::Host,
                speed: speeds[id],
                tile_rows: 16,
                threads: 1,
                storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&ranges)),
            })
            .collect();
        let cluster = Cluster::spawn(configs).unwrap();
        // master starts with a WRONG uniform prior and must learn
        let mut master = Master::new(MasterConfig {
            placement,
            sub_ranges,
            params: SolveParams::default(),
            policy: AssignPolicy::Heterogeneous,
            gamma: 0.6,
            initial_speeds: vec![],
            row_cost_ns: 300_000, // 0.3ms/row → measurable ratios
            recovery_timeout: Duration::from_secs(20),
        })
        .unwrap();
        let w = Arc::new(Block::single(vec![0.1f32; q]));
        let avail: Vec<usize> = (0..n).collect();
        for step in 0..6 {
            master.step(&cluster, step, &w, &avail, &[]).unwrap();
        }
        let est = master.speed_estimate();
        // measured units are sub-matrices/sec; only ratios matter
        let ratio = est[1] / est[0];
        assert!(
            ratio > 3.0,
            "estimator did not learn the 8x speed gap: {est:?}"
        );
        cluster.shutdown();
    }

    #[test]
    fn run_result_json_is_parseable() {
        let rr = RunResult {
            timeline: crate::metrics::Timeline::new(),
            final_iterate: vec![0.6, 0.8],
            eigval_estimate: 9.9,
        };
        let back = crate::util::json::Json::parse(&rr.to_json().to_string()).unwrap();
        assert_eq!(back.get_usize("iterate_len"), Some(2));
        assert!((back.get_num("iterate_norm").unwrap() - 1.0).abs() < 1e-6);
        assert!((back.get_num("eigval_estimate").unwrap() - 9.9).abs() < 1e-12);
    }

    #[test]
    fn uniform_policy_ignores_estimates() {
        let speeds = vec![1.0, 32.0, 1.0, 1.0, 1.0, 1.0];
        let (master, cluster, _) = build(60, &speeds, AssignPolicy::Uniform, 0);
        let a = master.plan(&(0..6).collect::<Vec<_>>()).unwrap();
        let rows: Vec<usize> = (0..6).map(|n| a.rows_for(n)).collect();
        let spread = rows.iter().max().unwrap() - rows.iter().min().unwrap();
        assert!(spread <= 6, "uniform policy skewed: {rows:?}");
        cluster.shutdown();
    }
}
