//! The master machine (Algorithm 1 lines 3–7, 16–17).
//!
//! [`Master::step`] performs one elastic computation step: solve the
//! assignment for the current speed estimates, ship work orders, wait
//! until the received segments *cover every row of `y`* (with straggler
//! tolerance `S`, coverage is guaranteed after any `N_t − S` reports),
//! assemble `y_t`, and fold measured speeds into the EWMA estimator.
//!
//! With [`RecoveryPolicy::enabled`] the collect loop also *recovers*
//! mid-step: a worker that disconnects, reports a failure, or goes silent
//! past the overdue fraction of the recovery timeout has its
//! still-uncovered rows re-planned onto surviving replicas
//! ([`crate::optim::recovery`]) and shipped as supplementary
//! [`WorkOrder`]s for the same step. Reports dedup by row through the
//! coverage bitmap and by worker id for the EWMA, so late originals and
//! recovery replacements coexist safely.
//!
//! ## Pipelining
//!
//! [`Master::step`] is really two halves: [`Master::begin_step`] (solve +
//! dispatch, returning an [`InFlightStep`]) and [`Master::collect_step`]
//! (the coverage wait). The synchronous `step` chains them back to back —
//! bit-identical to the pre-split loop — while the pipelined harness
//! ([`crate::apps::harness`], `--pipeline`) calls `begin_step` for step
//! `i+1` *before* finishing step `i`'s bookkeeping, so workers compute
//! while the master is busy. Worker order queues are step-agnostic, and
//! the collect loop already drops stale-step reports, so at most one
//! step's coverage is ever being collected at a time.
//!
//! All of the collect loop's waits are bounded by one
//! [`TimerWheel`]: the coverage deadline and the next-overdue instant are
//! armed slots, re-derived only when the state behind them changes (an
//! event burst no longer recomputes the overdue clock per event).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::config::types::AssignPolicy;
use crate::error::{Error, Result};
use crate::linalg::partition::RowRange;
use crate::linalg::Block;
use crate::net::{Transport, TransportEvent};
use crate::obs::{Event, EventKind, OrderStat, Recorder, Registry};
use crate::optim::{self, Assignment, SolveParams};
use crate::placement::Placement;
use crate::util::json::{Json, ObjBuilder};

use super::protocol::WorkOrder;
use super::recovery::{RecoveryEvent, RecoveryPolicy, RecoveryReason, RecoveryTracker};
use super::speed::SpeedEstimator;
use super::straggler::StraggleMode;
use super::timer::{DeadlineKind, TimerWheel};

/// Master configuration (static across steps).
#[derive(Clone)]
pub struct MasterConfig {
    pub placement: Placement,
    /// Global row range of each sub-matrix.
    pub sub_ranges: Vec<RowRange>,
    pub params: SolveParams,
    pub policy: AssignPolicy,
    /// EWMA factor γ.
    pub gamma: f64,
    /// Initial speed guess `ŝ₀` (uniform prior if empty).
    pub initial_speeds: Vec<f64>,
    /// Simulated per-row cost forwarded to workers (throttle).
    pub row_cost_ns: u64,
    /// How long to wait for coverage before declaring the step lost.
    pub recovery_timeout: Duration,
    /// Mid-step recovery: re-dispatch a victim's uncovered rows to
    /// surviving replicas (disabled by default — bit-identical to the
    /// classic redundancy-or-timeout behaviour).
    pub recovery: RecoveryPolicy,
}

/// What one step produced.
#[derive(Debug)]
pub struct StepOutcome {
    /// Assembled product block `Y_t = X W_t`, `q × nvec` interleaved
    /// (`y[row*nvec + k]` is row `row` of product vector `k`). With
    /// `nvec == 1` this is the plain product vector, unchanged from the
    /// single-vector plane.
    pub y: Vec<f32>,
    /// Block width `B` of this step's iterate.
    pub nvec: usize,
    /// Workers whose reports were used.
    pub reporters: Vec<usize>,
    /// Wall-clock of the whole step (solve + compute + assemble).
    pub wall: Duration,
    /// Time spent in the assignment solver.
    pub solve: Duration,
    /// Predicted computation time `c(M*)` under the *estimated* speeds.
    pub predicted_c: f64,
    /// Mid-step recoveries performed (empty unless
    /// [`MasterConfig::recovery`] is enabled and a worker was rescued).
    pub recoveries: Vec<RecoveryEvent>,
    /// Per-order round trips observed this step, with the worker-side
    /// breakdown when the report carried one. Populated only when a
    /// tracing [`Recorder`] is attached ([`Master::set_recorder`]) —
    /// empty otherwise, so the untraced step loop does no bookkeeping.
    pub order_stats: Vec<OrderStat>,
}

/// Result summary of a full run (filled by the apps layer).
#[derive(Debug)]
pub struct RunResult {
    pub timeline: crate::metrics::Timeline,
    pub final_iterate: Vec<f32>,
    pub eigval_estimate: f64,
}

impl RunResult {
    /// Machine-readable dump for library embedders: eigenvalue estimate,
    /// iterate geometry, and the full per-step timeline. (The `usec` CLI's
    /// `--json-out` builds its own document in [`crate::exp`] with
    /// app/backend/policy metadata around the same
    /// [`crate::metrics::Timeline::to_json`] payload.)
    pub fn to_json(&self) -> Json {
        let norm: f64 = self
            .final_iterate
            .iter()
            .map(|&x| f64::from(x) * f64::from(x))
            .sum::<f64>()
            .sqrt();
        ObjBuilder::new()
            .num("eigval_estimate", self.eigval_estimate)
            .num("iterate_len", self.final_iterate.len() as f64)
            .num("iterate_norm", norm)
            .val("timeline", self.timeline.to_json())
            .build()
    }
}

/// One dispatched-but-unanswered order, tracked only while tracing: the
/// master-side half of the `dispatch` → `order` journal pair.
struct PendingOrder {
    worker: usize,
    order: u64,
    rows: usize,
    sent: Instant,
    /// Journal timestamp of the dispatch (the order span's start).
    t_ns: u64,
}

/// A dispatched step whose coverage has not been collected yet — the
/// state handed from [`Master::begin_step`] to [`Master::collect_step`].
/// While one of these is outstanding, workers are computing; the caller
/// is free to do master-side bookkeeping for the *previous* step before
/// collecting (the `--pipeline` overlap).
pub struct InFlightStep {
    step: usize,
    nvec: usize,
    w: Arc<Block>,
    avail: Vec<usize>,
    t0: Instant,
    solve: Duration,
    predicted_c: f64,
    tracker: Option<RecoveryTracker>,
    expected: usize,
    pending: Vec<PendingOrder>,
    y: Vec<f32>,
    covered: Vec<bool>,
    missing: usize,
    reporters: Vec<usize>,
    reported: Vec<bool>,
    measurements: Vec<(usize, f64)>,
    recoveries: Vec<RecoveryEvent>,
    order_stats: Vec<OrderStat>,
    /// Coverage + overdue deadlines; every collect wait is sized off this.
    wheel: TimerWheel,
    /// True when the tracker changed since the overdue slot was armed.
    overdue_dirty: bool,
    overdue_delay: Option<Duration>,
}

impl InFlightStep {
    /// The step index this in-flight state belongs to.
    pub fn step(&self) -> usize {
        self.step
    }

    /// Re-derive the overdue slot from the tracker. Called only when the
    /// tracker actually changed (`overdue_dirty`) — a burst of received
    /// events no longer recomputes the next overdue instant per event.
    fn rearm_overdue(&mut self) {
        if let Some(delay) = self.overdue_delay {
            match self.tracker.as_ref().and_then(|t| t.next_overdue_at(delay)) {
                Some(at) => self.wheel.set(DeadlineKind::Overdue, at),
                None => self.wheel.clear(DeadlineKind::Overdue),
            }
        }
        self.overdue_dirty = false;
    }
}

/// The elastic master.
pub struct Master {
    cfg: MasterConfig,
    estimator: SpeedEstimator,
    q: usize,
    sub_rows: Vec<usize>,
    /// Tracing sink ([`crate::obs`]); `None` (the default) keeps every
    /// hot-loop instrumentation branch dead.
    recorder: Option<Recorder>,
    /// Per-worker counter registry shared with the harness.
    registry: Option<Arc<Registry>>,
    /// Run-unique order-id allocator (atomic: recovery re-dispatches
    /// allocate through `&self`).
    next_order: AtomicU64,
}

impl Master {
    pub fn new(cfg: MasterConfig) -> Result<Master> {
        let n = cfg.placement.machines();
        cfg.recovery.validate()?;
        if cfg.sub_ranges.len() != cfg.placement.submatrices() {
            return Err(Error::Shape(format!(
                "{} sub-ranges for G={}",
                cfg.sub_ranges.len(),
                cfg.placement.submatrices()
            )));
        }
        let estimator = if cfg.initial_speeds.is_empty() {
            SpeedEstimator::uniform(cfg.gamma, n)
        } else {
            if cfg.initial_speeds.len() != n {
                return Err(Error::Shape(format!(
                    "{} initial speeds for N={n}",
                    cfg.initial_speeds.len()
                )));
            }
            SpeedEstimator::new(cfg.gamma, cfg.initial_speeds.clone())
        };
        let q = cfg.sub_ranges.iter().map(|r| r.len()).sum();
        let sub_rows = cfg.sub_ranges.iter().map(|r| r.len()).collect();
        Ok(Master {
            cfg,
            estimator,
            q,
            sub_rows,
            recorder: None,
            registry: None,
            next_order: AtomicU64::new(0),
        })
    }

    /// Attach (or detach) a tracing recorder. While attached, every order
    /// is dispatched with [`WorkOrder::trace`] set, `solve`/`dispatch`/
    /// `order`/`recovery`/`heartbeat_lapse` events land in the journal,
    /// and [`StepOutcome::order_stats`] is populated.
    pub fn set_recorder(&mut self, recorder: Option<Recorder>) {
        self.recorder = recorder;
    }

    /// Attach the per-worker counter registry ([`crate::obs::Registry`]).
    pub fn set_registry(&mut self, registry: Arc<Registry>) {
        self.registry = Some(registry);
    }

    /// Current speed estimates `ŝ`.
    pub fn speed_estimate(&self) -> &[f64] {
        self.estimator.estimate()
    }

    /// The placement assignments are currently planned against.
    pub fn placement(&self) -> &Placement {
        &self.cfg.placement
    }

    /// Swap the placement between steps (live rebalancing,
    /// [`crate::rebalance`]). The caller guarantees the new placement's
    /// storage is actually resident (make-before-break migration); this
    /// only checks the geometry still matches the run.
    pub fn set_placement(&mut self, p: Placement) -> Result<()> {
        if p.machines() != self.cfg.placement.machines()
            || p.submatrices() != self.cfg.placement.submatrices()
        {
            return Err(Error::Shape(format!(
                "placement geometry changed: N {}→{}, G {}→{}",
                self.cfg.placement.machines(),
                p.machines(),
                self.cfg.placement.submatrices(),
                p.submatrices()
            )));
        }
        self.cfg.placement = p;
        Ok(())
    }

    /// Build this step's assignment under the configured policy.
    pub fn plan(&self, avail: &[usize]) -> Result<Assignment> {
        let speeds = self.estimator.estimate();
        match self.cfg.policy {
            AssignPolicy::Heterogeneous => optim::build_assignment(
                &self.cfg.placement,
                avail,
                speeds,
                &self.cfg.params,
                &self.sub_rows,
            ),
            AssignPolicy::Uniform => optim::assignment::build_uniform_assignment(
                &self.cfg.placement,
                avail,
                &self.cfg.params,
                &self.sub_rows,
            ),
            AssignPolicy::CyclicHomogeneous => {
                optim::assignment::build_cyclic_homogeneous_assignment(
                    &self.cfg.placement,
                    avail,
                    self.cfg.params.stragglers,
                    &self.sub_rows,
                )
            }
        }
    }

    /// One elastic computation step (Algorithm 1 lines 3–7 + 16).
    ///
    /// Generic over the [`Transport`]: the same loop drives in-process
    /// worker threads ([`crate::net::LocalTransport`] / the bare
    /// [`crate::sched::Cluster`]) and remote TCP worker daemons
    /// ([`crate::net::TcpTransport`]).
    ///
    /// `stragglers` are the chaos-injected victims for this step (the
    /// master ships the instruction; a real deployment would simply
    /// experience them).
    /// `w` is the iterate *block*: `B` vectors per step
    /// ([`crate::linalg::Block`]); wrap a plain vector with
    /// [`Block::single`] for the classic `B = 1` plane.
    pub fn step<T: Transport + ?Sized>(
        &mut self,
        cluster: &T,
        step: usize,
        w: &Arc<Block>,
        avail: &[usize],
        stragglers: &[(usize, StraggleMode)],
    ) -> Result<StepOutcome> {
        let fl = self.begin_step(cluster, step, w, avail, stragglers)?;
        self.collect_step(cluster, fl)
    }

    /// First half of [`Master::step`]: solve the assignment for the
    /// current speed estimates and dispatch this step's work orders.
    /// Returns the [`InFlightStep`] whose coverage
    /// [`Master::collect_step`] will wait for — between the two calls
    /// workers are computing and the master is free (the `--pipeline`
    /// overlap window). Dispatch-time send failures are recovered
    /// immediately when recovery is on (the channel is known dead).
    pub fn begin_step<T: Transport + ?Sized>(
        &mut self,
        cluster: &T,
        step: usize,
        w: &Arc<Block>,
        avail: &[usize],
        stragglers: &[(usize, StraggleMode)],
    ) -> Result<InFlightStep> {
        let t0 = Instant::now();
        let nvec = w.nvec();

        // ---- solve ----
        let solve_t_ns = self.recorder.as_ref().map(|r| r.now_ns());
        let solve_start = Instant::now();
        let assignment = self.plan(avail)?;
        let solve = solve_start.elapsed();
        if let (Some(rec), Some(t_ns)) = (&self.recorder, solve_t_ns) {
            rec.emit(
                Event::new(EventKind::Solve, step, t_ns)
                    .rows(self.q)
                    .dur(solve.as_nanos() as u64),
            );
        }
        let predicted_c = assignment
            .realized_load_matrix(&self.sub_rows)
            .computation_time(self.estimator.estimate(), avail);

        // ---- dispatch ----
        let machines = self.cfg.placement.machines();
        let recovery_on = self.cfg.recovery.enabled;
        // `None` when recovery is off: the classic dispatch path stays
        // free of per-task bookkeeping and per-step tracker allocations
        let mut tracker = recovery_on.then(|| RecoveryTracker::new(machines));
        let mut expected = 0usize;
        let mut dispatch_failures: Vec<usize> = Vec::new();
        // dispatch→report pairing for the journal; untouched (and empty)
        // when no recorder is attached
        let mut pending: Vec<PendingOrder> = Vec::new();
        let trace = self.recorder.is_some();
        for &n in avail {
            let tasks = assignment.tasks_for(n);
            if tasks.is_empty() {
                continue;
            }
            let order_rows: usize = tasks.iter().map(|t| t.rows.len()).sum();
            let straggle = stragglers
                .iter()
                .find(|&&(m, _)| m == n)
                .map(|&(_, mode)| mode);
            // Responsibility is recorded whether or not the send succeeds:
            // with recovery on, a dead worker's rows are re-planned below;
            // with recovery off, a dead worker (channel closed — backend
            // init failure or panic) is tolerated like a straggler and
            // redundancy or the coverage timeout decides the step's fate.
            if let Some(t) = tracker.as_mut() {
                t.assign(n, &tasks, &self.cfg.sub_ranges);
            }
            match cluster.send(
                n,
                WorkOrder {
                    step,
                    w: Arc::clone(w),
                    tasks,
                    row_cost_ns: self.cfg.row_cost_ns,
                    straggle,
                    trace,
                },
            ) {
                Ok(()) => {
                    expected += 1;
                    if let Some(t) = tracker.as_mut() {
                        t.note_order_sent(n, Instant::now());
                    }
                    if let Some(reg) = &self.registry {
                        reg.add_order(n, order_rows);
                    }
                    if let Some(rec) = &self.recorder {
                        let id = self.next_order.fetch_add(1, Ordering::Relaxed);
                        let t_ns = rec.now_ns();
                        rec.emit(
                            Event::new(EventKind::Dispatch, step, t_ns)
                                .worker(n)
                                .order(id)
                                .rows(order_rows),
                        );
                        pending.push(PendingOrder {
                            worker: n,
                            order: id,
                            rows: order_rows,
                            sent: Instant::now(),
                            t_ns,
                        });
                    }
                }
                Err(e) => {
                    crate::log_warn!("step {step}: dispatch to worker {n} failed: {e}");
                    if let Some(t) = tracker.as_mut() {
                        t.mark_unreachable(n);
                    }
                    dispatch_failures.push(n);
                }
            }
        }
        if expected == 0 {
            return Err(Error::infeasible("no worker received any task"));
        }

        // ---- collect-state init ----
        let mut covered = vec![false; self.q];
        let mut recoveries: Vec<RecoveryEvent> = Vec::new();
        let mut wheel = TimerWheel::new();
        wheel.set(
            DeadlineKind::Coverage,
            Instant::now() + self.cfg.recovery_timeout,
        );
        let overdue_delay = recovery_on
            .then(|| self.cfg.recovery.overdue_delay(self.cfg.recovery_timeout));

        // a dispatch-time send failure is already a dead channel: recover
        // its rows immediately instead of waiting for the deadline
        if let Some(t) = tracker.as_mut() {
            for n in dispatch_failures {
                self.recover_worker(
                    cluster,
                    step,
                    w,
                    n,
                    RecoveryReason::Disconnected,
                    &covered,
                    avail,
                    t,
                    &mut expected,
                    &mut recoveries,
                    &mut pending,
                )?;
            }
        }

        Ok(InFlightStep {
            step,
            nvec,
            w: Arc::clone(w),
            avail: avail.to_vec(),
            t0,
            solve,
            predicted_c,
            tracker,
            expected,
            pending,
            y: vec![0.0f32; self.q * nvec],
            covered,
            missing: self.q,
            reporters: Vec::new(),
            reported: vec![false; machines],
            measurements: Vec::new(),
            recoveries,
            order_stats: Vec::new(),
            wheel,
            overdue_dirty: true,
            overdue_delay,
        })
    }

    /// Second half of [`Master::step`]: block until the received segments
    /// cover every row, recovering mid-step victims along the way, then
    /// fold measured speeds into the EWMA. Every blocking wait is sized
    /// off the in-flight step's [`TimerWheel`]: the coverage deadline and
    /// a *cached* next-overdue instant that is only re-derived when an
    /// event actually mutated the tracker (`overdue_dirty`) — a burst of
    /// rejected reports cannot starve the overdue clock by forcing a
    /// rescan per event.
    pub fn collect_step<T: Transport + ?Sized>(
        &mut self,
        cluster: &T,
        mut fl: InFlightStep,
    ) -> Result<StepOutcome> {
        let step = fl.step;
        let nvec = fl.nvec;
        let machines = self.cfg.placement.machines();
        let recovery_on = self.cfg.recovery.enabled;
        while fl.missing > 0 {
            let now = Instant::now();
            if fl.wheel.due(DeadlineKind::Coverage, now) {
                return Err(self.coverage_error(
                    step,
                    &fl.covered,
                    fl.reporters.len(),
                    fl.expected,
                ));
            }
            if fl.overdue_dirty {
                fl.rearm_overdue();
            }
            if fl.wheel.due(DeadlineKind::Overdue, now) {
                // silent droppers: an unanswered order past the overdue
                // fraction of the timeout is recovered like a failure
                if let Some(delay) = fl.overdue_delay {
                    while let Some(victim) = fl
                        .tracker
                        .as_mut()
                        .and_then(|t| t.overdue_victim(now, delay))
                    {
                        if let Some(rec) = &self.recorder {
                            rec.emit(
                                Event::new(EventKind::HeartbeatLapse, step, rec.now_ns())
                                    .worker(victim)
                                    .note("order overdue"),
                            );
                        }
                        self.recover_worker(
                            cluster,
                            step,
                            &fl.w,
                            victim,
                            RecoveryReason::Overdue,
                            &fl.covered,
                            &fl.avail,
                            fl.tracker.as_mut().expect("overdue implies tracker"),
                            &mut fl.expected,
                            &mut fl.recoveries,
                            &mut fl.pending,
                        )?;
                    }
                }
                // the drain consumed the armed instant: re-derive it now so
                // a stale (already-passed) slot cannot pin the wait at 1 ms
                fl.rearm_overdue();
            }
            let wait = fl
                .wheel
                .wait_from(now)
                .unwrap_or(Duration::from_millis(1));
            match cluster.recv_timeout(wait) {
                Ok(TransportEvent::Report(r)) => {
                    if r.step != step {
                        continue; // stale report from a previous step
                    }
                    if r.worker >= machines {
                        // defense in depth vs a misbehaving transport: an
                        // unknown id must not index the speed estimator
                        crate::log_warn!(
                            "step {step}: report from unknown worker {}, dropped",
                            r.worker
                        );
                        continue;
                    }
                    if r.nvec != nvec {
                        // a report for a different block width cannot be
                        // spliced into this step's panel
                        crate::log_warn!(
                            "step {step}: worker {} reported B={}, expected B={nvec}, dropped",
                            r.worker,
                            r.nvec
                        );
                        continue;
                    }
                    let mut spliced = 0usize;
                    for seg in &r.segments {
                        debug_assert_eq!(seg.values.len(), seg.rows.len() * nvec);
                        if seg.rows.hi > self.q {
                            // a remote peer must not be able to panic the
                            // master with out-of-range rows
                            crate::log_warn!(
                                "worker {}: segment {}..{} exceeds q={}, dropped",
                                r.worker,
                                seg.rows.lo,
                                seg.rows.hi,
                                self.q
                            );
                            continue;
                        }
                        spliced += 1;
                        for (i, row) in (seg.rows.lo..seg.rows.hi).enumerate() {
                            if !fl.covered[row] {
                                fl.covered[row] = true;
                                fl.missing -= 1;
                            }
                            fl.y[row * nvec..(row + 1) * nvec]
                                .copy_from_slice(&seg.values[i * nvec..(i + 1) * nvec]);
                        }
                    }
                    // Only a report that actually delivered rows answers an
                    // outstanding order: a same-step report whose payload
                    // was entirely rejected must not clear the overdue
                    // clock (the worker's rows are still missing and may
                    // need re-dispatch).
                    if spliced > 0 {
                        if let Some(t) = fl.tracker.as_mut() {
                            t.note_report(r.worker);
                            // the answered order may have been the earliest
                            // unanswered one — re-derive before sleeping
                            fl.overdue_dirty = true;
                        }
                        // close the oldest open order span for this worker
                        // (FIFO — supplementary orders are answered after
                        // originals on a worker's serial execution loop)
                        if let Some(rec) = &self.recorder {
                            if let Some(pos) =
                                fl.pending.iter().position(|p| p.worker == r.worker)
                            {
                                let p = fl.pending.remove(pos);
                                let rtt_ns = p.sent.elapsed().as_nanos() as u64;
                                rec.emit(
                                    Event::new(EventKind::Order, step, p.t_ns)
                                        .worker(p.worker)
                                        .order(p.order)
                                        .rows(p.rows)
                                        .dur(rtt_ns)
                                        .breakdown(r.breakdown),
                                );
                                fl.order_stats.push(OrderStat {
                                    worker: p.worker,
                                    order: p.order,
                                    rows: p.rows,
                                    rtt_ns,
                                    breakdown: r.breakdown,
                                });
                            }
                        }
                    }
                    // One slot per worker per step: a late original racing
                    // its recovery replacement (or a rescuer's second,
                    // supplementary report) must not land twice in
                    // `reporters` nor fold its speed into the EWMA twice —
                    // and a report whose every segment was rejected carries
                    // no usable speed measurement at all.
                    if !fl.reported[r.worker] {
                        fl.reported[r.worker] = true;
                        fl.reporters.push(r.worker);
                        if spliced > 0 {
                            if let Some(v) = r.measured_speed {
                                fl.measurements.push((r.worker, v));
                            }
                        }
                    }
                }
                Ok(TransportEvent::Failed { worker, step: ev_step, error }) => {
                    crate::log_warn!("worker {worker} failed in step {step}: {error}");
                    if ev_step == step && worker < machines && fl.tracker.is_some() {
                        self.recover_worker(
                            cluster,
                            step,
                            &fl.w,
                            worker,
                            RecoveryReason::Failed,
                            &fl.covered,
                            &fl.avail,
                            fl.tracker.as_mut().expect("checked above"),
                            &mut fl.expected,
                            &mut fl.recoveries,
                            &mut fl.pending,
                        )?;
                        fl.overdue_dirty = true;
                    }
                }
                Ok(TransportEvent::Disconnected { worker }) => {
                    // Mid-step preemption. With recovery off, redundancy
                    // (S ≥ 1 or replica coverage) or the timeout decides
                    // the step; either way the transport's liveness view
                    // removes the worker from the availability set at the
                    // next step.
                    crate::log_warn!(
                        "worker {worker} disconnected during step {step} \
                         (treated as preemption)"
                    );
                    if worker < machines && fl.tracker.is_some() {
                        fl.tracker.as_mut().expect("checked above").mark_unreachable(worker);
                        self.recover_worker(
                            cluster,
                            step,
                            &fl.w,
                            worker,
                            RecoveryReason::Disconnected,
                            &fl.covered,
                            &fl.avail,
                            fl.tracker.as_mut().expect("checked above"),
                            &mut fl.expected,
                            &mut fl.recoveries,
                            &mut fl.pending,
                        )?;
                        fl.overdue_dirty = true;
                    }
                }
                Err(_) => {
                    if !recovery_on {
                        return Err(self.coverage_error(
                            step,
                            &fl.covered,
                            fl.reporters.len(),
                            fl.expected,
                        ));
                    }
                    // Woke for the overdue scan or the deadline check (both
                    // handled at the top of the loop), or the channel is
                    // gone entirely; a brief sleep keeps a closed channel
                    // from spinning hot until recovery declares the step
                    // infeasible or the deadline fires.
                    std::thread::sleep(Duration::from_millis(2).min(wait));
                }
            }
        }

        // ---- speed update (Algorithm 1 line 4, next step's estimate) ----
        self.estimator.update_all(&fl.measurements);

        Ok(StepOutcome {
            y: fl.y,
            nvec,
            reporters: fl.reporters,
            wall: fl.t0.elapsed(),
            solve: fl.solve,
            predicted_c: fl.predicted_c,
            recoveries: fl.recoveries,
            order_stats: fl.order_stats,
        })
    }

    /// Re-plan `victim`'s still-uncovered rows onto surviving replicas and
    /// ship supplementary orders for the in-flight step. A rescuer whose
    /// send fails is marked unreachable, its share re-planned over the
    /// remaining survivors (the set shrinks strictly, so this terminates),
    /// and its own rows recovered in turn — its channel is known dead;
    /// when some sub-matrix has no surviving replica at all the step fails
    /// fast with an [`Error::Infeasible`] instead of waiting out the
    /// coverage timeout.
    #[allow(clippy::too_many_arguments)]
    fn recover_worker<T: Transport + ?Sized>(
        &self,
        cluster: &T,
        step: usize,
        w: &Arc<Block>,
        victim: usize,
        reason: RecoveryReason,
        covered: &[bool],
        avail: &[usize],
        tracker: &mut RecoveryTracker,
        expected: &mut usize,
        recoveries: &mut Vec<RecoveryEvent>,
        pending: &mut Vec<PendingOrder>,
    ) -> Result<()> {
        if tracker.is_victim(victim) {
            return Ok(());
        }
        tracker.mark_victim(victim);
        let mut remaining = tracker.uncovered_rows(victim, covered);
        if remaining.is_empty() {
            // replicas already covered everything this worker owed
            crate::log_debug!(
                "step {step}: worker {victim} {} but its rows are covered",
                reason.name()
            );
            return Ok(());
        }
        let total_rows: usize = remaining.iter().map(|&(_, r)| r.len()).sum();
        // journal timestamp + wall clock of the whole re-plan/re-dispatch,
        // so the recovery span brackets its rescuer dispatches
        let rec_span = self
            .recorder
            .as_ref()
            .map(|r| (r.now_ns(), Instant::now()));
        let mut rescuers: Vec<usize> = Vec::new();
        let mut dead_rescuers: Vec<usize> = Vec::new();
        while !remaining.is_empty() {
            let survivors = tracker.survivors(avail);
            let plan = match optim::recovery::plan_recovery(
                &self.cfg.placement,
                &self.cfg.sub_ranges,
                &remaining,
                &survivors,
                self.estimator.estimate(),
            ) {
                Ok(plan) => plan,
                Err(e) if matches!(e, Error::Infeasible(_))
                    && reason == RecoveryReason::Overdue =>
                {
                    // An overdue victim is only *suspected* dead — its late
                    // report still splices if it arrives. With no surviving
                    // replica to re-plan onto, keep waiting for it (or the
                    // deadline) instead of failing a step that may yet
                    // complete. Definitely-dead victims (disconnect /
                    // failure) do fail fast here.
                    crate::log_warn!(
                        "step {step}: cannot re-plan overdue worker {victim}'s rows \
                         ({e}); waiting for its late report or the deadline"
                    );
                    return Ok(());
                }
                Err(e) => return Err(e),
            };
            let mut failed: Vec<(usize, RowRange)> = Vec::new();
            for (rescuer, tasks) in plan {
                let order_rows: usize = tasks.iter().map(|t| t.rows.len()).sum();
                match cluster.send(
                    rescuer,
                    WorkOrder {
                        step,
                        w: Arc::clone(w),
                        tasks: tasks.clone(),
                        row_cost_ns: self.cfg.row_cost_ns,
                        straggle: None,
                        trace: self.recorder.is_some(),
                    },
                ) {
                    Ok(()) => {
                        tracker.assign(rescuer, &tasks, &self.cfg.sub_ranges);
                        tracker.note_order_sent(rescuer, Instant::now());
                        *expected += 1;
                        if !rescuers.contains(&rescuer) {
                            rescuers.push(rescuer);
                        }
                        if let Some(reg) = &self.registry {
                            reg.add_order(rescuer, order_rows);
                        }
                        if let Some(rec) = &self.recorder {
                            let id = self.next_order.fetch_add(1, Ordering::Relaxed);
                            let t_ns = rec.now_ns();
                            rec.emit(
                                Event::new(EventKind::Dispatch, step, t_ns)
                                    .worker(rescuer)
                                    .order(id)
                                    .rows(order_rows)
                                    .note("recovery"),
                            );
                            pending.push(PendingOrder {
                                worker: rescuer,
                                order: id,
                                rows: order_rows,
                                sent: Instant::now(),
                                t_ns,
                            });
                        }
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "step {step}: recovery dispatch to worker {rescuer} failed: {e}"
                        );
                        tracker.mark_unreachable(rescuer);
                        dead_rescuers.push(rescuer);
                        failed.extend(
                            tasks
                                .iter()
                                .map(|t| (t.g, t.rows.offset(self.cfg.sub_ranges[t.g].lo))),
                        );
                    }
                }
            }
            remaining = failed;
        }
        rescuers.sort_unstable();
        crate::log_warn!(
            "step {step}: re-dispatched {total_rows} uncovered rows of worker {victim} \
             ({}) to {rescuers:?}",
            reason.name()
        );
        recoveries.push(RecoveryEvent {
            step,
            victim,
            reason,
            rows: total_rows,
            rescuers,
        });
        if let Some(reg) = &self.registry {
            reg.add_recovery(victim);
        }
        if let (Some(rec), Some((t_ns, start))) = (&self.recorder, rec_span) {
            rec.emit(
                Event::new(EventKind::Recovery, step, t_ns)
                    .worker(victim)
                    .rows(total_rows)
                    .note(reason.name())
                    .dur(start.elapsed().as_nanos() as u64),
            );
        }
        // A rescuer whose send failed has a *known-dead* channel, so its
        // own original rows cannot arrive either — recover it now instead
        // of leaving it to the overdue clock (which at a large factor can
        // coincide with the deadline). Victims only ever grow, so the
        // recursion is bounded by the machine count.
        for dead in dead_rescuers {
            self.recover_worker(
                cluster,
                step,
                w,
                dead,
                RecoveryReason::Disconnected,
                covered,
                avail,
                tracker,
                expected,
                recoveries,
                pending,
            )?;
        }
        Ok(())
    }

    /// The coverage-timeout error, shared by the deadline and
    /// `recv_timeout` paths: report progress (`reports/expected`) and the
    /// sub-matrices whose rows are still missing.
    fn coverage_error(
        &self,
        step: usize,
        covered: &[bool],
        reports: usize,
        expected: usize,
    ) -> Error {
        let missing = covered.iter().filter(|&&c| !c).count();
        let missing_subs: Vec<usize> = self
            .cfg
            .sub_ranges
            .iter()
            .enumerate()
            .filter(|(_, r)| (r.lo..r.hi).any(|row| !covered[row]))
            .map(|(g, _)| g)
            .collect();
        Error::Cluster(format!(
            "step {step}: coverage timeout with {missing} rows missing \
             ({reports}/{expected} reports; incomplete sub-matrices {missing_subs:?})"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::types::AssignPolicy;
    use crate::linalg::partition::submatrix_ranges;
    use crate::linalg::{gen, Matrix};
    use crate::placement::PlacementKind;
    use crate::runtime::BackendSpec;
    use crate::sched::cluster::Cluster;
    use crate::sched::worker::{WorkerConfig, WorkerStorage};

    fn build(
        q: usize,
        speeds: &[f64],
        policy: AssignPolicy,
        s: usize,
    ) -> (Master, Cluster, Arc<Matrix>) {
        let n = speeds.len();
        let placement = Placement::build(PlacementKind::Cyclic, n, n, 3).unwrap();
        let sub_ranges = submatrix_ranges(q, n).unwrap();
        let matrix = Arc::new(gen::random_dense(q, q, 9));
        let ranges = Arc::new(sub_ranges.clone());
        let configs: Vec<WorkerConfig> = (0..n)
            .map(|id| WorkerConfig {
                id,
                backend: BackendSpec::Host,
                speed: speeds[id],
                tile_rows: 16,
                threads: 1,
                storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&ranges)),
            })
            .collect();
        let cluster = Cluster::spawn(configs).unwrap();
        let master = Master::new(MasterConfig {
            placement,
            sub_ranges,
            params: SolveParams::with_stragglers(s),
            policy,
            gamma: 0.5,
            initial_speeds: speeds.to_vec(),
            row_cost_ns: 0,
            recovery_timeout: Duration::from_secs(10),
            recovery: RecoveryPolicy::default(),
        })
        .unwrap();
        (master, cluster, matrix)
    }

    fn oracle_y(matrix: &Matrix, w: &[f32]) -> Vec<f32> {
        matrix.matvec(w).unwrap()
    }

    #[test]
    fn step_assembles_exact_product() {
        let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        let w = Arc::new(Block::single(vec![0.25f32; 60]));
        let avail: Vec<usize> = (0..6).collect();
        let out = master.step(&cluster, 0, &w, &avail, &[]).unwrap();
        let want = oracle_y(&matrix, w.data());
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-4, "{a} vs {e}");
        }
        assert!(!out.reporters.is_empty());
        assert!(out.predicted_c > 0.0);
        cluster.shutdown();
    }

    #[test]
    fn step_assembles_block_product() {
        let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        let nvec = 3;
        let cols: Vec<Vec<f32>> = (0..nvec)
            .map(|k| (0..60).map(|i| ((i * (k + 1)) % 9) as f32 * 0.1 - 0.4).collect())
            .collect();
        let w = Arc::new(Block::from_columns(&cols).unwrap());
        let avail: Vec<usize> = (0..6).collect();
        let out = master.step(&cluster, 0, &w, &avail, &[]).unwrap();
        assert_eq!(out.nvec, nvec);
        assert_eq!(out.y.len(), 60 * nvec);
        for (k, col) in cols.iter().enumerate() {
            let want = oracle_y(&matrix, col);
            for (row, e) in want.iter().enumerate() {
                let a = out.y[row * nvec + k];
                assert!((a - e).abs() < 1e-4, "col {k} row {row}: {a} vs {e}");
            }
        }
        cluster.shutdown();
    }

    #[test]
    fn step_with_preempted_machines() {
        let speeds = vec![1.0; 6];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        let w = Arc::new(Block::single(vec![1.0f32; 60]));
        // cyclic J=3 placement tolerates 2 preemptions for S=0
        let avail = vec![0, 2, 3, 5];
        let out = master.step(&cluster, 1, &w, &avail, &[]).unwrap();
        let want = oracle_y(&matrix, w.data());
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-3);
        }
        assert!(out.reporters.iter().all(|r| avail.contains(r)));
        cluster.shutdown();
    }

    #[test]
    fn straggler_tolerant_step_recovers_with_drop() {
        let speeds = vec![1.0; 6];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 1);
        let w = Arc::new(Block::single(vec![0.5f32; 60]));
        let avail: Vec<usize> = (0..6).collect();
        let out = master
            .step(&cluster, 2, &w, &avail, &[(3, StraggleMode::Drop)])
            .unwrap();
        assert!(!out.reporters.contains(&3));
        let want = oracle_y(&matrix, w.data());
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-3);
        }
        cluster.shutdown();
    }

    #[test]
    fn unprotected_step_times_out_under_drop_without_recovery() {
        let speeds = vec![1.0; 6];
        let (mut master, cluster, _) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        master.cfg.recovery_timeout = Duration::from_millis(400);
        let w = Arc::new(Block::single(vec![0.5f32; 60]));
        let avail: Vec<usize> = (0..6).collect();
        let r = master.step(&cluster, 3, &w, &avail, &[(0, StraggleMode::Drop)]);
        let err = r.expect_err("S=0 without recovery cannot survive a dropped worker");
        let msg = err.to_string();
        assert!(msg.contains("coverage timeout"), "{msg}");
        assert!(msg.contains("incomplete sub-matrices"), "{msg}");
        cluster.shutdown();
    }

    #[test]
    fn unprotected_step_recovers_from_drop_via_overdue_redispatch() {
        // same scenario as above, recovery on: the silent dropper is
        // declared overdue and its rows re-dispatched to replicas
        let speeds = vec![1.0; 6];
        let (mut master, cluster, matrix) = build(60, &speeds, AssignPolicy::Heterogeneous, 0);
        master.cfg.recovery_timeout = Duration::from_secs(8);
        master.cfg.recovery = RecoveryPolicy {
            enabled: true,
            overdue_factor: 0.05, // 400ms
        };
        let w = Arc::new(Block::single(vec![0.5f32; 60]));
        let avail: Vec<usize> = (0..6).collect();
        let out = master
            .step(&cluster, 3, &w, &avail, &[(0, StraggleMode::Drop)])
            .unwrap();
        assert!(!out.reporters.contains(&0), "the dropper never reports");
        assert_eq!(out.recoveries.len(), 1, "{:?}", out.recoveries);
        let ev = &out.recoveries[0];
        assert_eq!(ev.victim, 0);
        assert_eq!(ev.reason, RecoveryReason::Overdue);
        assert!(ev.rows > 0);
        assert!(!ev.rescuers.is_empty() && !ev.rescuers.contains(&0));
        let want = oracle_y(&matrix, w.data());
        for (a, e) in out.y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-3);
        }
        cluster.shutdown();
    }

    #[test]
    fn speed_estimates_adapt_from_reports() {
        let speeds = vec![0.5, 4.0, 1.0, 1.0, 1.0, 1.0];
        let n = speeds.len();
        let placement = Placement::build(PlacementKind::Cyclic, n, n, 3).unwrap();
        let q = 120;
        let sub_ranges = submatrix_ranges(q, n).unwrap();
        let matrix = Arc::new(gen::random_dense(q, q, 11));
        let ranges = Arc::new(sub_ranges.clone());
        let configs: Vec<WorkerConfig> = (0..n)
            .map(|id| WorkerConfig {
                id,
                backend: BackendSpec::Host,
                speed: speeds[id],
                tile_rows: 16,
                threads: 1,
                storage: WorkerStorage::full(Arc::clone(&matrix), Arc::clone(&ranges)),
            })
            .collect();
        let cluster = Cluster::spawn(configs).unwrap();
        // master starts with a WRONG uniform prior and must learn
        let mut master = Master::new(MasterConfig {
            placement,
            sub_ranges,
            params: SolveParams::default(),
            policy: AssignPolicy::Heterogeneous,
            gamma: 0.6,
            initial_speeds: vec![],
            row_cost_ns: 300_000, // 0.3ms/row → measurable ratios
            recovery_timeout: Duration::from_secs(20),
            recovery: RecoveryPolicy::default(),
        })
        .unwrap();
        let w = Arc::new(Block::single(vec![0.1f32; q]));
        let avail: Vec<usize> = (0..n).collect();
        for step in 0..6 {
            master.step(&cluster, step, &w, &avail, &[]).unwrap();
        }
        let est = master.speed_estimate();
        // measured units are sub-matrices/sec; only ratios matter
        let ratio = est[1] / est[0];
        assert!(
            ratio > 3.0,
            "estimator did not learn the 8x speed gap: {est:?}"
        );
        cluster.shutdown();
    }

    /// Deterministic transport double: events are scripted, sends are
    /// recorded — lets the collect loop be driven event by event.
    struct Scripted {
        n: usize,
        events: std::sync::Mutex<std::collections::VecDeque<TransportEvent>>,
        sent: std::sync::Mutex<Vec<(usize, WorkOrder)>>,
    }

    impl Scripted {
        fn new(n: usize, events: Vec<TransportEvent>) -> Scripted {
            Scripted {
                n,
                events: std::sync::Mutex::new(events.into()),
                sent: std::sync::Mutex::new(Vec::new()),
            }
        }
    }

    impl Transport for Scripted {
        fn size(&self) -> usize {
            self.n
        }
        fn alive(&self) -> Vec<bool> {
            vec![true; self.n]
        }
        fn send(&self, worker: usize, order: WorkOrder) -> crate::error::Result<()> {
            self.sent.lock().unwrap().push((worker, order));
            Ok(())
        }
        fn recv_timeout(&self, _timeout: Duration) -> crate::error::Result<TransportEvent> {
            self.events
                .lock()
                .unwrap()
                .pop_front()
                .ok_or_else(|| Error::Cluster("recv: scripted queue empty".into()))
        }
        fn drain(&self) -> Vec<TransportEvent> {
            Vec::new()
        }
        fn shutdown(&mut self) {}
    }

    fn scripted_master(n: usize, recovery: RecoveryPolicy) -> Master {
        let placement = Placement::build(PlacementKind::Cyclic, n, n, n).unwrap();
        let sub_ranges = submatrix_ranges(30, n).unwrap();
        Master::new(MasterConfig {
            placement,
            sub_ranges,
            params: SolveParams::with_stragglers(0),
            policy: AssignPolicy::Heterogeneous,
            gamma: 0.5,
            initial_speeds: vec![1.0; n],
            row_cost_ns: 0,
            recovery_timeout: Duration::from_secs(5),
            recovery,
        })
        .unwrap()
    }

    fn report(worker: usize, step: usize, lo: usize, hi: usize, speed: f64) -> TransportEvent {
        TransportEvent::Report(crate::sched::protocol::WorkerReport {
            worker,
            step,
            segments: vec![crate::sched::protocol::Segment {
                rows: RowRange::new(lo, hi),
                values: vec![1.0; hi - lo],
            }],
            nvec: 1,
            measured_speed: Some(speed),
            elapsed: Duration::ZERO,
            breakdown: None,
        })
    }

    #[test]
    fn duplicate_report_counts_once_in_reporters_and_ewma() {
        // a late original racing its recovery replacement (or a readmitted
        // peer replaying) must not double-fold the EWMA
        let t = Scripted::new(
            3,
            vec![
                report(0, 4, 0, 15, 5.0),
                report(0, 4, 0, 15, 5.0), // duplicate
                report(1, 4, 15, 30, 3.0),
            ],
        );
        let mut master = scripted_master(3, RecoveryPolicy::default());
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let out = master.step(&t, 4, &w, &[0, 1, 2], &[]).unwrap();
        assert_eq!(out.reporters, vec![0, 1], "duplicate must not re-enter");
        // one EWMA fold: 0.5·5 + 0.5·1 = 3.0 (two folds would give 4.0)
        assert!((master.speed_estimate()[0] - 3.0).abs() < 1e-12);
        assert!((master.speed_estimate()[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fully_rejected_report_does_not_poison_speed_estimate() {
        // every segment out of range ⇒ nothing spliced ⇒ the measurement
        // is meaningless and must not reach the estimator
        let t = Scripted::new(
            3,
            vec![
                report(2, 0, 100, 110, 99.0), // rows exceed q=30, all dropped
                report(0, 0, 0, 30, 2.0),
            ],
        );
        let mut master = scripted_master(3, RecoveryPolicy::default());
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let out = master.step(&t, 0, &w, &[0, 1, 2], &[]).unwrap();
        assert!(out.reporters.contains(&2));
        assert_eq!(master.speed_estimate()[2], 1.0, "poisoned by rejected report");
        assert!((master.speed_estimate()[0] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn rejected_report_does_not_clear_overdue_clock() {
        // a same-step report whose payload was entirely rejected must not
        // count as answering the order: the worker's rows are still
        // missing, so the overdue path must still fire and re-dispatch
        let t = Scripted::new(3, vec![report(0, 2, 100, 110, 1.0)]); // garbage rows
        let mut master = scripted_master(
            3,
            RecoveryPolicy {
                enabled: true,
                overdue_factor: 0.2, // 80ms of the 400ms timeout below
            },
        );
        master.cfg.recovery_timeout = Duration::from_millis(400);
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let err = master.step(&t, 2, &w, &[0, 1, 2], &[]).unwrap_err();
        // nothing ever covers the rows (the scripted queue is empty), so
        // the deadline fires — but only after overdue recovery shipped
        // supplementary orders, which it could not have done had the
        // garbage report cleared worker 0's outstanding order
        assert!(err.to_string().contains("coverage timeout"), "{err}");
        let sent = t.sent.lock().unwrap();
        assert!(
            sent.len() > 3,
            "no supplementary orders were shipped ({} sends)",
            sent.len()
        );
    }

    #[test]
    fn report_burst_does_not_starve_overdue_clock() {
        // Regression for the timer wheel: the overdue instant is cached in
        // a wheel slot and only re-derived when an event mutates the
        // tracker. A burst of rejected (tracker-neutral) reports must not
        // starve that clock — overdue recovery still has to fire and ship
        // supplementary orders even though hundreds of events were
        // processed without a single re-arm.
        let mut burst = Vec::with_capacity(200);
        for _ in 0..200 {
            burst.push(report(0, 3, 100, 110, 1.0)); // garbage rows, all rejected
        }
        let t = Scripted::new(3, burst);
        let mut master = scripted_master(
            3,
            RecoveryPolicy {
                enabled: true,
                overdue_factor: 0.2, // 80ms of the 400ms timeout below
            },
        );
        master.cfg.recovery_timeout = Duration::from_millis(400);
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let err = master.step(&t, 3, &w, &[0, 1, 2], &[]).unwrap_err();
        assert!(err.to_string().contains("coverage timeout"), "{err}");
        let sent = t.sent.lock().unwrap();
        assert!(
            sent.len() > 3,
            "overdue clock starved by the report burst ({} sends)",
            sent.len()
        );
    }

    #[test]
    fn begin_collect_split_matches_step() {
        // `step()` is exactly begin + collect; drive the halves explicitly
        // (the pipelined harness path) and check the outcome matches what
        // the synchronous entry point produces on the same script
        let events = || vec![report(0, 4, 0, 15, 5.0), report(1, 4, 15, 30, 3.0)];
        let t = Scripted::new(3, events());
        let mut master = scripted_master(3, RecoveryPolicy::default());
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let fl = master.begin_step(&t, 4, &w, &[0, 1, 2], &[]).unwrap();
        assert_eq!(fl.step(), 4);
        let out = master.collect_step(&t, fl).unwrap();

        let t2 = Scripted::new(3, events());
        let mut master2 = scripted_master(3, RecoveryPolicy::default());
        let out2 = master2.step(&t2, 4, &w, &[0, 1, 2], &[]).unwrap();
        assert_eq!(out.y, out2.y);
        assert_eq!(out.reporters, out2.reporters);
        assert_eq!(master.speed_estimate(), master2.speed_estimate());
    }

    #[test]
    fn disconnect_triggers_supplementary_orders_to_replicas() {
        let t = Scripted::new(
            3,
            vec![
                TransportEvent::Disconnected { worker: 0 },
                report(1, 7, 0, 30, 1.0),
            ],
        );
        let mut master = scripted_master(3, RecoveryPolicy::enabled());
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let out = master.step(&t, 7, &w, &[0, 1, 2], &[]).unwrap();
        assert_eq!(out.recoveries.len(), 1);
        let ev = &out.recoveries[0];
        assert_eq!((ev.victim, ev.reason), (0, RecoveryReason::Disconnected));
        assert_eq!(ev.rescuers, vec![1, 2]);
        assert!(ev.rows > 0);
        // three original orders plus one supplementary per rescuer, all for
        // the same in-flight step
        let sent = t.sent.lock().unwrap();
        assert_eq!(sent.len(), 5);
        assert!(sent.iter().all(|(_, o)| o.step == 7));
        let extra: Vec<usize> = sent[3..].iter().map(|&(n, _)| n).collect();
        assert_eq!(extra, vec![1, 2]);
    }

    #[test]
    fn journal_span_tree_matches_scripted_step() {
        use crate::obs::{load_journal, Journal};
        let path = std::env::temp_dir().join(format!(
            "usec_master_journal_{}_{:?}.jsonl",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = path.to_str().unwrap().to_string();
        let journal = Journal::create(&path).unwrap();
        let rec = journal.recorder();
        // worker 0 disconnects mid-step; worker 1's report covers all rows
        let t = Scripted::new(
            3,
            vec![
                TransportEvent::Disconnected { worker: 0 },
                report(1, 7, 0, 30, 1.0),
            ],
        );
        let mut master = scripted_master(3, RecoveryPolicy::enabled());
        master.set_recorder(Some(journal.recorder()));
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let t0 = rec.now_ns();
        let out = master.step(&t, 7, &w, &[0, 1, 2], &[]).unwrap();
        rec.emit(
            Event::new(EventKind::Step, 7, t0)
                .rows(30)
                .dur(rec.now_ns() - t0),
        );
        journal.finish().unwrap();
        let events = load_journal(&path).unwrap();
        std::fs::remove_file(&path).ok();

        // a recorder turns tracing on for every shipped order
        assert!(t.sent.lock().unwrap().iter().all(|(_, o)| o.trace));

        // 3 original dispatches + 2 recovery re-dispatches, unique ids
        let dispatches: Vec<&Event> = events
            .iter()
            .filter(|e| e.kind == EventKind::Dispatch)
            .collect();
        assert_eq!(dispatches.len(), 5, "{dispatches:?}");
        let mut ids: Vec<u64> = dispatches.iter().map(|d| d.order.unwrap()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5, "order ids must be unique");
        assert_eq!(
            dispatches.iter().filter(|d| d.note == "recovery").count(),
            2
        );

        let step_ev = events.iter().find(|e| e.kind == EventKind::Step).unwrap();
        let step_end = step_ev.t_ns + step_ev.dur_ns.unwrap();

        // exactly one order span (only worker 1's report spliced); it
        // shares id and start timestamp with its dispatch and nests
        // inside the step span
        let orders: Vec<&Event> =
            events.iter().filter(|e| e.kind == EventKind::Order).collect();
        assert_eq!(orders.len(), 1, "{orders:?}");
        let o = orders[0];
        assert_eq!(o.worker, Some(1));
        let d = dispatches
            .iter()
            .find(|d| d.order == o.order)
            .expect("order span without a dispatch");
        assert_eq!(d.t_ns, o.t_ns, "order span must start at its dispatch");
        assert!(step_ev.t_ns <= o.t_ns && o.t_ns + o.dur_ns.unwrap() <= step_end);

        // one recovery span for the disconnected worker, nested in the step
        let recov: Vec<&Event> = events
            .iter()
            .filter(|e| e.kind == EventKind::Recovery)
            .collect();
        assert_eq!(recov.len(), 1);
        assert_eq!(recov[0].worker, Some(0));
        assert_eq!(recov[0].note, "disconnected");
        assert!(recov[0].rows > 0);
        assert!(
            step_ev.t_ns <= recov[0].t_ns
                && recov[0].t_ns + recov[0].dur_ns.unwrap() <= step_end
        );

        // the solve span exists, and order_stats mirrors the order span
        assert!(events
            .iter()
            .any(|e| e.kind == EventKind::Solve && e.dur_ns.is_some()));
        assert_eq!(out.order_stats.len(), 1);
        assert_eq!(out.order_stats[0].worker, 1);
        assert_eq!(out.order_stats[0].rows, 30);
    }

    #[test]
    fn untraced_step_has_no_order_stats() {
        let t = Scripted::new(
            3,
            vec![report(0, 1, 0, 15, 1.0), report(1, 1, 15, 30, 1.0)],
        );
        let mut master = scripted_master(3, RecoveryPolicy::default());
        let w = Arc::new(Block::single(vec![0.5f32; 30]));
        let out = master.step(&t, 1, &w, &[0, 1, 2], &[]).unwrap();
        assert!(out.order_stats.is_empty());
        assert!(t.sent.lock().unwrap().iter().all(|(_, o)| !o.trace));
    }

    #[test]
    fn run_result_json_is_parseable() {
        let rr = RunResult {
            timeline: crate::metrics::Timeline::new(),
            final_iterate: vec![0.6, 0.8],
            eigval_estimate: 9.9,
        };
        let back = crate::util::json::Json::parse(&rr.to_json().to_string()).unwrap();
        assert_eq!(back.get_usize("iterate_len"), Some(2));
        assert!((back.get_num("iterate_norm").unwrap() - 1.0).abs() < 1e-6);
        assert!((back.get_num("eigval_estimate").unwrap() - 9.9).abs() < 1e-12);
    }

    #[test]
    fn uniform_policy_ignores_estimates() {
        let speeds = vec![1.0, 32.0, 1.0, 1.0, 1.0, 1.0];
        let (master, cluster, _) = build(60, &speeds, AssignPolicy::Uniform, 0);
        let a = master.plan(&(0..6).collect::<Vec<_>>()).unwrap();
        let rows: Vec<usize> = (0..6).map(|n| a.rows_for(n)).collect();
        let spread = rows.iter().max().unwrap() - rows.iter().min().unwrap();
        assert!(spread <= 6, "uniform policy skewed: {rows:?}");
        cluster.shutdown();
    }
}
