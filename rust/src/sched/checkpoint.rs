//! Master checkpoint/resume: versioned, checksummed run state at step
//! boundaries (`--checkpoint-out` / `usec master --resume <ckpt>`).
//!
//! A [`Checkpoint`] captures everything the master needs to restart a
//! killed job mid-run and land on the *same* answer as an uninterrupted
//! oracle run:
//!
//! * the next step index and the iterate block `w` (bit-exact: every
//!   `f32` is stored as its raw bit pattern in hex, so no decimal
//!   round-trip error creeps in),
//! * the per-worker EWMA speed estimates (`f64` bit patterns), so the
//!   resumed assignment solve sees the same speeds the dead master saw,
//! * the placement's stored sets, so a run that `--rebalance`d its way
//!   to a custom placement resumes with that placement, not the seed one,
//! * the workload spec digest, so a checkpoint cannot be replayed
//!   against a different job, and
//! * the pending-migration ledger (sequence numbers still awaiting
//!   acks) — empty at a clean step boundary, recorded so a resume can
//!   refuse a checkpoint taken mid-transfer.
//!
//! ## File format
//!
//! One canonical JSON object (sorted keys — [`ObjBuilder`] is
//! `BTreeMap`-backed, so encoding is deterministic):
//!
//! ```text
//! {"checksum":<fnv32 of payload text>,
//!  "digest":<fnv32 of canonical workload string>,
//!  "payload":{...},
//!  "version":1}
//! ```
//!
//! [`load`] validates in order: format version, FNV-1a checksum over the
//! payload's canonical text, workload digest — each failure is a typed
//! [`Error::Checkpoint`] naming what was rejected. Writes go through a
//! temp file + rename so a crash mid-write never leaves a torn
//! checkpoint where a good one stood.
//!
//! [`CheckpointWriter`] mirrors the journal's writer-thread shape
//! ([`crate::obs::Journal`]): the step loop hands a snapshot over a
//! channel and keeps computing; serialization and fsync-adjacent work
//! happen off the critical path.

use std::fs;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

use crate::error::{Error, Result};
use crate::net::WorkloadSpec;
use crate::util::json::{Json, ObjBuilder};

/// Bump when the payload schema changes incompatibly.
pub const CHECKPOINT_VERSION: u32 = 1;

/// FNV-1a over raw bytes, 32-bit (the same constants as
/// [`crate::net::codec::data_checksum`], applied to text).
pub fn fnv32(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811C_9DC5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Digest of the workload identity — a checkpoint from a different job
/// (different matrix, seed, or shape) must be rejected at load.
pub fn workload_digest(spec: &WorkloadSpec) -> u32 {
    let canon = match spec {
        WorkloadSpec::PlantedSymmetric {
            q,
            eigval,
            gap,
            seed,
        } => format!(
            "planted:{q}:{:016x}:{:016x}:{seed}",
            eigval.to_bits(),
            gap.to_bits()
        ),
        WorkloadSpec::RandomDense { q, r, seed } => format!("dense:{q}:{r}:{seed}"),
        WorkloadSpec::Streamed { q, r } => format!("streamed:{q}:{r}"),
    };
    fnv32(canon.as_bytes())
}

/// A resumable snapshot of master state at a step boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// First step the resumed run should execute.
    pub next_step: usize,
    /// Batch width of the iterate block.
    pub nvec: usize,
    /// Iterate `w` in interleaved layout ([`crate::linalg::Block`]).
    pub w: Vec<f32>,
    /// Per-worker EWMA speed estimates (rows/sec), indexed by worker id.
    pub speeds: Vec<f64>,
    /// Last convergence metric the app observed (e.g. eigenvalue
    /// estimate); apps that don't track one store 0.
    pub last_metric: f64,
    /// `stored[n]` — sub-matrix ids worker `n` holds (the placement's
    /// `Z_n` sets, possibly rebalanced away from the seed placement).
    pub stored: Vec<Vec<usize>>,
    /// Migration sequence numbers still in flight when the snapshot was
    /// taken. Empty at a clean boundary; a resume refuses otherwise.
    pub pending: Vec<u64>,
}

fn hex_f32s(v: &[f32]) -> String {
    let mut s = String::with_capacity(v.len() * 8);
    for x in v {
        s.push_str(&format!("{:08x}", x.to_bits()));
    }
    s
}

fn hex_f64s(v: &[f64]) -> String {
    let mut s = String::with_capacity(v.len() * 16);
    for x in v {
        s.push_str(&format!("{:016x}", x.to_bits()));
    }
    s
}

fn unhex_f32s(s: &str) -> Result<Vec<f32>> {
    if s.len() % 8 != 0 {
        return Err(Error::checkpoint("iterate hex length not a multiple of 8"));
    }
    s.as_bytes()
        .chunks(8)
        .map(|c| {
            let txt = std::str::from_utf8(c).map_err(|_| Error::checkpoint("non-ASCII hex"))?;
            u32::from_str_radix(txt, 16)
                .map(f32::from_bits)
                .map_err(|_| Error::checkpoint(format!("bad f32 hex chunk '{txt}'")))
        })
        .collect()
}

fn unhex_f64s(s: &str) -> Result<Vec<f64>> {
    if s.len() % 16 != 0 {
        return Err(Error::checkpoint("speeds hex length not a multiple of 16"));
    }
    s.as_bytes()
        .chunks(16)
        .map(|c| {
            let txt = std::str::from_utf8(c).map_err(|_| Error::checkpoint("non-ASCII hex"))?;
            u64::from_str_radix(txt, 16)
                .map(f64::from_bits)
                .map_err(|_| Error::checkpoint(format!("bad f64 hex chunk '{txt}'")))
        })
        .collect()
}

impl Checkpoint {
    fn payload_json(&self) -> Json {
        ObjBuilder::new()
            .num("next_step", self.next_step as f64)
            .num("nvec", self.nvec as f64)
            .str("w", hex_f32s(&self.w))
            .str("speeds", hex_f64s(&self.speeds))
            .str("last_metric", format!("{:016x}", self.last_metric.to_bits()))
            .val(
                "stored",
                Json::Arr(
                    self.stored
                        .iter()
                        .map(|set| {
                            Json::Arr(set.iter().map(|&g| Json::Num(g as f64)).collect())
                        })
                        .collect(),
                ),
            )
            .val(
                "pending",
                Json::Arr(self.pending.iter().map(|&s| Json::Num(s as f64)).collect()),
            )
            .build()
    }

    /// Serialize to the canonical file text (version + checksum + digest
    /// envelope around the payload).
    pub fn encode(&self, spec: &WorkloadSpec) -> String {
        let payload = self.payload_json();
        let checksum = fnv32(payload.to_string().as_bytes());
        let doc = ObjBuilder::new()
            .num("version", CHECKPOINT_VERSION as f64)
            .num("checksum", checksum as f64)
            .num("digest", workload_digest(spec) as f64)
            .val("payload", payload)
            .build();
        let mut text = doc.to_string();
        text.push('\n');
        text
    }

    /// Atomically write the checkpoint: temp file in the same directory,
    /// then rename over the target, so a crash mid-write cannot corrupt
    /// the previous good checkpoint.
    pub fn save(&self, path: &Path, spec: &WorkloadSpec) -> Result<()> {
        let tmp = path.with_extension("tmp");
        fs::write(&tmp, self.encode(spec))?;
        fs::rename(&tmp, path)?;
        Ok(())
    }

    fn from_payload(payload: &Json) -> Result<Checkpoint> {
        let next_step = payload
            .get_usize("next_step")
            .ok_or_else(|| Error::checkpoint("payload missing next_step"))?;
        let nvec = payload
            .get_usize("nvec")
            .ok_or_else(|| Error::checkpoint("payload missing nvec"))?;
        if nvec == 0 {
            return Err(Error::checkpoint("nvec must be >= 1"));
        }
        let w = unhex_f32s(
            payload
                .get_str("w")
                .ok_or_else(|| Error::checkpoint("payload missing iterate w"))?,
        )?;
        if w.is_empty() || w.len() % nvec != 0 {
            return Err(Error::checkpoint(format!(
                "iterate length {} is not a positive multiple of nvec {nvec}",
                w.len()
            )));
        }
        let speeds = unhex_f64s(
            payload
                .get_str("speeds")
                .ok_or_else(|| Error::checkpoint("payload missing speeds"))?,
        )?;
        let metric_hex = payload
            .get_str("last_metric")
            .ok_or_else(|| Error::checkpoint("payload missing last_metric"))?;
        let last_metric = u64::from_str_radix(metric_hex, 16)
            .map(f64::from_bits)
            .map_err(|_| Error::checkpoint("bad last_metric hex"))?;
        let stored = payload
            .get("stored")
            .and_then(Json::items)
            .ok_or_else(|| Error::checkpoint("payload missing stored sets"))?
            .iter()
            .map(|set| {
                set.items()
                    .ok_or_else(|| Error::checkpoint("stored entry is not an array"))?
                    .iter()
                    .map(|g| {
                        g.as_num()
                            .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                            .map(|n| n as usize)
                            .ok_or_else(|| Error::checkpoint("stored id is not an index"))
                    })
                    .collect::<Result<Vec<usize>>>()
            })
            .collect::<Result<Vec<Vec<usize>>>>()?;
        let pending = payload
            .get("pending")
            .and_then(Json::items)
            .ok_or_else(|| Error::checkpoint("payload missing pending ledger"))?
            .iter()
            .map(|s| {
                s.as_num()
                    .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                    .map(|n| n as u64)
                    .ok_or_else(|| Error::checkpoint("pending seq is not an integer"))
            })
            .collect::<Result<Vec<u64>>>()?;
        Ok(Checkpoint {
            next_step,
            nvec,
            w,
            speeds,
            last_metric,
            stored,
            pending,
        })
    }

    /// Decode + validate file text. Checks, in order: format version,
    /// payload checksum, workload digest — then payload shape.
    pub fn decode(text: &str, spec: &WorkloadSpec) -> Result<Checkpoint> {
        let doc = Json::parse(text.trim_end())
            .map_err(|e| Error::checkpoint(format!("unparseable checkpoint: {e}")))?;
        let version = doc
            .get_usize("version")
            .ok_or_else(|| Error::checkpoint("missing format version"))?;
        if version != CHECKPOINT_VERSION as usize {
            return Err(Error::checkpoint(format!(
                "format version {version}, this build reads {CHECKPOINT_VERSION}"
            )));
        }
        let recorded = doc
            .get_num("checksum")
            .ok_or_else(|| Error::checkpoint("missing checksum"))? as u32;
        let payload = doc
            .get("payload")
            .ok_or_else(|| Error::checkpoint("missing payload"))?;
        let actual = fnv32(payload.to_string().as_bytes());
        if actual != recorded {
            return Err(Error::checkpoint(format!(
                "checksum mismatch: recorded {recorded:#010x}, computed {actual:#010x} \
                 (truncated or corrupted file)"
            )));
        }
        let digest = doc
            .get_num("digest")
            .ok_or_else(|| Error::checkpoint("missing workload digest"))? as u32;
        let expect = workload_digest(spec);
        if digest != expect {
            return Err(Error::checkpoint(format!(
                "workload digest {digest:#010x} does not match this job's {expect:#010x} \
                 (checkpoint is from a different run)"
            )));
        }
        let ckpt = Checkpoint::from_payload(payload)?;
        if !ckpt.pending.is_empty() {
            return Err(Error::checkpoint(format!(
                "{} migrations were in flight at snapshot time; refusing mid-transfer resume",
                ckpt.pending.len()
            )));
        }
        Ok(ckpt)
    }

    /// Load and validate a checkpoint file for the given workload.
    pub fn load(path: &Path, spec: &WorkloadSpec) -> Result<Checkpoint> {
        let text = fs::read_to_string(path).map_err(|e| {
            Error::checkpoint(format!("cannot read {}: {e}", path.display()))
        })?;
        Checkpoint::decode(&text, spec)
    }
}

/// Background checkpoint writer: the step loop sends snapshots over a
/// channel; a dedicated thread serializes and atomically replaces the
/// file. Later snapshots supersede earlier ones, so a slow disk can at
/// worst lose the most recent boundary, never corrupt an older one.
pub struct CheckpointWriter {
    tx: Sender<Option<Checkpoint>>,
    handle: Option<JoinHandle<()>>,
    path: PathBuf,
}

impl CheckpointWriter {
    /// Spawn the writer thread for `path`.
    pub fn new(path: &Path, spec: &WorkloadSpec) -> Self {
        let (tx, rx) = channel::<Option<Checkpoint>>();
        let spec = spec.clone();
        let target = path.to_path_buf();
        let thread_path = target.clone();
        let handle = std::thread::Builder::new()
            .name("usec-ckpt".into())
            .spawn(move || {
                while let Ok(Some(ckpt)) = rx.recv() {
                    // Best-effort: a failed write must not kill the run
                    // it exists to protect.
                    let _ = ckpt.save(&thread_path, &spec);
                }
            })
            .expect("spawn checkpoint writer");
        CheckpointWriter {
            tx,
            handle: Some(handle),
            path: target,
        }
    }

    /// Target file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Queue a snapshot for writing (non-blocking).
    pub fn submit(&self, ckpt: Checkpoint) {
        let _ = self.tx.send(Some(ckpt));
    }

    /// Flush queued snapshots and stop the writer thread.
    pub fn finish(&mut self) {
        let _ = self.tx.send(None);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for CheckpointWriter {
    fn drop(&mut self) {
        self.finish();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> WorkloadSpec {
        WorkloadSpec::PlantedSymmetric {
            q: 64,
            eigval: 4.0,
            gap: 0.5,
            seed: 7,
        }
    }

    fn sample() -> Checkpoint {
        Checkpoint {
            next_step: 5,
            nvec: 2,
            w: vec![1.0, -0.25, 3.5e-7, f32::MIN_POSITIVE, 0.0, -0.0],
            speeds: vec![1.0, 0.37218, 2.4e9],
            last_metric: 3.9991,
            stored: vec![vec![0, 1], vec![1, 2], vec![2, 0]],
            pending: vec![],
        }
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let c = sample();
        let text = c.encode(&spec());
        let back = Checkpoint::decode(&text, &spec()).unwrap();
        assert_eq!(back, c);
        for (a, b) in c.w.iter().zip(&back.w) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in c.speeds.iter().zip(&back.speeds) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn encode_is_canonical() {
        let c = sample();
        assert_eq!(c.encode(&spec()), c.encode(&spec()));
    }

    #[test]
    fn rejects_wrong_version() {
        let text = sample().encode(&spec()).replace("\"version\":1", "\"version\":9");
        let err = Checkpoint::decode(&text, &spec()).unwrap_err();
        assert!(matches!(err, Error::Checkpoint(_)), "{err}");
        assert!(err.to_string().contains("version"), "{err}");
    }

    #[test]
    fn rejects_corrupted_payload() {
        // Flip one hex digit of the iterate: checksum must catch it.
        let text = sample().encode(&spec());
        let idx = text.find("\"w\":\"").unwrap() + 6;
        let mut bytes = text.into_bytes();
        bytes[idx] = if bytes[idx] == b'0' { b'1' } else { b'0' };
        let text = String::from_utf8(bytes).unwrap();
        let err = Checkpoint::decode(&text, &spec()).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
    }

    #[test]
    fn rejects_wrong_job() {
        let text = sample().encode(&spec());
        let other = WorkloadSpec::PlantedSymmetric {
            q: 64,
            eigval: 4.0,
            gap: 0.5,
            seed: 8, // different matrix
        };
        let err = Checkpoint::decode(&text, &other).unwrap_err();
        assert!(err.to_string().contains("digest"), "{err}");
    }

    #[test]
    fn refuses_mid_transfer_snapshot() {
        let mut c = sample();
        c.pending = vec![3];
        let text = c.encode(&spec());
        let err = Checkpoint::decode(&text, &spec()).unwrap_err();
        assert!(err.to_string().contains("in flight"), "{err}");
    }

    #[test]
    fn save_load_via_writer_thread() {
        let dir = std::env::temp_dir().join(format!("usec-ckpt-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        {
            let mut w = CheckpointWriter::new(&path, &spec());
            let mut c = sample();
            w.submit(c.clone());
            c.next_step = 6;
            w.submit(c); // last submit wins
            w.finish();
        }
        let back = Checkpoint::load(&path, &spec()).unwrap();
        assert_eq!(back.next_step, 6);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_dir(&dir);
    }

    #[test]
    fn digest_separates_workloads() {
        let a = workload_digest(&spec());
        let b = workload_digest(&WorkloadSpec::RandomDense { q: 64, r: 64, seed: 7 });
        let c = workload_digest(&WorkloadSpec::Streamed { q: 64, r: 64 });
        assert_ne!(a, b);
        assert_ne!(b, c);
    }
}
