//! Build-time stub for the `xla` PJRT bindings.
//!
//! The offline crate set does not vendor the `xla` crate, so the default
//! build aliases `xla::` to this module (see [`super::pjrt`] and
//! [`crate::error`]). Every entry point fails fast at
//! [`PjRtClient::cpu`], which means [`super::pjrt::PjrtBackend::load`]
//! returns a clear "not compiled in" error and nothing downstream ever
//! executes. Enabling the `xla` cargo feature (with a vendored `xla`
//! dependency) swaps the real bindings back in without touching the
//! backend code.

use std::fmt;

/// Stand-in for `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable<T>() -> Result<T, Error> {
    Err(Error(
        "PJRT support not compiled in (build with `--features xla` and a \
         vendored xla crate, or use the host backend)"
            .into(),
    ))
}

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        unavailable()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        unavailable()
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_values: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        unavailable()
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("not compiled in"));
    }
}
