//! PJRT backend: load HLO-text artifacts, compile once, execute on the hot
//! path.
//!
//! Follows the pattern of `/opt/xla-example/load_hlo`: HLO *text* →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. One compiled executable per artifact;
//! compilation happens once at backend construction (worker spawn), never
//! per step.
//!
//! Ragged tiles (final rows of an assigned range) are zero-padded to the
//! baked `tile_rows`; padded outputs are truncated before returning, so the
//! math is exact.

use std::path::Path;

#[cfg(not(feature = "xla"))]
use crate::runtime::xla_stub as xla;

use crate::error::{Error, Result};

use super::manifest::Manifest;

/// A PJRT CPU backend over one artifact directory.
pub struct PjrtBackend {
    #[allow(dead_code)] // owns the executables' runtime
    client: xla::PjRtClient,
    matvec: xla::PjRtLoadedExecutable,
    normalize: xla::PjRtLoadedExecutable,
    dot: xla::PjRtLoadedExecutable,
    tile_rows: usize,
    cols: usize,
    q: usize,
}

impl PjrtBackend {
    /// Load + compile all artifacts in `dir`.
    pub fn load(dir: &Path) -> Result<PjrtBackend> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let compile = |kind: &str| -> Result<xla::PjRtLoadedExecutable> {
            let entry = manifest.find(kind)?;
            let path = entry.path.to_str().ok_or_else(|| {
                Error::Runtime(format!("non-UTF8 artifact path {:?}", entry.path))
            })?;
            let proto = xla::HloModuleProto::from_text_file(path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            Ok(client.compile(&comp)?)
        };
        Ok(PjrtBackend {
            matvec: compile("matvec")?,
            normalize: compile("normalize")?,
            dot: compile("dot")?,
            client,
            tile_rows: manifest.tile_rows,
            cols: manifest.cols,
            q: manifest.q,
        })
    }

    /// Baked execution-tile height.
    pub fn tile_rows(&self) -> usize {
        self.tile_rows
    }

    /// Baked column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Baked master vector length.
    pub fn q(&self) -> usize {
        self.q
    }

    pub fn matvec_tile(&self, x: &[f32], rows: usize, cols: usize, w: &[f32]) -> Result<Vec<f32>> {
        if cols != self.cols {
            return Err(Error::Runtime(format!(
                "artifact baked for {} cols, got {cols} (re-run `make artifacts COLS={cols}`)",
                self.cols
            )));
        }
        if rows > self.tile_rows {
            return Err(Error::Shape(format!(
                "tile of {rows} rows exceeds artifact tile_rows {}",
                self.tile_rows
            )));
        }
        if x.len() != rows * cols || w.len() != cols {
            return Err(Error::Shape(format!(
                "matvec_tile buffers: x={} ({rows}x{cols}), w={}",
                x.len(),
                w.len()
            )));
        }
        // zero-pad ragged tiles to the baked shape
        let x_lit = if rows == self.tile_rows {
            xla::Literal::vec1(x)
        } else {
            let mut padded = vec![0.0f32; self.tile_rows * cols];
            padded[..x.len()].copy_from_slice(x);
            xla::Literal::vec1(&padded)
        }
        .reshape(&[self.tile_rows as i64, cols as i64])?;
        let w_lit = xla::Literal::vec1(w);

        let result = self.matvec.execute::<xla::Literal>(&[x_lit, w_lit])?[0][0]
            .to_literal_sync()?;
        let y = result.to_tuple1()?;
        let mut out = y.to_vec::<f32>()?;
        out.truncate(rows);
        Ok(out)
    }

    /// Block mat-mat through the single-vector artifact: the compiled
    /// executable is baked for one iterate, so each of the `nvec` panel
    /// columns is gathered, executed, and scattered into the interleaved
    /// output. Correctness (the host oracle property) is preserved; the
    /// amortization win of the block plane belongs to the host kernel
    /// until multi-vector artifacts are compiled.
    pub fn matmat_tile_into(
        &self,
        x: &[f32],
        rows: usize,
        cols: usize,
        panel: &[f32],
        nvec: usize,
        out: &mut [f32],
    ) -> Result<()> {
        if nvec == 0 || panel.len() != cols * nvec {
            return Err(Error::Shape(format!(
                "panel length {} != cols {cols} x B {nvec}",
                panel.len()
            )));
        }
        if out.len() != rows * nvec {
            return Err(Error::Shape(format!(
                "output length {} != rows {rows} x B {nvec}",
                out.len()
            )));
        }
        let mut col = vec![0.0f32; cols];
        for k in 0..nvec {
            for (c, slot) in col.iter_mut().enumerate() {
                *slot = panel[c * nvec + k];
            }
            let y = self.matvec_tile(x, rows, cols, &col)?;
            for (r, &v) in y.iter().enumerate() {
                out[r * nvec + k] = v;
            }
        }
        Ok(())
    }

    pub fn normalize(&self, y: &[f32]) -> Result<(Vec<f32>, f64)> {
        if y.len() != self.q {
            return Err(Error::Runtime(format!(
                "normalize artifact baked for q={}, got {} (re-run `make artifacts Q={}`)",
                self.q,
                y.len(),
                y.len()
            )));
        }
        let y_lit = xla::Literal::vec1(y);
        let result = self.normalize.execute::<xla::Literal>(&[y_lit])?[0][0]
            .to_literal_sync()?;
        let (b, n) = result.to_tuple2()?;
        let b_vec = b.to_vec::<f32>()?;
        let n_val = n.to_vec::<f32>()?;
        Ok((b_vec, n_val.first().copied().unwrap_or(0.0) as f64))
    }

    pub fn dot(&self, a: &[f32], b: &[f32]) -> Result<f64> {
        if a.len() != self.q || b.len() != self.q {
            return Err(Error::Runtime(format!(
                "dot artifact baked for q={}, got {}/{}",
                self.q,
                a.len(),
                b.len()
            )));
        }
        let a_lit = xla::Literal::vec1(a);
        let b_lit = xla::Literal::vec1(b);
        let result = self.dot.execute::<xla::Literal>(&[a_lit, b_lit])?[0][0]
            .to_literal_sync()?;
        let d = result.to_tuple1()?;
        let v = d.to_vec::<f32>()?;
        Ok(v.first().copied().unwrap_or(0.0) as f64)
    }
}

#[cfg(test)]
mod tests {
    //! These tests require `make artifacts` to have run (they are skipped
    //! otherwise so `cargo test` works on a fresh checkout). The heavier
    //! PJRT-vs-host equivalence tests live in `tests/runtime_pjrt.rs`.
    use super::*;

    fn artifact_dir() -> Option<std::path::PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn load_and_execute_full_tile() {
        let Some(dir) = artifact_dir() else { return };
        let b = PjrtBackend::load(&dir).unwrap();
        let (rows, cols) = (b.tile_rows(), b.cols());
        let x: Vec<f32> = (0..rows * cols).map(|i| (i % 7) as f32 - 3.0).collect();
        let w: Vec<f32> = (0..cols).map(|i| ((i % 5) as f32 - 2.0) * 0.1).collect();
        let y = b.matvec_tile(&x, rows, cols, &w).unwrap();
        assert_eq!(y.len(), rows);
        // oracle
        let host = crate::runtime::host::HostBackend::new();
        let want = host.matvec_tile(&x, rows, cols, &w).unwrap();
        for (a, e) in y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-2 + 1e-4 * e.abs(), "{a} vs {e}");
        }
    }

    #[test]
    fn ragged_tile_zero_padded() {
        let Some(dir) = artifact_dir() else { return };
        let b = PjrtBackend::load(&dir).unwrap();
        let cols = b.cols();
        let rows = 5; // ragged
        let x: Vec<f32> = (0..rows * cols).map(|i| (i % 3) as f32).collect();
        let w: Vec<f32> = vec![0.5; cols];
        let y = b.matvec_tile(&x, rows, cols, &w).unwrap();
        assert_eq!(y.len(), rows);
        let host = crate::runtime::host::HostBackend::new();
        let want = host.matvec_tile(&x, rows, cols, &w).unwrap();
        for (a, e) in y.iter().zip(&want) {
            assert!((a - e).abs() < 1e-2, "{a} vs {e}");
        }
    }

    #[test]
    fn shape_guards() {
        let Some(dir) = artifact_dir() else { return };
        let b = PjrtBackend::load(&dir).unwrap();
        assert!(b.matvec_tile(&[0.0; 4], 2, 2, &[0.0; 2]).is_err()); // wrong cols
        assert!(b
            .matvec_tile(
                &vec![0.0; (b.tile_rows() + 1) * b.cols()],
                b.tile_rows() + 1,
                b.cols(),
                &vec![0.0; b.cols()],
            )
            .is_err()); // too many rows
        assert!(b.normalize(&[0.0; 3]).is_err()); // wrong q
    }

    #[test]
    fn normalize_and_dot_match_host() {
        let Some(dir) = artifact_dir() else { return };
        let b = PjrtBackend::load(&dir).unwrap();
        let q = b.q();
        let y: Vec<f32> = (0..q).map(|i| ((i % 11) as f32 - 5.0) * 0.3).collect();
        let (bn, n) = b.normalize(&y).unwrap();
        let host = crate::runtime::host::HostBackend::new();
        let (hn, hnorm) = host.normalize(&y).unwrap();
        assert!((n - hnorm).abs() < 1e-2 * (1.0 + hnorm));
        for (a, e) in bn.iter().zip(&hn) {
            assert!((a - e).abs() < 1e-4);
        }
        let d = b.dot(&y, &y).unwrap();
        let hd = host.dot(&y, &y).unwrap();
        assert!((d - hd).abs() < 1e-2 * (1.0 + hd.abs()));
    }
}
