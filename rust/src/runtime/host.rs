//! Pure-Rust host backend: the `linalg::ops` reference kernels.

use crate::error::{Error, Result};
use crate::linalg::ops;

/// Always-available backend; also the numerics oracle for PJRT.
#[derive(Debug, Default)]
pub struct HostBackend;

impl HostBackend {
    pub fn new() -> Self {
        HostBackend
    }

    pub fn matvec_tile(&self, x: &[f32], rows: usize, cols: usize, w: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; rows];
        self.matmat_tile_into(x, rows, cols, w, 1, &mut out)?;
        Ok(out)
    }

    /// `out = X_tile · W` for a `cols × nvec` interleaved column panel,
    /// writing into the caller's scratch (`rows × nvec`, interleaved) —
    /// the zero-allocation hot path of the block data plane.
    pub fn matmat_tile_into(
        &self,
        x: &[f32],
        rows: usize,
        cols: usize,
        panel: &[f32],
        nvec: usize,
        out: &mut [f32],
    ) -> Result<()> {
        if x.len() != rows * cols {
            return Err(Error::Shape(format!(
                "tile buffer {} != {rows}x{cols}",
                x.len()
            )));
        }
        if nvec == 0 || panel.len() != cols * nvec {
            return Err(Error::Shape(format!(
                "panel length {} != cols {cols} x B {nvec}",
                panel.len()
            )));
        }
        if out.len() != rows * nvec {
            return Err(Error::Shape(format!(
                "output length {} != rows {rows} x B {nvec}",
                out.len()
            )));
        }
        ops::matmat_into(x, rows, cols, panel, nvec, out);
        Ok(())
    }

    pub fn normalize(&self, y: &[f32]) -> Result<(Vec<f32>, f64)> {
        let mut b = y.to_vec();
        let n = ops::normalize(&mut b);
        Ok((b, n))
    }

    pub fn dot(&self, a: &[f32], b: &[f32]) -> Result<f64> {
        if a.len() != b.len() {
            return Err(Error::Shape(format!(
                "dot length mismatch {} vs {}",
                a.len(),
                b.len()
            )));
        }
        Ok(ops::dot(a, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_shapes() {
        let h = HostBackend::new();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = h.matvec_tile(&x, 2, 2, &[1.0, 1.0]).unwrap();
        assert_eq!(y, vec![3.0, 7.0]);
        assert!(h.matvec_tile(&x, 3, 2, &[1.0, 1.0]).is_err());
        assert!(h.matvec_tile(&x, 2, 2, &[1.0]).is_err());
    }

    #[test]
    fn matmat_into_shapes_and_values() {
        let h = HostBackend::new();
        let x = vec![1.0, 2.0, 3.0, 4.0]; // 2x2
        // panel: 2 cols x 2 vectors, interleaved — col0 = [1,1], col1 = [0,2]
        let panel = vec![1.0, 0.0, 1.0, 2.0];
        let mut out = vec![0.0f32; 4];
        h.matmat_tile_into(&x, 2, 2, &panel, 2, &mut out).unwrap();
        assert_eq!(out, vec![3.0, 4.0, 7.0, 8.0]);
        assert!(h.matmat_tile_into(&x, 2, 2, &panel, 3, &mut out).is_err());
        assert!(h.matmat_tile_into(&x, 2, 2, &panel, 0, &mut out).is_err());
        let mut short = vec![0.0f32; 3];
        assert!(h.matmat_tile_into(&x, 2, 2, &panel, 2, &mut short).is_err());
    }

    #[test]
    fn normalize_returns_norm() {
        let h = HostBackend::new();
        let (b, n) = h.normalize(&[3.0, 4.0]).unwrap();
        assert_eq!(n, 5.0);
        assert!((b[0] - 0.6).abs() < 1e-7);
    }

    #[test]
    fn dot_checks_lengths() {
        let h = HostBackend::new();
        assert_eq!(h.dot(&[1.0, 2.0], &[3.0, 4.0]).unwrap(), 11.0);
        assert!(h.dot(&[1.0], &[1.0, 2.0]).is_err());
    }
}
