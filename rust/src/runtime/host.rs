//! Pure-Rust host backend: the `linalg::ops` reference kernels.

use crate::error::{Error, Result};
use crate::linalg::ops;

/// Always-available backend; also the numerics oracle for PJRT.
#[derive(Debug, Default)]
pub struct HostBackend;

impl HostBackend {
    pub fn new() -> Self {
        HostBackend
    }

    pub fn matvec_tile(&self, x: &[f32], rows: usize, cols: usize, w: &[f32]) -> Result<Vec<f32>> {
        if x.len() != rows * cols {
            return Err(Error::Shape(format!(
                "tile buffer {} != {rows}x{cols}",
                x.len()
            )));
        }
        if w.len() != cols {
            return Err(Error::Shape(format!("w length {} != cols {cols}", w.len())));
        }
        let mut out = vec![0.0f32; rows];
        ops::matvec_into(x, rows, cols, w, &mut out);
        Ok(out)
    }

    pub fn normalize(&self, y: &[f32]) -> Result<(Vec<f32>, f64)> {
        let mut b = y.to_vec();
        let n = ops::normalize(&mut b);
        Ok((b, n))
    }

    pub fn dot(&self, a: &[f32], b: &[f32]) -> Result<f64> {
        if a.len() != b.len() {
            return Err(Error::Shape(format!(
                "dot length mismatch {} vs {}",
                a.len(),
                b.len()
            )));
        }
        Ok(ops::dot(a, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_and_shapes() {
        let h = HostBackend::new();
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let y = h.matvec_tile(&x, 2, 2, &[1.0, 1.0]).unwrap();
        assert_eq!(y, vec![3.0, 7.0]);
        assert!(h.matvec_tile(&x, 3, 2, &[1.0, 1.0]).is_err());
        assert!(h.matvec_tile(&x, 2, 2, &[1.0]).is_err());
    }

    #[test]
    fn normalize_returns_norm() {
        let h = HostBackend::new();
        let (b, n) = h.normalize(&[3.0, 4.0]).unwrap();
        assert_eq!(n, 5.0);
        assert!((b[0] - 0.6).abs() < 1e-7);
    }

    #[test]
    fn dot_checks_lengths() {
        let h = HostBackend::new();
        assert_eq!(h.dot(&[1.0, 2.0], &[3.0, 4.0]).unwrap(), 11.0);
        assert!(h.dot(&[1.0], &[1.0, 2.0]).is_err());
    }
}
