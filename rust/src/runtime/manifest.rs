//! Artifact manifest (`artifacts/manifest.json`) written by the Python AOT
//! pipeline and consumed by [`super::pjrt`].

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::json::Json;

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Tile height baked into the matvec artifact.
    pub tile_rows: usize,
    /// Matrix columns `r` baked into the matvec artifact.
    pub cols: usize,
    /// Vector length `q` baked into normalize/dot artifacts.
    pub q: usize,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (paths resolved relative to `dir`).
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let req = |k: &str| {
            v.get_usize(k)
                .ok_or_else(|| Error::Runtime(format!("manifest missing numeric '{k}'")))
        };
        let tile_rows = req("tile_rows")?;
        let cols = req("cols")?;
        let q = req("q")?;
        let arr = v
            .get("artifacts")
            .and_then(|a| a.items())
            .ok_or_else(|| Error::Runtime("manifest missing 'artifacts' array".into()))?;
        let mut artifacts = Vec::with_capacity(arr.len());
        for item in arr {
            let name = item
                .get_str("name")
                .ok_or_else(|| Error::Runtime("artifact missing 'name'".into()))?;
            let rel = item
                .get_str("path")
                .ok_or_else(|| Error::Runtime("artifact missing 'path'".into()))?;
            let kind = item
                .get_str("kind")
                .ok_or_else(|| Error::Runtime("artifact missing 'kind'".into()))?;
            artifacts.push(ArtifactEntry {
                name: name.to_string(),
                path: dir.join(rel),
                kind: kind.to_string(),
            });
        }
        Ok(Manifest {
            tile_rows,
            cols,
            q,
            artifacts,
        })
    }

    /// Find the artifact of a given kind.
    pub fn find(&self, kind: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.kind == kind)
            .ok_or_else(|| Error::Runtime(format!("no '{kind}' artifact in manifest")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "tile_rows": 128, "cols": 1536, "q": 1536,
        "artifacts": [
            {"name": "matvec_t128_c1536", "path": "matvec_t128_c1536.hlo.txt", "kind": "matvec"},
            {"name": "normalize_q1536", "path": "normalize_q1536.hlo.txt", "kind": "normalize"},
            {"name": "dot_q1536", "path": "dot_q1536.hlo.txt", "kind": "dot"}
        ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/arts")).unwrap();
        assert_eq!(m.tile_rows, 128);
        assert_eq!(m.cols, 1536);
        assert_eq!(m.artifacts.len(), 3);
        let mv = m.find("matvec").unwrap();
        assert_eq!(mv.path, Path::new("/arts/matvec_t128_c1536.hlo.txt"));
        assert!(m.find("conv").is_err());
    }

    #[test]
    fn missing_fields_rejected() {
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse(
            r#"{"tile_rows": 1, "cols": 2, "q": 3, "artifacts": [{"name": "x"}]}"#,
            Path::new(".")
        )
        .is_err());
    }

    #[test]
    fn load_generated_manifest_if_present() {
        // integration against the real `make artifacts` output when built
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.find("matvec").is_ok());
            assert!(m.find("normalize").is_ok());
            assert!(m.find("dot").is_ok());
            for a in &m.artifacts {
                assert!(a.path.exists(), "{} missing", a.path.display());
            }
        }
    }
}
