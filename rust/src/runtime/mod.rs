//! Compute backends: PJRT artifact execution + pure-Rust host fallback.
//!
//! Workers execute their assigned row tiles through a [`Backend`]:
//!
//! * [`host::HostBackend`] — the `linalg::ops` reference kernels. Always
//!   available; the numerics oracle for the PJRT path and the default for
//!   tests.
//! * [`pjrt::PjrtBackend`] — loads the HLO-text artifacts produced by
//!   `make artifacts` (`python/compile/aot.py`), compiles them once on a
//!   PJRT CPU client, and executes them on the hot path. Python never runs
//!   here.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (not `Send`), so backends
//! are instantiated *per worker thread* from a shareable [`BackendSpec`].

pub mod host;
pub mod manifest;
pub mod pjrt;
#[cfg(not(feature = "xla"))]
pub mod xla_stub;

// The `xla` feature swaps the stub for the real PJRT bindings, which are
// not vendored in the offline crate set. Fail loudly at compile time
// instead of with a wall of unresolved-path errors.
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature requires vendoring the `xla` crate as a dependency \
     (see Cargo.toml); the default build uses runtime::xla_stub instead"
);

pub use manifest::Manifest;

use std::path::PathBuf;

use crate::config::types::BackendKind;
use crate::error::Result;

/// Shareable recipe for building a backend on a worker thread.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// Pure-Rust reference kernels.
    Host,
    /// PJRT CPU client over the artifact directory.
    Pjrt { dir: PathBuf },
}

impl BackendSpec {
    /// Build from config (`artifacts/` is the conventional directory).
    pub fn from_kind(kind: BackendKind, artifact_dir: impl Into<PathBuf>) -> Self {
        match kind {
            BackendKind::Host => BackendSpec::Host,
            BackendKind::Pjrt => BackendSpec::Pjrt {
                dir: artifact_dir.into(),
            },
        }
    }

    /// Instantiate on the current thread.
    pub fn instantiate(&self) -> Result<Backend> {
        match self {
            BackendSpec::Host => Ok(Backend::Host(host::HostBackend::new())),
            BackendSpec::Pjrt { dir } => Ok(Backend::Pjrt(pjrt::PjrtBackend::load(dir)?)),
        }
    }
}

/// A worker/master compute backend (enum dispatch keeps the hot path free
/// of vtable indirection).
pub enum Backend {
    Host(host::HostBackend),
    Pjrt(pjrt::PjrtBackend),
}

impl Backend {
    /// `y = X_tile · w` where `x` is `rows × cols` row-major. `rows` may be
    /// ragged (less than the artifact tile); the PJRT path zero-pads.
    pub fn matvec_tile(&self, x: &[f32], rows: usize, cols: usize, w: &[f32]) -> Result<Vec<f32>> {
        match self {
            Backend::Host(h) => h.matvec_tile(x, rows, cols, w),
            Backend::Pjrt(p) => p.matvec_tile(x, rows, cols, w),
        }
    }

    /// `Y = X_tile · W` for a `cols × nvec` interleaved column panel
    /// (the [`crate::linalg::Block`] layout), written into the caller's
    /// `rows × nvec` scratch buffer — no allocation on the hot path. The
    /// host backend runs the cache-blocked mat-mat kernel; the PJRT
    /// backend executes its single-vector artifact per column (artifacts
    /// are compiled for B = 1).
    pub fn matmat_tile_into(
        &self,
        x: &[f32],
        rows: usize,
        cols: usize,
        panel: &[f32],
        nvec: usize,
        out: &mut [f32],
    ) -> Result<()> {
        match self {
            Backend::Host(h) => h.matmat_tile_into(x, rows, cols, panel, nvec, out),
            Backend::Pjrt(p) => p.matmat_tile_into(x, rows, cols, panel, nvec, out),
        }
    }

    /// Master combine: unit-normalize, returning `(b_next, ‖y‖)`.
    pub fn normalize(&self, y: &[f32]) -> Result<(Vec<f32>, f64)> {
        match self {
            Backend::Host(h) => h.normalize(y),
            Backend::Pjrt(p) => p.normalize(y),
        }
    }

    /// `<a, b>` (Rayleigh-quotient numerator).
    pub fn dot(&self, a: &[f32], b: &[f32]) -> Result<f64> {
        match self {
            Backend::Host(h) => h.dot(a, b),
            Backend::Pjrt(p) => p.dot(a, b),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Host(_) => "host",
            Backend::Pjrt(_) => "pjrt",
        }
    }

    /// The natural execution-tile height (PJRT: baked artifact shape; host:
    /// any — returns `None`).
    pub fn tile_rows(&self) -> Option<usize> {
        match self {
            Backend::Host(_) => None,
            Backend::Pjrt(p) => Some(p.tile_rows()),
        }
    }
}
