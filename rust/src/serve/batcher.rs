//! Continuous batching: many requests, one iterate block.
//!
//! Admitted requests become columns of a single [`Block`] that the
//! engine's distributed mat-vec advances once per elastic step. Columns
//! join and leave **only at step boundaries**: a request is admitted
//! into a free column before a step begins, rides the batch while its
//! residual is above `tol`, and retires the moment its own residual
//! converges (or its step budget runs out) — independently of its batch
//! mates. Because `Y = A·W` is column-independent, a request's iterate
//! trajectory is exactly what a dedicated single-request run would
//! produce, whatever else shares the block (property-tested in
//! [`super::session`]).

use std::time::Instant;

use crate::error::Result;
use crate::linalg::Block;

use super::request::{Query, Request, Response};

/// One request currently riding the batch.
#[derive(Debug)]
struct ActiveRequest {
    req: Request,
    /// The request's iterate column.
    w: Vec<f32>,
    /// Steps ridden so far.
    steps: usize,
    /// Latest residual (NaN before the first step).
    residual: f64,
    /// Ridge only: `‖b‖`, precomputed at admission.
    norm: f64,
}

/// Coalesces active requests into `B`-wide blocks at step boundaries.
#[derive(Debug)]
pub struct ContinuousBatcher {
    q: usize,
    max_width: usize,
    active: Vec<ActiveRequest>,
}

impl ContinuousBatcher {
    pub fn new(q: usize, max_width: usize) -> ContinuousBatcher {
        assert!(max_width > 0, "batch width must be at least 1");
        ContinuousBatcher {
            q,
            max_width,
            active: Vec::new(),
        }
    }

    /// Columns currently riding the batch.
    pub fn width(&self) -> usize {
        self.active.len()
    }

    /// Free columns before the next step.
    pub fn room(&self) -> usize {
        self.max_width - self.active.len()
    }

    pub fn is_empty(&self) -> bool {
        self.active.is_empty()
    }

    /// Request ids currently in flight (for poll/drain bookkeeping).
    pub fn active_ids(&self) -> Vec<u64> {
        self.active.iter().map(|a| a.req.id).collect()
    }

    /// In-flight columns per tenant (for telemetry snapshots).
    pub fn tenant_widths(&self) -> std::collections::BTreeMap<String, u64> {
        let mut widths = std::collections::BTreeMap::new();
        for a in &self.active {
            *widths.entry(a.req.tenant.clone()).or_insert(0) += 1;
        }
        widths
    }

    /// Seat a picked request in a free column. The initial iterate is
    /// query-specific: the seed basis vector for personalized PageRank,
    /// the query vector itself for a raw mat-vec, zero for ridge.
    pub fn admit(&mut self, req: Request) {
        assert!(self.room() > 0, "admit into a full batch");
        let (w, norm) = match &req.query {
            Query::Pagerank { seed_node, .. } => {
                let mut e = vec![0.0f32; self.q];
                e[*seed_node] = 1.0;
                (e, 0.0)
            }
            Query::Matvec { v } => (v.clone(), 0.0),
            Query::Ridge { b, .. } => {
                let norm = crate::linalg::ops::norm2(b);
                (vec![0.0f32; self.q], norm)
            }
        };
        self.active.push(ActiveRequest {
            req,
            w,
            steps: 0,
            residual: f64::NAN,
            norm,
        });
    }

    /// The iterate block for the next step (columns in admission order).
    /// Must not be called on an empty batch.
    pub fn block(&self) -> Result<Block> {
        let cols: Vec<Vec<f32>> = self.active.iter().map(|a| a.w.clone()).collect();
        Block::from_columns(&cols)
    }

    /// Fold one step's `Y = A·W` back into the columns: apply each
    /// request's update rule, retire converged/exhausted columns, and
    /// return their responses. `worst_residual` over the columns that
    /// remain active (NaN when none) is the step metric.
    pub fn apply(&mut self, y: &Block) -> (Vec<Response>, f64) {
        assert_eq!(y.nvec(), self.active.len(), "block width drifted mid-step");
        let q = self.q;
        for (k, a) in self.active.iter_mut().enumerate() {
            let yk = y.column(k);
            a.steps += 1;
            match &a.req.query {
                Query::Pagerank { seed_node, damping } => {
                    // p' = d·Ap + (1−d)·e_s ; residual = ‖p' − p‖₁
                    let d32 = *damping as f32;
                    let teleport = (1.0 - damping) as f32;
                    let mut delta = 0.0f64;
                    for i in 0..q {
                        let mut v = d32 * yk[i];
                        if i == *seed_node {
                            v += teleport;
                        }
                        delta += (v as f64 - a.w[i] as f64).abs();
                        a.w[i] = v;
                    }
                    a.residual = delta;
                }
                Query::Matvec { .. } => {
                    // answered in one step: the answer IS y
                    a.w = yk;
                    a.residual = 0.0;
                }
                Query::Ridge { b, lambda, eta } => {
                    // r = b − Aw − λw ; w' = w + ηr ; residual = ‖r‖/‖b‖
                    let mut res_sq = 0.0f64;
                    for i in 0..q {
                        let r = b[i] as f64 - yk[i] as f64 - lambda * a.w[i] as f64;
                        res_sq += r * r;
                        a.w[i] = (a.w[i] as f64 + eta * r) as f32;
                    }
                    a.residual = res_sq.sqrt() / a.norm;
                }
            }
        }
        let mut responses = Vec::new();
        let now = Instant::now();
        self.active.retain_mut(|a| {
            let done = a.residual <= a.req.tol || a.steps >= a.req.max_steps;
            if done {
                responses.push(Response {
                    id: a.req.id,
                    tenant: a.req.tenant.clone(),
                    answer: std::mem::take(&mut a.w),
                    residual: a.residual,
                    steps: a.steps,
                    latency_ns: now
                        .saturating_duration_since(a.req.submitted)
                        .as_nanos() as u64,
                });
            }
            !done
        });
        let worst = self
            .active
            .iter()
            .map(|a| a.residual)
            .fold(f64::NAN, f64::max);
        (responses, worst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    fn req(id: u64, tenant: &str, query: Query, tol: f64, max_steps: usize) -> Request {
        Request {
            id,
            tenant: tenant.to_string(),
            query,
            tol,
            max_steps,
            submitted: Instant::now(),
        }
    }

    #[test]
    fn matvec_retires_after_one_step_with_y() {
        let mut b = ContinuousBatcher::new(3, 4);
        b.admit(req(
            1,
            "a",
            Query::Matvec {
                v: vec![1.0, 2.0, 3.0],
            },
            1e-6,
            10,
        ));
        assert_eq!(b.width(), 1);
        assert_eq!(b.active_ids(), vec![1]);
        let y = Block::from_columns(&[vec![9.0, 8.0, 7.0]]).unwrap();
        let (resp, worst) = b.apply(&y);
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].answer, vec![9.0, 8.0, 7.0]);
        assert_eq!(resp[0].steps, 1);
        assert_eq!(resp[0].residual, 0.0);
        assert!(b.is_empty());
        assert!(worst.is_nan(), "no active columns left");
    }

    #[test]
    fn columns_retire_independently() {
        let mut b = ContinuousBatcher::new(2, 4);
        // column 0 retires on its step budget; column 1 keeps riding
        b.admit(req(
            1,
            "a",
            Query::Pagerank {
                seed_node: 0,
                damping: 0.85,
            },
            0.0,
            1,
        ));
        b.admit(req(
            2,
            "b",
            Query::Pagerank {
                seed_node: 1,
                damping: 0.85,
            },
            0.0,
            50,
        ));
        let widths = b.tenant_widths();
        assert_eq!(widths.get("a"), Some(&1));
        assert_eq!(widths.get("b"), Some(&1));
        let y = b.block().unwrap(); // pretend A = I for the test
        let (resp, worst) = b.apply(&y);
        assert_eq!(resp.len(), 1);
        assert_eq!(resp[0].id, 1);
        assert_eq!(b.width(), 1);
        assert_eq!(b.active_ids(), vec![2]);
        assert_eq!(b.tenant_widths().get("a"), None);
        assert!(worst.is_finite());
        assert!(b.room() == 3);
    }

    #[test]
    fn block_interleaves_admission_order() {
        let mut b = ContinuousBatcher::new(2, 4);
        b.admit(req(
            1,
            "a",
            Query::Matvec { v: vec![1.0, 2.0] },
            1e-6,
            1,
        ));
        b.admit(req(
            2,
            "b",
            Query::Matvec { v: vec![3.0, 4.0] },
            1e-6,
            1,
        ));
        let blk = b.block().unwrap();
        assert_eq!(blk.nvec(), 2);
        assert_eq!(blk.data(), &[1.0, 3.0, 2.0, 4.0]);
    }
}
