//! The serving wire protocol: submit/poll over framed TCP.
//!
//! Rides the same length-prefixed little-endian framing as the worker
//! protocol ([`crate::net::frame`]) but is its own codec with its own
//! magic, so a serve client dialing a worker daemon (or vice versa) is
//! rejected at the first frame instead of mis-parsing. The exchange:
//!
//! ```text
//! client                         server
//!   Hello{version}         ──▶
//!                          ◀──  HelloAck{q}
//!   Submit{tenant,query,…} ──▶
//!                          ◀──  SubmitAck{id} | Reject{reason}
//!   Poll{id}               ──▶
//!                          ◀──  Done{response} | Pending{depth}
//!   Bye                    ──▶
//! ```
//!
//! [`ServeClient`] wraps the client side; a `Reject` surfaces as the
//! same typed [`Error::Busy`] the in-process queue raises.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};
use crate::net::frame::{read_frame, write_frame};

use super::request::{Query, Response};

/// Serve-protocol magic (`"USEV"` LE) — distinct from the worker codec.
pub const SERVE_MAGIC: u32 = 0x5553_4556;
/// Serve-protocol version.
pub const SERVE_VERSION: u16 = 1;

const TAG_HELLO: u8 = 1;
const TAG_HELLO_ACK: u8 = 2;
const TAG_SUBMIT: u8 = 3;
const TAG_SUBMIT_ACK: u8 = 4;
const TAG_REJECT: u8 = 5;
const TAG_POLL: u8 = 6;
const TAG_PENDING: u8 = 7;
const TAG_DONE: u8 = 8;
const TAG_BYE: u8 = 9;

/// One serve-protocol message.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeMsg {
    Hello { version: u16 },
    HelloAck { q: u64 },
    Submit { tenant: String, query: Query, tol: f64, max_steps: u64 },
    SubmitAck { id: u64 },
    Reject { reason: String },
    Poll { id: u64 },
    Pending { depth: u64 },
    Done { resp: Response },
    Bye,
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_u32(out, xs.len() as u32);
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bounds-checked little-endian reader over a payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::wire(format!(
                "serve frame truncated at byte {} (wanted {n} more of {})",
                self.pos,
                self.buf.len()
            )));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn string(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| Error::wire("serve frame string is not UTF-8"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u32()? as usize;
        let bytes = self.take(len.checked_mul(4).ok_or_else(|| {
            Error::wire("serve frame vector length overflows")
        })?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn done(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            return Err(Error::wire(format!(
                "serve frame has {} trailing bytes",
                self.buf.len() - self.pos
            )));
        }
        Ok(())
    }
}

fn encode_query(out: &mut Vec<u8>, q: &Query) {
    match q {
        Query::Pagerank { seed_node, damping } => {
            out.push(0);
            put_u64(out, *seed_node as u64);
            put_f64(out, *damping);
        }
        Query::Matvec { v } => {
            out.push(1);
            put_f32s(out, v);
        }
        Query::Ridge { b, lambda, eta } => {
            out.push(2);
            put_f32s(out, b);
            put_f64(out, *lambda);
            put_f64(out, *eta);
        }
    }
}

fn decode_query(c: &mut Cursor) -> Result<Query> {
    match c.u8()? {
        0 => Ok(Query::Pagerank {
            seed_node: c.u64()? as usize,
            damping: c.f64()?,
        }),
        1 => Ok(Query::Matvec { v: c.f32s()? }),
        2 => Ok(Query::Ridge {
            b: c.f32s()?,
            lambda: c.f64()?,
            eta: c.f64()?,
        }),
        k => Err(Error::wire(format!("unknown serve query kind {k}"))),
    }
}

impl ServeMsg {
    /// Serialize into one frame payload (magic + tag + body).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        put_u32(&mut out, SERVE_MAGIC);
        match self {
            ServeMsg::Hello { version } => {
                out.push(TAG_HELLO);
                put_u16(&mut out, *version);
            }
            ServeMsg::HelloAck { q } => {
                out.push(TAG_HELLO_ACK);
                put_u64(&mut out, *q);
            }
            ServeMsg::Submit {
                tenant,
                query,
                tol,
                max_steps,
            } => {
                out.push(TAG_SUBMIT);
                put_str(&mut out, tenant);
                put_f64(&mut out, *tol);
                put_u64(&mut out, *max_steps);
                encode_query(&mut out, query);
            }
            ServeMsg::SubmitAck { id } => {
                out.push(TAG_SUBMIT_ACK);
                put_u64(&mut out, *id);
            }
            ServeMsg::Reject { reason } => {
                out.push(TAG_REJECT);
                put_str(&mut out, reason);
            }
            ServeMsg::Poll { id } => {
                out.push(TAG_POLL);
                put_u64(&mut out, *id);
            }
            ServeMsg::Pending { depth } => {
                out.push(TAG_PENDING);
                put_u64(&mut out, *depth);
            }
            ServeMsg::Done { resp } => {
                out.push(TAG_DONE);
                put_u64(&mut out, resp.id);
                put_str(&mut out, &resp.tenant);
                put_f32s(&mut out, &resp.answer);
                put_f64(&mut out, resp.residual);
                put_u64(&mut out, resp.steps as u64);
                put_u64(&mut out, resp.latency_ns);
            }
            ServeMsg::Bye => out.push(TAG_BYE),
        }
        out
    }

    /// Parse one frame payload.
    pub fn decode(payload: &[u8]) -> Result<ServeMsg> {
        let mut c = Cursor::new(payload);
        let magic = c.u32()?;
        if magic != SERVE_MAGIC {
            return Err(Error::wire(format!(
                "bad serve magic {magic:#010x} (is the peer a worker daemon?)"
            )));
        }
        let msg = match c.u8()? {
            TAG_HELLO => ServeMsg::Hello { version: c.u16()? },
            TAG_HELLO_ACK => ServeMsg::HelloAck { q: c.u64()? },
            TAG_SUBMIT => {
                let tenant = c.string()?;
                let tol = c.f64()?;
                let max_steps = c.u64()?;
                let query = decode_query(&mut c)?;
                ServeMsg::Submit {
                    tenant,
                    query,
                    tol,
                    max_steps,
                }
            }
            TAG_SUBMIT_ACK => ServeMsg::SubmitAck { id: c.u64()? },
            TAG_REJECT => ServeMsg::Reject { reason: c.string()? },
            TAG_POLL => ServeMsg::Poll { id: c.u64()? },
            TAG_PENDING => ServeMsg::Pending { depth: c.u64()? },
            TAG_DONE => ServeMsg::Done {
                resp: Response {
                    id: c.u64()?,
                    tenant: c.string()?,
                    answer: c.f32s()?,
                    residual: c.f64()?,
                    steps: c.u64()? as usize,
                    latency_ns: c.u64()?,
                },
            },
            TAG_BYE => ServeMsg::Bye,
            t => return Err(Error::wire(format!("unknown serve tag {t}"))),
        };
        c.done()?;
        Ok(msg)
    }
}

/// Send one message as a frame.
pub fn send_msg<W: Write>(w: &mut W, msg: &ServeMsg) -> Result<()> {
    write_frame(w, &msg.encode())
}

/// Receive one message frame.
pub fn recv_msg<R: Read>(r: &mut R) -> Result<ServeMsg> {
    ServeMsg::decode(&read_frame(r)?)
}

/// Client side of the serve protocol.
pub struct ServeClient {
    stream: TcpStream,
    /// Rows of the server's serve matrix (from the handshake).
    pub q: usize,
}

impl ServeClient {
    /// Dial a `usec serve --listen` server and shake hands.
    pub fn connect(addr: &str) -> Result<ServeClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        send_msg(
            &mut stream,
            &ServeMsg::Hello {
                version: SERVE_VERSION,
            },
        )?;
        match recv_msg(&mut stream)? {
            ServeMsg::HelloAck { q } => Ok(ServeClient {
                stream,
                q: q as usize,
            }),
            other => Err(Error::wire(format!(
                "expected HelloAck, got {other:?}"
            ))),
        }
    }

    /// Submit a request; a full queue surfaces as [`Error::Busy`].
    pub fn submit(
        &mut self,
        tenant: &str,
        query: Query,
        tol: f64,
        max_steps: usize,
    ) -> Result<u64> {
        send_msg(
            &mut self.stream,
            &ServeMsg::Submit {
                tenant: tenant.to_string(),
                query,
                tol,
                max_steps: max_steps as u64,
            },
        )?;
        match recv_msg(&mut self.stream)? {
            ServeMsg::SubmitAck { id } => Ok(id),
            ServeMsg::Reject { reason } => Err(Error::busy(reason)),
            other => Err(Error::wire(format!(
                "expected SubmitAck/Reject, got {other:?}"
            ))),
        }
    }

    /// Poll a submitted request once.
    pub fn poll(&mut self, id: u64) -> Result<Option<Response>> {
        send_msg(&mut self.stream, &ServeMsg::Poll { id })?;
        match recv_msg(&mut self.stream)? {
            ServeMsg::Done { resp } => Ok(Some(resp)),
            ServeMsg::Pending { .. } => Ok(None),
            other => Err(Error::wire(format!(
                "expected Done/Pending, got {other:?}"
            ))),
        }
    }

    /// Poll until the request completes or `timeout` elapses.
    pub fn wait(&mut self, id: u64, timeout: Duration) -> Result<Response> {
        let deadline = Instant::now() + timeout;
        loop {
            if let Some(resp) = self.poll(id)? {
                return Ok(resp);
            }
            if Instant::now() >= deadline {
                return Err(Error::Cluster(format!(
                    "request {id} still pending after {timeout:?}"
                )));
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    /// Polite goodbye (errors ignored; the server also survives EOF).
    pub fn bye(mut self) {
        let _ = send_msg(&mut self.stream, &ServeMsg::Bye);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: ServeMsg) {
        let bytes = msg.encode();
        assert_eq!(ServeMsg::decode(&bytes).unwrap(), msg);
    }

    #[test]
    fn all_messages_roundtrip() {
        roundtrip(ServeMsg::Hello {
            version: SERVE_VERSION,
        });
        roundtrip(ServeMsg::HelloAck { q: 1536 });
        roundtrip(ServeMsg::Submit {
            tenant: "alice".into(),
            query: Query::Pagerank {
                seed_node: 7,
                damping: 0.85,
            },
            tol: 1e-6,
            max_steps: 100,
        });
        roundtrip(ServeMsg::Submit {
            tenant: "bob".into(),
            query: Query::Matvec {
                v: vec![1.0, -2.5, 3.25],
            },
            tol: 0.0,
            max_steps: 1,
        });
        roundtrip(ServeMsg::Submit {
            tenant: "carol".into(),
            query: Query::Ridge {
                b: vec![0.5; 4],
                lambda: 3.0,
                eta: 0.13,
            },
            tol: 1e-7,
            max_steps: 300,
        });
        roundtrip(ServeMsg::SubmitAck { id: 42 });
        roundtrip(ServeMsg::Reject {
            reason: "admission queue full".into(),
        });
        roundtrip(ServeMsg::Poll { id: 42 });
        roundtrip(ServeMsg::Pending { depth: 3 });
        roundtrip(ServeMsg::Done {
            resp: Response {
                id: 42,
                tenant: "alice".into(),
                answer: vec![0.25, 0.75],
                residual: 1e-9,
                steps: 57,
                latency_ns: 1_234_567,
            },
        });
        roundtrip(ServeMsg::Bye);
    }

    #[test]
    fn rejects_wrong_magic_and_garbage() {
        // a worker-codec frame must not parse as a serve message
        let mut bytes = ServeMsg::Bye.encode();
        bytes[0] ^= 0xFF;
        assert!(ServeMsg::decode(&bytes).is_err());
        assert!(ServeMsg::decode(&[]).is_err());
        // truncated submit
        let full = ServeMsg::Submit {
            tenant: "t".into(),
            query: Query::Matvec { v: vec![1.0; 8] },
            tol: 1e-6,
            max_steps: 10,
        }
        .encode();
        assert!(ServeMsg::decode(&full[..full.len() - 3]).is_err());
        // trailing bytes
        let mut padded = ServeMsg::Poll { id: 1 }.encode();
        padded.push(0);
        assert!(ServeMsg::decode(&padded).is_err());
        // unknown tag
        let mut bad = ServeMsg::Bye.encode();
        bad[4] = 200;
        assert!(ServeMsg::decode(&bad).is_err());
    }
}
