//! The request plane: a resident elastic cluster serving multi-tenant
//! queries (`usec serve`).
//!
//! The classic binary runs one batch job and exits. This module keeps
//! the cluster resident and feeds it a stream of tenant-tagged requests
//! instead:
//!
//! * [`request`] — the query types (personalized PageRank seeds, raw
//!   mat-vecs, ridge solves) and their answers.
//! * [`queue`] — the bounded admission queue; a full queue rejects with
//!   the typed [`crate::Error::Busy`] instead of growing unboundedly.
//! * [`fairness`] — deficit round robin across tenants, so one flooding
//!   tenant cannot starve the rest.
//! * [`batcher`] — continuous batching: picked requests' iterate
//!   vectors coalesce into one `B`-wide [`crate::linalg::Block`] per
//!   elastic step; columns join/leave at step boundaries and retire
//!   individually when their own residual converges.
//! * [`session`] — [`ServeSession`], the glue driving the
//!   [`crate::engine::ClusterEngine`] step primitives under the batch.
//! * [`slo`] — per-tenant SLO tracking: rolling latency quantiles,
//!   Busy-reject rates, and burn thresholds (`--slo-p99-ms`,
//!   `--slo-reject-rate`) journaled as `slo_burn` events and published
//!   through the telemetry plane (`--metrics-listen`).
//! * [`wire`] / [`server`] — submit/poll over the framed TCP codec
//!   (`usec serve --listen`, [`ServeClient`] on the client side).
//!
//! ```text
//! tenants ──▶ AdmissionQueue ──DRR──▶ ContinuousBatcher ──Block──▶
//!     ClusterEngine (begin/complete step) ──Y──▶ retire columns ──▶
//!     Responses (latency quantiles → Timeline / --json-out)
//! ```

pub mod batcher;
pub mod fairness;
pub mod queue;
pub mod request;
pub mod server;
pub mod session;
pub mod slo;
pub mod wire;

pub use batcher::ContinuousBatcher;
pub use fairness::DrrScheduler;
pub use queue::AdmissionQueue;
pub use request::{Query, Request, Response};
pub use server::{serve_listen, ServeOpts};
pub use session::{serve_matrix, ServeSession, SessionOpts};
pub use slo::{SloBurn, SloThresholds, SloTracker};
pub use wire::{ServeClient, ServeMsg};

use std::net::TcpListener;
use std::sync::Arc;
use std::time::Duration;

use crate::cli::{ArgSpec, Args};
use crate::config::RunConfig;
use crate::error::{Error, Result};
use crate::obs::{MetricsServer, Telemetry};

/// Serving flags layered on top of the elastic-run flags.
pub fn serve_arg_specs() -> Vec<ArgSpec> {
    vec![
        ArgSpec::opt("listen", "", "serve requests on this host:port"),
        ArgSpec::opt("connect", "", "client mode: dial a serve server"),
        ArgSpec::opt("queue-cap", "64", "admission queue capacity"),
        ArgSpec::opt("quantum", "1", "DRR requests per tenant per round"),
        ArgSpec::opt("max-width", "8", "max batch width B (columns per step)"),
        ArgSpec::opt("exit-after", "0", "server: exit after N served requests (0 = no cap)"),
        ArgSpec::opt("idle-ms", "0", "server: exit after this long idle (0 = never)"),
        ArgSpec::opt(
            "metrics-listen",
            "",
            "server: serve /metrics, /healthz, /readyz on this host:port",
        ),
        ArgSpec::opt("slo-p99-ms", "0", "burn when rolling p99 latency exceeds this (0 = off)"),
        ArgSpec::opt(
            "slo-reject-rate",
            "0",
            "burn when rejects/submits exceeds this fraction (0 = off)",
        ),
        ArgSpec::opt("slo-min-requests", "1", "evaluate SLO burns only past this sample count"),
        ArgSpec::opt("slo-window-ms", "10000", "rolling SLO window width"),
        ArgSpec::opt("tenant", "t0", "client: tenant tag"),
        ArgSpec::opt("seed-node", "0", "client: personalized PageRank seed node"),
        ArgSpec::opt("damping", "0.85", "client: PageRank damping d"),
        ArgSpec::opt("tol", "1e-6", "client: retire the request at this residual"),
        ArgSpec::opt("req-steps", "100", "client: max steps the request may ride"),
    ]
}

/// `usec serve --listen host:port [run flags]` — resident server; or
/// `usec serve --connect host:port --tenant T --seed-node K` — client.
pub fn serve_cli(argv: &[String]) -> Result<()> {
    let mut specs = RunConfig::arg_specs();
    specs.extend(serve_arg_specs());
    let args = Args::parse(argv, &specs)?;
    let listen = args.get("listen").unwrap_or("").to_string();
    let connect = args.get("connect").unwrap_or("").to_string();
    match (listen.is_empty(), connect.is_empty()) {
        (false, true) => serve_server(&args, &listen),
        (true, false) => serve_client(&args, &connect),
        _ => Err(Error::Config(
            "usec serve needs exactly one of --listen (server) or \
             --connect (client)"
                .into(),
        )),
    }
}

fn serve_server(args: &Args, listen: &str) -> Result<()> {
    let cfg = RunConfig::from_args(args)?;
    let slo = SloThresholds {
        latency_p99_ms: args.get_f64("slo-p99-ms")?,
        reject_rate: args.get_f64("slo-reject-rate")?,
        min_requests: args.get_u64("slo-min-requests")?,
    };
    let metrics_listen = args.get("metrics-listen").unwrap_or("").to_string();
    // the telemetry plane exists when it has a consumer: a scrape
    // endpoint, or SLO thresholds that need evaluating
    let telemetry = if !metrics_listen.is_empty() || slo.enabled() {
        Some(Arc::new(Telemetry::new(cfg.n, cfg.j)))
    } else {
        None
    };
    let opts = ServeOpts {
        exit_after: args.get_usize("exit-after")?,
        idle_ms: args.get_u64("idle-ms")?,
        session: SessionOpts {
            queue_cap: args.get_usize("queue-cap")?,
            quantum: args.get_u64("quantum")?,
            max_width: args.get_usize("max-width")?,
            slo,
            slo_window: Duration::from_millis(args.get_u64("slo-window-ms")?.max(1)),
        },
        telemetry: telemetry.clone(),
    };
    let listener = TcpListener::bind(listen)?;
    let metrics = match (&telemetry, metrics_listen.is_empty()) {
        (Some(tel), false) => {
            let ml = TcpListener::bind(&metrics_listen)?;
            let srv = MetricsServer::spawn(ml, Arc::clone(tel))?;
            println!(
                "metrics on http://{}/metrics (probes /healthz, /readyz)",
                srv.addr()
            );
            Some(srv)
        }
        _ => None,
    };
    println!(
        "serving q={} matrix on {} (B ≤ {}, queue {}, transport={})",
        cfg.q,
        listener.local_addr()?,
        opts.session.max_width,
        opts.session.queue_cap,
        if cfg.is_distributed() { "tcp" } else { "local" },
    );
    let tl = serve_listen(listener, &cfg, &opts)?;
    if let Some(m) = metrics {
        m.stop();
    }
    if let Some(s) = tl.serve() {
        println!(
            "served {} request(s) over {} elastic step(s): p50 {:.3} ms, \
             p99 {:.3} ms, peak queue depth {}, {:.0} rows/s",
            s.requests,
            tl.len(),
            s.latency_p50_ns / 1e6,
            s.latency_p99_ns / 1e6,
            s.queue_depth,
            s.rows_per_s,
        );
    }
    if !cfg.json_out.is_empty() {
        let mut doc = crate::util::json::ObjBuilder::new()
            .str("app", "serve")
            .str(
                "transport",
                if cfg.is_distributed() { "tcp" } else { "local" },
            )
            .num("n", cfg.n as f64)
            .num("max_width", opts.session.max_width as f64)
            .num("seed", cfg.seed as f64)
            .val("timeline", tl.to_json());
        // final per-tenant SLO snapshot — present only when the
        // telemetry plane was on, so classic dumps stay byte-identical
        if let Some(slo) = telemetry.as_ref().and_then(|t| t.slo_json()) {
            doc = doc.val("slo", slo);
        }
        let doc = doc.build();
        std::fs::write(&cfg.json_out, format!("{doc}\n"))?;
        println!("wrote serve timeline JSON to {}", cfg.json_out);
    }
    Ok(())
}

fn serve_client(args: &Args, connect: &str) -> Result<()> {
    let tenant = args.get("tenant").unwrap_or("t0").to_string();
    let seed_node = args.get_usize("seed-node")?;
    let damping = args.get_f64("damping")?;
    let tol = args.get_f64("tol")?;
    let max_steps = args.get_usize("req-steps")?;
    let mut client = ServeClient::connect(connect)?;
    println!("connected to {connect} (q = {})", client.q);
    let id = client.submit(
        &tenant,
        Query::Pagerank { seed_node, damping },
        tol,
        max_steps,
    )?;
    println!("submitted request {id} (tenant {tenant}, seed node {seed_node})");
    let resp = client.wait(id, Duration::from_secs(120))?;
    let mut top: Vec<(usize, f32)> = resp.answer.iter().copied().enumerate().collect();
    top.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    let show: Vec<String> = top
        .iter()
        .take(5)
        .map(|(i, v)| format!("{i}:{v:.4}"))
        .collect();
    println!(
        "answered in {} step(s), residual {:.3e}, latency {:.3} ms; top ranks [{}]",
        resp.steps,
        resp.residual,
        resp.latency_ns as f64 / 1e6,
        show.join(", ")
    );
    client.bye();
    Ok(())
}
