//! `usec serve --listen`: the resident serving loop behind a socket.
//!
//! One thread steps the [`ServeSession`]; an acceptor thread admits
//! clients and spawns one handler thread per connection. Handlers share
//! the session's admission queue (submits are pushed straight into it,
//! full-queue rejects travel back as `Reject`) and a completed-response
//! map the stepping loop fills. The server exits after `exit_after`
//! served requests and/or after `idle_ms` without work — both zero
//! means serve forever.

use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::types::RunConfig;
use crate::error::{Error, Result};
use crate::metrics::Timeline;
use crate::obs::Telemetry;

use super::queue::AdmissionQueue;
use super::request::Response;
use super::session::{ServeSession, SessionOpts};
use super::wire::{recv_msg, send_msg, ServeMsg, SERVE_VERSION};

/// Server-mode knobs on top of the session's request-plane ones.
#[derive(Debug, Clone, Default)]
pub struct ServeOpts {
    /// Exit after serving this many requests (0 = no request cap).
    pub exit_after: usize,
    /// Exit after this long without queued or in-flight work
    /// (0 = never idle-exit).
    pub idle_ms: u64,
    pub session: SessionOpts,
    /// Live telemetry handle to publish into (`--metrics-listen` or SLO
    /// flags); `None` keeps the serving loop telemetry-free.
    pub telemetry: Option<Arc<Telemetry>>,
}

/// Is this I/O error just a read timeout (keep polling)?
fn is_timeout(e: &Error) -> bool {
    matches!(
        e,
        Error::Io(io) if io.kind() == std::io::ErrorKind::WouldBlock
            || io.kind() == std::io::ErrorKind::TimedOut
    )
}

/// One client connection: handshake, then submit/poll until Bye/EOF.
fn handle_client(
    mut stream: TcpStream,
    q: usize,
    queue: Arc<Mutex<AdmissionQueue>>,
    done: Arc<Mutex<HashMap<u64, Response>>>,
    stop: Arc<AtomicBool>,
) {
    stream.set_nodelay(true).ok();
    stream
        .set_read_timeout(Some(Duration::from_millis(100)))
        .ok();
    // handshake: wait (bounded by `stop`) for the client's Hello
    loop {
        match recv_msg(&mut stream) {
            Ok(ServeMsg::Hello { version }) if version == SERVE_VERSION => break,
            Ok(_) => return, // wrong opening message: drop the client
            Err(e) if is_timeout(&e) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
    if send_msg(&mut stream, &ServeMsg::HelloAck { q: q as u64 }).is_err() {
        return;
    }
    loop {
        let msg = match recv_msg(&mut stream) {
            Ok(m) => m,
            Err(e) if is_timeout(&e) => {
                if stop.load(Ordering::Relaxed) {
                    return;
                }
                continue;
            }
            Err(_) => return, // EOF or a broken frame: connection over
        };
        let reply = match msg {
            ServeMsg::Submit {
                tenant,
                query,
                tol,
                max_steps,
            } => {
                let res = queue
                    .lock()
                    .unwrap()
                    .submit(q, &tenant, query, tol, max_steps as usize);
                match res {
                    Ok(id) => ServeMsg::SubmitAck { id },
                    Err(e) => ServeMsg::Reject {
                        reason: e.to_string(),
                    },
                }
            }
            ServeMsg::Poll { id } => match done.lock().unwrap().get(&id) {
                Some(resp) => ServeMsg::Done { resp: resp.clone() },
                None => ServeMsg::Pending {
                    depth: queue.lock().unwrap().len() as u64,
                },
            },
            ServeMsg::Bye => return,
            _ => ServeMsg::Reject {
                reason: "unexpected client message".into(),
            },
        };
        if send_msg(&mut stream, &reply).is_err() {
            return;
        }
    }
}

/// Serve requests over `listener` until the exit condition holds, then
/// drain the cluster and return the timeline (serve summary attached).
pub fn serve_listen(
    listener: TcpListener,
    cfg: &RunConfig,
    opts: &ServeOpts,
) -> Result<Timeline> {
    let mut session = ServeSession::build(cfg, &opts.session)?;
    if opts.telemetry.is_some() {
        session.set_telemetry(opts.telemetry.clone());
    }
    let q = cfg.q;
    let queue = session.queue_handle();
    let done: Arc<Mutex<HashMap<u64, Response>>> = Arc::new(Mutex::new(HashMap::new()));
    let stop = Arc::new(AtomicBool::new(false));

    listener.set_nonblocking(true)?;
    let acceptor = {
        let queue = Arc::clone(&queue);
        let done = Arc::clone(&done);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut handlers = Vec::new();
            while !stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let queue = Arc::clone(&queue);
                        let done = Arc::clone(&done);
                        let stop = Arc::clone(&stop);
                        handlers.push(std::thread::spawn(move || {
                            handle_client(stream, q, queue, done, stop)
                        }));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => break,
                }
            }
            for h in handlers {
                h.join().ok();
            }
        })
    };

    let mut served = 0usize;
    let mut last_work = Instant::now();
    let outcome = loop {
        let responses = match session.step_once() {
            Ok(r) => r,
            Err(e) => break Err(e),
        };
        if !responses.is_empty() {
            let mut map = done.lock().unwrap();
            for r in responses {
                served += 1;
                map.insert(r.id, r);
            }
        }
        if opts.exit_after > 0 && served >= opts.exit_after {
            break Ok(());
        }
        if session.pending() {
            last_work = Instant::now();
        } else {
            if opts.idle_ms > 0
                && last_work.elapsed() >= Duration::from_millis(opts.idle_ms)
            {
                break Ok(());
            }
            std::thread::sleep(Duration::from_millis(1));
        }
    };
    stop.store(true, Ordering::Relaxed);
    acceptor.join().ok();
    outcome?;
    session.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Query;
    use crate::serve::session::serve_matrix;
    use crate::serve::wire::ServeClient;

    #[test]
    fn two_concurrent_clients_are_served_over_the_wire() {
        let q = 32;
        let cfg = RunConfig {
            q,
            r: q,
            g: 3,
            j: 2,
            n: 3,
            steps: 1,
            speeds: vec![1.0, 2.0, 3.0],
            seed: 19,
            ..Default::default()
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let opts = ServeOpts {
            exit_after: 4,
            idle_ms: 0,
            session: SessionOpts::default(),
            telemetry: None,
        };
        let server = {
            let cfg = cfg.clone();
            std::thread::spawn(move || serve_listen(listener, &cfg, &opts))
        };

        let clients: Vec<_> = (0..2usize)
            .map(|t| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let tenant = format!("tenant{t}");
                    let mut c = ServeClient::connect(&addr).unwrap();
                    assert_eq!(c.q, 32);
                    let ids = [
                        c.submit(
                            &tenant,
                            Query::Pagerank {
                                seed_node: 2 * t + 1,
                                damping: 0.85,
                            },
                            1e-8,
                            200,
                        )
                        .unwrap(),
                        c.submit(
                            &tenant,
                            Query::Matvec {
                                v: (0..32).map(|i| (i + t) as f32 * 0.25).collect(),
                            },
                            1e-6,
                            1,
                        )
                        .unwrap(),
                    ];
                    let resps: Vec<Response> = ids
                        .iter()
                        .map(|&id| c.wait(id, Duration::from_secs(20)).unwrap())
                        .collect();
                    c.bye();
                    (t, resps)
                })
            })
            .collect();

        let a = serve_matrix(q, cfg.seed);
        for client in clients {
            let (t, resps) = client.join().unwrap();
            assert_eq!(resps[0].tenant, format!("tenant{t}"));
            assert!(resps[0].residual <= 1e-8);
            // the matvec answer must equal the dense product exactly
            let v: Vec<f32> = (0..32).map(|i| (i + t) as f32 * 0.25).collect();
            let want = a.matvec(&v).unwrap();
            let diff: f64 = resps[1]
                .answer
                .iter()
                .zip(&want)
                .map(|(&x, &y)| (x as f64 - y as f64).abs())
                .fold(0.0, f64::max);
            assert!(diff <= 1e-5, "matvec diverged over the wire: {diff}");
        }

        let tl = server.join().unwrap().unwrap();
        let summary = tl.serve().expect("serve summary attached");
        assert_eq!(summary.requests, 4);
        assert!(summary.latency_p99_ns >= summary.latency_p50_ns);
    }

    #[test]
    fn idle_server_exits_on_idle_timeout() {
        let cfg = RunConfig {
            q: 16,
            r: 16,
            g: 2,
            j: 2,
            n: 2,
            steps: 1,
            speeds: vec![1.0, 1.0],
            seed: 3,
            ..Default::default()
        };
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let opts = ServeOpts {
            exit_after: 0,
            idle_ms: 50,
            session: SessionOpts::default(),
            telemetry: None,
        };
        let tl = serve_listen(listener, &cfg, &opts).unwrap();
        assert_eq!(tl.serve().unwrap().requests, 0);
    }
}
