//! Bounded multi-tenant admission queue with typed backpressure.
//!
//! Requests wait here between submission and being picked into the
//! continuous batch by the DRR scheduler ([`super::DrrScheduler`]). The
//! queue is bounded: a submit against a full queue is rejected with
//! [`Error::Busy`] rather than growing without bound — the client sees
//! the rejection immediately and can back off, and the serving loop's
//! memory stays proportional to `capacity`, not to offered load.

use std::collections::{BTreeMap, VecDeque};
use std::time::Instant;

use crate::error::{Error, Result};

use super::request::{Query, Request};

/// Bounded FIFO-per-tenant admission queue.
#[derive(Debug)]
pub struct AdmissionQueue {
    capacity: usize,
    /// Per-tenant FIFO lanes (BTreeMap ⇒ deterministic tenant order).
    tenants: BTreeMap<String, VecDeque<Request>>,
    len: usize,
    next_id: u64,
    peak_depth: usize,
    /// Cumulative valid submits per tenant (admitted or Busy-rejected).
    admits: BTreeMap<String, u64>,
    /// Cumulative `Error::Busy` rejections per tenant. Malformed queries
    /// (`Error::Config`) are the caller's bug, not load shed, and are
    /// not counted against the tenant's SLO.
    rejects: BTreeMap<String, u64>,
}

impl AdmissionQueue {
    pub fn new(capacity: usize) -> AdmissionQueue {
        assert!(capacity > 0, "admission queue needs capacity ≥ 1");
        AdmissionQueue {
            capacity,
            tenants: BTreeMap::new(),
            len: 0,
            next_id: 1,
            peak_depth: 0,
            admits: BTreeMap::new(),
            rejects: BTreeMap::new(),
        }
    }

    /// Admit a request, assigning its id. Rejects with [`Error::Busy`]
    /// when the queue is at capacity and with [`Error::Config`] when the
    /// query is malformed for a `q`-row serve matrix.
    pub fn submit(
        &mut self,
        q: usize,
        tenant: &str,
        query: Query,
        tol: f64,
        max_steps: usize,
    ) -> Result<u64> {
        query.validate(q)?;
        if max_steps == 0 {
            return Err(Error::Config("max_steps must be at least 1".into()));
        }
        if self.len >= self.capacity {
            *self.rejects.entry(tenant.to_string()).or_insert(0) += 1;
            return Err(Error::busy(format!(
                "admission queue full ({} requests queued, capacity {})",
                self.len, self.capacity
            )));
        }
        *self.admits.entry(tenant.to_string()).or_insert(0) += 1;
        let id = self.next_id;
        self.next_id += 1;
        self.tenants
            .entry(tenant.to_string())
            .or_default()
            .push_back(Request {
                id,
                tenant: tenant.to_string(),
                query,
                tol,
                max_steps,
                submitted: Instant::now(),
            });
        self.len += 1;
        self.peak_depth = self.peak_depth.max(self.len);
        Ok(id)
    }

    /// Pop the oldest queued request of `tenant`, if any.
    pub fn pop_for(&mut self, tenant: &str) -> Option<Request> {
        let lane = self.tenants.get_mut(tenant)?;
        let req = lane.pop_front();
        if req.is_some() {
            self.len -= 1;
        }
        if lane.is_empty() {
            self.tenants.remove(tenant);
        }
        req
    }

    /// Tenants with at least one queued request, in deterministic order.
    pub fn waiting_tenants(&self) -> Vec<String> {
        self.tenants.keys().cloned().collect()
    }

    /// Queued requests of one tenant.
    pub fn depth_of(&self, tenant: &str) -> usize {
        self.tenants.get(tenant).map_or(0, VecDeque::len)
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Highest queue depth ever observed (for the serve summary).
    pub fn peak_depth(&self) -> usize {
        self.peak_depth
    }

    /// Cumulative admitted submits per tenant since queue creation.
    pub fn admits(&self) -> &BTreeMap<String, u64> {
        &self.admits
    }

    /// Cumulative Busy rejections per tenant since queue creation.
    pub fn rejects(&self) -> &BTreeMap<String, u64> {
        &self.rejects
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ppr(seed: usize) -> Query {
        Query::Pagerank {
            seed_node: seed,
            damping: 0.85,
        }
    }

    #[test]
    fn submit_assigns_ids_and_pops_fifo_per_tenant() {
        let mut q = AdmissionQueue::new(8);
        let a1 = q.submit(16, "a", ppr(0), 1e-6, 50).unwrap();
        let b1 = q.submit(16, "b", ppr(1), 1e-6, 50).unwrap();
        let a2 = q.submit(16, "a", ppr(2), 1e-6, 50).unwrap();
        assert_eq!(q.len(), 3);
        assert_eq!(q.waiting_tenants(), vec!["a".to_string(), "b".to_string()]);
        assert_eq!(q.depth_of("a"), 2);
        assert_eq!(q.pop_for("a").unwrap().id, a1);
        assert_eq!(q.pop_for("a").unwrap().id, a2);
        assert!(q.pop_for("a").is_none());
        assert_eq!(q.pop_for("b").unwrap().id, b1);
        assert!(q.is_empty());
        assert_eq!(q.peak_depth(), 3);
    }

    #[test]
    fn full_queue_rejects_with_typed_busy() {
        let mut q = AdmissionQueue::new(2);
        q.submit(16, "a", ppr(0), 1e-6, 50).unwrap();
        q.submit(16, "b", ppr(1), 1e-6, 50).unwrap();
        let err = q.submit(16, "c", ppr(2), 1e-6, 50).unwrap_err();
        assert!(
            matches!(err, Error::Busy(_)),
            "expected Error::Busy, got {err:?}"
        );
        // draining one slot re-opens admission
        q.pop_for("a").unwrap();
        q.submit(16, "c", ppr(2), 1e-6, 50).unwrap();
        // admission accounting: the Busy reject is attributed to "c",
        // the successful retry counted as its admit
        assert_eq!(q.rejects().get("c"), Some(&1));
        assert_eq!(q.admits().get("c"), Some(&1));
        assert_eq!(q.admits().get("a"), Some(&1));
        assert_eq!(q.rejects().get("a"), None);
    }

    #[test]
    fn malformed_queries_are_rejected_before_queuing() {
        let mut q = AdmissionQueue::new(4);
        assert!(q.submit(16, "a", ppr(99), 1e-6, 50).is_err());
        assert!(q.submit(16, "a", ppr(0), 1e-6, 0).is_err());
        assert!(q.is_empty(), "rejected submits must not occupy slots");
        // malformed submits are neither admits nor Busy rejects
        assert!(q.admits().is_empty());
        assert!(q.rejects().is_empty());
    }
}
