//! Deficit-round-robin tenant fairness.
//!
//! Every batching boundary, the scheduler walks the waiting tenants in
//! rounds; each round credits every tenant `quantum` deficit and lets it
//! dequeue requests (cost 1 each) while its deficit lasts. A tenant that
//! floods the queue therefore cannot starve the others: per round it is
//! limited to `quantum` picks, exactly like everyone else, so a tenant
//! with `k` queued requests waits at most `⌈k/quantum⌉` rounds of
//! `T·quantum` picks regardless of how deep any other tenant's backlog
//! is. Deficit of a tenant with nothing queued is dropped (idle tenants
//! don't bank credit).

use std::collections::BTreeMap;

use super::queue::AdmissionQueue;
use super::request::Request;

/// Deficit-round-robin picker over the admission queue's tenants.
#[derive(Debug)]
pub struct DrrScheduler {
    quantum: u64,
    deficits: BTreeMap<String, u64>,
}

impl DrrScheduler {
    pub fn new(quantum: u64) -> DrrScheduler {
        assert!(quantum > 0, "DRR quantum must be at least 1");
        DrrScheduler {
            quantum,
            deficits: BTreeMap::new(),
        }
    }

    /// Pick up to `slots` requests from the queue, fairly across tenants.
    pub fn pick(&mut self, queue: &mut AdmissionQueue, slots: usize) -> Vec<Request> {
        let mut picked = Vec::new();
        // idle tenants lose their banked deficit: credit only counts
        // while a tenant actually has work waiting
        let waiting = queue.waiting_tenants();
        self.deficits.retain(|t, _| waiting.contains(t));
        while picked.len() < slots && !queue.is_empty() {
            let round: Vec<String> = queue.waiting_tenants();
            for tenant in round {
                let deficit = self.deficits.entry(tenant.clone()).or_insert(0);
                *deficit += self.quantum;
                while *deficit >= 1 && picked.len() < slots {
                    match queue.pop_for(&tenant) {
                        Some(req) => {
                            *deficit -= 1;
                            picked.push(req);
                        }
                        None => {
                            // drained: drop the leftover credit
                            *deficit = 0;
                            break;
                        }
                    }
                }
                if queue.depth_of(&tenant) == 0 {
                    self.deficits.remove(&tenant);
                }
                if picked.len() >= slots {
                    break;
                }
            }
        }
        picked
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::request::Query;

    fn ppr(seed: usize) -> Query {
        Query::Pagerank {
            seed_node: seed,
            damping: 0.85,
        }
    }

    fn count_tenant(picked: &[Request], tenant: &str) -> usize {
        picked.iter().filter(|r| r.tenant == tenant).count()
    }

    #[test]
    fn saturating_tenant_cannot_starve_the_other() {
        let mut q = AdmissionQueue::new(256);
        // tenant "flood" saturates the queue; "light" has 4 requests
        for i in 0..100 {
            q.submit(128, "flood", ppr(i % 128), 1e-6, 50).unwrap();
        }
        for i in 0..4 {
            q.submit(128, "light", ppr(i), 1e-6, 50).unwrap();
        }
        let mut drr = DrrScheduler::new(1);
        // with quantum 1 each round gives both tenants one pick, so a
        // width-4 batch splits 2/2: the light tenant's 4 requests are
        // fully served within 2 batches regardless of the flood's depth
        for batch in 0..2 {
            let picked = drr.pick(&mut q, 4);
            assert_eq!(picked.len(), 4);
            assert_eq!(
                count_tenant(&picked, "light"),
                2,
                "batch {batch} shorted the light tenant: {picked:?}"
            );
        }
        assert_eq!(q.depth_of("light"), 0, "light tenant drained in 2 batches");
        // once light is drained, the flood gets the full width
        let picked = drr.pick(&mut q, 4);
        assert_eq!(count_tenant(&picked, "flood"), 4);
    }

    #[test]
    fn idle_tenants_do_not_bank_deficit() {
        let mut q = AdmissionQueue::new(64);
        for i in 0..20 {
            q.submit(128, "a", ppr(i % 128), 1e-6, 50).unwrap();
        }
        q.submit(128, "b", ppr(0), 1e-6, 50).unwrap();
        let mut drr = DrrScheduler::new(1);
        // b drains in the first pick…
        let first = drr.pick(&mut q, 2);
        assert_eq!(count_tenant(&first, "b"), 1);
        // …then sits idle for several picks while a keeps its backlog
        drr.pick(&mut q, 2);
        drr.pick(&mut q, 2);
        // b re-submits a burst: it gets the fair half of the next batch,
        // not a bonus from deficit banked while idle
        for i in 0..10 {
            q.submit(128, "b", ppr(i), 1e-6, 50).unwrap();
        }
        let picked = drr.pick(&mut q, 4);
        assert_eq!(
            count_tenant(&picked, "b"),
            2,
            "returning tenant gets the fair half, not banked credit: {picked:?}"
        );
        assert_eq!(count_tenant(&picked, "a"), 2);
    }

    #[test]
    fn pick_respects_slots_and_empties() {
        let mut q = AdmissionQueue::new(8);
        q.submit(16, "a", ppr(0), 1e-6, 50).unwrap();
        let mut drr = DrrScheduler::new(4);
        assert_eq!(drr.pick(&mut q, 8).len(), 1);
        assert!(drr.pick(&mut q, 8).is_empty());
        assert_eq!(drr.pick(&mut q, 0).len(), 0);
    }
}
