//! Per-tenant SLO tracking for the serve plane.
//!
//! The [`SloTracker`] rides inside a [`crate::serve::ServeSession`]:
//! every retired response is recorded against its tenant's rolling
//! latency histogram ([`crate::metrics::RollingHistogram`]), every
//! Busy-reject is counted at admission, and once per step the session
//! calls [`SloTracker::tick`], which
//!
//! 1. recomputes each tenant's rolling p50/p99 latency, rows/s, queue
//!    depth, in-flight width, and Busy-reject rate,
//! 2. evaluates the configured burn thresholds ([`SloThresholds`]),
//! 3. reports healthy→burning transitions as [`SloBurn`]s — the
//!    session journals each one as an `EventKind::SloBurn` event and
//!    bumps the `usec_slo_burns_total` counter — and
//! 4. returns the per-tenant snapshot the telemetry plane publishes as
//!    `usec_tenant_*` / `usec_slo_healthy` series.
//!
//! Thresholds default to disabled (0), so a session without SLO flags
//! tracks stats but never burns — and with no telemetry attached the
//! whole tracker is invisible: no journal events, no wire or JSON
//! changes.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::metrics::RollingHistogram;
use crate::obs::telemetry::TenantStats;

/// Ring positions per SLO window (decay granularity = window / slots).
const WINDOW_SLOTS: usize = 10;

/// Burn thresholds; `0` disables a threshold.
#[derive(Debug, Clone, Copy)]
pub struct SloThresholds {
    /// Burn when the rolling p99 submit→answer latency exceeds this
    /// many milliseconds.
    pub latency_p99_ms: f64,
    /// Burn when `rejects / (admits + rejects)` exceeds this fraction.
    pub reject_rate: f64,
    /// Evaluate a threshold only once this many samples back it
    /// (answers in the window for latency, submits for reject rate).
    pub min_requests: u64,
}

impl Default for SloThresholds {
    fn default() -> Self {
        SloThresholds {
            latency_p99_ms: 0.0,
            reject_rate: 0.0,
            min_requests: 1,
        }
    }
}

impl SloThresholds {
    pub fn enabled(&self) -> bool {
        self.latency_p99_ms > 0.0 || self.reject_rate > 0.0
    }
}

/// One healthy→burning transition, ready to journal.
#[derive(Debug, Clone, PartialEq)]
pub struct SloBurn {
    pub tenant: String,
    /// Which threshold fired: `latency_p99` or `reject_rate`.
    pub slo: &'static str,
    pub value: f64,
    pub threshold: f64,
}

impl SloBurn {
    pub fn note(&self) -> String {
        format!(
            "{}: {} {:.3} > {:.3}",
            self.tenant, self.slo, self.value, self.threshold
        )
    }
}

#[derive(Debug)]
struct TenantTrack {
    latency: RollingHistogram,
    answered: u64,
    rows: u64,
    first_answer: Option<Instant>,
    healthy: bool,
    burns: u64,
}

impl TenantTrack {
    fn new(window: Duration) -> TenantTrack {
        TenantTrack {
            latency: RollingHistogram::new(window, WINDOW_SLOTS),
            answered: 0,
            rows: 0,
            first_answer: None,
            healthy: true,
            burns: 0,
        }
    }
}

/// Rolling per-tenant SLO state (owned by the serve session).
#[derive(Debug)]
pub struct SloTracker {
    thresholds: SloThresholds,
    window: Duration,
    tenants: BTreeMap<String, TenantTrack>,
}

impl SloTracker {
    pub fn new(thresholds: SloThresholds, window: Duration) -> SloTracker {
        SloTracker {
            thresholds,
            window,
            tenants: BTreeMap::new(),
        }
    }

    pub fn thresholds(&self) -> &SloThresholds {
        &self.thresholds
    }

    fn track(&mut self, tenant: &str) -> &mut TenantTrack {
        let window = self.window;
        self.tenants
            .entry(tenant.to_string())
            .or_insert_with(|| TenantTrack::new(window))
    }

    /// Record one retired response (rows = matrix rows the request's
    /// column contributed over its lifetime).
    pub fn record_response(&mut self, now: Instant, tenant: &str, latency_ns: u64, rows: u64) {
        let t = self.track(tenant);
        t.latency.push_at(now, latency_ns as f64);
        t.answered += 1;
        t.rows += rows;
        t.first_answer.get_or_insert(now);
    }

    /// Re-evaluate every tenant and build the telemetry snapshot.
    /// `admits`/`rejects` are cumulative per-tenant submit outcomes
    /// (from the admission queue); `queued`/`inflight` are current
    /// depths. Returns the snapshot plus any healthy→burning
    /// transitions since the previous tick.
    pub fn tick(
        &mut self,
        now: Instant,
        admits: &BTreeMap<String, u64>,
        rejects: &BTreeMap<String, u64>,
        queued: &BTreeMap<String, u64>,
        inflight: &BTreeMap<String, u64>,
    ) -> (BTreeMap<String, TenantStats>, Vec<SloBurn>) {
        // a tenant rejected before its first answer still needs a row
        for tenant in admits.keys().chain(rejects.keys()) {
            self.track(tenant);
        }

        let th = self.thresholds;
        let mut snapshot = BTreeMap::new();
        let mut burns = Vec::new();
        for (tenant, t) in &mut self.tenants {
            let p50 = t.latency.quantile_at(now, 0.5);
            let p99 = t.latency.quantile_at(now, 0.99);
            let in_window = t.latency.count_at(now);
            let rej = rejects.get(tenant).copied().unwrap_or(0);
            let adm = admits.get(tenant).copied().unwrap_or(0);
            let submits = adm + rej;

            let mut burn: Option<SloBurn> = None;
            if th.latency_p99_ms > 0.0 && in_window >= th.min_requests {
                let p99_ms = p99 / 1e6;
                if p99_ms > th.latency_p99_ms {
                    burn = Some(SloBurn {
                        tenant: tenant.clone(),
                        slo: "latency_p99",
                        value: p99_ms,
                        threshold: th.latency_p99_ms,
                    });
                }
            }
            if burn.is_none() && th.reject_rate > 0.0 && submits >= th.min_requests {
                let rate = rej as f64 / submits as f64;
                if rate > th.reject_rate {
                    burn = Some(SloBurn {
                        tenant: tenant.clone(),
                        slo: "reject_rate",
                        value: rate,
                        threshold: th.reject_rate,
                    });
                }
            }

            let burning = burn.is_some();
            if burning && t.healthy {
                t.burns += 1;
                burns.push(burn.unwrap());
            }
            t.healthy = !burning;

            let rows_per_s = match t.first_answer {
                Some(first) if t.rows > 0 => {
                    let dt = now.saturating_duration_since(first).as_secs_f64();
                    if dt > 0.0 {
                        t.rows as f64 / dt
                    } else {
                        0.0
                    }
                }
                _ => 0.0,
            };

            snapshot.insert(
                tenant.clone(),
                TenantStats {
                    requests: t.answered,
                    rejects: rej,
                    inflight: inflight.get(tenant).copied().unwrap_or(0),
                    queued: queued.get(tenant).copied().unwrap_or(0),
                    rows: t.rows,
                    latency_p50_ns: p50,
                    latency_p99_ns: p99,
                    rows_per_s,
                    healthy: t.healthy,
                    burns: t.burns,
                },
            );
        }
        (snapshot, burns)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn maps(
        pairs: &[(&str, u64)],
    ) -> BTreeMap<String, u64> {
        pairs
            .iter()
            .map(|(k, v)| (k.to_string(), *v))
            .collect()
    }

    #[test]
    fn disabled_thresholds_never_burn() {
        let mut tr = SloTracker::new(SloThresholds::default(), Duration::from_secs(10));
        let now = Instant::now();
        tr.record_response(now, "alice", 500_000_000, 100); // 500ms
        let (snap, burns) = tr.tick(
            now,
            &maps(&[("alice", 1)]),
            &maps(&[("alice", 9)]),
            &maps(&[]),
            &maps(&[]),
        );
        assert!(burns.is_empty());
        let a = &snap["alice"];
        assert!(a.healthy);
        assert_eq!(a.requests, 1);
        assert_eq!(a.rejects, 9);
        assert!(a.latency_p50_ns > 4e8);
    }

    #[test]
    fn latency_burn_fires_once_per_transition_and_recovers() {
        let th = SloThresholds {
            latency_p99_ms: 10.0,
            ..Default::default()
        };
        let mut tr = SloTracker::new(th, Duration::from_millis(500));
        let now = Instant::now();
        tr.record_response(now, "alice", 50_000_000, 10); // 50ms > 10ms
        let empty = maps(&[]);
        let (snap, burns) = tr.tick(now, &empty, &empty, &empty, &empty);
        assert_eq!(burns.len(), 1);
        assert_eq!(burns[0].slo, "latency_p99");
        assert!(!snap["alice"].healthy);
        assert_eq!(snap["alice"].burns, 1);

        // still burning: no new transition
        let (_, burns) = tr.tick(now, &empty, &empty, &empty, &empty);
        assert!(burns.is_empty());

        // window slides past the slow sample: healthy again
        let later = now + Duration::from_secs(2);
        let (snap, burns) = tr.tick(later, &empty, &empty, &empty, &empty);
        assert!(burns.is_empty());
        assert!(snap["alice"].healthy, "recovered once the window drained");
        assert_eq!(snap["alice"].burns, 1, "burn count is cumulative");
    }

    #[test]
    fn reject_rate_burn_counts_busy_rejects() {
        let th = SloThresholds {
            reject_rate: 0.5,
            min_requests: 4,
            ..Default::default()
        };
        let mut tr = SloTracker::new(th, Duration::from_secs(10));
        let now = Instant::now();
        // 1 admit, 2 rejects → below min_requests: no burn yet
        let (snap, burns) = tr.tick(
            now,
            &maps(&[("bob", 1)]),
            &maps(&[("bob", 2)]),
            &maps(&[]),
            &maps(&[]),
        );
        assert!(burns.is_empty());
        assert!(snap["bob"].healthy);
        // 1 admit, 3 rejects → rate 0.75 > 0.5 with 4 submits
        let (snap, burns) = tr.tick(
            now,
            &maps(&[("bob", 1)]),
            &maps(&[("bob", 3)]),
            &maps(&[]),
            &maps(&[]),
        );
        assert_eq!(burns.len(), 1);
        assert_eq!(burns[0].slo, "reject_rate");
        assert!(burns[0].note().contains("reject_rate"));
        assert!(!snap["bob"].healthy);
    }

    #[test]
    fn snapshot_carries_depths_and_rates() {
        let mut tr = SloTracker::new(SloThresholds::default(), Duration::from_secs(10));
        let t0 = Instant::now();
        tr.record_response(t0, "alice", 1_000_000, 480);
        let later = t0 + Duration::from_secs(2);
        tr.record_response(later, "alice", 2_000_000, 480);
        let (snap, _) = tr.tick(
            later,
            &maps(&[("alice", 2)]),
            &maps(&[]),
            &maps(&[("alice", 3)]),
            &maps(&[("alice", 2)]),
        );
        let a = &snap["alice"];
        assert_eq!(a.queued, 3);
        assert_eq!(a.inflight, 2);
        assert_eq!(a.rows, 960);
        // 960 rows over 2s
        assert!((a.rows_per_s - 480.0).abs() < 1.0, "rows/s {}", a.rows_per_s);
    }
}
