//! The resident serving session: engine + queue + batcher glued into a
//! step loop.
//!
//! A [`ServeSession`] owns a [`ClusterEngine`] over the serve matrix
//! `A = Mᵀ` (`M` row-stochastic, seeded from the run config) and drives
//! it one elastic step at a time. Each [`ServeSession::step_once`]:
//!
//! 1. at the step boundary, lets the DRR scheduler pick waiting
//!    requests into the batch's free columns,
//! 2. runs one distributed `Y = A·W` over the coalesced block via the
//!    engine's step primitives
//!    ([`ClusterEngine::begin_block_step`] /
//!    [`ClusterEngine::complete_block_step`]), so preemption, recovery,
//!    rebalancing and chaos all keep working under the request plane,
//! 3. folds `Y` back into the columns and retires the converged ones,
//!    returning their [`Response`]s.
//!
//! [`ServeSession::finish`] attaches the request-plane totals
//! ([`ServeSummary`]) to the engine's [`Timeline`] and drains the
//! cluster.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::types::RunConfig;
use crate::engine::ClusterEngine;
use crate::error::{Error, Result};
use crate::linalg::{gen, Matrix};
use crate::metrics::{stats, ServeSummary, Timeline};
use crate::obs::{Event, EventKind, Telemetry};

use super::batcher::ContinuousBatcher;
use super::fairness::DrrScheduler;
use super::queue::AdmissionQueue;
use super::request::{Query, Response};
use super::slo::{SloThresholds, SloTracker};

/// Request-plane knobs of a serving session.
#[derive(Debug, Clone)]
pub struct SessionOpts {
    /// Admission queue capacity (submits beyond it get [`Error::Busy`]).
    pub queue_cap: usize,
    /// DRR quantum: requests a tenant may take per scheduling round.
    pub quantum: u64,
    /// Maximum batch width `B` (columns coalesced per step).
    pub max_width: usize,
    /// Per-tenant SLO burn thresholds (`0` disables a threshold).
    pub slo: SloThresholds,
    /// Rolling window the SLO quantiles and burn checks look over.
    pub slo_window: Duration,
}

impl Default for SessionOpts {
    fn default() -> Self {
        SessionOpts {
            queue_cap: 64,
            quantum: 1,
            max_width: 8,
            slo: SloThresholds::default(),
            slo_window: Duration::from_secs(10),
        }
    }
}

/// A resident cluster serving multi-tenant requests.
pub struct ServeSession {
    engine: ClusterEngine,
    queue: Arc<Mutex<AdmissionQueue>>,
    drr: DrrScheduler,
    batcher: ContinuousBatcher,
    q: usize,
    step: usize,
    latencies_ns: Vec<f64>,
    requests_done: u64,
    rows_done: u64,
    /// First served step (rows/s clock starts here).
    started: Option<Instant>,
    slo: SloTracker,
    telemetry: Option<Arc<Telemetry>>,
}

/// Transpose a dense matrix (setup-time only).
fn transpose(m: &Matrix) -> Matrix {
    let mut t = Matrix::zeros(m.cols(), m.rows());
    for r in 0..m.rows() {
        for c in 0..m.cols() {
            t.set(c, r, m.at(r, c));
        }
    }
    t
}

/// The session's serve matrix: `A = Mᵀ` for the seeded row-stochastic
/// link matrix `M` — column-stochastic, so personalized PageRank is a
/// plain iterate update and mat-vec/ridge queries stay well-conditioned.
pub fn serve_matrix(q: usize, seed: u64) -> Matrix {
    transpose(&gen::random_stochastic(q, seed))
}

impl ServeSession {
    /// Build the resident cluster. Distributed sessions (TCP workers)
    /// must set `cfg.stream_data`: the serve matrix has no per-row
    /// generator the daemons could regenerate it from.
    pub fn build(cfg: &RunConfig, opts: &SessionOpts) -> Result<ServeSession> {
        if cfg.q != cfg.r {
            return Err(Error::Config("serving needs a square matrix".into()));
        }
        if cfg.is_distributed() && !cfg.stream_data {
            return Err(Error::Config(
                "distributed serving requires --stream-data (the serve matrix \
                 has no generator the worker daemons could rebuild it from)"
                    .into(),
            ));
        }
        if opts.max_width == 0 || opts.max_width > crate::net::codec::MAX_NVEC {
            return Err(Error::Config(format!(
                "batch width {} not in [1, {}]",
                opts.max_width,
                crate::net::codec::MAX_NVEC
            )));
        }
        let matrix = Arc::new(serve_matrix(cfg.q, cfg.seed));
        let engine = ClusterEngine::build(cfg, matrix)?;
        Ok(ServeSession {
            engine,
            queue: Arc::new(Mutex::new(AdmissionQueue::new(opts.queue_cap))),
            drr: DrrScheduler::new(opts.quantum),
            batcher: ContinuousBatcher::new(cfg.q, opts.max_width),
            q: cfg.q,
            step: 0,
            latencies_ns: Vec::new(),
            requests_done: 0,
            rows_done: 0,
            started: None,
            slo: SloTracker::new(opts.slo, opts.slo_window),
            telemetry: None,
        })
    }

    /// Shared handle on the admission queue (for server threads).
    pub fn queue_handle(&self) -> Arc<Mutex<AdmissionQueue>> {
        Arc::clone(&self.queue)
    }

    /// The resident engine (state machine, timeline, transport).
    pub fn engine(&self) -> &ClusterEngine {
        &self.engine
    }

    /// Mutable engine access (tests inject faults through this).
    pub fn engine_mut(&mut self) -> &mut ClusterEngine {
        &mut self.engine
    }

    /// Attach (or detach) the live telemetry plane. The handle is
    /// forwarded to the engine (state/readiness/worker gauges) and the
    /// session starts publishing its per-tenant SLO snapshot, queue
    /// depth, and batch width at every step boundary. With no telemetry
    /// attached, SLO tracking is fully dormant: no journal events, no
    /// extra work in the step loop.
    pub fn set_telemetry(&mut self, tel: Option<Arc<Telemetry>>) {
        self.engine.set_telemetry(tel.clone());
        self.telemetry = tel;
        self.tick_slo();
    }

    /// The attached telemetry handle, if any.
    pub fn telemetry(&self) -> Option<&Arc<Telemetry>> {
        self.telemetry.as_ref()
    }

    /// Re-evaluate per-tenant SLOs and publish the serve-plane gauges.
    /// Burn transitions are journaled as `slo_burn` events when the
    /// engine has a recorder. No-op without telemetry.
    fn tick_slo(&mut self) {
        if self.telemetry.is_none() {
            return;
        }
        let now = Instant::now();
        let (admits, rejects, queued, depth) = {
            let q = self.queue.lock().unwrap();
            let queued: std::collections::BTreeMap<String, u64> = q
                .waiting_tenants()
                .into_iter()
                .map(|t| {
                    let d = q.depth_of(&t) as u64;
                    (t, d)
                })
                .collect();
            (q.admits().clone(), q.rejects().clone(), queued, q.len())
        };
        let inflight = self.batcher.tenant_widths();
        let (snapshot, burns) = self.slo.tick(now, &admits, &rejects, &queued, &inflight);
        if !burns.is_empty() {
            if let Some(rec) = self.engine.recorder_handle() {
                for b in &burns {
                    rec.emit(
                        Event::new(EventKind::SloBurn, self.step, rec.now_ns())
                            .note(b.note()),
                    );
                }
            }
        }
        let t = self.telemetry.as_ref().expect("gated above");
        t.slo_burns.add(burns.len() as u64);
        t.queue_depth.set(depth as f64);
        t.batch_width.set(self.batcher.width() as f64);
        t.set_tenants(snapshot);
    }

    /// Submit a request into the admission queue.
    pub fn submit(
        &self,
        tenant: &str,
        query: Query,
        tol: f64,
        max_steps: usize,
    ) -> Result<u64> {
        self.queue
            .lock()
            .unwrap()
            .submit(self.q, tenant, query, tol, max_steps)
    }

    /// Work is waiting (queued or riding the batch).
    pub fn pending(&self) -> bool {
        !self.batcher.is_empty() || !self.queue.lock().unwrap().is_empty()
    }

    /// Run one elastic step of the coalesced batch; returns the requests
    /// that retired this step. A no-op returning no responses when
    /// nothing is queued or active.
    pub fn step_once(&mut self) -> Result<Vec<Response>> {
        let room = self.batcher.room();
        if room > 0 {
            let picked = {
                let mut q = self.queue.lock().unwrap();
                self.drr.pick(&mut q, room)
            };
            for r in picked {
                self.batcher.admit(r);
            }
        }
        if self.batcher.is_empty() {
            self.tick_slo();
            return Ok(Vec::new());
        }
        if self.started.is_none() {
            self.started = Some(Instant::now());
        }
        let w = Arc::new(self.batcher.block()?);
        let width = w.nvec();
        let step = self.step;
        self.step += 1;
        let (y, tail) = match self.engine.begin_block_step(step, &w, f64::NAN)? {
            Some(pair) => pair,
            // infeasible (too few workers): a skip record was pushed;
            // the batch stays seated and retries at the next boundary
            None => {
                self.tick_slo();
                return Ok(Vec::new());
            }
        };
        let (responses, worst) = self.batcher.apply(&y);
        // the timeline metric is the worst still-active residual; the
        // checkpoint iterate is the surviving columns' next block
        let next = if self.batcher.is_empty() {
            y
        } else {
            self.batcher.block()?
        };
        self.engine.complete_block_step(tail, &next, worst)?;
        self.rows_done += (self.q * width) as u64;
        let now = Instant::now();
        for r in &responses {
            self.latencies_ns.push(r.latency_ns as f64);
            if self.telemetry.is_some() {
                self.slo
                    .record_response(now, &r.tenant, r.latency_ns, (r.steps * self.q) as u64);
            }
        }
        self.requests_done += responses.len() as u64;
        self.tick_slo();
        Ok(responses)
    }

    /// Step until queue and batch are empty (at most `step_cap` steps).
    pub fn run_until_drained(&mut self, step_cap: usize) -> Result<Vec<Response>> {
        let mut out = Vec::new();
        let mut used = 0;
        while self.pending() {
            if used >= step_cap {
                return Err(Error::Cluster(format!(
                    "serve drain exceeded {step_cap} steps with {} request(s) \
                     still in flight",
                    self.batcher.width()
                )));
            }
            out.extend(self.step_once()?);
            used += 1;
        }
        Ok(out)
    }

    /// Request-plane totals so far.
    pub fn summary(&self) -> ServeSummary {
        let (p50, p99) = if self.latencies_ns.is_empty() {
            (f64::NAN, f64::NAN)
        } else {
            (
                stats::quantile(&self.latencies_ns, 0.5),
                stats::quantile(&self.latencies_ns, 0.99),
            )
        };
        let rows_per_s = match self.started {
            Some(t) => {
                let s = t.elapsed().as_secs_f64();
                if s > 0.0 {
                    self.rows_done as f64 / s
                } else {
                    f64::NAN
                }
            }
            None => f64::NAN,
        };
        ServeSummary {
            requests: self.requests_done,
            latency_p50_ns: p50,
            latency_p99_ns: p99,
            queue_depth: self.queue.lock().unwrap().peak_depth() as u64,
            rows_per_s,
        }
    }

    /// Attach the serve summary to the timeline, drain the cluster, and
    /// hand the timeline back.
    pub fn finish(mut self) -> Result<Timeline> {
        let summary = self.summary();
        self.engine.timeline.set_serve(summary);
        let tl = std::mem::take(&mut self.engine.timeline);
        self.engine.drain()?;
        Ok(tl)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::Rng;

    fn cfg(q: usize) -> RunConfig {
        RunConfig {
            q,
            r: q,
            g: 3,
            j: 2,
            n: 3,
            steps: 1,
            speeds: vec![1.0, 2.0, 3.0],
            seed: 17,
            ..Default::default()
        }
    }

    /// Dense single-request oracle: iterate the query's update rule with
    /// plain `Matrix::matvec` until its own tol/step budget retires it.
    fn oracle(a: &Matrix, query: &Query, tol: f64, max_steps: usize) -> Vec<f32> {
        let q = a.rows();
        match query {
            Query::Pagerank { seed_node, damping } => {
                let mut p = vec![0.0f32; q];
                p[*seed_node] = 1.0;
                for _ in 0..max_steps {
                    let y = a.matvec(&p).unwrap();
                    let d32 = *damping as f32;
                    let teleport = (1.0 - damping) as f32;
                    let mut delta = 0.0f64;
                    for i in 0..q {
                        let mut v = d32 * y[i];
                        if i == *seed_node {
                            v += teleport;
                        }
                        delta += (v as f64 - p[i] as f64).abs();
                        p[i] = v;
                    }
                    if delta <= tol {
                        break;
                    }
                }
                p
            }
            Query::Matvec { v } => a.matvec(v).unwrap(),
            Query::Ridge { b, lambda, eta } => {
                let b_norm = crate::linalg::ops::norm2(b);
                let mut w = vec![0.0f32; q];
                for _ in 0..max_steps {
                    let y = a.matvec(&w).unwrap();
                    let mut res_sq = 0.0f64;
                    for i in 0..q {
                        let r = b[i] as f64 - y[i] as f64 - lambda * w[i] as f64;
                        res_sq += r * r;
                        w[i] = (w[i] as f64 + eta * r) as f32;
                    }
                    if res_sq.sqrt() / b_norm <= tol {
                        break;
                    }
                }
                w
            }
        }
    }

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(&x, &y)| (x as f64 - y as f64).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn batched_answers_match_the_dedicated_oracle() {
        let c = cfg(48);
        let a = serve_matrix(48, c.seed);
        let mut s = ServeSession::build(&c, &SessionOpts::default()).unwrap();
        let queries = [
            (
                "alice",
                Query::Pagerank {
                    seed_node: 3,
                    damping: 0.85,
                },
                1e-9,
                200,
            ),
            (
                "bob",
                Query::Matvec {
                    v: (0..48).map(|i| (i as f32).sin()).collect(),
                },
                1e-6,
                1,
            ),
            (
                "bob",
                Query::Ridge {
                    b: (0..48).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect(),
                    lambda: 3.0,
                    eta: 0.13,
                },
                1e-7,
                300,
            ),
        ];
        let mut ids = Vec::new();
        for (tenant, query, tol, max_steps) in &queries {
            ids.push(s.submit(tenant, query.clone(), *tol, *max_steps).unwrap());
        }
        let responses = s.run_until_drained(2000).unwrap();
        assert_eq!(responses.len(), 3);
        for ((tenant, query, tol, max_steps), id) in queries.iter().zip(&ids) {
            let r = responses.iter().find(|r| r.id == *id).unwrap();
            assert_eq!(&r.tenant, tenant);
            let want = oracle(&a, query, *tol, *max_steps);
            let diff = max_abs_diff(&r.answer, &want);
            assert!(
                diff <= 1e-5,
                "{} query diverged from its dedicated oracle: {diff}",
                query.kind()
            );
            assert!(r.latency_ns > 0);
        }
        let summary = s.summary();
        assert_eq!(summary.requests, 3);
        assert!(summary.latency_p50_ns.is_finite());
        assert!(summary.latency_p99_ns >= summary.latency_p50_ns);
        assert!(summary.queue_depth >= 3);
        let tl = s.finish().unwrap();
        assert!(tl.serve().is_some());
        assert!(tl.len() > 0, "served steps land in the timeline");
    }

    #[test]
    fn idle_session_steps_are_noops() {
        let c = cfg(24);
        let mut s = ServeSession::build(&c, &SessionOpts::default()).unwrap();
        assert!(!s.pending());
        assert!(s.step_once().unwrap().is_empty());
        let summary = s.summary();
        assert_eq!(summary.requests, 0);
        assert!(summary.latency_p50_ns.is_nan());
        let tl = s.finish().unwrap();
        assert_eq!(tl.len(), 0);
    }

    #[test]
    fn telemetry_publishes_tenant_slo_series() {
        use crate::obs::Telemetry;
        let c = cfg(24);
        let mut s = ServeSession::build(
            &c,
            &SessionOpts {
                // any real latency exceeds a 1ns p99 budget → guaranteed burn
                slo: crate::serve::SloThresholds {
                    latency_p99_ms: 1e-6,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .unwrap();
        let tel = Arc::new(Telemetry::new(3, 2));
        s.set_telemetry(Some(Arc::clone(&tel)));
        s.submit(
            "alice",
            Query::Pagerank {
                seed_node: 0,
                damping: 0.85,
            },
            1e-7,
            100,
        )
        .unwrap();
        s.submit(
            "bob",
            Query::Matvec {
                v: vec![1.0; 24],
            },
            1e-6,
            1,
        )
        .unwrap();
        s.run_until_drained(500).unwrap();
        let tenants = tel.tenants();
        assert_eq!(
            tenants.keys().cloned().collect::<Vec<_>>(),
            vec!["alice".to_string(), "bob".to_string()]
        );
        let alice = &tenants["alice"];
        assert_eq!(alice.requests, 1);
        assert!(alice.latency_p50_ns > 0.0);
        assert!(!alice.healthy, "1ns p99 budget must be burning");
        assert!(tel.slo_burns.get() >= 2, "both tenants burned");
        assert!(!tel.slo_healthy());
        assert!(tel.slo_json().is_some());
        // gauges settle to the drained state
        assert_eq!(tel.queue_depth.get(), 0.0);
        assert_eq!(tel.batch_width.get(), 0.0);
        s.finish().unwrap();
    }

    #[test]
    fn build_rejects_distributed_without_streaming() {
        let mut c = cfg(24);
        c.workers = vec!["127.0.0.1:1".into(), "127.0.0.1:2".into(), "127.0.0.1:3".into()];
        let err = ServeSession::build(&c, &SessionOpts::default()).unwrap_err();
        assert!(err.to_string().contains("stream-data"), "{err}");
    }

    /// Satellite: continuous batching must never mix tenants' columns —
    /// whatever shares the block, every request's answer equals the one
    /// a dedicated single-request session produces.
    #[test]
    fn property_batching_never_mixes_tenant_columns() {
        prop::run(
            prop::Config::default().cases(6).name("batch-isolation"),
            |rng: &mut Rng| {
                let q = 24;
                let c = cfg(q);
                let n_reqs = rng.range(2, 6);
                let tenants = ["a", "b", "c"];
                let reqs: Vec<(String, Query)> = (0..n_reqs)
                    .map(|_| {
                        let tenant = tenants[rng.range(0, tenants.len())];
                        let query = match rng.range(0, 3) {
                            0 => Query::Pagerank {
                                seed_node: rng.range(0, q),
                                damping: 0.85,
                            },
                            1 => Query::Matvec {
                                v: (0..q).map(|_| rng.f64() as f32).collect(),
                            },
                            _ => Query::Ridge {
                                b: (0..q)
                                    .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                                    .collect(),
                                lambda: 3.0,
                                eta: 0.13,
                            },
                        };
                        (tenant.to_string(), query)
                    })
                    .collect();
                // one coalesced multi-tenant session…
                let mut batched = ServeSession::build(&c, &SessionOpts::default()).unwrap();
                let ids: Vec<u64> = reqs
                    .iter()
                    .map(|(t, qu)| batched.submit(t, qu.clone(), 1e-7, 120).unwrap())
                    .collect();
                let responses = batched.run_until_drained(2000).unwrap();
                assert_eq!(responses.len(), reqs.len());
                // …vs each request alone in its own dedicated session
                for ((tenant, query), id) in reqs.iter().zip(&ids) {
                    let got = responses.iter().find(|r| r.id == *id).unwrap();
                    assert_eq!(&got.tenant, tenant);
                    let mut solo = ServeSession::build(&c, &SessionOpts::default()).unwrap();
                    solo.submit(tenant, query.clone(), 1e-7, 120).unwrap();
                    let solo_resp = solo.run_until_drained(2000).unwrap();
                    assert_eq!(solo_resp.len(), 1);
                    let diff = max_abs_diff(&got.answer, &solo_resp[0].answer);
                    assert!(
                        diff <= 1e-5,
                        "{} answer changed when batched with other tenants: {diff}",
                        query.kind()
                    );
                    solo.finish().unwrap();
                }
                batched.finish().unwrap();
            },
        );
    }
}
