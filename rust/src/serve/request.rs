//! Tenant-tagged serving requests and their answers.
//!
//! A [`Request`] names a tenant, a [`Query`] over the resident serve
//! matrix `A` (`q×q`, column-stochastic), and its convergence contract
//! (`tol`, `max_steps`). Each query kind maps to one iterate column of
//! the continuous batch ([`super::ContinuousBatcher`]):
//!
//! * [`Query::Pagerank`] — personalized PageRank from one seed node:
//!   `p ← d·Ap + (1−d)·e_s`, L1 step delta as the residual.
//! * [`Query::Matvec`] — one raw mat-vec `y = Av`; answered after a
//!   single step with residual 0.
//! * [`Query::Ridge`] — Richardson iteration for `(A + λI)w = b`:
//!   `w ← w + η(b − Aw − λw)`, relative residual `‖r‖/‖b‖`.

use std::time::Instant;

use crate::error::{Error, Result};

/// What a request asks of the resident serve matrix.
#[derive(Debug, Clone, PartialEq)]
pub enum Query {
    /// Personalized PageRank from `seed_node` with damping `d`.
    Pagerank { seed_node: usize, damping: f64 },
    /// One mat-vec `y = A v`.
    Matvec { v: Vec<f32> },
    /// Richardson ridge solve of `(A + λI) w = b` with step size `eta`.
    Ridge { b: Vec<f32>, lambda: f64, eta: f64 },
}

impl Query {
    /// Short kind name for logs and the wire protocol.
    pub fn kind(&self) -> &'static str {
        match self {
            Query::Pagerank { .. } => "pagerank",
            Query::Matvec { .. } => "matvec",
            Query::Ridge { .. } => "ridge",
        }
    }

    /// Reject a query that cannot run against a `q×q` serve matrix.
    pub fn validate(&self, q: usize) -> Result<()> {
        match self {
            Query::Pagerank { seed_node, damping } => {
                if *seed_node >= q {
                    return Err(Error::Config(format!(
                        "seed node {seed_node} out of range (q = {q})"
                    )));
                }
                if !(0.0..1.0).contains(damping) {
                    return Err(Error::Config(format!("damping {damping} not in [0,1)")));
                }
            }
            Query::Matvec { v } => {
                if v.len() != q {
                    return Err(Error::Config(format!(
                        "matvec query of {} rows against a q = {q} matrix",
                        v.len()
                    )));
                }
            }
            Query::Ridge { b, lambda, eta } => {
                if b.len() != q {
                    return Err(Error::Config(format!(
                        "ridge right-hand side of {} rows against a q = {q} matrix",
                        b.len()
                    )));
                }
                if !lambda.is_finite() || !eta.is_finite() || *eta <= 0.0 {
                    return Err(Error::Config(format!(
                        "ridge needs finite λ and positive η (got λ={lambda}, η={eta})"
                    )));
                }
            }
        }
        Ok(())
    }
}

/// One admitted request, tenant-tagged and timestamped at submission.
#[derive(Debug, Clone)]
pub struct Request {
    /// Session-unique id, assigned by the admission queue.
    pub id: u64,
    pub tenant: String,
    pub query: Query,
    /// Residual below which the request's column retires.
    pub tol: f64,
    /// Hard cap on steps the column may ride the batch.
    pub max_steps: usize,
    /// When the queue admitted the request (latency starts here).
    pub submitted: Instant,
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tenant: String,
    /// The answer vector (ranks / `Av` / the ridge solution).
    pub answer: Vec<f32>,
    /// Residual at retirement (0 for matvec).
    pub residual: f64,
    /// Elastic steps the request's column rode the batch.
    pub steps: usize,
    /// Submit→answer latency in nanoseconds.
    pub latency_ns: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_rejects_bad_shapes() {
        assert!(Query::Pagerank {
            seed_node: 3,
            damping: 0.85
        }
        .validate(8)
        .is_ok());
        assert!(Query::Pagerank {
            seed_node: 8,
            damping: 0.85
        }
        .validate(8)
        .is_err());
        assert!(Query::Pagerank {
            seed_node: 0,
            damping: 1.0
        }
        .validate(8)
        .is_err());
        assert!(Query::Matvec { v: vec![0.0; 7] }.validate(8).is_err());
        assert!(Query::Ridge {
            b: vec![0.0; 8],
            lambda: 3.0,
            eta: 0.0
        }
        .validate(8)
        .is_err());
        assert!(Query::Ridge {
            b: vec![0.0; 8],
            lambda: 3.0,
            eta: 0.13
        }
        .validate(8)
        .is_ok());
    }

    #[test]
    fn kind_names_are_stable() {
        assert_eq!(
            Query::Pagerank {
                seed_node: 0,
                damping: 0.85
            }
            .kind(),
            "pagerank"
        );
        assert_eq!(Query::Matvec { v: vec![] }.kind(), "matvec");
        assert_eq!(
            Query::Ridge {
                b: vec![],
                lambda: 0.0,
                eta: 1.0
            }
            .kind(),
            "ridge"
        );
    }
}
