//! EXP-F3 — paper Fig. 3: straggler-tolerant assignment, homogeneous
//! speeds.
//!
//! N=N_t=6, J=3, S=1, repetition placement, homogeneous speeds. The paper
//! prints `μ* = [2,2,2,3,3]` and `c* = 3`; as DESIGN.md §5 notes, the
//! printed vector is inconsistent with its own constraints (sum must be
//! `G·(1+S) = 12` over 6 machines, and the exact optimum of (8) is
//! `μ* = [2,2,2,2,2,2]`, `c* = 2`). We report the true optimum plus the
//! full filling-algorithm assignment `{F_g, M_g, P_g}`, and verify the
//! S=1 recovery property exhaustively.

use crate::error::Result;
use crate::linalg::partition::submatrix_ranges;
use crate::optim::{build_assignment, solve_load_matrix, Assignment, Solution, SolveParams};
use crate::placement::{Placement, PlacementKind};

/// Fig. 3 configuration outputs.
#[derive(Debug)]
pub struct Fig3Result {
    pub solution: Solution,
    pub assignment: Assignment,
    /// Machine loads `μ[n]` of the optimum.
    pub machine_loads: Vec<f64>,
}

/// Rows used when materializing the row sets (paper's example is unitless;
/// 600 rows divide evenly into the F_g sets).
pub const ROWS_PER_SUB: usize = 600;

pub fn run() -> Result<Fig3Result> {
    let p = Placement::build(PlacementKind::Repetition, 6, 6, 3)?;
    let avail: Vec<usize> = (0..6).collect();
    let speeds = vec![1.0; 6];
    let params = SolveParams::with_stragglers(1);
    let solution = solve_load_matrix(&p, &avail, &speeds, &params)?;
    let sub_rows = submatrix_ranges(6 * ROWS_PER_SUB, 6)?
        .iter()
        .map(|r| r.len())
        .collect::<Vec<_>>();
    let assignment = build_assignment(&p, &avail, &speeds, &params, &sub_rows)?;
    let machine_loads = solution.load.machine_loads();
    Ok(Fig3Result {
        solution,
        assignment,
        machine_loads,
    })
}

/// Render the Fig. 3 report.
pub fn report() -> Result<String> {
    let r = run()?;
    let mut out = String::new();
    out.push_str("EXP-F3 (paper Fig. 3): N=6, J=3, S=1, repetition, homogeneous speeds\n\n");
    out.push_str(&format!(
        "optimal c* = {:.4}  (paper prints 3 — see DESIGN.md §5 on the inconsistency;\n\
         the exact optimum of (8) for this configuration is 2)\n",
        r.solution.time
    ));
    out.push_str(&format!(
        "optimal machine loads μ* = {:?} (paper prints [2,2,2,3,3])\n\n",
        r.machine_loads
    ));
    out.push_str("μ*[g,n]:\n");
    out.push_str(&crate::util::fmt::render_load_matrix(
        &r.solution.load.to_rows(),
        "X",
        "m",
    ));
    out.push_str("\nfilling-algorithm assignment (row sets × machines, per sub-matrix):\n");
    for sub in &r.assignment.subs {
        out.push_str(&format!("X_{}: ", sub.g + 1));
        for ((a, p), rows) in sub
            .alphas
            .iter()
            .zip(&sub.psets)
            .zip(&sub.row_sets)
        {
            let ms: Vec<String> = p.iter().map(|m| format!("m{}", m + 1)).collect();
            out.push_str(&format!(
                "[α={:.3} rows {}..{} → {}] ",
                a,
                rows.lo,
                rows.hi,
                ms.join("+")
            ));
        }
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_optimum_is_two() {
        let r = run().unwrap();
        assert!(
            (r.solution.time - 2.0).abs() < 1e-8,
            "c* = {} (exact optimum for this config)",
            r.solution.time
        );
        // all machines equally loaded at 2 sub-matrix units
        for (n, &l) in r.machine_loads.iter().enumerate() {
            assert!((l - 2.0).abs() < 1e-7, "machine {n} load {l}");
        }
    }

    #[test]
    fn every_row_set_has_two_distinct_machines() {
        let r = run().unwrap();
        let sub_rows = vec![ROWS_PER_SUB; 6];
        r.assignment.validate(&sub_rows).unwrap();
        for sub in &r.assignment.subs {
            for p in &sub.psets {
                assert_eq!(p.len(), 2);
                assert_ne!(p[0], p[1]);
            }
        }
    }

    #[test]
    fn any_single_straggler_recoverable() {
        let r = run().unwrap();
        for straggler in 0..6 {
            let reporters: Vec<usize> = (0..6).filter(|&n| n != straggler).collect();
            for g in 0..6 {
                let rec = r.assignment.recovered_rows(g, &reporters);
                let covered: usize = rec.iter().map(|x| x.len()).sum();
                assert_eq!(covered, ROWS_PER_SUB, "g={g} straggler={straggler}");
            }
        }
    }

    #[test]
    fn report_mentions_paper_discrepancy() {
        let rep = report().unwrap();
        assert!(rep.contains("paper prints 3"));
        assert!(rep.contains("c* = 2.0000"));
    }
}
