//! EXP-F4 — paper Fig. 4: elastic power iteration, heterogeneous vs
//! homogeneous task assignment, with and without stragglers.
//!
//! The paper runs a 6000×6000 dense symmetric matrix on 6 EC2 VMs (3×
//! t2.large + 3× t2.xlarge), repetition placement, and reports ≈20 %
//! lower computation time for the heterogeneous (Algorithm 1) assignment.
//! Here the EC2 fleet is the simulated cluster (DESIGN.md §3): workers are
//! speed-throttled threads with the same 2-class speed profile; the
//! comparison and the time series are produced the same way.

use crate::config::types::{AssignPolicy, RunConfig};
use crate::error::Result;
use crate::metrics::Timeline;

use super::super::apps::power_iteration::run_power_iteration;

/// Fig. 4 experiment parameters.
#[derive(Debug, Clone)]
pub struct Fig4Params {
    /// Matrix dimension (paper: 6000; default smaller for CI speed).
    pub q: usize,
    pub steps: usize,
    /// Stragglers injected per step (paper bottom panel: 2).
    pub injected: usize,
    /// Straggler tolerance `S`. The paper's §V runs `S = 0` even in the
    /// bottom panel — its EC2 stragglers are *slow*, not lost, so the
    /// master waits for them. Set `slowdown > 1` with `tolerance = 0` for
    /// that reading, or `slowdown = 0` (drop) with `tolerance ≥ injected`
    /// for the redundant-assignment reading.
    pub tolerance: usize,
    /// Injected-straggler slowdown factor (0 ⇒ drop).
    pub slowdown: f64,
    /// Same victims every step (overloaded instances the EWMA can learn)
    /// vs fresh random victims.
    pub fixed_victims: bool,
    /// Simulated per-row cost (ns at speed 1) — dominates wall time so the
    /// speed heterogeneity shows.
    pub row_cost_ns: u64,
    pub seed: u64,
    pub backend: crate::config::types::BackendKind,
}

impl Default for Fig4Params {
    fn default() -> Self {
        Fig4Params {
            q: 1536,
            steps: 40,
            injected: 0,
            tolerance: 0,
            slowdown: 0.0,
            fixed_victims: false,
            row_cost_ns: 100_000,
            seed: 2021,
            backend: crate::config::types::BackendKind::Host,
        }
    }
}

/// One policy's run.
#[derive(Debug)]
pub struct PolicyRun {
    pub policy: AssignPolicy,
    pub timeline: Timeline,
    pub final_nmse: f64,
    pub total_wall_s: f64,
}

/// The heterogeneous-vs-uniform comparison.
#[derive(Debug)]
pub struct Fig4Result {
    pub hetero: PolicyRun,
    pub uniform: PolicyRun,
    /// Wall-clock gain of heterogeneous over uniform (paper: ≈0.20).
    pub gain: f64,
}

fn config(p: &Fig4Params, policy: AssignPolicy) -> RunConfig {
    RunConfig {
        q: p.q,
        r: p.q,
        g: 6,
        j: 3,
        n: 6,
        placement: crate::placement::PlacementKind::Repetition,
        stragglers: p.tolerance,
        injected_stragglers: p.injected,
        straggler_slowdown: p.slowdown,
        straggler_fixed: p.fixed_victims,
        policy,
        backend: p.backend,
        steps: p.steps,
        gamma: 0.5,
        row_cost_ns: p.row_cost_ns,
        seed: p.seed,
        // the EC2-like profile: 3 slower + 3 faster machines
        speeds: crate::sched::speed::ec2_mixed_profile(6),
        ..Default::default()
    }
}

/// Run both policies on identical workloads/chaos.
pub fn run(p: &Fig4Params) -> Result<Fig4Result> {
    let mut runs = Vec::new();
    for policy in [AssignPolicy::Heterogeneous, AssignPolicy::Uniform] {
        let cfg = config(p, policy);
        let res = run_power_iteration(&cfg)?;
        runs.push(PolicyRun {
            policy,
            total_wall_s: res.timeline.total_wall().as_secs_f64(),
            final_nmse: res.final_nmse,
            timeline: res.timeline,
        });
    }
    let uniform = runs.pop().unwrap();
    let hetero = runs.pop().unwrap();
    let gain = 1.0 - hetero.total_wall_s / uniform.total_wall_s;
    Ok(Fig4Result {
        hetero,
        uniform,
        gain,
    })
}

/// Render the Fig. 4 report (series + headline gain).
pub fn report(p: &Fig4Params) -> Result<String> {
    let r = run(p)?;
    let mut out = String::new();
    out.push_str(&format!(
        "EXP-F4 (paper Fig. 4{}): power iteration, q={}, {} steps, repetition placement\n\
         simulated EC2 profile (3 slow + 3 fast workers), S={}, injected stragglers/step={}\n\n",
        if p.injected > 0 { " bottom" } else { " top" },
        p.q,
        p.steps,
        p.tolerance,
        p.injected
    ));
    for run in [&r.hetero, &r.uniform] {
        out.push_str(&format!(
            "{:<14} total wall {:.3}s   final NMSE {:.3e}\n",
            run.policy.name(),
            run.total_wall_s,
            run.final_nmse
        ));
    }
    out.push_str(&format!(
        "\nheterogeneous gain over uniform: {:.1}% (paper: ≈20%)\n",
        r.gain * 100.0
    ));
    out.push_str("\nNMSE vs elapsed seconds (hetero | uniform):\n");
    let hs = r.hetero.timeline.metric_series();
    let us = r.uniform.timeline.metric_series();
    for i in (0..hs.len().max(us.len())).step_by(1.max(hs.len() / 20)) {
        let h = hs.get(i).map(|&(t, m)| format!("{t:7.3}s {m:9.3e}"));
        let u = us.get(i).map(|&(t, m)| format!("{t:7.3}s {m:9.3e}"));
        out.push_str(&format!(
            "step {i:3}  {} | {}\n",
            h.unwrap_or_else(|| " ".repeat(18)),
            u.unwrap_or_else(|| " ".repeat(18)),
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hetero_beats_uniform_with_heterogeneous_speeds() {
        let p = Fig4Params {
            q: 240,
            steps: 12,
            // large per-row cost so the throttle dominates thread-timing
            // noise and measured speeds are clean
            row_cost_ns: 400_000,
            ..Default::default()
        };
        let r = run(&p).unwrap();
        // both converge on the same workload
        assert!(r.hetero.final_nmse < 0.2);
        assert!(r.uniform.final_nmse < 0.2);
        // the headline: heterogeneous assignment is faster (paper ≈20%)
        assert!(
            r.gain > 0.05,
            "expected material gain, got {:.1}%",
            r.gain * 100.0
        );
    }

    #[test]
    fn straggler_variant_runs() {
        let p = Fig4Params {
            q: 240,
            steps: 8,
            injected: 2,
            tolerance: 2,
            row_cost_ns: 20_000,
            ..Default::default()
        };
        let r = run(&p).unwrap();
        assert!(r
            .hetero
            .timeline
            .steps()
            .iter()
            .all(|s| s.stragglers == 2));
        // with S=2 tolerance every step still completed
        assert_eq!(r.hetero.timeline.len(), 8);
    }
}
