//! EXP-F2 / EXP-T1 — paper Fig. 2 histograms + Table I moments.
//!
//! 5000 random speed vectors; per realization solve (6) under repetition
//! (G=6), cyclic (G=6) and MAN (G=C(6,3)=20) placements and compare the
//! optimal computation times.
//!
//! **Normalization** (DESIGN.md §5): speeds are drawn per *machine* as
//! `σ[n] ~ Exp(1)` in "fractions of X per unit time"; each placement's
//! Definition-2 speed is `s[n] = σ[n]·G`, making the optimal `c` a
//! wall-time comparable across different `G`. With `G = G_ref = 6` this
//! reduces to the paper's setup exactly.

use crate::error::Result;
use crate::metrics::{Histogram, Stats};
use crate::optim::{solve_load_matrix, SolveParams};
use crate::placement::{Placement, PlacementKind};
use crate::util::Rng;

/// Experiment parameters.
#[derive(Debug, Clone)]
pub struct Fig2Params {
    pub realizations: usize,
    pub seed: u64,
    /// Exponential rate for speed draws.
    pub lambda: f64,
    pub solver: crate::optim::SolverKind,
}

impl Default for Fig2Params {
    fn default() -> Self {
        Fig2Params {
            realizations: 5000,
            seed: 2021,
            // The paper does not state the exponential rate. λ = 0.64
            // (mean speed ≈ 1.56) reproduces Table I's means to within
            // Monte-Carlo error (cyclic 0.149, repetition 0.230, MAN
            // 0.144); see EXPERIMENTS.md for the calibration note.
            lambda: 0.64,
            solver: crate::optim::SolverKind::Simplex,
        }
    }
}

/// Per-placement aggregate results.
#[derive(Debug)]
pub struct PlacementSeries {
    pub kind: PlacementKind,
    pub times: Vec<f64>,
    pub stats: Stats,
    pub histogram: Histogram,
}

/// Strictly-worse / exactly-tied counts for one pairwise comparison.
#[derive(Debug, Clone, Copy)]
pub struct WinCount {
    pub worse: usize,
    pub tied: usize,
}

/// Full experiment output.
#[derive(Debug)]
pub struct Fig2Result {
    pub repetition: PlacementSeries,
    pub cyclic: PlacementSeries,
    pub man: PlacementSeries,
    /// Pairwise comparisons (paper reports 68, 9, 1621 of 5000; on many
    /// draws two placements share the *same* optimum — both hit the
    /// work-conservation bound — and the paper's large third count is
    /// consistent with strict fp comparison splitting those ties).
    pub cyclic_vs_rep: WinCount,
    pub man_vs_rep: WinCount,
    pub man_vs_cyclic: WinCount,
}

fn series(kind: PlacementKind, times: Vec<f64>) -> PlacementSeries {
    let mut stats = Stats::new();
    let mut histogram = Histogram::new(0.0, 0.8, 40);
    for &t in &times {
        stats.push(t);
        histogram.push(t);
    }
    PlacementSeries {
        kind,
        times,
        stats,
        histogram,
    }
}

/// Run the sweep.
pub fn run(params: &Fig2Params) -> Result<Fig2Result> {
    let n = 6;
    let avail: Vec<usize> = (0..n).collect();
    let placements = [
        (PlacementKind::Repetition, Placement::build(PlacementKind::Repetition, n, 6, 3)?),
        (PlacementKind::Cyclic, Placement::build(PlacementKind::Cyclic, n, 6, 3)?),
        (PlacementKind::Man, Placement::build(PlacementKind::Man, n, 20, 3)?),
    ];
    let solve_params = SolveParams {
        solver: params.solver,
        ..Default::default()
    };

    let mut rng = Rng::new(params.seed);
    let mut times: [Vec<f64>; 3] = [
        Vec::with_capacity(params.realizations),
        Vec::with_capacity(params.realizations),
        Vec::with_capacity(params.realizations),
    ];
    for _ in 0..params.realizations {
        // σ[n] ~ Exp(λ): X-fractions per unit time
        let sigma: Vec<f64> = (0..n).map(|_| rng.exponential(params.lambda).max(1e-6)).collect();
        for (i, (_, p)) in placements.iter().enumerate() {
            let g = p.submatrices() as f64;
            let s: Vec<f64> = sigma.iter().map(|&x| x * g).collect();
            let sol = solve_load_matrix(p, &avail, &s, &solve_params)?;
            times[i].push(sol.time);
        }
    }
    let [rep_t, cyc_t, man_t] = times;
    // Tie-tolerant comparison: on many draws two placements share the same
    // optimum exactly (both hit the work-conservation bound), so strict fp
    // comparison would attribute ~half of those ties to either side. Count
    // genuine losses and ties separately.
    let compare = |a: &[f64], b: &[f64]| {
        let rel = |x: f64, y: f64| (x - y).abs() <= 1e-7 * (1.0 + y.abs());
        WinCount {
            worse: a
                .iter()
                .zip(b)
                .filter(|(&x, &y)| x > y && !rel(x, y))
                .count(),
            tied: a.iter().zip(b).filter(|(&x, &y)| rel(x, y)).count(),
        }
    };
    Ok(Fig2Result {
        cyclic_vs_rep: compare(&cyc_t, &rep_t),
        man_vs_rep: compare(&man_t, &rep_t),
        man_vs_cyclic: compare(&man_t, &cyc_t),
        repetition: series(PlacementKind::Repetition, rep_t),
        cyclic: series(PlacementKind::Cyclic, cyc_t),
        man: series(PlacementKind::Man, man_t),
    })
}

/// Render the Fig. 2 + Table I report.
pub fn report(params: &Fig2Params) -> Result<String> {
    let r = run(params)?;
    let mut out = String::new();
    out.push_str(&format!(
        "EXP-F2/T1 (paper Fig. 2 + Table I): {} realizations, σ ~ Exp({})\n\n",
        params.realizations, params.lambda
    ));
    let table = crate::util::fmt::render_table(
        &["computation time", "cyclic", "repetition", "MAN"],
        &[
            vec![
                "mean".into(),
                format!("{:.4}", r.cyclic.stats.mean()),
                format!("{:.4}", r.repetition.stats.mean()),
                format!("{:.4}", r.man.stats.mean()),
            ],
            vec![
                "variance".into(),
                format!("{:.4}", r.cyclic.stats.variance()),
                format!("{:.4}", r.repetition.stats.variance()),
                format!("{:.4}", r.man.stats.variance()),
            ],
            vec![
                "paper mean".into(),
                "0.1492".into(),
                "0.2296".into(),
                "0.1442".into(),
            ],
            vec![
                "paper variance".into(),
                "0.0033".into(),
                "0.0114".into(),
                "0.0032".into(),
            ],
        ],
    );
    out.push_str(&table);
    out.push_str(&format!(
        "\nwin counts (of {}), 'worse (+ exact ties)':\n\
         cyclic worse than repetition: {} (+{} ties)   [paper 68]\n\
         man worse than repetition:    {} (+{} ties)   [paper 9]\n\
         man worse than cyclic:        {} (+{} ties)   [paper 1621 — consistent\n\
         \x20   with strict fp comparison splitting the tied optima]\n",
        params.realizations,
        r.cyclic_vs_rep.worse,
        r.cyclic_vs_rep.tied,
        r.man_vs_rep.worse,
        r.man_vs_rep.tied,
        r.man_vs_cyclic.worse,
        r.man_vs_cyclic.tied
    ));
    for s in [&r.repetition, &r.cyclic, &r.man] {
        out.push_str(&format!("\nhistogram of c(M), {} placement:\n", s.kind.name()));
        out.push_str(&s.histogram.render(50));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> Fig2Result {
        run(&Fig2Params {
            realizations: 300,
            seed: 9,
            ..Default::default()
        })
        .unwrap()
    }

    #[test]
    fn ordering_matches_paper_shape() {
        let r = quick();
        // MAN ≤ cyclic < repetition in mean (paper Table I shape)
        assert!(r.man.stats.mean() <= r.cyclic.stats.mean() + 1e-9);
        assert!(r.cyclic.stats.mean() < r.repetition.stats.mean());
        // variance ordering too
        assert!(r.man.stats.variance() < r.repetition.stats.variance());
    }

    #[test]
    fn win_counts_shape() {
        let r = quick();
        // cyclic rarely loses to repetition; MAN essentially never does;
        // MAN vs cyclic ties on a large fraction of draws (both often hit
        // the work-conservation bound) — the paper's 1621/5000 "worse"
        // matches strict tie-splitting of those.
        let n = 300.0;
        assert!((r.cyclic_vs_rep.worse as f64) < 0.1 * n);
        assert!(r.man_vs_rep.worse <= r.cyclic_vs_rep.worse);
        assert!((r.man_vs_cyclic.tied as f64) > 0.2 * n);
        // genuinely-worse MAN-vs-cyclic cases are rare
        assert!((r.man_vs_cyclic.worse as f64) < 0.2 * n);
    }

    #[test]
    fn man_rarely_loses_to_repetition() {
        // Not a per-realization domination (the paper itself observes 9
        // counterexamples in 5000): MAN wins the overwhelming majority.
        let r = quick();
        let losses = r
            .man
            .times
            .iter()
            .zip(&r.repetition.times)
            .filter(|(m, rep)| *m > &(*rep + 1e-9))
            .count();
        assert!(
            (losses as f64) < 0.02 * r.man.times.len() as f64,
            "MAN lost to repetition {losses} times"
        );
    }

    #[test]
    fn deterministic_by_seed() {
        let a = run(&Fig2Params {
            realizations: 50,
            seed: 4,
            ..Default::default()
        })
        .unwrap();
        let b = run(&Fig2Params {
            realizations: 50,
            seed: 4,
            ..Default::default()
        })
        .unwrap();
        assert_eq!(a.cyclic.times, b.cyclic.times);
    }
}
