//! EXP-F1 — paper Fig. 1 + in-text computation times.
//!
//! N=6, G=6, J=3, s=\[1,2,4,8,16,32\]; solve (6) under the repetition and
//! cyclic placements. The paper reports `c_rep = 0.4286 (=3/7)` and
//! `c_cyc = 0.1429 (=1/7)`.

use crate::error::Result;
use crate::optim::{solve_load_matrix, Solution, SolveParams};
use crate::placement::{Placement, PlacementKind};

/// Fig. 1's speed vector.
pub fn fig1_speeds() -> Vec<f64> {
    vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0]
}

/// One placement's Fig. 1 result.
#[derive(Debug)]
pub struct Fig1Row {
    pub placement: PlacementKind,
    pub solution: Solution,
    /// Paper's reported value for cross-checking.
    pub paper_time: f64,
}

/// Solve both placements of Fig. 1.
pub fn run() -> Result<Vec<Fig1Row>> {
    let speeds = fig1_speeds();
    let avail: Vec<usize> = (0..6).collect();
    let params = SolveParams::default();
    let mut rows = Vec::new();
    for (kind, paper_time) in [
        (PlacementKind::Repetition, 3.0 / 7.0),
        (PlacementKind::Cyclic, 1.0 / 7.0),
    ] {
        let p = Placement::build(kind, 6, 6, 3)?;
        let solution = solve_load_matrix(&p, &avail, &speeds, &params)?;
        rows.push(Fig1Row {
            placement: kind,
            solution,
            paper_time,
        });
    }
    Ok(rows)
}

/// Render the Fig. 1 report (μ matrices + times vs paper).
pub fn report() -> Result<String> {
    let rows = run()?;
    let mut out = String::new();
    out.push_str("EXP-F1 (paper Fig. 1): N=6, G=6, J=3, s=[1,2,4,8,16,32]\n\n");
    for r in &rows {
        out.push_str(&format!(
            "{} placement: c = {:.4} (paper: {:.4})\n",
            r.placement.name(),
            r.solution.time,
            r.paper_time
        ));
        out.push_str(&crate::util::fmt::render_load_matrix(
            &r.solution.load.to_rows(),
            "X",
            "m",
        ));
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_paper_times() {
        for r in run().unwrap() {
            assert!(
                (r.solution.time - r.paper_time).abs() < 1e-6,
                "{}: {} vs paper {}",
                r.placement.name(),
                r.solution.time,
                r.paper_time
            );
        }
    }

    #[test]
    fn report_renders() {
        let rep = report().unwrap();
        assert!(rep.contains("repetition placement: c = 0.4286"));
        assert!(rep.contains("cyclic placement: c = 0.1429"));
    }
}
