//! Paper experiment harnesses (one module per table/figure), shared by the
//! benches (`benches/fig*_*.rs`) and the `usec exp` subcommand.
//!
//! | module | paper artifact | bench |
//! |---|---|---|
//! | [`fig1`] | Fig. 1 + in-text `c` values | `fig1_example` |
//! | [`fig2`] | Fig. 2 histograms + Table I | `fig2_placements` |
//! | [`fig3`] | Fig. 3 straggler example | `fig3_straggler` |
//! | [`fig4`] | Fig. 4 power-iteration E2E | `fig4_power_iteration` |

pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;

use crate::cli::{ArgSpec, Args};
use crate::config::RunConfig;
use crate::error::{Error, Result};

/// `usec run …` — full elastic power-iteration run from CLI flags.
pub fn run_cli(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &RunConfig::arg_specs())?;
    let cfg = RunConfig::from_args(&args)?;
    run_and_report(&cfg)
}

/// `usec master --workers host:port,… [run flags]` — the same elastic
/// power-iteration run, distributed over TCP worker daemons.
pub fn master_cli(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv, &RunConfig::arg_specs())?;
    let cfg = RunConfig::from_args(&args)?;
    if !cfg.is_distributed() {
        return Err(Error::Config(
            "usec master requires --workers host:port,host:port,…".into(),
        ));
    }
    run_and_report(&cfg)
}

/// Shared `run`/`master` body: execute, print the human summary, and dump
/// the machine-readable timeline when `--json-out` is set.
///
/// ## `--json-out` schema
///
/// The document is one object: run identity (`app`, `backend`, `policy`,
/// `placement`, `transport`, `n`, `batch`, `threads`, `recovery`,
/// `rebalance`, `seed`), result scalars (`final_nmse`, `eigval`,
/// `truth_eigval`), an optional `trace_out` (path of the JSONL journal,
/// present only when `--trace-out` was set), and `timeline` — the
/// [`crate::metrics::Timeline::to_json`] dump. Each timeline step carries
/// the per-step series plus, when tracing is on, a `counters` array (one
/// [`crate::obs::CounterSnapshot`] object per worker: orders, rows, wire
/// bytes/frames, reconnects, recoveries, migrations) and order latency
/// quantiles `rtt_p50_ms`/`rtt_p99_ms`/`compute_p50_ms`/`compute_p99_ms`
/// (null when untraced). Pipelined runs (`--pipeline`) additionally
/// carry `overlap_ns` per step — the previous step's combine time
/// hidden inside this step's dispatch+compute window; the key is
/// omitted on synchronous steps, keeping classic dumps byte-identical.
/// Robustness runs add three more per-step keys, each omitted when
/// zero/false so classic dumps stay byte-identical: `faults` (chaos
/// faults injected during the step, `--chaos`), `retries` (backed-off
/// re-admission dials attempted before the step), and `checkpoint`
/// (`true` on steps whose boundary wrote a `--checkpoint-out`
/// snapshot). With tracing on, each worker's counters always carry
/// `dial_attempts`/`dial_successes` (zero until a backed-off dial
/// happens), so the key set is identical across steps and workers.
/// The run-identity object gains `chaos` (the schedule string) only
/// when `--chaos` is set, and `resumed_from_step` only under
/// `--resume`. The journal itself is converted offline with
/// `usec trace <journal> [--out trace.json] [--summary]`.
///
/// Serving sessions (`usec serve --listen … --json-out …`) reuse the
/// same timeline dump and add five top-level keys, present only when a
/// serve summary was attached (classic dumps stay byte-identical):
/// `requests` (requests answered over the session), `latency_p50_ns` /
/// `latency_p99_ns` (submit-to-answer latency quantiles in
/// nanoseconds, null before any request completes), `queue_depth` (the
/// admission queue's peak depth), and `rows_per_s` (matrix rows
/// processed per second across all batched columns). When the
/// telemetry plane was on (`--metrics-listen` or any `--slo-*`
/// threshold), the serve document additionally carries a top-level
/// `slo` array: one object per tenant with `tenant`, `requests`,
/// `rejects`, `rows`, `latency_p50_ns` / `latency_p99_ns` (omitted
/// before any answered sample), `rows_per_s`, `healthy` (0/1), and
/// `burns` — the final rolling-window snapshot that also backs the
/// `usec_tenant_*` scrape series. The key is omitted entirely when the
/// plane was off, keeping plain serve dumps byte-identical.
fn run_and_report(cfg: &RunConfig) -> Result<()> {
    let res = crate::apps::run_power_iteration(cfg)?;
    println!(
        "power iteration: {} steps, backend={}, policy={}, placement={}, transport={}, \
         batch={}, threads={}",
        cfg.steps,
        cfg.backend.name(),
        cfg.policy.name(),
        cfg.placement.name(),
        if cfg.is_distributed() {
            "tcp"
        } else {
            "local"
        },
        cfg.batch,
        cfg.worker_threads
    );
    if cfg.batch > 1 {
        let evs: Vec<String> = res.eigvals.iter().map(|v| format!("{v:.4}")).collect();
        println!("block spectrum estimate (R diagonal): [{}]", evs.join(", "));
    }
    println!(
        "final NMSE {:.3e}, eigenvalue estimate {:.4} (truth {:.4}), total wall {:?}",
        res.final_nmse,
        res.eigval,
        res.truth_eigval,
        res.timeline.total_wall()
    );
    let storage = res.timeline.storage_bytes();
    if !storage.is_empty() {
        let full = (cfg.q * cfg.r * 4) as u64;
        let shares: Vec<String> = storage
            .iter()
            .map(|&b| format!("{b} ({:.0}%)", b as f64 / full as f64 * 100.0))
            .collect();
        println!(
            "per-worker resident storage bytes (full matrix = {full}): [{}]",
            shares.join(", ")
        );
    }
    let migrated = res.timeline.total_migrations();
    if migrated > 0 {
        println!(
            "live rebalancing: {migrated} replica move(s), {} bytes of shard \
             rows migrated between steps",
            res.timeline.total_migrated_bytes()
        );
    }
    let recovered = res.timeline.total_recoveries();
    if recovered > 0 {
        let rows: usize = res
            .timeline
            .steps()
            .iter()
            .flat_map(|s| s.recoveries.iter().map(|r| r.rows))
            .sum();
        println!(
            "mid-step recoveries: {recovered} victim(s), {rows} uncovered rows \
             re-dispatched to surviving replicas"
        );
    }
    let faults: u64 = res.timeline.steps().iter().map(|s| s.faults).sum();
    if faults > 0 {
        let retries: u64 = res.timeline.steps().iter().map(|s| s.retries).sum();
        println!(
            "chaos: {faults} fault(s) injected ({}), {retries} backed-off \
             re-admission dial(s)",
            cfg.chaos
        );
    }
    if !cfg.checkpoint_out.is_empty() {
        let boundaries = res.timeline.steps().iter().filter(|s| s.checkpoint).count();
        println!(
            "checkpointed {boundaries} step boundarie(s) to {} (resume with \
             `usec master --resume {}`)",
            cfg.checkpoint_out, cfg.checkpoint_out
        );
    }
    if !cfg.resume.is_empty() {
        if let Some(first) = res.timeline.steps().first() {
            println!(
                "resumed from {} at step {} ({} step(s) executed)",
                cfg.resume,
                first.step,
                res.timeline.len()
            );
        }
    }
    if !cfg.trace_out.is_empty() {
        println!(
            "wrote tracing journal to {} (convert with `usec trace {}`)",
            cfg.trace_out, cfg.trace_out
        );
    }
    if !cfg.json_out.is_empty() {
        let mut doc = crate::util::json::ObjBuilder::new()
            .str("app", "power-iteration")
            .str("backend", cfg.backend.name())
            .str("policy", cfg.policy.name())
            .str("placement", cfg.placement.name())
            .str(
                "transport",
                if cfg.is_distributed() { "tcp" } else { "local" },
            )
            .num("n", cfg.n as f64)
            .num("batch", cfg.batch as f64)
            .num("threads", cfg.worker_threads as f64)
            .val(
                "recovery",
                crate::util::json::Json::Bool(cfg.recovery.enabled),
            )
            .val(
                "rebalance",
                crate::util::json::Json::Bool(cfg.rebalance.enabled),
            )
            .num("seed", cfg.seed as f64)
            .num("final_nmse", res.final_nmse)
            .num("eigval", res.eigval)
            .num("truth_eigval", res.truth_eigval)
            .val("timeline", res.timeline.to_json());
        if !cfg.trace_out.is_empty() {
            doc = doc.str("trace_out", &cfg.trace_out);
        }
        if !cfg.chaos.is_empty() {
            doc = doc.str("chaos", &cfg.chaos);
        }
        if let Some(first) = res.timeline.steps().first() {
            if !cfg.resume.is_empty() {
                doc = doc.num("resumed_from_step", first.step as f64);
            }
        }
        std::fs::write(&cfg.json_out, format!("{}\n", doc.build()))?;
        println!("wrote timeline JSON to {}", cfg.json_out);
    }
    println!("\nper-step series (CSV):\n{}", res.timeline.to_csv());
    Ok(())
}

/// `usec exp <fig1|fig2|fig3|fig4|fig4s> [--realizations N] [--q N] …`
pub fn exp_cli(argv: &[String]) -> Result<()> {
    let which = argv
        .first()
        .ok_or_else(|| Error::Config("usage: usec exp <fig1|fig2|fig3|fig4|fig4s>".into()))?;
    let rest = &argv[1..];
    let specs = vec![
        ArgSpec::opt("realizations", "5000", "fig2: speed draws"),
        ArgSpec::opt("seed", "2021", "PRNG seed"),
        ArgSpec::opt("q", "1536", "fig4: matrix dimension"),
        ArgSpec::opt("steps", "40", "fig4: iteration count"),
        ArgSpec::opt("row-cost-ns", "20000", "fig4: simulated ns/row"),
        ArgSpec::opt("backend", "host", "fig4: host|pjrt"),
    ];
    let args = Args::parse(rest, &specs)?;
    let out = match which.as_str() {
        "fig1" => fig1::report()?,
        "fig2" | "table1" => fig2::report(&fig2::Fig2Params {
            realizations: args.get_usize("realizations")?,
            seed: args.get_u64("seed")?,
            ..Default::default()
        })?,
        "fig3" => fig3::report()?,
        "fig4" | "fig4s" => fig4::report(&fig4::Fig4Params {
            q: args.get_usize("q")?,
            steps: args.get_usize("steps")?,
            row_cost_ns: args.get_u64("row-cost-ns")?,
            seed: args.get_u64("seed")?,
            backend: crate::config::types::BackendKind::parse(
                args.get("backend").unwrap_or("host"),
            )?,
            injected: if which == "fig4s" { 2 } else { 0 },
            tolerance: 0, // paper §V: S = 0; stragglers are slow, not lost
            slowdown: if which == "fig4s" { 3.0 } else { 0.0 },
            fixed_victims: which == "fig4s",
        })?,
        other => {
            return Err(Error::Config(format!(
                "unknown experiment '{other}' (fig1|fig2|fig3|fig4|fig4s)"
            )))
        }
    };
    println!("{out}");
    Ok(())
}

/// `usec solve --placement cyclic --speeds 1,2,4,8,16,32 [--stragglers S]`
/// — one-shot assignment solve, prints `M*` and `c*`.
pub fn solve_cli(argv: &[String]) -> Result<()> {
    let specs = vec![
        ArgSpec::opt("placement", "cyclic", "repetition|cyclic|man"),
        ArgSpec::opt("n", "6", "machines"),
        ArgSpec::opt("g", "6", "sub-matrices"),
        ArgSpec::opt("j", "3", "replication"),
        ArgSpec::opt("speeds", "1,2,4,8,16,32", "speed vector"),
        ArgSpec::opt("avail", "", "available machines (default: all)"),
        ArgSpec::opt("stragglers", "0", "straggler tolerance S"),
        ArgSpec::opt("solver", "simplex", "simplex|flow"),
    ];
    let args = Args::parse(argv, &specs)?;
    let kind = crate::placement::PlacementKind::parse(args.get("placement").unwrap())?;
    let n = args.get_usize("n")?;
    let p =
        crate::placement::Placement::build(kind, n, args.get_usize("g")?, args.get_usize("j")?)?;
    let speeds = args.get_f64_list("speeds")?;
    let avail: Vec<usize> = match args.get("avail") {
        Some("") | None => (0..n).collect(),
        Some(list) => list
            .split(',')
            .map(|x| {
                x.trim()
                    .parse()
                    .map_err(|_| Error::Config(format!("bad machine id '{x}'")))
            })
            .collect::<Result<_>>()?,
    };
    let params = crate::optim::SolveParams {
        stragglers: args.get_usize("stragglers")?,
        solver: crate::optim::SolverKind::parse(args.get("solver").unwrap())?,
        ..Default::default()
    };
    let sol = crate::optim::solve_load_matrix(&p, &avail, &speeds, &params)?;
    println!(
        "placement={} N={} G={} J={} S={} solver={}",
        kind.name(),
        n,
        p.submatrices(),
        p.replication(),
        params.stragglers,
        params.solver.name()
    );
    println!("c* = {:.6}\n", sol.time);
    println!(
        "{}",
        crate::util::fmt::render_load_matrix(&sol.load.to_rows(), "X", "m")
    );
    println!("machine loads μ[n] = {:?}", sol.load.machine_loads());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn solve_cli_runs() {
        solve_cli(&sv(&["--placement", "cyclic"])).unwrap();
        solve_cli(&sv(&["--placement", "rep", "--stragglers", "1", "--speeds", "1,1,1,1,1,1"]))
            .unwrap();
    }

    #[test]
    fn exp_cli_fig1_and_fig3() {
        exp_cli(&sv(&["fig1"])).unwrap();
        exp_cli(&sv(&["fig3"])).unwrap();
        assert!(exp_cli(&sv(&["nope"])).is_err());
        assert!(exp_cli(&[]).is_err());
    }

    #[test]
    fn exp_cli_fig2_small() {
        exp_cli(&sv(&["fig2", "--realizations", "30"])).unwrap();
    }

    #[test]
    fn run_cli_small() {
        run_cli(&sv(&[
            "--q", "60", "--r", "60", "--steps", "5", "--speeds", "1,2,3,4,5,6",
        ]))
        .unwrap();
    }

    #[test]
    fn run_cli_block_batch() {
        run_cli(&sv(&[
            "--q", "60", "--r", "60", "--steps", "8", "--batch", "4", "--threads", "2",
            "--speeds", "1,2,3,4,5,6",
        ]))
        .unwrap();
    }

    #[test]
    fn run_cli_writes_json_out() {
        let path = std::env::temp_dir().join("usec_run_cli_json_out_test.json");
        let p = path.to_str().unwrap();
        run_cli(&sv(&[
            "--q", "60", "--r", "60", "--steps", "3", "--speeds", "1,2,3,4,5,6",
            "--json-out", p,
        ]))
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let j = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(j.get_str("app"), Some("power-iteration"));
        assert_eq!(j.get_str("transport"), Some("local"));
        let tl = j.get("timeline").unwrap();
        assert_eq!(tl.get_usize("steps"), Some(3));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn run_cli_writes_trace_journal() {
        let dir = std::env::temp_dir();
        let jpath = dir.join("usec_run_cli_trace_test.jsonl");
        let opath = dir.join("usec_run_cli_trace_test.json");
        let jp = jpath.to_str().unwrap();
        let op = opath.to_str().unwrap();
        run_cli(&sv(&[
            "--q", "60", "--r", "60", "--steps", "3", "--speeds", "1,2,3,4,5,6",
            "--trace-out", jp, "--json-out", op,
        ]))
        .unwrap();
        let events = crate::obs::load_journal(jp).unwrap();
        let steps = events
            .iter()
            .filter(|e| e.kind == crate::obs::EventKind::Step)
            .count();
        assert_eq!(steps, 3, "one step span per iteration");
        assert!(events
            .iter()
            .any(|e| e.kind == crate::obs::EventKind::Order && e.breakdown.is_some()));
        let text = std::fs::read_to_string(&opath).unwrap();
        let j = crate::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(j.get_str("trace_out"), Some(jp));
        let _ = std::fs::remove_file(&jpath);
        let _ = std::fs::remove_file(&opath);
    }

    #[test]
    fn master_cli_requires_workers() {
        assert!(master_cli(&sv(&["--q", "60", "--r", "60"])).is_err());
    }
}
