//! [`RowShard`]: the rows a machine actually stores, and nothing else.

use crate::error::{Error, Result};
use crate::linalg::partition::RowRange;
use crate::linalg::Matrix;

use super::view::StorageView;

/// One contiguous resident block of global rows.
#[derive(Debug, Clone, PartialEq)]
struct Block {
    /// Global row range `[lo, hi)` this block covers.
    range: RowRange,
    /// Row-major `range.len() × cols` payload.
    data: Vec<f32>,
}

/// Owned storage for a (possibly non-contiguous) set of global row blocks
/// of a `global_rows × cols` matrix.
///
/// Blocks are kept sorted, non-overlapping, and coalesced (adjacent blocks
/// merge on insert), so any row range that lies inside one placed region is
/// borrowable as a single contiguous slice — exactly what the tiled SpMV
/// kernels need.
///
/// Local indices are the rank of a resident row among all resident rows in
/// global order: a shard holding global rows `10..20` and `40..50` maps
/// global row 42 to local row 12 and back.
#[derive(Debug, Clone, PartialEq)]
pub struct RowShard {
    global_rows: usize,
    cols: usize,
    blocks: Vec<Block>,
}

impl RowShard {
    /// Empty shard of a `global_rows × cols` matrix.
    pub fn new(global_rows: usize, cols: usize) -> Self {
        RowShard {
            global_rows,
            cols,
            blocks: Vec::new(),
        }
    }

    /// Copy the given global row ranges out of a fully materialized matrix
    /// (the generator-backed path: build everything once, keep the share).
    pub fn from_matrix(m: &Matrix, ranges: &[RowRange]) -> Result<RowShard> {
        let mut shard = RowShard::new(m.rows(), m.cols());
        for r in ranges {
            shard.insert(*r, m.try_row_block(r.lo, r.hi)?.to_vec())?;
        }
        Ok(shard)
    }

    /// Insert one block of rows. Rejects shape mismatches, out-of-range
    /// rows, and overlap with already-resident rows; coalesces with
    /// adjacent blocks. Empty ranges are accepted and ignored.
    pub fn insert(&mut self, range: RowRange, data: Vec<f32>) -> Result<()> {
        if range.is_empty() {
            return Ok(());
        }
        if range.hi > self.global_rows {
            return Err(Error::Shape(format!(
                "block {}..{} exceeds the {}-row matrix",
                range.lo, range.hi, self.global_rows
            )));
        }
        let expect = range.len().checked_mul(self.cols).ok_or_else(|| {
            Error::Shape(format!(
                "block {}..{} x {} cols overflows usize",
                range.lo, range.hi, self.cols
            ))
        })?;
        if data.len() != expect {
            return Err(Error::Shape(format!(
                "block {}..{} carries {} values, expected {expect}",
                range.lo,
                range.hi,
                data.len()
            )));
        }
        // insertion point: first block starting at or after range.lo
        let pos = self.blocks.partition_point(|b| b.range.lo < range.lo);
        if pos > 0 && self.blocks[pos - 1].range.hi > range.lo {
            return Err(Error::Shape(format!(
                "block {}..{} overlaps resident rows",
                range.lo, range.hi
            )));
        }
        if pos < self.blocks.len() && range.hi > self.blocks[pos].range.lo {
            return Err(Error::Shape(format!(
                "block {}..{} overlaps resident rows",
                range.lo, range.hi
            )));
        }
        // coalesce with the left neighbour, then the right one
        if pos > 0 && self.blocks[pos - 1].range.hi == range.lo {
            let left = &mut self.blocks[pos - 1];
            left.data.extend_from_slice(&data);
            left.range.hi = range.hi;
            if pos < self.blocks.len() && self.blocks[pos].range.lo == range.hi {
                let right = self.blocks.remove(pos);
                let left = &mut self.blocks[pos - 1];
                left.data.extend_from_slice(&right.data);
                left.range.hi = right.range.hi;
            }
            return Ok(());
        }
        if pos < self.blocks.len() && self.blocks[pos].range.lo == range.hi {
            let right = &mut self.blocks[pos];
            let mut merged = data;
            merged.extend_from_slice(&right.data);
            right.data = merged;
            right.range.lo = range.lo;
            return Ok(());
        }
        self.blocks.insert(pos, Block { range, data });
        Ok(())
    }

    /// Remove the intersection of `range` with the resident rows — the
    /// eviction half of live shard migration ([`crate::rebalance`]).
    ///
    /// Coalescing-aware: evicting from the middle of a resident block
    /// splits it in two; evicting a block edge trims it. Rows of `range`
    /// that are not resident are ignored (an eviction order may race a
    /// partially applied plan), so the call is idempotent. Returns the
    /// number of rows actually removed; resident-byte accounting
    /// ([`StorageView::resident_bytes`]) shrinks by `removed · cols · 4`.
    pub fn remove_rows(&mut self, range: RowRange) -> Result<usize> {
        if range.hi > self.global_rows {
            return Err(Error::Shape(format!(
                "eviction {}..{} exceeds the {}-row matrix",
                range.lo, range.hi, self.global_rows
            )));
        }
        if range.is_empty() {
            return Ok(0);
        }
        let cols = self.cols;
        let mut removed = 0usize;
        let mut blocks = Vec::with_capacity(self.blocks.len() + 1);
        for b in self.blocks.drain(..) {
            let inter = b.range.intersect(&range);
            if inter.is_empty() {
                blocks.push(b);
                continue;
            }
            removed += inter.len();
            if inter.lo > b.range.lo {
                // surviving head of the block
                blocks.push(Block {
                    range: RowRange::new(b.range.lo, inter.lo),
                    data: b.data[..(inter.lo - b.range.lo) * cols].to_vec(),
                });
            }
            if inter.hi < b.range.hi {
                // surviving tail of the block (middle eviction splits)
                blocks.push(Block {
                    range: RowRange::new(inter.hi, b.range.hi),
                    data: b.data[(inter.hi - b.range.lo) * cols..].to_vec(),
                });
            }
        }
        self.blocks = blocks;
        Ok(removed)
    }

    /// Resident global row ranges, sorted and coalesced.
    pub fn ranges(&self) -> Vec<RowRange> {
        self.blocks.iter().map(|b| b.range).collect()
    }

    /// Number of resident (coalesced) blocks.
    pub fn block_count(&self) -> usize {
        self.blocks.len()
    }

    /// Local index (rank among resident rows) of a global row, or `None`
    /// when the row is not resident.
    pub fn global_to_local(&self, global: usize) -> Option<usize> {
        let mut before = 0usize;
        for b in &self.blocks {
            if global < b.range.lo {
                return None;
            }
            if global < b.range.hi {
                return Some(before + (global - b.range.lo));
            }
            before += b.range.len();
        }
        None
    }

    /// Global row of a local index, or `None` when `local` is beyond the
    /// resident row count.
    pub fn local_to_global(&self, local: usize) -> Option<usize> {
        let mut before = 0usize;
        for b in &self.blocks {
            if local < before + b.range.len() {
                return Some(b.range.lo + (local - before));
            }
            before += b.range.len();
        }
        None
    }
}

impl StorageView for RowShard {
    fn global_rows(&self) -> usize {
        self.global_rows
    }

    fn cols(&self) -> usize {
        self.cols
    }

    fn resident_rows(&self) -> usize {
        self.blocks.iter().map(|b| b.range.len()).sum()
    }

    fn holds(&self, rows: RowRange) -> bool {
        if rows.is_empty() {
            return true;
        }
        self.blocks
            .iter()
            .any(|b| b.range.lo <= rows.lo && rows.hi <= b.range.hi)
    }

    fn row_slice(&self, rows: RowRange) -> Result<&[f32]> {
        if rows.is_empty() {
            return Ok(&[]);
        }
        let b = self
            .blocks
            .iter()
            .find(|b| b.range.lo <= rows.lo && rows.hi <= b.range.hi)
            .ok_or_else(|| {
                Error::Shape(format!(
                    "rows {}..{} are not resident in this shard",
                    rows.lo, rows.hi
                ))
            })?;
        let lo = (rows.lo - b.range.lo) * self.cols;
        let hi = (rows.hi - b.range.lo) * self.cols;
        Ok(&b.data[lo..hi])
    }
}

/// Coalesce the global row ranges of the given sub-matrices into sorted
/// maximal contiguous runs (adjacent placed sub-matrices merge).
///
/// `ids` are sub-matrix indices into `sub_ranges`; duplicates are ignored,
/// out-of-range indices rejected.
pub fn coalesce_sub_ranges(ids: &[usize], sub_ranges: &[RowRange]) -> Result<Vec<RowRange>> {
    let mut sorted: Vec<usize> = ids.to_vec();
    sorted.sort_unstable();
    sorted.dedup();
    let mut out: Vec<RowRange> = Vec::new();
    for g in sorted {
        let r = *sub_ranges.get(g).ok_or_else(|| {
            Error::Shape(format!(
                "sub-matrix {g} out of range (G={})",
                sub_ranges.len()
            ))
        })?;
        if r.is_empty() {
            continue;
        }
        match out.last_mut() {
            Some(last) if last.hi == r.lo => last.hi = r.hi,
            _ => out.push(r),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gen;
    use crate::linalg::partition::submatrix_ranges;

    fn shard_of(q: usize, cols: usize, ranges: &[(usize, usize)]) -> RowShard {
        let m = gen::random_dense(q, cols, 17);
        let rr: Vec<RowRange> = ranges.iter().map(|&(lo, hi)| RowRange::new(lo, hi)).collect();
        RowShard::from_matrix(&m, &rr).unwrap()
    }

    #[test]
    fn from_matrix_copies_exact_rows() {
        let m = gen::random_dense(10, 4, 3);
        let s = RowShard::from_matrix(&m, &[RowRange::new(2, 5), RowRange::new(7, 9)]).unwrap();
        assert_eq!(s.resident_rows(), 5);
        assert_eq!(s.resident_bytes(), 5 * 4 * 4);
        assert_eq!(s.row_slice(RowRange::new(3, 4)).unwrap(), m.row(3));
        assert_eq!(
            s.row_slice(RowRange::new(7, 9)).unwrap(),
            m.row_block(7, 9)
        );
        assert!(s.row_slice(RowRange::new(5, 8)).is_err(), "gap not resident");
        assert!(s.holds(RowRange::new(2, 5)));
        assert!(!s.holds(RowRange::new(4, 6)));
    }

    #[test]
    fn adjacent_blocks_coalesce() {
        let s = shard_of(12, 3, &[(0, 4), (8, 12), (4, 8)]);
        assert_eq!(s.block_count(), 1);
        assert_eq!(s.ranges(), vec![RowRange::new(0, 12)]);
        // a range spanning the former block boundary is one slice
        assert_eq!(s.row_slice(RowRange::new(2, 10)).unwrap().len(), 8 * 3);
    }

    #[test]
    fn insert_rejects_overlap_and_bad_shapes() {
        let mut s = RowShard::new(10, 2);
        s.insert(RowRange::new(2, 5), vec![0.0; 6]).unwrap();
        assert!(s.insert(RowRange::new(4, 6), vec![0.0; 4]).is_err());
        assert!(s.insert(RowRange::new(0, 2), vec![0.0; 3]).is_err());
        assert!(s.insert(RowRange::new(9, 11), vec![0.0; 4]).is_err());
        // empty insert is a no-op
        s.insert(RowRange::new(7, 7), vec![]).unwrap();
        assert_eq!(s.block_count(), 1);
    }

    #[test]
    fn global_local_mapping() {
        let s = shard_of(50, 2, &[(10, 20), (40, 50)]);
        assert_eq!(s.global_to_local(10), Some(0));
        assert_eq!(s.global_to_local(19), Some(9));
        assert_eq!(s.global_to_local(40), Some(10));
        assert_eq!(s.global_to_local(42), Some(12));
        assert_eq!(s.global_to_local(20), None);
        assert_eq!(s.global_to_local(9), None);
        assert_eq!(s.local_to_global(0), Some(10));
        assert_eq!(s.local_to_global(12), Some(42));
        assert_eq!(s.local_to_global(20), None);
    }

    #[test]
    fn remove_rows_trims_splits_and_accounts_bytes() {
        let m = gen::random_dense(20, 3, 9);
        let mut s = RowShard::from_matrix(&m, &[RowRange::new(0, 20)]).unwrap();
        assert_eq!(s.block_count(), 1);
        // middle eviction splits the block in two
        assert_eq!(s.remove_rows(RowRange::new(8, 12)).unwrap(), 4);
        assert_eq!(s.ranges(), vec![RowRange::new(0, 8), RowRange::new(12, 20)]);
        assert_eq!(s.resident_rows(), 16);
        assert_eq!(s.resident_bytes(), 16 * 3 * 4);
        // edge eviction trims
        assert_eq!(s.remove_rows(RowRange::new(0, 3)).unwrap(), 3);
        assert_eq!(s.ranges(), vec![RowRange::new(3, 8), RowRange::new(12, 20)]);
        // eviction spanning a gap removes only resident rows (idempotent)
        assert_eq!(s.remove_rows(RowRange::new(5, 14)).unwrap(), 5);
        assert_eq!(s.remove_rows(RowRange::new(5, 14)).unwrap(), 0);
        assert_eq!(s.ranges(), vec![RowRange::new(3, 5), RowRange::new(14, 20)]);
        // surviving rows are bitwise intact
        assert_eq!(s.row_slice(RowRange::new(3, 5)).unwrap(), m.row_block(3, 5));
        assert_eq!(s.row_slice(RowRange::new(14, 20)).unwrap(), m.row_block(14, 20));
        // empty and out-of-range evictions
        assert_eq!(s.remove_rows(RowRange::new(4, 4)).unwrap(), 0);
        assert!(s.remove_rows(RowRange::new(15, 25)).is_err());
    }

    #[test]
    fn evicted_rows_can_be_reinserted() {
        // the migration round trip: evict a block, stream it back, and the
        // shard is bitwise where it started (coalescing included)
        let m = gen::random_dense(12, 4, 21);
        let mut s = RowShard::from_matrix(&m, &[RowRange::new(0, 12)]).unwrap();
        let gone = RowRange::new(4, 9);
        s.remove_rows(gone).unwrap();
        assert!(!s.holds(gone));
        s.insert(gone, m.row_block(4, 9).to_vec()).unwrap();
        assert_eq!(s.block_count(), 1, "reinsert must re-coalesce");
        assert_eq!(s.row_slice(RowRange::new(0, 12)).unwrap(), m.row_block(0, 12));
    }

    #[test]
    fn insert_evict_round_trips_hold_for_random_shards() {
        use crate::testing::prop::{gen as pgen, run, Config};
        run(
            Config::default().cases(120).name("shard-insert-evict"),
            |rng| {
                let shard = pgen::row_shard(rng);
                let before = shard.ranges();
                let resident = shard.resident_rows();
                let q = shard.global_rows();

                // evicting a random window and re-inserting exactly the
                // evicted runs restores ranges and byte accounting
                let lo = rng.below(q);
                let hi = rng.range(lo, q) + 1;
                let window = RowRange::new(lo, hi.min(q));
                let mut s = shard.clone();
                let evicted: Vec<RowRange> = before
                    .iter()
                    .map(|r| r.intersect(&window))
                    .filter(|r| !r.is_empty())
                    .collect();
                let want_removed: usize = evicted.iter().map(|r| r.len()).sum();
                let removed = s.remove_rows(window).expect("in-range eviction");
                assert_eq!(removed, want_removed, "eviction count mismatch");
                assert_eq!(s.resident_rows(), resident - removed);
                for r in &evicted {
                    assert!(!s.holds(*r) || r.is_empty());
                    s.insert(*r, vec![0.5; r.len() * StorageView::cols(&s)])
                        .expect("re-insert of evicted rows");
                }
                assert_eq!(s.ranges(), before, "round trip changed the ranges");
                assert_eq!(s.resident_rows(), resident);

                // evicting everything leaves an empty, consistent shard
                let mut empty = shard.clone();
                let all = empty.remove_rows(RowRange::new(0, q)).expect("evict all");
                assert_eq!(all, resident);
                assert_eq!(empty.resident_rows(), 0);
                assert_eq!(empty.resident_bytes(), 0);
                assert_eq!(empty.block_count(), 0);
                assert_eq!(empty.global_to_local(lo.min(q - 1)), None);
            },
        );
    }

    #[test]
    fn coalesce_sub_ranges_merges_adjacent() {
        let subs = submatrix_ranges(100, 5).unwrap(); // 20-row parts
        let r = coalesce_sub_ranges(&[3, 0, 1, 3], &subs).unwrap();
        assert_eq!(r, vec![RowRange::new(0, 40), RowRange::new(60, 80)]);
        assert!(coalesce_sub_ranges(&[5], &subs).is_err());
        assert!(coalesce_sub_ranges(&[], &subs).unwrap().is_empty());
    }

    #[test]
    fn empty_shard_is_consistent() {
        let s = RowShard::new(8, 3);
        assert_eq!(s.resident_rows(), 0);
        assert_eq!(s.resident_bytes(), 0);
        assert!(s.holds(RowRange::new(4, 4)));
        assert!(!s.holds(RowRange::new(0, 1)));
        assert_eq!(s.row_slice(RowRange::new(2, 2)).unwrap(), &[] as &[f32]);
        assert!(s.row_slice(RowRange::new(0, 1)).is_err());
    }
}
