//! [`StorageView`]: the uniform read interface over full and sharded
//! storage, and the [`StoreHandle`] workers hold.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::linalg::partition::RowRange;
use crate::linalg::Matrix;

use super::shard::RowShard;

/// Read-only view of (part of) a `global_rows × cols` row-major matrix.
///
/// Kernels address rows in *global* coordinates; the view decides whether
/// they are resident and where they live. `Matrix` is the everything-
/// resident case; [`RowShard`] holds only the placed share.
pub trait StorageView {
    /// Rows of the full matrix this view is a window of.
    fn global_rows(&self) -> usize;

    /// Columns (same for the full matrix and every view of it).
    fn cols(&self) -> usize;

    /// Rows actually resident in this view.
    fn resident_rows(&self) -> usize;

    /// Bytes of matrix payload actually resident (`f32` entries).
    fn resident_bytes(&self) -> usize {
        self.resident_rows() * self.cols() * std::mem::size_of::<f32>()
    }

    /// Whether every row of `rows` is resident (empty ranges trivially are).
    fn holds(&self, rows: RowRange) -> bool;

    /// Borrow global rows `[rows.lo, rows.hi)` as one contiguous row-major
    /// slice. Errors when any row is missing or the range spans a gap.
    fn row_slice(&self, rows: RowRange) -> Result<&[f32]>;
}

impl StorageView for Matrix {
    fn global_rows(&self) -> usize {
        self.rows()
    }

    fn cols(&self) -> usize {
        Matrix::cols(self)
    }

    fn resident_rows(&self) -> usize {
        self.rows()
    }

    fn holds(&self, rows: RowRange) -> bool {
        rows.hi <= self.rows()
    }

    fn row_slice(&self, rows: RowRange) -> Result<&[f32]> {
        self.try_row_block(rows.lo, rows.hi)
    }
}

/// The storage a worker holds, cheap to clone across threads.
///
/// `Full` is the local simulator mode: every worker shares one `Arc` of
/// the matrix (zero-copy, bit-identical with the pre-shard behaviour).
/// `Shard` is the distributed mode: the worker owns exactly its placed
/// rows and nothing else.
#[derive(Debug, Clone)]
pub enum StoreHandle {
    Full(Arc<Matrix>),
    Shard(Arc<RowShard>),
}

impl StoreHandle {
    /// Whether this handle is a placement-shaped shard (vs a full view).
    pub fn is_shard(&self) -> bool {
        matches!(self, StoreHandle::Shard(_))
    }

    /// Evict global row ranges — the worker-side half of live shard
    /// migration ([`crate::rebalance`]). Shards evict in place
    /// (copy-on-write through `Arc::make_mut`; between orders the worker
    /// holds the only strong reference, so no copy happens). A `Full`
    /// handle is narrowed to a [`RowShard`] built directly from the
    /// *surviving* rows — only what is kept is copied, so a worker asked
    /// to shed storage never transiently doubles its footprint. Returns
    /// the number of rows removed.
    pub fn evict_rows(&mut self, ranges: &[RowRange]) -> Result<usize> {
        if ranges.iter().all(|r| r.is_empty()) {
            return Ok(0);
        }
        match self {
            StoreHandle::Full(m) => {
                let rows = m.rows();
                if let Some(bad) = ranges.iter().find(|r| r.hi > rows) {
                    return Err(Error::Shape(format!(
                        "eviction {}..{} exceeds the {rows}-row matrix",
                        bad.lo, bad.hi
                    )));
                }
                let keep = complement_ranges(ranges, rows);
                let shard = RowShard::from_matrix(m, &keep)?;
                let removed = rows - shard.resident_rows();
                *self = StoreHandle::Shard(Arc::new(shard));
                Ok(removed)
            }
            StoreHandle::Shard(shard) => {
                let shard = Arc::make_mut(shard);
                let mut removed = 0usize;
                for r in ranges {
                    removed += shard.remove_rows(*r)?;
                }
                Ok(removed)
            }
        }
    }

    /// Insert one block of global rows (the receiving half of a shard
    /// migration). Rows already fully resident are skipped, so a re-sent
    /// chunk is idempotent; `Full` handles hold every row already and
    /// only validate the payload shape.
    pub fn insert_rows(&mut self, range: RowRange, data: Vec<f32>) -> Result<()> {
        let expect = range.len().checked_mul(self.cols()).ok_or_else(|| {
            Error::Shape(format!(
                "block {}..{} x {} cols overflows usize",
                range.lo,
                range.hi,
                self.cols()
            ))
        })?;
        if data.len() != expect {
            return Err(Error::Shape(format!(
                "block {}..{} carries {} values, expected {expect}",
                range.lo,
                range.hi,
                data.len()
            )));
        }
        if self.holds(range) {
            return Ok(()); // already resident (Full view, or a re-send)
        }
        match self {
            // a full view holds every in-range row, so reaching here means
            // the range overruns the matrix
            StoreHandle::Full(m) => Err(Error::Shape(format!(
                "block {}..{} exceeds the {}-row matrix",
                range.lo,
                range.hi,
                m.rows()
            ))),
            StoreHandle::Shard(shard) => Arc::make_mut(shard).insert(range, data),
        }
    }
}

impl StorageView for StoreHandle {
    fn global_rows(&self) -> usize {
        match self {
            StoreHandle::Full(m) => m.rows(),
            StoreHandle::Shard(s) => s.global_rows(),
        }
    }

    fn cols(&self) -> usize {
        match self {
            StoreHandle::Full(m) => Matrix::cols(m),
            StoreHandle::Shard(s) => StorageView::cols(s.as_ref()),
        }
    }

    fn resident_rows(&self) -> usize {
        match self {
            StoreHandle::Full(m) => m.rows(),
            StoreHandle::Shard(s) => s.resident_rows(),
        }
    }

    fn holds(&self, rows: RowRange) -> bool {
        match self {
            StoreHandle::Full(m) => StorageView::holds(m.as_ref(), rows),
            StoreHandle::Shard(s) => s.holds(rows),
        }
    }

    fn row_slice(&self, rows: RowRange) -> Result<&[f32]> {
        match self {
            StoreHandle::Full(m) => StorageView::row_slice(m.as_ref(), rows),
            StoreHandle::Shard(s) => s.row_slice(rows),
        }
    }
}

/// The sorted maximal runs of `[0, rows)` *not* covered by `ranges`
/// (which may overlap or arrive unsorted) — the rows a narrowing
/// eviction keeps.
fn complement_ranges(ranges: &[RowRange], rows: usize) -> Vec<RowRange> {
    let mut sorted: Vec<RowRange> = ranges.iter().copied().filter(|r| !r.is_empty()).collect();
    sorted.sort_by_key(|r| r.lo);
    let mut keep = Vec::new();
    let mut lo = 0usize;
    for r in sorted {
        if r.lo > lo {
            keep.push(RowRange::new(lo, r.lo));
        }
        lo = lo.max(r.hi);
    }
    if lo < rows {
        keep.push(RowRange::new(lo, rows));
    }
    keep
}

/// Matvec over a resident row range through any view: the reference
/// kernel used by tests and the `storage_view` bench to compare full vs
/// shard access paths.
pub fn matvec_range<V: StorageView + ?Sized>(
    view: &V,
    rows: RowRange,
    w: &[f32],
) -> Result<Vec<f32>> {
    if w.len() != view.cols() {
        return Err(Error::Shape(format!(
            "matvec_range: vector length {} vs {} columns",
            w.len(),
            view.cols()
        )));
    }
    let x = view.row_slice(rows)?;
    let mut out = vec![0.0f32; rows.len()];
    crate::linalg::ops::matvec_into(x, rows.len(), view.cols(), w, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gen;

    #[test]
    fn matrix_view_is_fully_resident() {
        let m = gen::random_dense(6, 4, 1);
        assert_eq!(m.global_rows(), 6);
        assert_eq!(StorageView::cols(&m), 4);
        assert_eq!(m.resident_rows(), 6);
        assert_eq!(m.resident_bytes(), 6 * 4 * 4);
        assert!(StorageView::holds(&m, RowRange::new(0, 6)));
        assert!(!StorageView::holds(&m, RowRange::new(4, 7)));
        assert_eq!(
            StorageView::row_slice(&m, RowRange::new(2, 4)).unwrap(),
            m.row_block(2, 4)
        );
        assert!(StorageView::row_slice(&m, RowRange::new(5, 7)).is_err());
    }

    #[test]
    fn handles_agree_on_resident_rows() {
        let q = 20;
        let m = Arc::new(gen::random_dense(q, q, 5));
        let ranges = vec![RowRange::new(5, 10), RowRange::new(15, 20)];
        let shard = Arc::new(RowShard::from_matrix(&m, &ranges).unwrap());
        let full = StoreHandle::Full(Arc::clone(&m));
        let sharded = StoreHandle::Shard(shard);
        assert!(!full.is_shard());
        assert!(sharded.is_shard());
        assert_eq!(full.resident_rows(), q);
        assert_eq!(sharded.resident_rows(), 10);
        assert_eq!(sharded.resident_bytes() * 2, full.resident_bytes());
        let w = vec![0.3f32; q];
        let r = RowRange::new(6, 9);
        let a = matvec_range(&full, r, &w).unwrap();
        let b = matvec_range(&sharded, r, &w).unwrap();
        assert_eq!(a, b, "shard and full views must compute identical rows");
        assert!(matvec_range(&sharded, RowRange::new(0, 3), &w).is_err());
    }

    #[test]
    fn shard_handle_migrates_rows_in_and_out() {
        let q = 16;
        let m = gen::random_dense(q, 2, 3);
        let shard = RowShard::from_matrix(&m, &[RowRange::new(0, 8)]).unwrap();
        let mut h = StoreHandle::Shard(Arc::new(shard));
        // receive rows 8..12, evict rows 0..4: the migrated share
        h.insert_rows(RowRange::new(8, 12), m.row_block(8, 12).to_vec())
            .unwrap();
        assert_eq!(h.evict_rows(&[RowRange::new(0, 4)]).unwrap(), 4);
        assert_eq!(h.resident_rows(), 8);
        assert!(h.holds(RowRange::new(4, 12)));
        assert!(!h.holds(RowRange::new(0, 1)));
        // idempotent re-send of resident rows, rejected bad shapes
        h.insert_rows(RowRange::new(8, 12), m.row_block(8, 12).to_vec())
            .unwrap();
        assert!(h.insert_rows(RowRange::new(12, 14), vec![0.0; 3]).is_err());
        assert!(h.insert_rows(RowRange::new(14, 18), vec![0.0; 8]).is_err());
        assert_eq!(h.resident_rows(), 8);
    }

    #[test]
    fn full_handle_narrows_to_a_shard_on_eviction() {
        let q = 10;
        let m = Arc::new(gen::random_dense(q, 3, 7));
        let mut h = StoreHandle::Full(Arc::clone(&m));
        // inserts into a full view are idempotent no-ops; overruns error
        h.insert_rows(RowRange::new(2, 4), m.row_block(2, 4).to_vec())
            .unwrap();
        assert!(h.insert_rows(RowRange::new(8, 12), vec![0.0; 12]).is_err());
        assert!(!h.is_shard());
        // the first eviction narrows the handle to the surviving rows
        assert_eq!(h.evict_rows(&[RowRange::new(3, 6)]).unwrap(), 3);
        assert!(h.is_shard());
        assert_eq!(h.resident_rows(), 7);
        assert_eq!(
            h.row_slice(RowRange::new(0, 3)).unwrap(),
            m.row_block(0, 3)
        );
        assert_eq!(
            h.row_slice(RowRange::new(6, 10)).unwrap(),
            m.row_block(6, 10)
        );
        assert!(h.row_slice(RowRange::new(3, 6)).is_err());
        // overlapping/unsorted eviction ranges are counted once
        let mut multi = StoreHandle::Full(Arc::clone(&m));
        assert_eq!(
            multi
                .evict_rows(&[RowRange::new(4, 7), RowRange::new(2, 5)])
                .unwrap(),
            5
        );
        assert_eq!(multi.resident_rows(), 5);
        assert!(multi.holds(RowRange::new(0, 2)));
        assert!(multi.holds(RowRange::new(7, 10)));
        assert!(!multi.holds(RowRange::new(3, 4)));
        // out-of-range eviction is rejected without narrowing
        let mut oob = StoreHandle::Full(Arc::clone(&m));
        assert!(oob.evict_rows(&[RowRange::new(8, 12)]).is_err());
        assert!(!oob.is_shard());
        // an all-empty eviction never narrows
        let mut untouched = StoreHandle::Full(m);
        assert_eq!(untouched.evict_rows(&[RowRange::new(4, 4)]).unwrap(), 0);
        assert!(!untouched.is_shard());
    }
}
