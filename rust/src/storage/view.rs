//! [`StorageView`]: the uniform read interface over full and sharded
//! storage, and the [`StoreHandle`] workers hold.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::linalg::partition::RowRange;
use crate::linalg::Matrix;

use super::shard::RowShard;

/// Read-only view of (part of) a `global_rows × cols` row-major matrix.
///
/// Kernels address rows in *global* coordinates; the view decides whether
/// they are resident and where they live. `Matrix` is the everything-
/// resident case; [`RowShard`] holds only the placed share.
pub trait StorageView {
    /// Rows of the full matrix this view is a window of.
    fn global_rows(&self) -> usize;

    /// Columns (same for the full matrix and every view of it).
    fn cols(&self) -> usize;

    /// Rows actually resident in this view.
    fn resident_rows(&self) -> usize;

    /// Bytes of matrix payload actually resident (`f32` entries).
    fn resident_bytes(&self) -> usize {
        self.resident_rows() * self.cols() * std::mem::size_of::<f32>()
    }

    /// Whether every row of `rows` is resident (empty ranges trivially are).
    fn holds(&self, rows: RowRange) -> bool;

    /// Borrow global rows `[rows.lo, rows.hi)` as one contiguous row-major
    /// slice. Errors when any row is missing or the range spans a gap.
    fn row_slice(&self, rows: RowRange) -> Result<&[f32]>;
}

impl StorageView for Matrix {
    fn global_rows(&self) -> usize {
        self.rows()
    }

    fn cols(&self) -> usize {
        Matrix::cols(self)
    }

    fn resident_rows(&self) -> usize {
        self.rows()
    }

    fn holds(&self, rows: RowRange) -> bool {
        rows.hi <= self.rows()
    }

    fn row_slice(&self, rows: RowRange) -> Result<&[f32]> {
        self.try_row_block(rows.lo, rows.hi)
    }
}

/// The storage a worker holds, cheap to clone across threads.
///
/// `Full` is the local simulator mode: every worker shares one `Arc` of
/// the matrix (zero-copy, bit-identical with the pre-shard behaviour).
/// `Shard` is the distributed mode: the worker owns exactly its placed
/// rows and nothing else.
#[derive(Debug, Clone)]
pub enum StoreHandle {
    Full(Arc<Matrix>),
    Shard(Arc<RowShard>),
}

impl StoreHandle {
    /// Whether this handle is a placement-shaped shard (vs a full view).
    pub fn is_shard(&self) -> bool {
        matches!(self, StoreHandle::Shard(_))
    }
}

impl StorageView for StoreHandle {
    fn global_rows(&self) -> usize {
        match self {
            StoreHandle::Full(m) => m.rows(),
            StoreHandle::Shard(s) => s.global_rows(),
        }
    }

    fn cols(&self) -> usize {
        match self {
            StoreHandle::Full(m) => Matrix::cols(m),
            StoreHandle::Shard(s) => StorageView::cols(s.as_ref()),
        }
    }

    fn resident_rows(&self) -> usize {
        match self {
            StoreHandle::Full(m) => m.rows(),
            StoreHandle::Shard(s) => s.resident_rows(),
        }
    }

    fn holds(&self, rows: RowRange) -> bool {
        match self {
            StoreHandle::Full(m) => StorageView::holds(m.as_ref(), rows),
            StoreHandle::Shard(s) => s.holds(rows),
        }
    }

    fn row_slice(&self, rows: RowRange) -> Result<&[f32]> {
        match self {
            StoreHandle::Full(m) => StorageView::row_slice(m.as_ref(), rows),
            StoreHandle::Shard(s) => s.row_slice(rows),
        }
    }
}

/// Matvec over a resident row range through any view: the reference
/// kernel used by tests and the `storage_view` bench to compare full vs
/// shard access paths.
pub fn matvec_range<V: StorageView + ?Sized>(
    view: &V,
    rows: RowRange,
    w: &[f32],
) -> Result<Vec<f32>> {
    if w.len() != view.cols() {
        return Err(Error::Shape(format!(
            "matvec_range: vector length {} vs {} columns",
            w.len(),
            view.cols()
        )));
    }
    let x = view.row_slice(rows)?;
    let mut out = vec![0.0f32; rows.len()];
    crate::linalg::ops::matvec_into(x, rows.len(), view.cols(), w, &mut out);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gen;

    #[test]
    fn matrix_view_is_fully_resident() {
        let m = gen::random_dense(6, 4, 1);
        assert_eq!(m.global_rows(), 6);
        assert_eq!(StorageView::cols(&m), 4);
        assert_eq!(m.resident_rows(), 6);
        assert_eq!(m.resident_bytes(), 6 * 4 * 4);
        assert!(StorageView::holds(&m, RowRange::new(0, 6)));
        assert!(!StorageView::holds(&m, RowRange::new(4, 7)));
        assert_eq!(
            StorageView::row_slice(&m, RowRange::new(2, 4)).unwrap(),
            m.row_block(2, 4)
        );
        assert!(StorageView::row_slice(&m, RowRange::new(5, 7)).is_err());
    }

    #[test]
    fn handles_agree_on_resident_rows() {
        let q = 20;
        let m = Arc::new(gen::random_dense(q, q, 5));
        let ranges = vec![RowRange::new(5, 10), RowRange::new(15, 20)];
        let shard = Arc::new(RowShard::from_matrix(&m, &ranges).unwrap());
        let full = StoreHandle::Full(Arc::clone(&m));
        let sharded = StoreHandle::Shard(shard);
        assert!(!full.is_shard());
        assert!(sharded.is_shard());
        assert_eq!(full.resident_rows(), q);
        assert_eq!(sharded.resident_rows(), 10);
        assert_eq!(sharded.resident_bytes() * 2, full.resident_bytes());
        let w = vec![0.3f32; q];
        let r = RowRange::new(6, 9);
        let a = matvec_range(&full, r, &w).unwrap();
        let b = matvec_range(&sharded, r, &w).unwrap();
        assert_eq!(a, b, "shard and full views must compute identical rows");
        assert!(matvec_range(&sharded, RowRange::new(0, 3), &w).is_err());
    }
}
