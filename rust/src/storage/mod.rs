//! Placement-shaped storage: what a worker actually holds in RAM.
//!
//! The paper's defining property is *uncoded storage placement*: machine
//! `n` stores only its `|Z_n|` of the `G` sub-matrices (a `J/G` fraction
//! of `X` under the named families of §III–IV). The seed implementation
//! simulated that with an `Arc` of the **full** matrix per worker, so the
//! storage cost never showed up anywhere. This module makes the placement
//! shape real:
//!
//! * [`StorageView`] — the read interface kernels use: global geometry,
//!   residency queries, and borrowing a global row range as a contiguous
//!   row-major slice. Both [`crate::linalg::Matrix`] (everything resident)
//!   and [`RowShard`] implement it.
//! * [`RowShard`] — owned, possibly non-contiguous row blocks with
//!   global↔local index mapping. A TCP worker materializes exactly its
//!   placed share into one of these, whether by regenerating it from the
//!   handshake's workload spec or by receiving streamed `Data` frames
//!   ([`crate::net::codec`], tag 8). Eviction matches the coalescing
//!   insert ([`RowShard::remove_rows`]: edge trims, middle splits), so
//!   live rebalancing ([`crate::rebalance`]) can move placed rows between
//!   workers mid-run with exact resident-byte accounting.
//! * [`StoreHandle`] — the cheap-to-clone handle workers hold: a
//!   zero-copy full-matrix view (local simulator mode, bit-identical with
//!   the seed behaviour) or a placement-shaped shard (distributed mode).
//!
//! [`StorageView::resident_bytes`] is what [`crate::metrics::Timeline`]
//! and `--json-out` report per worker, so simulated storage cost is now an
//! observable, not a fiction.

pub mod shard;
pub mod view;

pub use shard::{coalesce_sub_ranges, RowShard};
pub use view::{StorageView, StoreHandle};
