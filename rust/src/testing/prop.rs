//! Mini property-testing framework (offline replacement for `proptest`).
//!
//! Deterministic seeded case generation with a simple halving shrinker.
//! Each property runs `cases` times; on failure the framework shrinks the
//! failing input (where the generator supports it) and reports the seed so
//! the case can be replayed.
//!
//! ```no_run
//! use usec::testing::prop::{run, Config};
//! run(Config::default().cases(64), |rng| {
//!     let n = rng.range(1, 100);
//!     assert!(n * 2 >= n, "overflow-free doubling");
//! });
//! ```

use crate::util::Rng;

/// Property-run configuration.
#[derive(Debug, Clone)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub name: &'static str,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            seed: 0x5EED,
            name: "property",
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
    pub fn name(mut self, n: &'static str) -> Self {
        self.name = n;
        self
    }
}

/// Run a property over `cfg.cases` seeded cases. The property receives a
/// per-case [`Rng`]; any panic fails the run with the replay seed printed.
pub fn run<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cfg: Config, prop: F) {
    for case in 0..cfg.cases {
        let case_seed = cfg.seed.wrapping_add(case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            prop(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{}' failed at case {case}/{} (replay seed {case_seed:#x}): {msg}",
                cfg.name, cfg.cases
            );
        }
    }
}

/// Generators for domain objects used across property tests.
pub mod gen {
    use crate::placement::{Placement, PlacementKind};
    use crate::util::Rng;

    /// A random valid placement (family, N, G, J all varied).
    pub fn placement(rng: &mut Rng) -> Placement {
        loop {
            let n = rng.range(2, 9);
            let j = rng.range(1, n + 1);
            match rng.below(4) {
                0 => {
                    if n % j == 0 {
                        let groups = n / j;
                        let per = rng.range(1, 4);
                        if let Ok(p) =
                            Placement::build(PlacementKind::Repetition, n, groups * per, j)
                        {
                            return p;
                        }
                    }
                }
                1 => {
                    let m = rng.range(1, 3);
                    if let Ok(p) = Placement::build(PlacementKind::Cyclic, n, n * m, j) {
                        return p;
                    }
                }
                2 => {
                    let c = crate::placement::builders::binomial(n, j);
                    if c > 0 && c <= 40 {
                        if let Ok(p) = Placement::build(PlacementKind::Man, n, c, j) {
                            return p;
                        }
                    }
                }
                _ => {
                    // custom: random J-subsets per sub-matrix
                    let g = rng.range(1, 8);
                    let replicas: Vec<Vec<usize>> =
                        (0..g).map(|_| rng.sample_indices(n, j)).collect();
                    if let Ok(p) = Placement::from_replicas(PlacementKind::Custom, n, replicas)
                    {
                        return p;
                    }
                }
            }
        }
    }

    /// A strictly positive speed vector of length `n` (exponential draws,
    /// floored to avoid degenerate near-zero speeds).
    pub fn speeds(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.exponential(1.0).max(0.05)).collect()
    }

    /// A non-empty availability subset of `[0, n)`.
    pub fn availability(rng: &mut Rng, n: usize) -> Vec<usize> {
        let k = rng.range(1, n + 1);
        let mut a = rng.sample_indices(n, k);
        a.sort_unstable();
        a
    }

    /// An arbitrary wire-safe [`crate::sched::protocol::WorkOrder`]:
    /// random iterate block (width 1..=4 — the B=1 case keeps the legacy
    /// wire tag covered), task list, throttle, and straggle instruction.
    pub fn work_order(rng: &mut Rng) -> crate::sched::protocol::WorkOrder {
        use crate::linalg::partition::RowRange;
        use crate::linalg::Block;
        use crate::optim::Task;
        use crate::sched::straggler::StraggleMode;

        let q = rng.range(1, 64);
        let nvec = rng.range(1, 5);
        let w: Vec<f32> = (0..q * nvec).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let w = Block::from_interleaved(q, nvec, w).expect("generated block is consistent");
        let tasks: Vec<Task> = (0..rng.below(5))
            .map(|_| {
                let lo = rng.below(50);
                let len = rng.below(20);
                Task {
                    g: rng.below(8),
                    rows: RowRange::new(lo, lo + len),
                }
            })
            .collect();
        let straggle = match rng.below(3) {
            0 => None,
            1 => Some(StraggleMode::Drop),
            _ => Some(StraggleMode::Slow(rng.range_f64(1.0, 10.0))),
        };
        crate::sched::protocol::WorkOrder {
            step: rng.below(1000),
            w: std::sync::Arc::new(w),
            tasks,
            row_cost_ns: rng.next_u64() % 1_000_000,
            straggle,
            trace: rng.chance(0.5),
        }
    }

    /// A random [`crate::storage::RowShard`]: random non-overlapping
    /// blocks of a random geometry (possibly empty, possibly adjacent so
    /// coalescing is exercised).
    pub fn row_shard(rng: &mut Rng) -> crate::storage::RowShard {
        use crate::linalg::partition::RowRange;
        use crate::storage::RowShard;

        let q = rng.range(1, 80);
        let cols = rng.range(1, 12);
        let mut shard = RowShard::new(q, cols);
        let mut lo = 0usize;
        while lo < q {
            let gap = rng.below(4);
            let start = (lo + gap).min(q);
            if start >= q {
                break;
            }
            let len = rng.range(1, (q - start).min(10) + 1);
            shard
                .insert(RowRange::new(start, start + len), vec![0.5; len * cols])
                .expect("generated blocks never overlap");
            lo = start + len;
        }
        shard
    }

    /// An arbitrary wire-safe [`crate::net::codec::DataFrame`] whose
    /// values are consistent with its row range and column count.
    pub fn data_frame(rng: &mut Rng) -> crate::net::codec::DataFrame {
        use crate::linalg::partition::RowRange;

        let lo = rng.below(100);
        let len = rng.below(8);
        let cols = rng.range(1, 16);
        crate::net::codec::DataFrame {
            rows: RowRange::new(lo, lo + len),
            cols,
            done: rng.chance(0.5),
            values: (0..len * cols)
                .map(|_| (rng.f64() * 4.0 - 2.0) as f32)
                .collect(),
        }
    }

    /// An arbitrary wire-safe [`crate::sched::protocol::WorkerReport`]
    /// whose segments are internally consistent
    /// (`values.len == rows.len · nvec`, block width 1..=4).
    pub fn worker_report(rng: &mut Rng) -> crate::sched::protocol::WorkerReport {
        use crate::linalg::partition::RowRange;
        use crate::sched::protocol::Segment;

        let nvec = rng.range(1, 5);
        let segments: Vec<Segment> = (0..rng.below(4))
            .map(|_| {
                let lo = rng.below(100);
                let len = rng.below(16);
                Segment {
                    rows: RowRange::new(lo, lo + len),
                    values: (0..len * nvec).map(|_| rng.f64() as f32).collect(),
                }
            })
            .collect();
        crate::sched::protocol::WorkerReport {
            worker: rng.below(16),
            step: rng.below(1000),
            segments,
            nvec,
            measured_speed: if rng.chance(0.5) {
                Some(rng.range_f64(0.01, 10.0))
            } else {
                None
            },
            elapsed: std::time::Duration::from_nanos(rng.next_u64() % 10_000_000_000),
            breakdown: if rng.chance(0.5) {
                Some(crate::obs::OrderBreakdown {
                    decode_ns: rng.next_u64() % 1_000_000,
                    compute_ns: rng.next_u64() % 1_000_000,
                    throttle_ns: rng.next_u64() % 1_000_000,
                    assemble_ns: rng.next_u64() % 1_000_000,
                    encode_ns: rng.next_u64() % 1_000_000,
                    idle_ns: rng.next_u64() % 1_000_000,
                })
            } else {
                None
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run(Config::default().cases(32).name("tautology"), |rng| {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "replay seed")]
    fn failing_property_reports_seed() {
        run(Config::default().cases(16).name("always-fails"), |_| {
            panic!("boom");
        });
    }

    #[test]
    fn generators_produce_valid_placements() {
        run(Config::default().cases(50).name("placement-gen"), |rng| {
            let p = gen::placement(rng);
            assert!(p.machines() >= 2);
            for g in 0..p.submatrices() {
                assert_eq!(p.machines_storing(g).len(), p.replication());
            }
        });
    }

    #[test]
    fn speed_generator_positive() {
        run(Config::default().cases(20).name("speed-gen"), |rng| {
            let s = gen::speeds(rng, 6);
            assert!(s.iter().all(|&x| x >= 0.05));
        });
    }

    #[test]
    fn matmat_matches_independent_matvecs_for_any_shape() {
        use crate::linalg::ops::{matmat_into, matvec_into};
        run(Config::default().cases(150).name("matmat-vs-matvec"), |rng| {
            let rows = rng.range(1, 24);
            let cols = rng.range(1, 48);
            // widths crossing the 8-wide group boundary exercise the tail
            let nvec = rng.range(1, 20);
            let a: Vec<f32> = (0..rows * cols)
                .map(|_| (rng.f64() * 4.0 - 2.0) as f32)
                .collect();
            let x: Vec<f32> = (0..cols * nvec)
                .map(|_| (rng.f64() * 2.0 - 1.0) as f32)
                .collect();
            let mut out = vec![0.0f32; rows * nvec];
            matmat_into(&a, rows, cols, &x, nvec, &mut out);
            for k in 0..nvec {
                let col: Vec<f32> = (0..cols).map(|c| x[c * nvec + k]).collect();
                let mut want = vec![0.0f32; rows];
                matvec_into(&a, rows, cols, &col, &mut want);
                for r in 0..rows {
                    let got = out[r * nvec + k];
                    assert!(
                        (got - want[r]).abs() <= 1e-6 * want[r].abs().max(1.0),
                        "rows={rows} cols={cols} B={nvec} col {k} row {r}: {got} vs {}",
                        want[r]
                    );
                }
            }
        });
    }

    #[test]
    fn multithreaded_execute_order_is_bit_identical() {
        use crate::linalg::partition::{submatrix_ranges, RowRange, TilePlan};
        use crate::linalg::{gen as lgen, Block};
        use crate::optim::Task;
        use crate::runtime::BackendSpec;
        use crate::sched::worker::{execute_order, ExecScratch, WorkerConfig, WorkerStorage};

        run(Config::default().cases(24).name("threaded-worker"), |rng| {
            let q = rng.range(24, 80);
            let g = rng.range(2, 5);
            let matrix = std::sync::Arc::new(lgen::random_dense(q, q, rng.next_u64()));
            let ranges =
                std::sync::Arc::new(submatrix_ranges(q, g).expect("valid partition"));
            // fixed odd tile height → ragged tails in most cases
            let mk = |threads: usize| WorkerConfig {
                id: 0,
                backend: BackendSpec::Host,
                speed: 1.0,
                tile_rows: 7,
                threads,
                storage: WorkerStorage::full(
                    std::sync::Arc::clone(&matrix),
                    std::sync::Arc::clone(&ranges),
                ),
            };
            let nvec = rng.range(1, 6);
            let w = Block::from_interleaved(
                q,
                nvec,
                (0..q * nvec).map(|_| (rng.f64() - 0.5) as f32).collect(),
            )
            .expect("generated block is consistent");
            let tasks: Vec<Task> = (0..g)
                .filter(|_| rng.chance(0.8))
                .map(|gi| {
                    let sub_len = ranges[gi].len();
                    let lo = rng.below(sub_len);
                    let hi = rng.range(lo, sub_len) + 1;
                    Task {
                        g: gi,
                        rows: RowRange::new(lo, hi.min(sub_len)),
                    }
                })
                .collect();
            let order = crate::sched::protocol::WorkOrder {
                step: 1,
                w: std::sync::Arc::new(w),
                tasks,
                row_cost_ns: 0,
                straggle: None,
            };
            let serial_cfg = mk(1);
            let threaded_cfg = mk(1 + rng.range(1, 6));
            let backend = BackendSpec::Host.instantiate().expect("host backend");
            let tile = TilePlan::new(serial_cfg.tile_rows);
            let mut s1 = ExecScratch::new();
            let mut s2 = ExecScratch::new();
            let a = execute_order(&serial_cfg, &backend, &tile, &order, &mut s1)
                .expect("serial order")
                .expect("report");
            let b = execute_order(&threaded_cfg, &backend, &tile, &order, &mut s2)
                .expect("threaded order")
                .expect("report");
            assert_eq!(a.segments, b.segments, "thread fan-out changed the numerics");
            assert_eq!(a.nvec, b.nvec);
        });
    }

    #[test]
    fn codec_work_order_roundtrips() {
        use crate::net::codec::{decode, encode};
        use crate::net::WireMsg;
        run(Config::default().cases(200).name("codec-work-order"), |rng| {
            let order = gen::work_order(rng);
            let bytes = encode(&WireMsg::Work(order.clone()));
            match decode(&bytes).expect("decode of valid work order") {
                WireMsg::Work(back) => assert_eq!(back, order),
                other => panic!("decoded wrong variant {other:?}"),
            }
        });
    }

    #[test]
    fn codec_worker_report_roundtrips() {
        use crate::net::codec::{decode, encode};
        use crate::net::WireMsg;
        run(Config::default().cases(200).name("codec-report"), |rng| {
            let report = gen::worker_report(rng);
            let bytes = encode(&WireMsg::Report(report.clone()));
            match decode(&bytes).expect("decode of valid report") {
                WireMsg::Report(back) => assert_eq!(back, report),
                other => panic!("decoded wrong variant {other:?}"),
            }
        });
    }

    #[test]
    fn codec_rejects_every_truncation() {
        use crate::net::codec::{decode, encode};
        use crate::net::WireMsg;
        run(Config::default().cases(40).name("codec-truncation"), |rng| {
            // The v5 tracing section is deliberately a *suffix*: a traced
            // report cut at exactly -48 bytes IS a valid untraced frame.
            // Strict-prefix rejection therefore holds for the core layout
            // only, so strip the optional breakdown before encoding.
            let mut report = gen::worker_report(rng);
            report.breakdown = None;
            let bytes = encode(&WireMsg::Report(report));
            for cut in 0..bytes.len() {
                assert!(
                    decode(&bytes[..cut]).is_err(),
                    "strict prefix of {cut}/{} bytes decoded",
                    bytes.len()
                );
            }
        });
    }

    #[test]
    fn shard_global_local_mapping_round_trips() {
        use crate::storage::StorageView;
        run(Config::default().cases(120).name("shard-mapping"), |rng| {
            let shard = gen::row_shard(rng);
            let resident = shard.resident_rows();
            // local → global → local is the identity on [0, resident)
            for local in 0..resident {
                let global = shard
                    .local_to_global(local)
                    .expect("local index within resident count");
                assert_eq!(
                    shard.global_to_local(global),
                    Some(local),
                    "row {global} did not round-trip"
                );
            }
            assert_eq!(shard.local_to_global(resident), None);
            // global → local round-trips exactly on resident rows
            let mut seen = 0usize;
            for global in 0..shard.global_rows() {
                let r = crate::linalg::partition::RowRange::new(global, global + 1);
                match shard.global_to_local(global) {
                    Some(local) => {
                        assert!(shard.holds(r));
                        assert_eq!(shard.local_to_global(local), Some(global));
                        seen += 1;
                    }
                    None => assert!(!shard.holds(r)),
                }
            }
            assert_eq!(seen, resident, "mapping and residency disagree");
        });
    }

    #[test]
    fn codec_data_frame_roundtrips() {
        use crate::net::codec::{decode, encode};
        use crate::net::WireMsg;
        run(Config::default().cases(200).name("codec-data"), |rng| {
            let frame = gen::data_frame(rng);
            let bytes = encode(&WireMsg::Data(frame.clone()));
            match decode(&bytes).expect("decode of valid data frame") {
                WireMsg::Data(back) => assert_eq!(back, frame),
                other => panic!("decoded wrong variant {other:?}"),
            }
        });
    }

    #[test]
    fn codec_data_frame_rejects_every_truncation() {
        use crate::net::codec::{decode, encode};
        use crate::net::WireMsg;
        run(Config::default().cases(40).name("codec-data-truncation"), |rng| {
            let bytes = encode(&WireMsg::Data(gen::data_frame(rng)));
            for cut in 0..bytes.len() {
                assert!(
                    decode(&bytes[..cut]).is_err(),
                    "strict prefix of {cut}/{} bytes decoded",
                    bytes.len()
                );
            }
        });
    }

    #[test]
    fn codec_data_frame_rejects_payload_corruption() {
        use crate::net::codec::{decode, encode};
        use crate::net::WireMsg;
        run(Config::default().cases(60).name("codec-data-corruption"), |rng| {
            let mut frame = gen::data_frame(rng);
            if frame.values.is_empty() {
                frame.rows = crate::linalg::partition::RowRange::new(0, 1);
                frame.values = vec![1.0; frame.cols];
            }
            let mut bytes = encode(&WireMsg::Data(frame.clone()));
            // flip one byte inside the trailing values region: either the
            // checksum or the value-count validation must catch it
            let values_bytes = frame.values.len() * 4;
            let idx = bytes.len() - 1 - rng.below(values_bytes);
            bytes[idx] ^= 1 << rng.below(8);
            assert!(decode(&bytes).is_err(), "corrupted payload decoded");
        });
    }

    #[test]
    fn malformed_frames_rejected() {
        use crate::net::frame::read_frame;
        use std::io::Cursor;
        run(Config::default().cases(50).name("frame-garbage-length"), |rng| {
            // a length prefix beyond MAX_FRAME must be rejected before any
            // allocation, whatever follows
            let bogus = (crate::net::frame::MAX_FRAME as u32)
                .saturating_add(1 + rng.below(1 << 20) as u32);
            let mut buf = bogus.to_le_bytes().to_vec();
            buf.extend_from_slice(&[0xAB; 8]);
            assert!(read_frame(&mut Cursor::new(buf)).is_err());
        });
    }
}
