//! Test support: mini property-testing framework — see [`prop`].

pub mod prop;
