//! Test support: mini property-testing framework ([`prop`]) and
//! chaos-soak helpers ([`chaos`]).

pub mod chaos;
pub mod prop;
