//! Chaos-soak support: canned fault schedules, a soak-cell config
//! builder, and a deadline-bounded runner — shared by
//! `tests/chaos_soak.rs` and `benches/chaos.rs`.
//!
//! The soak matrix crosses the four canned fault classes with batch
//! width and straggler tolerance; every cell runs with recovery,
//! rebalancing, and pipelining on, so the full robustness surface is
//! exercised at once. Each cell must either match the fault-free oracle
//! (the product `y_t = X w_t` is assignment-invariant, so a recovered
//! run lands on the same trajectory) or return a typed error — and must
//! do either before the deadline.

use std::sync::mpsc::{channel, RecvTimeoutError};
use std::time::Duration;

use crate::apps::{run_power_iteration, PowerIterationResult};
use crate::config::types::RunConfig;
use crate::error::{Error, Result};
use crate::rebalance::RebalanceConfig;
use crate::sched::recovery::RecoveryPolicy;

/// The four canned soak fault classes, each as a `--chaos` schedule
/// kept mild enough that a recovered run still terminates quickly:
/// order drops, delivery delays, a two-step asymmetric partition, and a
/// crash-then-restart.
pub fn soak_schedules() -> Vec<(&'static str, &'static str)> {
    vec![
        ("drop", "drop=0.1"),
        ("delay", "delay=5:0.3,dup=0.1"),
        ("partition", "partition=1@1..3"),
        ("crash-restart", "crash=2@2+2"),
    ]
}

/// One soak cell's config: a small planted-matrix power iteration with
/// recovery, rebalancing, and pipelining all on. `chaos` is left empty —
/// the caller sets it (the oracle run keeps it empty).
pub fn soak_config(batch: usize, stragglers: usize) -> RunConfig {
    RunConfig {
        q: 96,
        r: 96,
        g: 6,
        j: 3,
        n: 6,
        steps: 6,
        batch,
        stragglers,
        speeds: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
        seed: 11,
        recovery: RecoveryPolicy {
            enabled: true,
            overdue_factor: 0.5,
        },
        rebalance: RebalanceConfig {
            enabled: true,
            ..Default::default()
        },
        pipeline: true,
        ..Default::default()
    }
}

/// Run a config on a worker thread and fail with a typed error if it
/// neither finishes nor errors before `deadline` — the soak matrix's
/// no-hang guarantee. (A run that does hang leaks its thread; the test
/// process is about to fail anyway.)
pub fn run_with_deadline(
    cfg: &RunConfig,
    deadline: Duration,
) -> Result<PowerIterationResult> {
    let cfg = cfg.clone();
    let (tx, rx) = channel();
    std::thread::Builder::new()
        .name("usec-soak".into())
        .spawn(move || {
            let _ = tx.send(run_power_iteration(&cfg));
        })
        .expect("spawn soak runner");
    rx.recv_timeout(deadline).map_err(|e| match e {
        RecvTimeoutError::Timeout => {
            Error::Cluster(format!("soak run exceeded the {deadline:?} deadline"))
        }
        // sender dropped without sending: the runner thread panicked
        RecvTimeoutError::Disconnected => {
            Error::Cluster("soak run panicked before producing a result".into())
        }
    })?
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn soak_configs_validate_with_every_schedule() {
        for batch in [1, 8] {
            for s in [0, 1] {
                for (_, sched) in soak_schedules() {
                    let mut cfg = soak_config(batch, s);
                    cfg.chaos = sched.to_string();
                    cfg.validate().unwrap();
                }
            }
        }
    }

    #[test]
    fn deadline_runner_times_out_instead_of_hanging() {
        // a real (fault-free) run of this size takes well under a second;
        // an absurdly short deadline must surface as a typed error
        let cfg = soak_config(1, 0);
        let err = run_with_deadline(&cfg, Duration::from_nanos(1)).unwrap_err();
        assert!(err.to_string().contains("deadline"), "{err}");
    }
}
