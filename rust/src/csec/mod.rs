//! CSEC baseline — Coded Storage Elastic Computing (Yang et al. \[1\],
//! heterogeneous variant of Woolsey et al. \[5\]).
//!
//! The system the paper positions USEC *against*. `X` is row-partitioned
//! into `L` blocks; machine `n` stores one *coded* block
//! `C_n = Σ_l A[n,l] · X_l` (an MDS-style combination, `1/L` of the
//! uncoded storage). Every machine's coded block is row-aligned, so coded
//! row `i` computed at any `L` distinct machines decodes — via the
//! coding matrix restricted to those machines — into row `i` of all `L`
//! original blocks.
//!
//! Trade-off demonstrated by `benches/ablation_csec_baseline.rs`:
//!
//! * CSEC reaches the *unconstrained* optimum `c* = (coded rows)·L/Σs`
//!   with only `1/L` storage — placement never binds because every
//!   machine can substitute for any other.
//! * USEC pays `J×` storage but needs **no decode** (CSEC's master does an
//!   `L×L` solve per coded row) and no floating-point conditioning risk,
//!   and works for computations that don't commute with linear coding —
//!   the paper's motivation.

pub mod coding;
pub mod pipeline;

pub use coding::CodingMatrix;
pub use pipeline::{csec_optimal_time, CsecSystem};
