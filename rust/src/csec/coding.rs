//! MDS-style coding matrices over the reals.
//!
//! Any `L` rows of the `N×L` coding matrix must be invertible — and,
//! because we decode in floating point, *well-conditioned*. A Vandermonde
//! matrix on Chebyshev nodes keeps every `L×L` minor invertible with
//! moderate condition numbers at the small `L` used here.

use crate::error::{Error, Result};
use crate::linalg::solve::Lu;

/// An `N×L` real MDS coding matrix.
#[derive(Debug, Clone)]
pub struct CodingMatrix {
    n: usize,
    l: usize,
    /// Row-major `n×l`.
    a: Vec<f64>,
}

impl CodingMatrix {
    /// Vandermonde on Chebyshev nodes: `A[n, l] = T_l(x_n)` with
    /// `x_n = cos(π(2n+1)/(2N))` — i.e. columns are Chebyshev polynomials
    /// evaluated at distinct nodes, so every minor is nonsingular.
    pub fn chebyshev(n: usize, l: usize) -> Result<CodingMatrix> {
        if l == 0 || l > n {
            return Err(Error::Config(format!("coding needs 1 ≤ L ≤ N (L={l}, N={n})")));
        }
        let mut a = vec![0.0; n * l];
        for row in 0..n {
            let x = (std::f64::consts::PI * (2.0 * row as f64 + 1.0) / (2.0 * n as f64)).cos();
            // Chebyshev recurrence T_0 = 1, T_1 = x, T_k = 2x T_{k-1} − T_{k-2}
            let mut t_prev = 1.0;
            let mut t_cur = x;
            for col in 0..l {
                let v = match col {
                    0 => 1.0,
                    1 => x,
                    _ => {
                        let t_next = 2.0 * x * t_cur - t_prev;
                        t_prev = t_cur;
                        t_cur = t_next;
                        t_next
                    }
                };
                a[row * l + col] = v;
            }
        }
        Ok(CodingMatrix { n, l, a })
    }

    pub fn machines(&self) -> usize {
        self.n
    }

    pub fn blocks(&self) -> usize {
        self.l
    }

    /// Coefficients of machine `n`'s stored combination.
    pub fn row(&self, n: usize) -> &[f64] {
        &self.a[n * self.l..(n + 1) * self.l]
    }

    /// LU of the sub-matrix restricted to `machines` (must have length L).
    pub fn restricted_lu(&self, machines: &[usize]) -> Result<Lu> {
        if machines.len() != self.l {
            return Err(Error::Shape(format!(
                "decode needs exactly L={} machines, got {}",
                self.l,
                machines.len()
            )));
        }
        let mut sub = Vec::with_capacity(self.l * self.l);
        for &m in machines {
            if m >= self.n {
                return Err(Error::Config(format!("machine {m} out of range")));
            }
            sub.extend_from_slice(self.row(m));
        }
        Lu::factor(&sub, self.l, 1e-12)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dimensions_and_rows() {
        let c = CodingMatrix::chebyshev(6, 3).unwrap();
        assert_eq!(c.machines(), 6);
        assert_eq!(c.blocks(), 3);
        assert_eq!(c.row(0).len(), 3);
        assert_eq!(c.row(2)[0], 1.0); // T_0 ≡ 1
    }

    #[test]
    fn every_minor_invertible() {
        let c = CodingMatrix::chebyshev(6, 3).unwrap();
        // all C(6,3) = 20 subsets decode
        for subset in crate::placement::builders::combinations(6, 3) {
            c.restricted_lu(&subset).unwrap();
        }
    }

    #[test]
    fn decode_roundtrip() {
        // encode a known y-vector, decode from an arbitrary subset
        let c = CodingMatrix::chebyshev(5, 3).unwrap();
        let y = [2.0, -1.0, 0.5]; // per-block values at one row index
        let coded: Vec<f64> = (0..5)
            .map(|m| c.row(m).iter().zip(&y).map(|(a, v)| a * v).sum())
            .collect();
        let subset = [0usize, 2, 4];
        let lu = c.restricted_lu(&subset).unwrap();
        let rhs: Vec<f64> = subset.iter().map(|&m| coded[m]).collect();
        let decoded = lu.solve(&rhs).unwrap();
        for (d, t) in decoded.iter().zip(&y) {
            assert!((d - t).abs() < 1e-10, "{d} vs {t}");
        }
    }

    #[test]
    fn rejects_bad_params() {
        assert!(CodingMatrix::chebyshev(3, 4).is_err());
        assert!(CodingMatrix::chebyshev(3, 0).is_err());
        let c = CodingMatrix::chebyshev(4, 2).unwrap();
        assert!(c.restricted_lu(&[0]).is_err());
        assert!(c.restricted_lu(&[0, 9]).is_err());
    }
}
