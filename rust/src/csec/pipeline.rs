//! The CSEC compute pipeline: encode → assign (filling over coded rows) →
//! coded mat-vec → decode, all in-process (the baseline does not need the
//! threaded cluster to make the comparison — compute cost and decode cost
//! are measured directly).

use crate::error::{Error, Result};
use crate::linalg::partition::{quantize_fractions, submatrix_ranges};
use crate::linalg::{ops, Matrix};
use crate::optim::filling;

use super::coding::CodingMatrix;

/// A CSEC deployment: every machine holds one coded block of `q/L` rows.
pub struct CsecSystem {
    coding: CodingMatrix,
    /// Coded blocks, one `q/L × r` matrix per machine.
    coded: Vec<Matrix>,
    block_rows: usize,
    cols: usize,
}

impl CsecSystem {
    /// Encode `x` into `n` coded blocks with recovery threshold `l`.
    /// Requires `l | x.rows()`.
    pub fn encode(x: &Matrix, n: usize, l: usize) -> Result<CsecSystem> {
        if x.rows() % l != 0 {
            return Err(Error::Shape(format!(
                "CSEC needs L | q (q={}, L={l})",
                x.rows()
            )));
        }
        let coding = CodingMatrix::chebyshev(n, l)?;
        let block_rows = x.rows() / l;
        let parts = submatrix_ranges(x.rows(), l)?;
        let mut coded = Vec::with_capacity(n);
        for m in 0..n {
            let coeffs = coding.row(m);
            let mut c = Matrix::zeros(block_rows, x.cols());
            for (li, part) in parts.iter().enumerate() {
                let a = coeffs[li] as f32;
                let src = x.row_block(part.lo, part.hi);
                let dst = c.data_mut();
                for (d, s) in dst.iter_mut().zip(src) {
                    *d += a * s;
                }
            }
            coded.push(c);
        }
        Ok(CsecSystem {
            coding,
            coded,
            block_rows,
            cols: x.cols(),
        })
    }

    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Storage per machine as a fraction of `X` (CSEC's selling point).
    pub fn storage_fraction(&self) -> f64 {
        1.0 / self.coding.blocks() as f64
    }

    /// One coded elastic step: assign coded rows to the available machines
    /// by the filling algorithm (coverage `L`), compute, decode, return
    /// `y = X w` plus the realized computation time in sub-matrix units.
    pub fn step(&self, avail: &[usize], speeds: &[f64], w: &[f32]) -> Result<(Vec<f32>, f64)> {
        let l = self.coding.blocks();
        if avail.len() < l {
            return Err(Error::infeasible(format!(
                "CSEC needs ≥ L={l} machines, {} available",
                avail.len()
            )));
        }
        if w.len() != self.cols {
            return Err(Error::Shape(format!("w of {} for r={}", w.len(), self.cols)));
        }

        // Optimal fractional loads: every machine stores the whole coded
        // block, so the relaxed program has no placement constraint — the
        // water-filled optimum is proportional-to-speed, capped at 1.
        let loads = proportional_loads(avail, speeds, l as f64)?;
        let f = filling::fill(&loads, l)?;
        let row_sets = quantize_fractions(&f.alphas, self.block_rows)?;

        // Compute: machine m computes its coded rows for every row set
        // containing it. Realized time = max load/speed.
        let mut realized: f64 = 0.0;
        for &(m, mu) in &loads {
            realized = realized.max(mu / speeds[m]);
        }

        // Per row set: L machines computed those coded rows → decode.
        let mut y = vec![0.0f32; self.block_rows * l];
        for (p, rows) in f.psets.iter().zip(&row_sets) {
            if rows.is_empty() {
                continue;
            }
            let lu = self.coding.restricted_lu(p)?;
            // coded results for this row set: one vector per machine in p
            let mut coded_vals = vec![0.0f64; p.len()];
            for i in rows.lo..rows.hi {
                for (k, &m) in p.iter().enumerate() {
                    let row = self.coded[m].row(i);
                    coded_vals[k] = ops::dot(row, w);
                }
                let decoded = lu.solve(&coded_vals)?;
                for (li, &v) in decoded.iter().enumerate() {
                    y[li * self.block_rows + i] = v as f32;
                }
            }
        }
        Ok((y, realized))
    }
}

/// Water-filling of `total` units proportional to speed with per-machine
/// cap 1 (the CSEC relaxed optimum when storage never binds).
fn proportional_loads(avail: &[usize], speeds: &[f64], total: f64) -> Result<Vec<(usize, f64)>> {
    let mut remaining = total;
    let mut active: Vec<usize> = avail.to_vec();
    let mut load = vec![0.0f64; speeds.len()];
    // iteratively cap machines that would exceed μ = 1
    for _ in 0..avail.len() + 1 {
        let speed_sum: f64 = active.iter().map(|&m| speeds[m]).sum();
        if speed_sum <= 0.0 {
            return Err(Error::infeasible("no capacity left in CSEC assignment"));
        }
        let mut capped = Vec::new();
        let mut assigned = 0.0;
        for &m in &active {
            let share = remaining * speeds[m] / speed_sum;
            if share >= 1.0 - 1e-12 {
                load[m] = 1.0;
                assigned += 1.0;
                capped.push(m);
            }
        }
        if capped.is_empty() {
            for &m in &active {
                load[m] = remaining * speeds[m] / speed_sum;
            }
            remaining = 0.0;
            break;
        }
        active.retain(|m| !capped.contains(m));
        remaining -= assigned;
        if active.is_empty() {
            break;
        }
    }
    if remaining > 1e-9 {
        return Err(Error::infeasible(format!(
            "CSEC could not place {remaining} units (all machines capped)"
        )));
    }
    Ok(avail
        .iter()
        .map(|&m| (m, load[m]))
        .filter(|&(_, x)| x > 0.0)
        .collect())
}

/// The CSEC optimal computation time for the given availability/speeds:
/// `max(L/Σs, 1/max_k …)` — equals the water-filled bottleneck.
pub fn csec_optimal_time(avail: &[usize], speeds: &[f64], l: usize) -> Result<f64> {
    let loads = proportional_loads(avail, speeds, l as f64)?;
    Ok(loads
        .iter()
        .map(|&(m, mu)| mu / speeds[m])
        .fold(0.0, f64::max))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::gen;

    #[test]
    fn encode_decodes_exactly() {
        let x = gen::random_dense(60, 40, 3);
        let sys = CsecSystem::encode(&x, 6, 3).unwrap();
        assert_eq!(sys.block_rows(), 20);
        assert!((sys.storage_fraction() - 1.0 / 3.0).abs() < 1e-12);
        let w: Vec<f32> = (0..40).map(|i| (i as f32) * 0.05 - 1.0).collect();
        let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let avail: Vec<usize> = (0..6).collect();
        let (y, time) = sys.step(&avail, &speeds, &w).unwrap();
        let want = x.matvec(&w).unwrap();
        for (a, e) in y.iter().zip(&want) {
            assert!((a - e).abs() < 2e-3 * (1.0 + e.abs()), "{a} vs {e}");
        }
        assert!(time > 0.0);
    }

    #[test]
    fn elastic_subset_still_decodes() {
        let x = gen::random_dense(30, 24, 4);
        let sys = CsecSystem::encode(&x, 6, 3).unwrap();
        let w = vec![0.25f32; 24];
        let speeds = vec![1.0; 6];
        // only 3 machines up — exactly the recovery threshold
        let (y, _) = sys.step(&[1, 3, 5], &speeds, &w).unwrap();
        let want = x.matvec(&w).unwrap();
        for (a, e) in y.iter().zip(&want) {
            assert!((a - e).abs() < 2e-3 * (1.0 + e.abs()));
        }
    }

    #[test]
    fn below_threshold_rejected() {
        let x = gen::random_dense(30, 10, 5);
        let sys = CsecSystem::encode(&x, 6, 3).unwrap();
        assert!(sys.step(&[0, 1], &vec![1.0; 6], &vec![0.0; 10]).is_err());
    }

    #[test]
    fn optimal_time_matches_work_conservation_when_uncapped() {
        // total speed large relative to L ⇒ no caps ⇒ c = L/Σs
        let speeds = vec![2.0, 3.0, 5.0, 7.0, 11.0, 13.0];
        let avail: Vec<usize> = (0..6).collect();
        let c = csec_optimal_time(&avail, &speeds, 3).unwrap();
        let sum: f64 = speeds.iter().sum();
        assert!((c - 3.0 / sum).abs() < 1e-9);
    }

    #[test]
    fn caps_respected_with_dominant_machine() {
        // one machine so fast the proportional share would exceed 1
        let speeds = vec![100.0, 1.0, 1.0, 1.0];
        let avail: Vec<usize> = (0..4).collect();
        let c = csec_optimal_time(&avail, &speeds, 2).unwrap();
        // machine 0 capped at μ=1 → c ≥ 1/100; remaining 1 unit over the
        // three slow machines → c = (1/3)/1
        assert!((c - 1.0 / 3.0).abs() < 1e-9, "c = {c}");
    }

    #[test]
    fn csec_beats_usec_repetition_under_elasticity() {
        // the structural advantage: coded storage never strands work
        let speeds = vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0];
        let avail: Vec<usize> = (0..6).collect();
        let c_csec = csec_optimal_time(&avail, &speeds, 3).unwrap();
        let p = crate::placement::Placement::build(
            crate::placement::PlacementKind::Repetition,
            6,
            6,
            3,
        )
        .unwrap();
        // USEC repetition at G=6: paper value 3/7 in sub-matrix units →
        // normalize to per-X units (÷ G) for comparison
        let sol = crate::optim::solve_load_matrix(
            &p,
            &avail,
            &speeds.iter().map(|s| s * 6.0).collect::<Vec<_>>(),
            &crate::optim::SolveParams::default(),
        )
        .unwrap();
        // CSEC time is per coded block of q/3 rows at coverage 3: per-X
        // normalize by L as well
        assert!(
            c_csec / 3.0 <= sol.time + 1e-9,
            "csec {} vs usec repetition {}",
            c_csec / 3.0,
            sol.time
        );
    }
}
