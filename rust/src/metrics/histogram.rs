//! Fixed-bucket histogram over a closed range (Fig. 2 reproduction).

/// A histogram with `buckets` equal-width bins over `[lo, hi)`.
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    counts: Vec<u64>,
    below: u64,
    above: u64,
    nan: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, buckets: usize) -> Self {
        assert!(hi > lo && buckets > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0; buckets],
            below: 0,
            above: 0,
            nan: 0,
        }
    }

    pub fn push(&mut self, x: f64) {
        // NaN compares false against both bounds, so without this check it
        // would cast to bucket 0 and silently skew the distribution.
        if x.is_nan() {
            self.nan += 1;
        } else if x < self.lo {
            self.below += 1;
        } else if x >= self.hi {
            self.above += 1;
        } else {
            let b = ((x - self.lo) / (self.hi - self.lo) * self.counts.len() as f64) as usize;
            let last = self.counts.len() - 1;
            self.counts[b.min(last)] += 1;
        }
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    pub fn outliers(&self) -> (u64, u64) {
        (self.below, self.above)
    }

    /// Samples rejected as NaN (distinct from the range outliers).
    pub fn nan_count(&self) -> u64 {
        self.nan
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.below + self.above + self.nan
    }

    /// Bucket midpoints (x-axis for plotting/reporting).
    pub fn midpoints(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }

    /// Render as an ASCII bar chart.
    pub fn render(&self, width: usize) -> String {
        let max = self.counts.iter().copied().max().unwrap_or(1).max(1);
        let mids = self.midpoints();
        let mut out = String::new();
        for (m, &c) in mids.iter().zip(&self.counts) {
            let bar = "#".repeat((c as usize * width) / max as usize);
            out.push_str(&format!("{m:8.4} | {bar} {c}\n"));
        }
        if self.below + self.above > 0 {
            out.push_str(&format!(
                "(outliers: {} below, {} above)\n",
                self.below, self.above
            ));
        }
        if self.nan > 0 {
            out.push_str(&format!("({} NaN samples rejected)\n", self.nan));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_and_outliers() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        for &x in &[-0.1, 0.0, 0.1, 0.3, 0.6, 0.9, 1.0, 2.0] {
            h.push(x);
        }
        assert_eq!(h.counts(), &[2, 1, 1, 1]);
        assert_eq!(h.outliers(), (1, 2));
        assert_eq!(h.total(), 8);
    }

    #[test]
    fn nan_is_counted_apart_not_binned() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.push(f64::NAN);
        h.push(0.1);
        h.push(f64::NAN);
        assert_eq!(h.counts(), &[1, 0, 0, 0], "NaN must not land in bucket 0");
        assert_eq!(h.outliers(), (0, 0), "NaN is not a range outlier");
        assert_eq!(h.nan_count(), 2);
        assert_eq!(h.total(), 3);
        assert!(h.render(10).contains("2 NaN"));
    }

    #[test]
    fn midpoints_centered() {
        let h = Histogram::new(0.0, 1.0, 2);
        assert_eq!(h.midpoints(), vec![0.25, 0.75]);
    }

    #[test]
    fn render_contains_counts() {
        let mut h = Histogram::new(0.0, 1.0, 2);
        h.push(0.1);
        h.push(0.2);
        let r = h.render(10);
        assert!(r.contains("2"));
    }
}
