//! Measurement: streaming statistics, histograms, per-step timelines.

pub mod histogram;
pub mod rolling;
pub mod stats;
pub mod timeline;

pub use histogram::Histogram;
pub use rolling::RollingHistogram;
pub use stats::Stats;
pub use timeline::{ServeSummary, StepRecord, Timeline};
