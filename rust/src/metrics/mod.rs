//! Measurement: streaming statistics, histograms, per-step timelines.

pub mod histogram;
pub mod stats;
pub mod timeline;

pub use histogram::Histogram;
pub use stats::Stats;
pub use timeline::{ServeSummary, StepRecord, Timeline};
