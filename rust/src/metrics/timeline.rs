//! Per-step records of an elastic run (Fig. 4 series + EXPERIMENTS.md logs).

use std::time::Duration;

use crate::obs::CounterSnapshot;
use crate::rebalance::MigrationRecord;
use crate::sched::recovery::RecoveryEvent;
use crate::util::json::{Json, ObjBuilder};

/// What happened in one elastic computation step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    /// Machines available this step (`|N_t|`).
    pub available: usize,
    /// Machines that actually reported (≥ `available − S`).
    pub reported: usize,
    /// Stragglers injected this step.
    pub stragglers: usize,
    /// Wall-clock time of the step (scheduling + compute + combine).
    pub wall: Duration,
    /// Time spent solving the assignment problem.
    pub solve: Duration,
    /// Predicted computation time `c(M*)` in sub-matrix units.
    pub predicted_c: f64,
    /// Application metric (power iteration: NMSE vs true eigenvector).
    pub metric: f64,
    /// Mid-step recoveries: victims whose uncovered rows were
    /// re-dispatched to surviving replicas (empty unless `--recovery`).
    pub recoveries: Vec<RecoveryEvent>,
    /// Replica moves executed in this step's inter-step window (empty
    /// unless `--rebalance` fired): bytes moved plus the before/after
    /// expected time of the plan they belong to.
    pub migrations: Vec<MigrationRecord>,
    /// Per-worker cumulative counters snapshotted at the end of this step
    /// ([`crate::obs::Registry::snapshot`]). Empty when no counter
    /// registry is attached (tracing off).
    pub counters: Vec<CounterSnapshot>,
    /// Order round-trip quantiles over this step's traced orders, in
    /// milliseconds (NaN when untraced or no orders closed).
    pub rtt_p50_ms: f64,
    pub rtt_p99_ms: f64,
    /// Worker-reported compute-time quantiles over this step's traced
    /// orders, in milliseconds (NaN when no breakdowns arrived).
    pub compute_p50_ms: f64,
    pub compute_p99_ms: f64,
    /// Master-side combine/finish time for the *previous* step that ran
    /// concurrently with this step's worker compute (`--pipeline`). Zero
    /// in the synchronous loop, where the key is omitted from the JSON
    /// so sync dumps stay byte-identical to the pre-pipeline schema.
    pub overlap_ns: u64,
    /// Chaos faults injected during this step (`--chaos`). Zero in
    /// fault-free runs, where the key is omitted from the JSON.
    pub faults: u64,
    /// Backed-off retry attempts (dial/readmit) made during this step.
    /// Zero on healthy steps, where the key is omitted from the JSON.
    pub retries: u64,
    /// True when a checkpoint was written at the end of this step
    /// (`--checkpoint-out`); the key is omitted when false.
    pub checkpoint: bool,
}

/// Request-plane totals of a `usec serve` session
/// ([`crate::serve::ServeSession`]). Attached to the [`Timeline`] when
/// the run served requests; absent (and absent from the JSON) for
/// classic one-job runs, keeping their dumps byte-identical.
#[derive(Debug, Clone, Default)]
pub struct ServeSummary {
    /// Requests completed (answered) over the session.
    pub requests: u64,
    /// Submit→answer latency quantiles, in nanoseconds (NaN when no
    /// request completed).
    pub latency_p50_ns: f64,
    pub latency_p99_ns: f64,
    /// Peak admission-queue depth observed.
    pub queue_depth: u64,
    /// Iterate rows computed per second of serving wall-clock.
    pub rows_per_s: f64,
}

/// An append-only run log.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    steps: Vec<StepRecord>,
    /// Matrix payload bytes resident per worker (what the storage layer
    /// actually materialized — the placement's J/G share for distributed
    /// shard workers, the shared full view locally). Empty when unknown.
    storage_bytes: Vec<u64>,
    /// Serving totals, present only for `usec serve` sessions.
    serve: Option<ServeSummary>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the per-worker resident storage snapshot.
    pub fn set_storage_bytes(&mut self, bytes: Vec<u64>) {
        self.storage_bytes = bytes;
    }

    /// Per-worker resident storage bytes (empty when unknown).
    pub fn storage_bytes(&self) -> &[u64] {
        &self.storage_bytes
    }

    /// Attach serving totals (request counts, latency quantiles).
    pub fn set_serve(&mut self, s: ServeSummary) {
        self.serve = Some(s);
    }

    /// Serving totals, when this run served requests.
    pub fn serve(&self) -> Option<&ServeSummary> {
        self.serve.as_ref()
    }

    pub fn push(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    pub fn steps(&self) -> &[StepRecord] {
        &self.steps
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Total wall-clock across steps.
    pub fn total_wall(&self) -> Duration {
        self.steps.iter().map(|s| s.wall).sum()
    }

    /// Cumulative (elapsed, metric) series — the Fig. 4 y-vs-x data.
    pub fn metric_series(&self) -> Vec<(f64, f64)> {
        let mut t = 0.0;
        self.steps
            .iter()
            .map(|s| {
                t += s.wall.as_secs_f64();
                (t, s.metric)
            })
            .collect()
    }

    /// First elapsed time at which the metric drops below `threshold`.
    pub fn time_to_metric(&self, threshold: f64) -> Option<f64> {
        self.metric_series()
            .into_iter()
            .find(|&(_, m)| m < threshold)
            .map(|(t, _)| t)
    }

    /// JSON dump: one object per step plus cumulative elapsed seconds —
    /// the machine-readable twin of [`Timeline::to_csv`] (`--json-out`),
    /// so benches and the net integration tests can diff runs.
    pub fn to_json(&self) -> Json {
        // NaN (skipped steps carry NaN metrics) is not valid JSON — null.
        let num_or_null = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let mut t = 0.0;
        let steps: Vec<Json> = self
            .steps
            .iter()
            .map(|s| {
                t += s.wall.as_secs_f64();
                let recoveries: Vec<Json> = s
                    .recoveries
                    .iter()
                    .map(|r| {
                        ObjBuilder::new()
                            .num("victim", r.victim as f64)
                            .str("reason", r.reason.name())
                            .num("rows", r.rows as f64)
                            .val(
                                "rescuers",
                                Json::Arr(
                                    r.rescuers.iter().map(|&n| Json::Num(n as f64)).collect(),
                                ),
                            )
                            .build()
                    })
                    .collect();
                let migrations: Vec<Json> = s
                    .migrations
                    .iter()
                    .map(|m| {
                        ObjBuilder::new()
                            .num("g", m.g as f64)
                            .num("from", m.from as f64)
                            .num("to", m.to as f64)
                            .num("rows", m.rows as f64)
                            .num("bytes", m.bytes as f64)
                            .val("expected_before", num_or_null(m.expected_before))
                            .val("expected_after", num_or_null(m.expected_after))
                            .build()
                    })
                    .collect();
                let counters: Vec<Json> =
                    s.counters.iter().map(|c| c.to_json()).collect();
                let mut b = ObjBuilder::new()
                    .num("step", s.step as f64)
                    .num("available", s.available as f64)
                    .num("reported", s.reported as f64)
                    .num("stragglers", s.stragglers as f64)
                    .num("wall_s", s.wall.as_secs_f64())
                    .num("elapsed_s", t)
                    .num("solve_s", s.solve.as_secs_f64())
                    .val("predicted_c", num_or_null(s.predicted_c))
                    .val("metric", num_or_null(s.metric));
                // pipelined runs only: overlapped master-side work
                if s.overlap_ns > 0 {
                    b = b.num("overlap_ns", s.overlap_ns as f64);
                }
                // robustness keys only when something actually happened,
                // so fault-free dumps keep the pre-chaos schema bytes
                if s.faults > 0 {
                    b = b.num("faults", s.faults as f64);
                }
                if s.retries > 0 {
                    b = b.num("retries", s.retries as f64);
                }
                if s.checkpoint {
                    b = b.val("checkpoint", Json::Bool(true));
                }
                // tracing tail only on traced steps, so untraced dumps stay
                // byte-identical to the pre-tracing schema
                if !s.counters.is_empty() {
                    b = b
                        .val("rtt_p50_ms", num_or_null(s.rtt_p50_ms))
                        .val("rtt_p99_ms", num_or_null(s.rtt_p99_ms))
                        .val("compute_p50_ms", num_or_null(s.compute_p50_ms))
                        .val("compute_p99_ms", num_or_null(s.compute_p99_ms))
                        .val("counters", Json::Arr(counters));
                }
                b.val("recoveries", Json::Arr(recoveries))
                    .val("migrations", Json::Arr(migrations))
                    .build()
            })
            .collect();
        let per_worker: Vec<Json> = self
            .storage_bytes
            .iter()
            .map(|&b| Json::Num(b as f64))
            .collect();
        let storage = ObjBuilder::new()
            .num(
                "total_bytes",
                self.storage_bytes.iter().map(|&b| b as f64).sum::<f64>(),
            )
            .val("per_worker_bytes", Json::Arr(per_worker))
            .build();
        let mut top = ObjBuilder::new()
            .num("steps", self.steps.len() as f64)
            .num("total_wall_s", self.total_wall().as_secs_f64())
            .num("recoveries_total", self.total_recoveries() as f64)
            .num("migrations_total", self.total_migrations() as f64)
            .num("migrated_bytes_total", self.total_migrated_bytes() as f64);
        // serving keys only on serve sessions, so classic one-job dumps
        // keep the pre-serving schema bytes
        if let Some(s) = &self.serve {
            top = top
                .num("requests", s.requests as f64)
                .val("latency_p50_ns", num_or_null(s.latency_p50_ns))
                .val("latency_p99_ns", num_or_null(s.latency_p99_ns))
                .num("queue_depth", s.queue_depth as f64)
                .val("rows_per_s", num_or_null(s.rows_per_s));
        }
        top.val("storage", storage)
            .val("timeline", Json::Arr(steps))
            .build()
    }

    /// Mid-step recoveries across the whole run.
    pub fn total_recoveries(&self) -> usize {
        self.steps.iter().map(|s| s.recoveries.len()).sum()
    }

    /// Replica moves across the whole run (`--rebalance`).
    pub fn total_migrations(&self) -> usize {
        self.steps.iter().map(|s| s.migrations.len()).sum()
    }

    /// Payload bytes migrated across the whole run.
    pub fn total_migrated_bytes(&self) -> u64 {
        self.steps
            .iter()
            .flat_map(|s| s.migrations.iter().map(|m| m.bytes))
            .sum()
    }

    /// CSV dump — the flat twin of [`Timeline::to_json`]: one row per
    /// step with the same recovery/migration totals, order-RTT
    /// quantiles, and (on serve sessions) the request-plane totals. NaN
    /// quantiles (untraced runs) and the serve columns of non-serve runs
    /// render as empty fields so the CSV stays numeric-parseable.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "step,elapsed_s,metric,available,reported,solve_ms,\
             recoveries,migrations,migrated_bytes,rtt_p50_ms,rtt_p99_ms,\
             requests,latency_p50_ns,latency_p99_ns,queue_depth,rows_per_s\n",
        );
        let ms_or_empty = |v: f64| {
            if v.is_finite() {
                format!("{v:.3}")
            } else {
                String::new()
            }
        };
        // the serve summary is a run-level total; the flat format repeats
        // it on every row (constant per run, empty on non-serve runs)
        let serve_tail = match &self.serve {
            Some(s) => format!(
                "{},{},{},{},{}",
                s.requests,
                if s.latency_p50_ns.is_finite() {
                    format!("{:.0}", s.latency_p50_ns)
                } else {
                    String::new()
                },
                if s.latency_p99_ns.is_finite() {
                    format!("{:.0}", s.latency_p99_ns)
                } else {
                    String::new()
                },
                s.queue_depth,
                ms_or_empty(s.rows_per_s),
            ),
            None => ",,,,".to_string(),
        };
        let mut t = 0.0;
        for s in &self.steps {
            t += s.wall.as_secs_f64();
            let migrated: u64 = s.migrations.iter().map(|m| m.bytes).sum();
            out.push_str(&format!(
                "{},{:.6},{:.6e},{},{},{:.3},{},{},{},{},{},{}\n",
                s.step,
                t,
                s.metric,
                s.available,
                s.reported,
                s.solve.as_secs_f64() * 1e3,
                s.recoveries.len(),
                s.migrations.len(),
                migrated,
                ms_or_empty(s.rtt_p50_ms),
                ms_or_empty(s.rtt_p99_ms),
                serve_tail,
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, wall_ms: u64, metric: f64) -> StepRecord {
        StepRecord {
            step,
            available: 6,
            reported: 6,
            stragglers: 0,
            wall: Duration::from_millis(wall_ms),
            solve: Duration::from_micros(100),
            predicted_c: 0.15,
            metric,
            recoveries: Vec::new(),
            migrations: Vec::new(),
            counters: Vec::new(),
            rtt_p50_ms: f64::NAN,
            rtt_p99_ms: f64::NAN,
            compute_p50_ms: f64::NAN,
            compute_p99_ms: f64::NAN,
            overlap_ns: 0,
            faults: 0,
            retries: 0,
            checkpoint: false,
        }
    }

    #[test]
    fn series_accumulates_time() {
        let mut t = Timeline::new();
        t.push(rec(0, 100, 0.5));
        t.push(rec(1, 100, 0.05));
        let s = t.metric_series();
        assert!((s[0].0 - 0.1).abs() < 1e-9);
        assert!((s[1].0 - 0.2).abs() < 1e-9);
        assert_eq!(t.total_wall(), Duration::from_millis(200));
    }

    #[test]
    fn time_to_metric_threshold() {
        let mut t = Timeline::new();
        t.push(rec(0, 100, 0.5));
        t.push(rec(1, 100, 0.05));
        t.push(rec(2, 100, 0.001));
        assert!((t.time_to_metric(0.1).unwrap() - 0.2).abs() < 1e-9);
        assert!(t.time_to_metric(1e-9).is_none());
    }

    #[test]
    fn csv_has_rows() {
        let mut t = Timeline::new();
        t.push(rec(0, 10, 0.5));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("step,"));
    }

    #[test]
    fn csv_golden_row_matches_json_fields() {
        use crate::sched::recovery::{RecoveryEvent, RecoveryReason};
        let mut t = Timeline::new();
        let mut r = rec(3, 250, 0.0625);
        r.recoveries.push(RecoveryEvent {
            step: 3,
            victim: 1,
            reason: RecoveryReason::Overdue,
            rows: 10,
            rescuers: vec![0],
        });
        r.migrations.push(MigrationRecord {
            g: 0,
            from: 1,
            to: 2,
            rows: 20,
            bytes: 9600,
            expected_before: 0.5,
            expected_after: 0.4,
        });
        r.rtt_p50_ms = 12.5;
        r.rtt_p99_ms = 40.0;
        t.push(r);
        let csv = t.to_csv();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "step,elapsed_s,metric,available,reported,solve_ms,\
             recoveries,migrations,migrated_bytes,rtt_p50_ms,rtt_p99_ms,\
             requests,latency_p50_ns,latency_p99_ns,queue_depth,rows_per_s"
        );
        assert_eq!(
            lines.next().unwrap(),
            "3,0.250000,6.250000e-2,6,6,0.100,1,1,9600,12.500,40.000,,,,,"
        );
        // untraced steps leave the quantile fields empty, not NaN; a
        // non-serve run leaves all five serve columns empty too
        let mut t2 = Timeline::new();
        t2.push(rec(0, 10, 0.5));
        assert!(t2.to_csv().lines().nth(1).unwrap().ends_with(",0,0,0,,,,,,,"));
    }

    #[test]
    fn csv_serve_columns_golden_row() {
        let mut t = Timeline::new();
        t.push(rec(0, 10, 0.5));
        t.set_serve(ServeSummary {
            requests: 12,
            latency_p50_ns: 1_500_000.0,
            latency_p99_ns: 9_000_000.0,
            queue_depth: 5,
            rows_per_s: 48_000.0,
        });
        let csv = t.to_csv();
        let row = csv.lines().nth(1).unwrap();
        assert!(row.ends_with(",12,1500000,9000000,5,48000.000"), "{row}");
        // the header gained exactly the five serve columns
        assert_eq!(csv.lines().next().unwrap().matches(',').count(), 15);
    }

    #[test]
    fn counters_and_quantiles_surface_in_json() {
        let mut t = Timeline::new();
        let mut r = rec(0, 10, 0.5);
        r.counters = vec![CounterSnapshot {
            worker: 0,
            orders: 4,
            rows: 120,
            bytes_tx: 1000,
            ..Default::default()
        }];
        r.rtt_p50_ms = 2.0;
        r.rtt_p99_ms = 5.0;
        r.compute_p50_ms = 1.5;
        r.compute_p99_ms = 4.0;
        t.push(r);
        t.push(rec(1, 10, 0.1)); // untraced step: tracing keys absent entirely
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        let steps = back.get("timeline").unwrap().items().unwrap();
        let c = steps[0].get("counters").unwrap().items().unwrap();
        assert_eq!(c.len(), 1);
        assert_eq!(c[0].get_usize("orders"), Some(4));
        assert_eq!(c[0].get_usize("bytes_tx"), Some(1000));
        assert_eq!(steps[0].get_num("rtt_p50_ms"), Some(2.0));
        assert_eq!(steps[0].get_num("compute_p99_ms"), Some(4.0));
        // untraced steps carry no tracing keys, keeping the schema (and
        // byte output) identical to pre-tracing runs
        assert!(steps[1].get("rtt_p50_ms").is_none());
        assert!(steps[1].get("counters").is_none());
    }

    #[test]
    fn overlap_ns_surfaces_only_on_pipelined_steps() {
        let mut t = Timeline::new();
        let mut pipelined = rec(0, 10, 0.5);
        pipelined.overlap_ns = 2_500_000;
        t.push(pipelined);
        t.push(rec(1, 10, 0.1)); // synchronous step: key absent entirely
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        let steps = back.get("timeline").unwrap().items().unwrap();
        assert_eq!(steps[0].get_num("overlap_ns"), Some(2_500_000.0));
        assert!(
            steps[1].get("overlap_ns").is_none(),
            "sync dumps must stay byte-identical to the pre-pipeline schema"
        );
    }

    #[test]
    fn robustness_keys_surface_only_when_set() {
        let mut t = Timeline::new();
        let mut chaotic = rec(0, 10, 0.5);
        chaotic.faults = 3;
        chaotic.retries = 2;
        chaotic.checkpoint = true;
        t.push(chaotic);
        t.push(rec(1, 10, 0.1)); // fault-free step: keys absent entirely
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        let steps = back.get("timeline").unwrap().items().unwrap();
        assert_eq!(steps[0].get_num("faults"), Some(3.0));
        assert_eq!(steps[0].get_num("retries"), Some(2.0));
        assert_eq!(
            steps[0].get("checkpoint"),
            Some(&crate::util::json::Json::Bool(true))
        );
        for key in ["faults", "retries", "checkpoint"] {
            assert!(
                steps[1].get(key).is_none(),
                "fault-free dumps must stay byte-identical to the pre-chaos schema"
            );
        }
    }

    #[test]
    fn storage_bytes_surface_in_json() {
        let mut t = Timeline::new();
        t.push(rec(0, 10, 0.5));
        t.set_storage_bytes(vec![34_560, 34_560, 57_600]);
        assert_eq!(t.storage_bytes(), &[34_560, 34_560, 57_600]);
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        let storage = back.get("storage").unwrap();
        assert_eq!(storage.get_usize("total_bytes"), Some(126_720));
        let per = storage.get("per_worker_bytes").unwrap().items().unwrap();
        assert_eq!(per.len(), 3);
        assert_eq!(per[2].as_num(), Some(57_600.0));
    }

    #[test]
    fn recovery_events_surface_in_json() {
        use crate::sched::recovery::{RecoveryEvent, RecoveryReason};
        let mut t = Timeline::new();
        let mut r = rec(0, 10, 0.5);
        r.recoveries.push(RecoveryEvent {
            step: 0,
            victim: 2,
            reason: RecoveryReason::Disconnected,
            rows: 17,
            rescuers: vec![0, 4],
        });
        t.push(r);
        t.push(rec(1, 10, 0.1));
        assert_eq!(t.total_recoveries(), 1);
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        assert_eq!(back.get_usize("recoveries_total"), Some(1));
        let steps = back.get("timeline").unwrap().items().unwrap();
        let evs = steps[0].get("recoveries").unwrap().items().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get_usize("victim"), Some(2));
        assert_eq!(evs[0].get_str("reason"), Some("disconnected"));
        assert_eq!(evs[0].get_usize("rows"), Some(17));
        let rescuers = evs[0].get("rescuers").unwrap().items().unwrap();
        assert_eq!(rescuers.len(), 2);
        assert!(steps[1].get("recoveries").unwrap().items().unwrap().is_empty());
    }

    #[test]
    fn migration_records_surface_in_json() {
        let mut t = Timeline::new();
        let mut r = rec(0, 10, 0.5);
        r.migrations.push(MigrationRecord {
            g: 2,
            from: 4,
            to: 0,
            rows: 20,
            bytes: 9600,
            expected_before: 0.5,
            expected_after: 0.31,
        });
        t.push(r);
        t.push(rec(1, 10, 0.1));
        assert_eq!(t.total_migrations(), 1);
        assert_eq!(t.total_migrated_bytes(), 9600);
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        assert_eq!(back.get_usize("migrations_total"), Some(1));
        assert_eq!(back.get_usize("migrated_bytes_total"), Some(9600));
        let steps = back.get("timeline").unwrap().items().unwrap();
        let moves = steps[0].get("migrations").unwrap().items().unwrap();
        assert_eq!(moves.len(), 1);
        assert_eq!(moves[0].get_usize("g"), Some(2));
        assert_eq!(moves[0].get_usize("from"), Some(4));
        assert_eq!(moves[0].get_usize("to"), Some(0));
        assert_eq!(moves[0].get_usize("bytes"), Some(9600));
        assert!((moves[0].get_num("expected_before").unwrap() - 0.5).abs() < 1e-12);
        assert!((moves[0].get_num("expected_after").unwrap() - 0.31).abs() < 1e-12);
        assert!(steps[1].get("migrations").unwrap().items().unwrap().is_empty());
    }

    #[test]
    fn serve_keys_surface_only_on_serve_sessions() {
        let mut t = Timeline::new();
        t.push(rec(0, 10, 0.5));
        // a classic run: no serving keys at all
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        for key in [
            "requests",
            "latency_p50_ns",
            "latency_p99_ns",
            "queue_depth",
            "rows_per_s",
        ] {
            assert!(
                back.get(key).is_none(),
                "classic dumps must stay byte-identical to the pre-serving schema"
            );
        }
        // a serve session: totals land at the top level
        t.set_serve(ServeSummary {
            requests: 12,
            latency_p50_ns: 1_500_000.0,
            latency_p99_ns: 9_000_000.0,
            queue_depth: 5,
            rows_per_s: 48_000.0,
        });
        assert_eq!(t.serve().unwrap().requests, 12);
        let back = crate::util::json::Json::parse(&t.to_json().to_string()).unwrap();
        assert_eq!(back.get_usize("requests"), Some(12));
        assert_eq!(back.get_num("latency_p50_ns"), Some(1_500_000.0));
        assert_eq!(back.get_num("latency_p99_ns"), Some(9_000_000.0));
        assert_eq!(back.get_usize("queue_depth"), Some(5));
        assert_eq!(back.get_num("rows_per_s"), Some(48_000.0));
    }

    #[test]
    fn json_round_trips_and_nulls_nan() {
        let mut t = Timeline::new();
        t.push(rec(0, 100, 0.5));
        let mut skipped = rec(1, 0, f64::NAN);
        skipped.predicted_c = f64::NAN;
        t.push(skipped);
        let j = t.to_json();
        // parses back as valid JSON despite the NaN metric
        let text = j.to_string();
        let back = crate::util::json::Json::parse(&text).unwrap();
        assert_eq!(back.get_usize("steps"), Some(2));
        let steps = back.get("timeline").unwrap().items().unwrap();
        assert_eq!(steps.len(), 2);
        assert_eq!(steps[0].get_num("metric"), Some(0.5));
        assert_eq!(steps[1].get("metric"), Some(&crate::util::json::Json::Null));
        assert!((steps[1].get_num("elapsed_s").unwrap() - 0.1).abs() < 1e-9);
    }
}
