//! Per-step records of an elastic run (Fig. 4 series + EXPERIMENTS.md logs).

use std::time::Duration;

/// What happened in one elastic computation step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    /// Machines available this step (`|N_t|`).
    pub available: usize,
    /// Machines that actually reported (≥ `available − S`).
    pub reported: usize,
    /// Stragglers injected this step.
    pub stragglers: usize,
    /// Wall-clock time of the step (scheduling + compute + combine).
    pub wall: Duration,
    /// Time spent solving the assignment problem.
    pub solve: Duration,
    /// Predicted computation time `c(M*)` in sub-matrix units.
    pub predicted_c: f64,
    /// Application metric (power iteration: NMSE vs true eigenvector).
    pub metric: f64,
}

/// An append-only run log.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    steps: Vec<StepRecord>,
}

impl Timeline {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    pub fn steps(&self) -> &[StepRecord] {
        &self.steps
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Total wall-clock across steps.
    pub fn total_wall(&self) -> Duration {
        self.steps.iter().map(|s| s.wall).sum()
    }

    /// Cumulative (elapsed, metric) series — the Fig. 4 y-vs-x data.
    pub fn metric_series(&self) -> Vec<(f64, f64)> {
        let mut t = 0.0;
        self.steps
            .iter()
            .map(|s| {
                t += s.wall.as_secs_f64();
                (t, s.metric)
            })
            .collect()
    }

    /// First elapsed time at which the metric drops below `threshold`.
    pub fn time_to_metric(&self, threshold: f64) -> Option<f64> {
        self.metric_series()
            .into_iter()
            .find(|&(_, m)| m < threshold)
            .map(|(t, _)| t)
    }

    /// CSV dump (step, elapsed, metric, available, reported, solve_ms).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,elapsed_s,metric,available,reported,solve_ms\n");
        let mut t = 0.0;
        for s in &self.steps {
            t += s.wall.as_secs_f64();
            out.push_str(&format!(
                "{},{:.6},{:.6e},{},{},{:.3}\n",
                s.step,
                t,
                s.metric,
                s.available,
                s.reported,
                s.solve.as_secs_f64() * 1e3
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(step: usize, wall_ms: u64, metric: f64) -> StepRecord {
        StepRecord {
            step,
            available: 6,
            reported: 6,
            stragglers: 0,
            wall: Duration::from_millis(wall_ms),
            solve: Duration::from_micros(100),
            predicted_c: 0.15,
            metric,
        }
    }

    #[test]
    fn series_accumulates_time() {
        let mut t = Timeline::new();
        t.push(rec(0, 100, 0.5));
        t.push(rec(1, 100, 0.05));
        let s = t.metric_series();
        assert!((s[0].0 - 0.1).abs() < 1e-9);
        assert!((s[1].0 - 0.2).abs() < 1e-9);
        assert_eq!(t.total_wall(), Duration::from_millis(200));
    }

    #[test]
    fn time_to_metric_threshold() {
        let mut t = Timeline::new();
        t.push(rec(0, 100, 0.5));
        t.push(rec(1, 100, 0.05));
        t.push(rec(2, 100, 0.001));
        assert!((t.time_to_metric(0.1).unwrap() - 0.2).abs() < 1e-9);
        assert!(t.time_to_metric(1e-9).is_none());
    }

    #[test]
    fn csv_has_rows() {
        let mut t = Timeline::new();
        t.push(rec(0, 10, 0.5));
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("step,"));
    }
}
