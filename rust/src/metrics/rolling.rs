//! Sliding-window histogram: a ring of time-bucketed log-scale
//! histograms whose old slots decay out as the window advances.
//!
//! The live telemetry plane ([`crate::obs::telemetry`]) needs latency
//! quantiles over "the last few seconds", not over the whole run — a
//! tenant whose p99 was bad an hour ago but is fine now should scrape
//! as healthy. A [`RollingHistogram`] covers a wall-clock window split
//! into `slots` ring positions; each push lands in the slot owning the
//! current instant, and a slot is dropped wholesale once the window
//! slides past it. Values are binned on a log2 scale with 8 linear
//! sub-buckets per octave, so quantile estimates are within ~12.5% of
//! the true value (one bucket width) at any magnitude — the "bucket
//! resolution" the integration tests allow for.
//!
//! All methods take an explicit `now: Instant` variant so tests and
//! replays stay deterministic; the plain variants use `Instant::now()`.

use std::time::{Duration, Instant};

/// Linear sub-buckets per power of two.
const SUB: usize = 8;
/// Highest octave tracked (values up to 2^50 ≈ 13 days in ns).
const OCTAVES: usize = 50;
const BUCKETS: usize = OCTAVES * SUB;

#[derive(Debug, Clone)]
struct Slot {
    /// Absolute slot index this ring position currently holds; stale
    /// positions (lapped by the window) are reset lazily on touch.
    abs: u64,
    counts: Vec<u32>,
    count: u64,
    sum: f64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            abs: u64::MAX,
            counts: vec![0; BUCKETS],
            count: 0,
            sum: 0.0,
        }
    }

    fn reset(&mut self, abs: u64) {
        self.abs = abs;
        self.counts.iter_mut().for_each(|c| *c = 0);
        self.count = 0;
        self.sum = 0.0;
    }
}

/// Map a non-negative value to its log2/linear bucket.
fn bucket_of(v: f64) -> usize {
    if !(v >= 1.0) {
        // NaN and sub-unit values collapse into the first bucket.
        return 0;
    }
    let exp = v.log2().floor();
    let e = exp as usize;
    if e >= OCTAVES {
        return BUCKETS - 1;
    }
    // fraction through the octave, in [0, 1)
    let frac = v / exp.exp2() - 1.0;
    let s = ((frac * SUB as f64) as usize).min(SUB - 1);
    e * SUB + s
}

/// Arithmetic midpoint of a bucket's value range (quantile estimate).
fn bucket_mid(b: usize) -> f64 {
    if b == 0 {
        return 1.0;
    }
    let e = (b / SUB) as f64;
    let s = (b % SUB) as f64;
    let lo = e.exp2() * (1.0 + s / SUB as f64);
    let hi = e.exp2() * (1.0 + (s + 1.0) / SUB as f64);
    (lo + hi) / 2.0
}

/// A decaying histogram over the trailing `window` of wall-clock time.
#[derive(Debug, Clone)]
pub struct RollingHistogram {
    slot_len: Duration,
    slots: Vec<Slot>,
    epoch: Instant,
}

impl RollingHistogram {
    /// A window of `window` split into `slots` ring positions. The
    /// effective resolution of "how fast old samples decay" is one
    /// slot; `slots = 8..16` is plenty for SLO windows.
    pub fn new(window: Duration, slots: usize) -> RollingHistogram {
        assert!(slots > 0 && !window.is_zero());
        RollingHistogram {
            slot_len: window / slots as u32,
            slots: vec![Slot::new(); slots],
            epoch: Instant::now(),
        }
    }

    /// Total window covered (slot length × slot count).
    pub fn window(&self) -> Duration {
        self.slot_len * self.slots.len() as u32
    }

    fn abs_slot(&self, now: Instant) -> u64 {
        let dt = now.saturating_duration_since(self.epoch);
        (dt.as_nanos() / self.slot_len.as_nanos().max(1)) as u64
    }

    /// A slot is live iff the window has not slid past it.
    fn live(&self, slot: &Slot, now_abs: u64) -> bool {
        slot.abs != u64::MAX && now_abs.saturating_sub(slot.abs) < self.slots.len() as u64
    }

    pub fn push(&mut self, v: f64) {
        self.push_at(Instant::now(), v);
    }

    pub fn push_at(&mut self, now: Instant, v: f64) {
        let abs = self.abs_slot(now);
        let idx = (abs % self.slots.len() as u64) as usize;
        let slot = &mut self.slots[idx];
        if slot.abs != abs {
            slot.reset(abs);
        }
        slot.counts[bucket_of(v)] += 1;
        slot.count += 1;
        slot.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.count_at(Instant::now())
    }

    /// Samples still inside the window at `now`.
    pub fn count_at(&self, now: Instant) -> u64 {
        let now_abs = self.abs_slot(now);
        self.slots
            .iter()
            .filter(|s| self.live(s, now_abs))
            .map(|s| s.count)
            .sum()
    }

    pub fn mean(&self) -> f64 {
        self.mean_at(Instant::now())
    }

    pub fn mean_at(&self, now: Instant) -> f64 {
        let now_abs = self.abs_slot(now);
        let (mut n, mut sum) = (0u64, 0.0f64);
        for s in self.slots.iter().filter(|s| self.live(s, now_abs)) {
            n += s.count;
            sum += s.sum;
        }
        if n == 0 {
            f64::NAN
        } else {
            sum / n as f64
        }
    }

    pub fn quantile(&self, q: f64) -> f64 {
        self.quantile_at(Instant::now(), q)
    }

    /// Estimate the `q`-quantile of the samples inside the window:
    /// the midpoint of the bucket where the cumulative count crosses
    /// `q · total`. `NaN` when the window is empty.
    pub fn quantile_at(&self, now: Instant, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        let now_abs = self.abs_slot(now);
        let live: Vec<&Slot> = self
            .slots
            .iter()
            .filter(|s| self.live(s, now_abs))
            .collect();
        let total: u64 = live.iter().map(|s| s.count).sum();
        if total == 0 {
            return f64::NAN;
        }
        let target = ((q * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for b in 0..BUCKETS {
            cum += live.iter().map(|s| s.counts[b] as u64).sum::<u64>();
            if cum >= target {
                return bucket_mid(b);
            }
        }
        bucket_mid(BUCKETS - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn at(h: &RollingHistogram, ms: u64) -> Instant {
        h.epoch + Duration::from_millis(ms)
    }

    #[test]
    fn buckets_cover_magnitudes_within_one_octave_slice() {
        for &v in &[1.0, 7.0, 1000.0, 1.5e6, 9.9e9] {
            let b = bucket_of(v);
            let mid = bucket_mid(b);
            let rel = (mid - v).abs() / v;
            assert!(rel <= 0.13, "value {v} → bucket {b} mid {mid} rel {rel}");
        }
    }

    #[test]
    fn quantiles_track_a_known_distribution() {
        let mut h = RollingHistogram::new(Duration::from_secs(10), 10);
        let now = at(&h, 1);
        for i in 1..=1000u64 {
            h.push_at(now, (i * 1000) as f64); // 1k..1M ns, uniform
        }
        assert_eq!(h.count_at(now), 1000);
        let p50 = h.quantile_at(now, 0.5);
        let p99 = h.quantile_at(now, 0.99);
        assert!((p50 - 500_000.0).abs() / 500_000.0 < 0.13, "p50={p50}");
        assert!((p99 - 990_000.0).abs() / 990_000.0 < 0.13, "p99={p99}");
        assert!(p99 > p50);
    }

    #[test]
    fn old_samples_decay_out_of_the_window() {
        let mut h = RollingHistogram::new(Duration::from_millis(1000), 4);
        h.push_at(at(&h, 0), 1e9); // slot 0: a huge outlier
        h.push_at(at(&h, 300), 100.0);
        // both inside the window at t=500ms
        assert_eq!(h.count_at(at(&h, 500)), 2);
        assert!(h.quantile_at(at(&h, 500), 1.0) > 1e8);
        // at t=1100ms slot 0 has slid out; only the 100 remains
        assert_eq!(h.count_at(at(&h, 1100)), 1);
        let p100 = h.quantile_at(at(&h, 1100), 1.0);
        assert!((90.0..130.0).contains(&p100), "p100={p100}");
        // far past the window: empty again
        assert_eq!(h.count_at(at(&h, 5000)), 0);
        assert!(h.quantile_at(at(&h, 5000), 0.5).is_nan());
        assert!(h.mean_at(at(&h, 5000)).is_nan());
    }

    #[test]
    fn ring_positions_are_recycled_not_leaked() {
        let mut h = RollingHistogram::new(Duration::from_millis(400), 4);
        // wrap the ring many times; count never exceeds the window
        for ms in (0..4000).step_by(50) {
            h.push_at(at(&h, ms), 42.0);
        }
        // window holds at most 400ms of pushes = 8 samples
        assert!(h.count_at(at(&h, 3999)) <= 8);
        assert!(h.count_at(at(&h, 3999)) >= 6);
    }

    #[test]
    fn sub_unit_and_nan_values_collapse_into_bucket_zero() {
        let mut h = RollingHistogram::new(Duration::from_secs(1), 2);
        let now = at(&h, 1);
        h.push_at(now, 0.25);
        h.push_at(now, f64::NAN);
        assert_eq!(h.count_at(now), 2);
        assert_eq!(h.quantile_at(now, 1.0), 1.0);
    }

    #[test]
    fn mean_is_exact_not_bucketed() {
        let mut h = RollingHistogram::new(Duration::from_secs(1), 2);
        let now = at(&h, 1);
        h.push_at(now, 10.0);
        h.push_at(now, 30.0);
        assert_eq!(h.mean_at(now), 20.0);
    }
}
