//! Streaming mean/variance (Welford) + order statistics.

/// Online mean/variance accumulator (Welford's algorithm), plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    pub fn new() -> Self {
        Stats {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn extend(&mut self, xs: &[f64]) {
        for &x in xs {
            self.push(x);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (the paper's Table I reports population-style
    /// moments over 5000 realizations; at n=5000 the distinction from the
    /// sample variance is negligible).
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample (Bessel-corrected) variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Exact quantile of a sample (copies + sorts; fine at experiment sizes).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty() && (0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_known() {
        let mut s = Stats::new();
        s.extend(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert_eq!(s.count(), 8);
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.variance() - 4.0).abs() < 1e-12);
        assert!((s.stddev() - 2.0).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn sample_variance_bessel() {
        let mut s = Stats::new();
        s.extend(&[1.0, 2.0, 3.0]);
        assert!((s.sample_variance() - 1.0).abs() < 1e-12);
        assert!((s.variance() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stats_are_safe() {
        let s = Stats::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.variance(), 0.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }
}
