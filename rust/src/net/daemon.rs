//! Worker daemon: the remote end of [`super::TcpTransport`].
//!
//! `usec worker --listen host:port` runs [`serve_worker`]: accept a master
//! connection, handshake (version check + placement-shaped storage
//! materialization), then execute [`WorkOrder`]s through the exact same
//! [`crate::sched::worker::execute_order`] compute path the in-process
//! cluster uses — straggler injection, speed throttling and all — replying
//! with framed [`WireMsg::Report`]s and pushing heartbeats from a side
//! thread so liveness is visible even mid-compute.
//!
//! Orders are executed serially and **step-agnostically**: the daemon
//! never assumes one `Work` per step, so the supplementary orders the
//! master ships during mid-step recovery ([`crate::sched::recovery`])
//! simply queue on the socket and each produces its own `Report`.
//!
//! Storage is the uncoded USEC model made real: the `Hello` names the
//! sub-matrices this worker stores (`Z_n`), and the daemon keeps **only
//! those rows** resident — regenerated from the deterministic workload
//! spec's row-seeded generators (peak memory = the placed share, via
//! [`crate::net::WorkloadSpec::materialize_shard`]), or received as
//! checksummed `Data` frames when the master streams external data
//! ([`crate::net::WorkloadSpec::Streamed`]). The daemon reports its
//! actual resident byte count in `StorageReady`, which is what
//! `--json-out` surfaces per worker.
//!
//! Storage is **live** (wire v4): a `PlacementUpdate` between orders
//! evicts named row ranges and/or absorbs master-streamed rows into the
//! resident shard ([`crate::rebalance`] drives this when drift makes the
//! placement stale), acknowledged with a `MigrateAck` carrying the new
//! resident byte count. Generator-backed workloads migrate without row
//! bytes on the wire at all (wire v5 `regenerate` trailer): the daemon
//! rematerializes the gained ranges from the workload seed and verifies
//! them against the master's FNV digest before touching its shard.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::cli::{ArgSpec, Args};
use crate::engine::EngineState;
use crate::error::{Error, Result};
use crate::linalg::partition::{submatrix_ranges, RowRange, TilePlan};
use crate::obs::{MetricsServer, Registry, Telemetry};
use crate::runtime::BackendSpec;
use crate::sched::worker::{execute_order, ExecScratch, WorkerConfig, WorkerStorage};
use crate::storage::{coalesce_sub_ranges, RowShard, StorageView, StoreHandle};

use super::codec::{self, Hello, HelloAck, WireMsg, WIRE_VERSION};
use super::{frame, lock};

/// How long the daemon waits for the master's `Hello` (and for each
/// streamed `Data` frame) before dropping a connection that goes quiet.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Default post-handshake read timeout (see [`DaemonOpts::idle_timeout`]):
/// generous — a healthy master sends work at least once per step, and the
/// master-side coverage timeout is a minute — but finite, so a master
/// host that dies without FIN/RST cannot wedge the daemon in a dead
/// session forever.
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(300);

/// Daemon behaviour knobs.
#[derive(Debug, Clone)]
pub struct DaemonOpts {
    /// Exit after this many master sessions (0 = serve forever). A
    /// re-admitted master counts as a fresh session.
    pub max_sessions: usize,
    /// Post-handshake read timeout: a session with no master traffic for
    /// this long is dropped and the daemon loops back to `accept`, so a
    /// vanished master (no FIN/RST — powered-off host, dropped VPN)
    /// cannot brick the worker. `Duration::ZERO` disables the timeout
    /// (the pre-liveness behaviour).
    pub idle_timeout: Duration,
    /// Live telemetry handle (`usec worker --metrics-listen`): the daemon
    /// publishes its resident bytes, order/row counters, and busy/idle
    /// state into it. `None` (the default) skips all bookkeeping.
    pub telemetry: Option<Arc<Telemetry>>,
}

impl Default for DaemonOpts {
    fn default() -> Self {
        DaemonOpts {
            max_sessions: 0,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
            telemetry: None,
        }
    }
}

/// Accept master sessions forever (or `max_sessions`, per `opts`). Each
/// session is serial: one master drives one worker daemon at a time,
/// matching the paper's single-master Algorithm 1.
pub fn serve_worker(listener: TcpListener, opts: DaemonOpts) -> Result<()> {
    let mut served = 0usize;
    loop {
        let (stream, peer_addr) = listener.accept()?;
        let _ = stream.set_nodelay(true);
        crate::log_info!("worker daemon: master connected from {peer_addr}");
        match serve_session(stream, &opts) {
            Ok(()) => crate::log_info!("worker daemon: session from {peer_addr} closed"),
            Err(e) => crate::log_warn!("worker daemon: session from {peer_addr} ended: {e}"),
        }
        served += 1;
        if opts.max_sessions > 0 && served >= opts.max_sessions {
            return Ok(());
        }
    }
}

/// Absorb one master-streamed sequence of checksummed `Data` frames
/// (terminated by the `done = 1` chunk), feeding each chunk to `insert`.
/// Shared by handshake storage streaming and live migration — the two
/// paths must never diverge on the protocol. Returns the rows received.
fn absorb_data_frames<R: std::io::Read>(
    reader: &mut R,
    cols: usize,
    mut insert: impl FnMut(RowRange, Vec<f32>) -> Result<()>,
) -> Result<u64> {
    let mut received = 0u64;
    loop {
        match codec::read_msg(reader)? {
            WireMsg::Data(d) => {
                if d.cols != cols {
                    return Err(Error::wire(format!(
                        "data chunk has {} cols, expected {cols}",
                        d.cols
                    )));
                }
                received += d.rows.len() as u64;
                insert(d.rows, d.values)?;
                if d.done {
                    break;
                }
            }
            other => {
                return Err(Error::wire(format!(
                    "expected Data during row streaming, got {other:?}"
                )))
            }
        }
    }
    Ok(received)
}

/// Materialize the placement-shaped storage the `Hello` prescribes:
/// regenerate from the workload spec (keeping only the placed rows when a
/// proper subset is stored), or assemble streamed `Data` frames into a
/// [`RowShard`].
fn materialize_storage(stream: &TcpStream, hello: &Hello) -> Result<StoreHandle> {
    let q = hello.workload.rows();
    let r = hello.workload.cols();
    if hello.workload.is_streamed() {
        let mut shard = RowShard::new(q, r);
        absorb_data_frames(&mut &*stream, r, |rows, values| shard.insert(rows, values))?;
        return Ok(StoreHandle::Shard(Arc::new(shard)));
    }

    // Generator-backed: deterministic in the seed, so master and worker
    // agree on every stored row without shipping the matrix. The
    // generators are row-seeded, so a proper-subset share is produced
    // row by row — peak memory is the placed share plus O(q) generator
    // state; the full q×r matrix is never built, not even transiently.
    let distinct: std::collections::BTreeSet<usize> = hello.stored.iter().copied().collect();
    if distinct.is_empty() || distinct.len() == hello.g {
        return Ok(StoreHandle::Full(hello.workload.materialize()?));
    }
    let sub_ranges = submatrix_ranges(q, hello.g)?;
    let placed = coalesce_sub_ranges(&hello.stored, &sub_ranges)?;
    let shard = hello.workload.materialize_shard(&placed)?;
    Ok(StoreHandle::Shard(Arc::new(shard)))
}

/// One master session: handshake, storage materialization, then
/// order→report until `Shutdown`, the socket dies, or the master goes
/// silent past `opts.idle_timeout`.
fn serve_session(stream: TcpStream, opts: &DaemonOpts) -> Result<()> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let hello = match codec::read_msg(&mut &stream)? {
        WireMsg::Hello(h) => h,
        other => return Err(Error::wire(format!("expected Hello, got {other:?}"))),
    };
    if hello.version != WIRE_VERSION {
        return Err(Error::wire(format!(
            "master speaks wire version {} (this daemon needs {WIRE_VERSION})",
            hello.version
        )));
    }
    if hello.tile_rows == 0 || hello.g == 0 || hello.workload.rows() == 0 {
        return Err(Error::wire(format!(
            "degenerate handshake geometry: tile_rows={} G={} q={}",
            hello.tile_rows,
            hello.g,
            hello.workload.rows()
        )));
    }
    if let Some(&bad) = hello.stored.iter().find(|&&g| g >= hello.g) {
        return Err(Error::wire(format!(
            "stored sub-matrix {bad} out of range (G={})",
            hello.g
        )));
    }

    codec::write_msg(
        &mut &stream,
        &WireMsg::HelloAck(HelloAck {
            version: WIRE_VERSION,
            worker: hello.worker,
        }),
    )?;

    let store = materialize_storage(&stream, &hello)?;
    let resident_bytes = store.resident_bytes() as u64;
    let sub_ranges = Arc::new(submatrix_ranges(hello.workload.rows(), hello.g)?);
    let mut cfg = WorkerConfig {
        id: hello.worker,
        backend: BackendSpec::from_kind(hello.backend, crate::apps::harness::artifact_dir()),
        speed: hello.speed,
        tile_rows: hello.tile_rows,
        threads: hello.threads.max(1),
        storage: WorkerStorage { store, sub_ranges },
    };
    let backend = cfg.backend.instantiate()?;

    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    codec::write_msg(
        &mut *lock(&writer),
        &WireMsg::StorageReady {
            worker: hello.worker,
            resident_bytes,
        },
    )?;
    // single-worker telemetry view: whatever id the master assigned,
    // this daemon scrapes as worker 0 of 1 on its own endpoint
    let tel = opts.telemetry.as_ref();
    let reg = tel.map(|_| Registry::new(1));
    if let Some(t) = tel {
        t.set_resident(&[resident_bytes]);
        t.set_state(EngineState::Idle);
    }
    // daemon-side liveness: a finite read timeout means a master host
    // that dies without FIN/RST ends this session instead of wedging the
    // daemon forever (the next master then gets accepted)
    if opts.idle_timeout.is_zero() {
        stream.set_read_timeout(None)?;
    } else {
        stream.set_read_timeout(Some(opts.idle_timeout))?;
    }
    crate::log_info!(
        "worker daemon: storage ready ({} of {} rows resident, {resident_bytes} bytes)",
        cfg.storage.store.resident_rows(),
        cfg.storage.store.global_rows()
    );

    // Heartbeat pump: keeps the master's liveness view fresh even while
    // the session thread is deep in a long tile computation.
    let stop = Arc::new(AtomicBool::new(false));
    let hb_handle = if hello.heartbeat_ms > 0 {
        let w = Arc::clone(&writer);
        let stop2 = Arc::clone(&stop);
        let period = Duration::from_millis(u64::from(hello.heartbeat_ms));
        let id = hello.worker;
        Some(std::thread::spawn(move || {
            use crate::sched::{DeadlineKind, TimerWheel};
            let mut seq = 0u64;
            // the beat rides the shared timer wheel: re-arming from the
            // *previous deadline* (not from "after the send") keeps the
            // cadence drift-free even when a write stalls on the socket
            let mut wheel = TimerWheel::new();
            wheel.set(DeadlineKind::Heartbeat, Instant::now() + period);
            while !stop2.load(Ordering::Relaxed) {
                if let Some(wait) = wheel.wait_from(Instant::now()) {
                    std::thread::sleep(wait);
                }
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                let now = Instant::now();
                if !wheel.due(DeadlineKind::Heartbeat, now) {
                    continue;
                }
                let at = wheel.get(DeadlineKind::Heartbeat).expect("armed above");
                // skip ahead (instead of bursting) if a stalled write left
                // the clock more than one whole period behind
                let next = if now > at + period { now } else { at } + period;
                wheel.set(DeadlineKind::Heartbeat, next);
                seq += 1;
                if codec::write_msg(&mut *lock(&w), &WireMsg::Heartbeat { worker: id, seq })
                    .is_err()
                {
                    break;
                }
            }
        }))
    } else {
        None
    };

    let tile = TilePlan::new(cfg.tile_rows);
    // per-session scratch arena: the compute hot loop stays
    // zero-allocation across tiles and steps
    let mut scratch = ExecScratch::new();
    let mut reader = stream;
    // daemon-side thirds of the traced breakdown: the encode+write of the
    // *previous* report (a report cannot time its own serialization), and
    // the socket-starved gap since the last message finished processing
    let mut last_encode_ns = 0u64;
    let mut idle_since = Instant::now();
    let result = loop {
        // read the frame and decode separately (instead of read_msg) so a
        // traced order can report how long the daemon sat idle on the
        // socket and how long the payload took to decode
        let framed = frame::read_frame(&mut reader);
        let idle_ns = idle_since.elapsed().as_nanos() as u64;
        let decode_start = Instant::now();
        let decoded = framed.and_then(|payload| codec::decode(&payload));
        let decode_ns = decode_start.elapsed().as_nanos() as u64;
        match decoded {
            Ok(WireMsg::Work(order)) => {
                let step = order.step;
                let order_rows: usize = order.tasks.iter().map(|t| t.rows.len()).sum();
                if let Err(e) = validate_order(&cfg, &order) {
                    // a malformed order must produce a Failed reply, not a
                    // panic that kills the daemon
                    let _ = codec::write_msg(
                        &mut *lock(&writer),
                        &WireMsg::Failed {
                            worker: cfg.id,
                            step,
                            error: e.to_string(),
                        },
                    );
                    idle_since = Instant::now();
                    continue;
                }
                if let Some(t) = tel {
                    t.set_state(EngineState::Stepping);
                }
                let executed = execute_order(&cfg, &backend, &tile, &order, &mut scratch);
                if let (Some(t), Some(reg)) = (tel, &reg) {
                    t.set_state(EngineState::Idle);
                    t.steps.inc();
                    reg.add_order(0, order_rows);
                    t.set_counters(reg.snapshot(&[]));
                }
                match executed {
                    Ok(Some(mut report)) => {
                        if let Some(bd) = report.breakdown.as_mut() {
                            bd.decode_ns = decode_ns;
                            bd.idle_ns = idle_ns;
                            bd.encode_ns = last_encode_ns;
                        }
                        let encode_start = Instant::now();
                        let sent =
                            codec::write_msg(&mut *lock(&writer), &WireMsg::Report(report));
                        last_encode_ns = encode_start.elapsed().as_nanos() as u64;
                        if let Err(e) = sent {
                            break Err(e);
                        }
                    }
                    Ok(None) => {} // injected Drop straggler: stay silent
                    Err(e) => {
                        let _ = codec::write_msg(
                            &mut *lock(&writer),
                            &WireMsg::Failed {
                                worker: cfg.id,
                                step,
                                error: e.to_string(),
                            },
                        );
                    }
                }
            }
            Ok(WireMsg::PlacementUpdate(update)) => {
                // live migration (wire v4): absorb streamed rows, then
                // evict, then acknowledge the outcome — `ok = false` tells
                // the master immediately (no ack-timeout burn) and
                // guarantees no rows were lost
                let ok = match apply_placement_update(
                    &mut cfg,
                    &mut reader,
                    &update,
                    &hello.workload,
                ) {
                    Ok(()) => {
                        crate::log_info!(
                            "worker daemon: placement update seq {} applied \
                             ({} rows resident)",
                            update.seq,
                            cfg.storage.store.resident_rows()
                        );
                        true
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "worker daemon: placement update seq {} rejected: {e}",
                            update.seq
                        );
                        false
                    }
                };
                if let (Some(t), Some(reg)) = (tel, &reg) {
                    if ok {
                        reg.add_migration(0);
                    }
                    t.set_resident(&[cfg.storage.store.resident_bytes() as u64]);
                    t.set_counters(reg.snapshot(&[]));
                }
                if let Err(e) = codec::write_msg(
                    &mut *lock(&writer),
                    &WireMsg::MigrateAck {
                        worker: cfg.id,
                        seq: update.seq,
                        ok,
                        resident_bytes: cfg.storage.store.resident_bytes() as u64,
                    },
                ) {
                    break Err(e);
                }
            }
            Ok(WireMsg::Shutdown) => break Ok(()),
            Ok(other) => {
                crate::log_debug!("worker daemon: ignoring unexpected message {other:?}");
            }
            Err(e) => break Err(e),
        }
        idle_since = Instant::now();
    };
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = hb_handle {
        let _ = h.join();
    }
    result
}

/// Apply one live-migration order ([`crate::net::codec::PlacementUpdate`]):
/// absorb `expect_rows` incoming rows from checksummed `Data` frames (the
/// same [`absorb_data_frames`] loop the streamed handshake uses) — or,
/// for a `regenerate` order, rematerialize the gained ranges from the
/// workload seed and verify them against the master's digest — then
/// evict the named global row ranges. Gain-first matters: a mid-stream
/// failure or a digest mismatch must leave the evicted rows untouched, so
/// a nacked update really means "nothing was lost" — the transient cost
/// is holding both copies until the gain completes. Chunk re-sends and
/// re-regenerations are idempotent ([`StoreHandle::insert_rows`]), so a
/// retried move converges.
fn apply_placement_update(
    cfg: &mut WorkerConfig,
    reader: &mut TcpStream,
    update: &codec::PlacementUpdate,
    workload: &crate::net::WorkloadSpec,
) -> Result<()> {
    let cols = cfg.storage.store.cols();
    if update.regenerate {
        if update.expect_rows > 0 {
            return Err(Error::wire(format!(
                "placement update seq {} both streams and regenerates rows",
                update.seq
            )));
        }
        // rematerialize from the seed — zero row bytes crossed the wire —
        // and prove bit-identity to the master's copy before inserting
        let shard = workload.materialize_shard(&update.gain)?;
        let mut values = Vec::new();
        for r in &update.gain {
            values.extend_from_slice(shard.row_slice(*r)?);
        }
        if codec::data_checksum(&values) != update.checksum {
            return Err(Error::wire(format!(
                "regenerated rows fail the master's checksum (seq {})",
                update.seq
            )));
        }
        let store = &mut cfg.storage.store;
        let mut off = 0usize;
        for r in &update.gain {
            let n = r.len() * cols;
            store.insert_rows(*r, values[off..off + n].to_vec())?;
            off += n;
        }
    } else if update.expect_rows > 0 {
        let store = &mut cfg.storage.store;
        let received =
            absorb_data_frames(reader, cols, |rows, values| store.insert_rows(rows, values))?;
        if received != update.expect_rows {
            return Err(Error::wire(format!(
                "migration stream delivered {received} of {} announced rows",
                update.expect_rows
            )));
        }
    }
    cfg.storage.store.evict_rows(&update.evict)?;
    Ok(())
}

/// Reject orders a malformed/hostile master could send. Task geometry
/// (sub-matrix bounds, offset overflow, placed-row residency) is already
/// validated row-by-row inside [`execute_order`] via the storage view and
/// surfaces as the same `Failed` reply; the only check it cannot make
/// before touching the backend is the iterate length.
fn validate_order(
    cfg: &WorkerConfig,
    order: &crate::sched::protocol::WorkOrder,
) -> Result<()> {
    if order.w.len() != cfg.storage.store.cols() {
        return Err(Error::wire(format!(
            "iterate length {} != matrix cols {}",
            order.w.len(),
            cfg.storage.store.cols()
        )));
    }
    Ok(())
}

/// `usec worker --listen host:port [--once] [--idle-timeout-secs N]
/// [--metrics-listen host:port]`.
pub fn worker_cli(argv: &[String]) -> Result<()> {
    let specs = vec![
        ArgSpec::opt("listen", "127.0.0.1:7070", "address to bind"),
        ArgSpec::flag("once", "exit after a single master session"),
        ArgSpec::opt(
            "idle-timeout-secs",
            "300",
            "drop a session with no master traffic for this long (0 = never)",
        ),
        ArgSpec::opt(
            "metrics-listen",
            "",
            "serve /metrics, /healthz, /readyz on this host:port",
        ),
    ];
    let args = Args::parse(argv, &specs)?;
    let addr = args.get("listen").unwrap_or("127.0.0.1:7070");
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Cluster(format!("bind {addr}: {e}")))?;
    let metrics_listen = args.get("metrics-listen").unwrap_or("").to_string();
    let (telemetry, _metrics) = if metrics_listen.is_empty() {
        (None, None)
    } else {
        let tel = Arc::new(Telemetry::new(1, 1));
        let ml = TcpListener::bind(&metrics_listen)
            .map_err(|e| Error::Cluster(format!("bind {metrics_listen}: {e}")))?;
        let srv = MetricsServer::spawn(ml, Arc::clone(&tel))?;
        println!(
            "metrics on http://{}/metrics (probes /healthz, /readyz)",
            srv.addr()
        );
        (Some(tel), Some(srv))
    };
    println!("usec worker listening on {}", listener.local_addr()?);
    serve_worker(
        listener,
        DaemonOpts {
            max_sessions: usize::from(args.has("once")),
            idle_timeout: Duration::from_secs(args.get_u64("idle-timeout-secs")?),
            telemetry,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::types::BackendKind;
    use crate::linalg::partition::RowRange;
    use crate::net::codec::{DataFrame, Hello};
    use crate::net::transport::WorkloadSpec;

    fn test_hello(worker: usize) -> Hello {
        Hello {
            version: WIRE_VERSION,
            worker,
            speed: 1.0,
            tile_rows: 8,
            backend: BackendKind::Host,
            g: 2,
            heartbeat_ms: 0,
            threads: 1,
            workload: WorkloadSpec::RandomDense {
                q: 16,
                r: 16,
                seed: 5,
            },
            stored: vec![],
        }
    }

    fn spawn_daemon() -> (std::net::SocketAddr, std::thread::JoinHandle<Result<()>>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 1,
                    ..Default::default()
                },
            )
        });
        (addr, h)
    }

    fn read_storage_ready(stream: &TcpStream) -> u64 {
        match codec::read_msg(&mut &*stream).unwrap() {
            WireMsg::StorageReady { resident_bytes, .. } => resident_bytes,
            other => panic!("expected StorageReady, got {other:?}"),
        }
    }

    #[test]
    fn daemon_rejects_version_mismatch() {
        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        let mut bad = test_hello(0);
        bad.version = 999;
        codec::write_msg(&mut &stream, &WireMsg::Hello(bad)).unwrap();
        // daemon must close without an ack: next read errors (EOF)
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        assert!(codec::read_msg(&mut &stream).is_err());
        h.join().unwrap().unwrap();
    }

    #[test]
    fn daemon_handshakes_and_shuts_down() {
        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        codec::write_msg(&mut &stream, &WireMsg::Hello(test_hello(4))).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(ack) => {
                assert_eq!(ack.version, WIRE_VERSION);
                assert_eq!(ack.worker, 4);
            }
            other => panic!("expected HelloAck, got {other:?}"),
        }
        // full storage: empty stored list ⇒ the whole 16x16 matrix
        assert_eq!(read_storage_ready(&stream), 16 * 16 * 4);
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn daemon_materializes_only_the_placed_share() {
        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut hello = test_hello(1);
        hello.stored = vec![1]; // one of G=2 sub-matrices ⇒ half the rows
        codec::write_msg(&mut &stream, &WireMsg::Hello(hello)).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        assert_eq!(read_storage_ready(&stream), 8 * 16 * 4);
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn silent_master_session_times_out_and_daemon_serves_again() {
        // ROADMAP daemon-side liveness: a master that handshakes and then
        // vanishes without FIN/RST must not wedge the daemon. The first
        // session goes silent; the idle timeout ends it, and a second
        // master gets served.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 2,
                    idle_timeout: Duration::from_millis(200),
                    ..Default::default()
                },
            )
        });

        // session 1: handshake, then silence (socket kept open, no traffic)
        let dead = TcpStream::connect(addr).unwrap();
        dead.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        codec::write_msg(&mut &dead, &WireMsg::Hello(test_hello(0))).unwrap();
        match codec::read_msg(&mut &dead).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        read_storage_ready(&dead);
        // do NOT send Work or Shutdown — the daemon must time the session
        // out on its own and loop back to accept

        // session 2: a fresh master is accepted and served normally
        let live = TcpStream::connect(addr).unwrap();
        live.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        codec::write_msg(&mut &live, &WireMsg::Hello(test_hello(1))).unwrap();
        match codec::read_msg(&mut &live).unwrap() {
            WireMsg::HelloAck(ack) => assert_eq!(ack.worker, 1),
            other => panic!("expected HelloAck, got {other:?}"),
        }
        read_storage_ready(&live);
        codec::write_msg(&mut &live, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
        drop(dead);
    }

    #[test]
    fn daemon_executes_supplementary_order_for_in_flight_step() {
        use crate::linalg::Block;
        use crate::optim::Task;
        use crate::sched::protocol::WorkOrder;

        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        codec::write_msg(&mut &stream, &WireMsg::Hello(test_hello(3))).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        read_storage_ready(&stream);
        // original order and a recovery re-dispatch for the same step
        for g in [0usize, 1] {
            codec::write_msg(
                &mut &stream,
                &WireMsg::Work(WorkOrder {
                    step: 5,
                    w: Arc::new(Block::single(vec![0.5f32; 16])),
                    tasks: vec![Task {
                        g,
                        rows: RowRange::new(0, 4),
                    }],
                    row_cost_ns: 0,
                    straggle: None,
                    trace: false,
                }),
            )
            .unwrap();
        }
        for _ in 0..2 {
            match codec::read_msg(&mut &stream).unwrap() {
                WireMsg::Report(r) => {
                    assert_eq!(r.step, 5);
                    assert_eq!(r.segments.len(), 1);
                    assert_eq!(r.segments[0].rows.len(), 4);
                }
                other => panic!("expected Report, got {other:?}"),
            }
        }
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn traced_orders_carry_daemon_side_timings() {
        use crate::linalg::Block;
        use crate::optim::Task;
        use crate::sched::protocol::WorkOrder;

        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        codec::write_msg(&mut &stream, &WireMsg::Hello(test_hello(7))).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        read_storage_ready(&stream);
        for i in 0..2usize {
            if i == 1 {
                // a deliberate gap the second order's idle_ns must cover
                std::thread::sleep(Duration::from_millis(50));
            }
            codec::write_msg(
                &mut &stream,
                &WireMsg::Work(WorkOrder {
                    step: 6,
                    w: Arc::new(Block::single(vec![0.5f32; 16])),
                    tasks: vec![Task {
                        g: 0,
                        rows: RowRange::new(0, 4),
                    }],
                    row_cost_ns: 0,
                    straggle: None,
                    trace: true,
                }),
            )
            .unwrap();
            match codec::read_msg(&mut &stream).unwrap() {
                WireMsg::Report(r) => {
                    let bd = r.breakdown.expect("traced order must carry a breakdown");
                    if i == 0 {
                        // nothing was encoded before the first report
                        assert_eq!(bd.encode_ns, 0);
                    } else {
                        assert!(
                            bd.idle_ns >= 40_000_000,
                            "50ms gap not visible as idle: {}ns",
                            bd.idle_ns
                        );
                    }
                }
                other => panic!("expected Report, got {other:?}"),
            }
        }
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn daemon_publishes_telemetry_counters() {
        use crate::linalg::Block;
        use crate::optim::Task;
        use crate::sched::protocol::WorkOrder;

        let tel = Arc::new(Telemetry::new(1, 1));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let t2 = Arc::clone(&tel);
        let h = std::thread::spawn(move || {
            serve_worker(
                listener,
                DaemonOpts {
                    max_sessions: 1,
                    telemetry: Some(t2),
                    ..Default::default()
                },
            )
        });
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        codec::write_msg(&mut &stream, &WireMsg::Hello(test_hello(0))).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        read_storage_ready(&stream);
        codec::write_msg(
            &mut &stream,
            &WireMsg::Work(WorkOrder {
                step: 1,
                w: Arc::new(Block::single(vec![0.5f32; 16])),
                tasks: vec![Task {
                    g: 0,
                    rows: RowRange::new(0, 4),
                }],
                row_cost_ns: 0,
                straggle: None,
                trace: false,
            }),
        )
        .unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::Report(_) => {}
            other => panic!("expected Report, got {other:?}"),
        }
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
        // the scrape view saw the session: one order of 4 rows executed,
        // the full 16x16 f32 matrix resident, probe ready throughout
        assert_eq!(tel.steps.get(), 1);
        let counters = tel.counters();
        assert_eq!(counters.len(), 1);
        assert_eq!(counters[0].orders, 1);
        assert_eq!(counters[0].rows, 4);
        assert_eq!(tel.resident(0), (16 * 16 * 4) as f64);
        assert!(tel.ready());
    }

    #[test]
    fn daemon_applies_live_placement_updates() {
        use crate::net::PlacementUpdate;

        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        // shard worker: stores sub-matrix 0 of G=2 (global rows 0..8)
        let mut hello = test_hello(5);
        hello.stored = vec![0];
        codec::write_msg(&mut &stream, &WireMsg::Hello(hello)).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        assert_eq!(read_storage_ready(&stream), 8 * 16 * 4);

        // gain sub-matrix 1 (rows 8..16): announce, stream, expect the ack
        codec::write_msg(
            &mut &stream,
            &WireMsg::PlacementUpdate(PlacementUpdate {
                seq: 1,
                expect_rows: 8,
                evict: vec![],
                regenerate: false,
                gain: vec![],
                checksum: 0,
            }),
        )
        .unwrap();
        let spec = WorkloadSpec::RandomDense {
            q: 16,
            r: 16,
            seed: 5,
        };
        let oracle = spec.materialize().unwrap();
        for (lo, hi, done) in [(8usize, 12usize, false), (12, 16, true)] {
            codec::write_msg(
                &mut &stream,
                &WireMsg::Data(DataFrame {
                    rows: RowRange::new(lo, hi),
                    cols: 16,
                    done,
                    values: oracle.row_block(lo, hi).to_vec(),
                }),
            )
            .unwrap();
        }
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::MigrateAck {
                worker,
                seq,
                ok,
                resident_bytes,
            } => {
                assert_eq!((worker, seq, ok), (5, 1, true));
                assert_eq!(resident_bytes, 16 * 16 * 4);
            }
            other => panic!("expected MigrateAck, got {other:?}"),
        }
        // shed sub-matrix 0 (rows 0..8): pure eviction, acked with the
        // shrunken residency — and an order over the evicted rows now fails
        codec::write_msg(
            &mut &stream,
            &WireMsg::PlacementUpdate(PlacementUpdate {
                seq: 2,
                expect_rows: 0,
                evict: vec![RowRange::new(0, 8)],
                regenerate: false,
                gain: vec![],
                checksum: 0,
            }),
        )
        .unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::MigrateAck {
                seq,
                ok,
                resident_bytes,
                ..
            } => {
                assert_eq!((seq, ok), (2, true));
                assert_eq!(resident_bytes, 8 * 16 * 4);
            }
            other => panic!("expected MigrateAck, got {other:?}"),
        }
        {
            use crate::linalg::Block;
            use crate::optim::Task;
            use crate::sched::protocol::WorkOrder;
            // rows of the evicted sub-matrix are gone; rows of the gained
            // one compute fine
            for (g, ok) in [(0usize, false), (1usize, true)] {
                codec::write_msg(
                    &mut &stream,
                    &WireMsg::Work(WorkOrder {
                        step: 9,
                        w: Arc::new(Block::single(vec![0.25f32; 16])),
                        tasks: vec![Task {
                            g,
                            rows: RowRange::new(0, 4),
                        }],
                        row_cost_ns: 0,
                        straggle: None,
                        trace: false,
                    }),
                )
                .unwrap();
                match codec::read_msg(&mut &stream).unwrap() {
                    WireMsg::Report(r) if ok => assert_eq!(r.segments.len(), 1),
                    WireMsg::Failed { .. } if !ok => {}
                    other => panic!("sub-matrix {g}: unexpected reply {other:?}"),
                }
            }
        }
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn daemon_rejects_bad_migration_with_immediate_nack() {
        use crate::net::PlacementUpdate;

        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut hello = test_hello(6);
        hello.stored = vec![0];
        codec::write_msg(&mut &stream, &WireMsg::Hello(hello)).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        assert_eq!(read_storage_ready(&stream), 8 * 16 * 4);

        // a migration chunk with the wrong column count must be rejected
        // with an ok=false ack (not silence, not a dead session)
        codec::write_msg(
            &mut &stream,
            &WireMsg::PlacementUpdate(PlacementUpdate {
                seq: 9,
                expect_rows: 4,
                evict: vec![],
                regenerate: false,
                gain: vec![],
                checksum: 0,
            }),
        )
        .unwrap();
        codec::write_msg(
            &mut &stream,
            &WireMsg::Data(DataFrame {
                rows: RowRange::new(8, 12),
                cols: 7, // workload says 16
                done: true,
                values: vec![0.0; 4 * 7],
            }),
        )
        .unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::MigrateAck {
                seq,
                ok,
                resident_bytes,
                ..
            } => {
                assert_eq!((seq, ok), (9, false));
                assert_eq!(resident_bytes, 8 * 16 * 4, "storage must be untouched");
            }
            other => panic!("expected MigrateAck, got {other:?}"),
        }
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn daemon_regenerates_migrated_rows_from_the_seed() {
        use crate::net::PlacementUpdate;

        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        // shard worker: stores sub-matrix 0 of G=2 (global rows 0..8)
        let mut hello = test_hello(4);
        hello.stored = vec![0];
        codec::write_msg(&mut &stream, &WireMsg::Hello(hello)).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        assert_eq!(read_storage_ready(&stream), 8 * 16 * 4);

        // gain sub-matrix 1 (rows 8..16) with ZERO Data frames: the daemon
        // regenerates the rows from the workload seed and checks them
        // against the digest of the master's copy
        let spec = WorkloadSpec::RandomDense {
            q: 16,
            r: 16,
            seed: 5,
        };
        let oracle = spec.materialize().unwrap();
        codec::write_msg(
            &mut &stream,
            &WireMsg::PlacementUpdate(PlacementUpdate {
                seq: 3,
                expect_rows: 0,
                evict: vec![],
                regenerate: true,
                gain: vec![RowRange::new(8, 16)],
                checksum: codec::data_checksum(oracle.row_block(8, 16)),
            }),
        )
        .unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::MigrateAck {
                worker,
                seq,
                ok,
                resident_bytes,
            } => {
                assert_eq!((worker, seq, ok), (4, 3, true));
                assert_eq!(resident_bytes, 16 * 16 * 4);
            }
            other => panic!("expected MigrateAck, got {other:?}"),
        }
        // the regenerated rows really compute: an order over sub-matrix 1
        {
            use crate::linalg::Block;
            use crate::optim::Task;
            use crate::sched::protocol::WorkOrder;
            codec::write_msg(
                &mut &stream,
                &WireMsg::Work(WorkOrder {
                    step: 2,
                    w: Arc::new(Block::single(vec![0.25f32; 16])),
                    tasks: vec![Task {
                        g: 1,
                        rows: RowRange::new(0, 4),
                    }],
                    row_cost_ns: 0,
                    straggle: None,
                    trace: false,
                }),
            )
            .unwrap();
            match codec::read_msg(&mut &stream).unwrap() {
                WireMsg::Report(r) => assert_eq!(r.segments.len(), 1),
                other => panic!("expected Report, got {other:?}"),
            }
        }
        // a wrong digest must nack and leave the shard untouched
        codec::write_msg(
            &mut &stream,
            &WireMsg::PlacementUpdate(PlacementUpdate {
                seq: 4,
                expect_rows: 0,
                evict: vec![RowRange::new(0, 8)],
                regenerate: true,
                gain: vec![RowRange::new(8, 16)],
                checksum: 0xBAD_F00D,
            }),
        )
        .unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::MigrateAck {
                seq,
                ok,
                resident_bytes,
                ..
            } => {
                assert_eq!((seq, ok), (4, false));
                assert_eq!(
                    resident_bytes,
                    16 * 16 * 4,
                    "nacked regenerate must not evict"
                );
            }
            other => panic!("expected MigrateAck, got {other:?}"),
        }
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }

    #[test]
    fn daemon_assembles_streamed_storage() {
        let (addr, h) = spawn_daemon();
        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut hello = test_hello(2);
        hello.workload = WorkloadSpec::Streamed { q: 16, r: 4 };
        hello.stored = vec![0];
        codec::write_msg(&mut &stream, &WireMsg::Hello(hello)).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(_) => {}
            other => panic!("expected HelloAck, got {other:?}"),
        }
        // stream global rows 0..8 in two chunks
        for (lo, hi, done) in [(0usize, 5usize, false), (5, 8, true)] {
            codec::write_msg(
                &mut &stream,
                &WireMsg::Data(DataFrame {
                    rows: RowRange::new(lo, hi),
                    cols: 4,
                    done,
                    values: vec![0.25; (hi - lo) * 4],
                }),
            )
            .unwrap();
        }
        assert_eq!(read_storage_ready(&stream), 8 * 4 * 4);
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }
}
