//! Worker daemon: the remote end of [`super::TcpTransport`].
//!
//! `usec worker --listen host:port` runs [`serve_worker`]: accept a master
//! connection, handshake (version check + workload materialization), then
//! execute [`WorkOrder`]s through the exact same
//! [`crate::sched::worker::execute_order`] compute path the in-process
//! cluster uses — straggler injection, speed throttling and all — replying
//! with framed [`WireMsg::Report`]s and pushing heartbeats from a side
//! thread so liveness is visible even mid-compute.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cli::{ArgSpec, Args};
use crate::error::{Error, Result};
use crate::linalg::partition::{submatrix_ranges, TilePlan};
use crate::runtime::BackendSpec;
use crate::sched::worker::{execute_order, WorkerConfig, WorkerStorage};

use super::codec::{self, HelloAck, WireMsg, WIRE_VERSION};
use super::lock;

/// How long the daemon waits for the master's `Hello` before dropping a
/// connection that never speaks.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(10);

/// Daemon behaviour knobs.
#[derive(Debug, Clone, Default)]
pub struct DaemonOpts {
    /// Exit after one master session instead of looping back to `accept`.
    pub once: bool,
}

/// Accept master sessions forever (or once, per `opts`). Each session is
/// serial: one master drives one worker daemon at a time, matching the
/// paper's single-master Algorithm 1.
pub fn serve_worker(listener: TcpListener, opts: DaemonOpts) -> Result<()> {
    loop {
        let (stream, peer_addr) = listener.accept()?;
        let _ = stream.set_nodelay(true);
        crate::log_info!("worker daemon: master connected from {peer_addr}");
        match serve_session(stream) {
            Ok(()) => crate::log_info!("worker daemon: session from {peer_addr} closed"),
            Err(e) => crate::log_warn!("worker daemon: session from {peer_addr} ended: {e}"),
        }
        if opts.once {
            return Ok(());
        }
    }
}

/// One master session: handshake, then order→report until `Shutdown` or
/// the socket dies.
fn serve_session(stream: TcpStream) -> Result<()> {
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let hello = match codec::read_msg(&mut &stream)? {
        WireMsg::Hello(h) => h,
        other => return Err(Error::wire(format!("expected Hello, got {other:?}"))),
    };
    if hello.version != WIRE_VERSION {
        return Err(Error::wire(format!(
            "master speaks wire version {} (this daemon needs {WIRE_VERSION})",
            hello.version
        )));
    }
    if hello.tile_rows == 0 || hello.g == 0 || hello.workload.rows() == 0 {
        return Err(Error::wire(format!(
            "degenerate handshake geometry: tile_rows={} G={} q={}",
            hello.tile_rows,
            hello.g,
            hello.workload.rows()
        )));
    }

    // Materialize the uncoded storage this worker is responsible for. The
    // generator is deterministic in the seed, so master and worker agree
    // on every stored row without shipping the matrix.
    let matrix = hello.workload.materialize()?;
    let sub_ranges = Arc::new(submatrix_ranges(hello.workload.rows(), hello.g)?);
    let cfg = WorkerConfig {
        id: hello.worker,
        backend: BackendSpec::from_kind(hello.backend, crate::apps::harness::artifact_dir()),
        speed: hello.speed,
        tile_rows: hello.tile_rows,
        storage: WorkerStorage { matrix, sub_ranges },
    };
    let backend = cfg.backend.instantiate()?;

    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    codec::write_msg(
        &mut *lock(&writer),
        &WireMsg::HelloAck(HelloAck {
            version: WIRE_VERSION,
            worker: hello.worker,
        }),
    )?;
    stream.set_read_timeout(None)?;

    // Heartbeat pump: keeps the master's liveness view fresh even while
    // the session thread is deep in a long tile computation.
    let stop = Arc::new(AtomicBool::new(false));
    let hb_handle = if hello.heartbeat_ms > 0 {
        let w = Arc::clone(&writer);
        let stop2 = Arc::clone(&stop);
        let period = Duration::from_millis(u64::from(hello.heartbeat_ms));
        let id = hello.worker;
        Some(std::thread::spawn(move || {
            let mut seq = 0u64;
            while !stop2.load(Ordering::Relaxed) {
                std::thread::sleep(period);
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                seq += 1;
                if codec::write_msg(&mut *lock(&w), &WireMsg::Heartbeat { worker: id, seq })
                    .is_err()
                {
                    break;
                }
            }
        }))
    } else {
        None
    };

    let tile = TilePlan::new(cfg.tile_rows);
    let mut reader = stream;
    let result = loop {
        match codec::read_msg(&mut reader) {
            Ok(WireMsg::Work(order)) => {
                let step = order.step;
                if let Err(e) = validate_order(&cfg, &order) {
                    // a malformed order must produce a Failed reply, not a
                    // panic that kills the daemon
                    let _ = codec::write_msg(
                        &mut *lock(&writer),
                        &WireMsg::Failed {
                            worker: cfg.id,
                            step,
                            error: e.to_string(),
                        },
                    );
                    continue;
                }
                match execute_order(&cfg, &backend, &tile, &order) {
                    Ok(Some(report)) => {
                        if let Err(e) =
                            codec::write_msg(&mut *lock(&writer), &WireMsg::Report(report))
                        {
                            break Err(e);
                        }
                    }
                    Ok(None) => {} // injected Drop straggler: stay silent
                    Err(e) => {
                        let _ = codec::write_msg(
                            &mut *lock(&writer),
                            &WireMsg::Failed {
                                worker: cfg.id,
                                step,
                                error: e.to_string(),
                            },
                        );
                    }
                }
            }
            Ok(WireMsg::Shutdown) => break Ok(()),
            Ok(other) => {
                crate::log_debug!("worker daemon: ignoring unexpected message {other:?}");
            }
            Err(e) => break Err(e),
        }
    };
    stop.store(true, Ordering::Relaxed);
    if let Some(h) = hb_handle {
        let _ = h.join();
    }
    result
}

/// Reject orders that reference sub-matrices or rows this worker does not
/// store — [`execute_order`] indexes them directly (the in-process cluster
/// is trusted; a socket peer is not).
fn validate_order(
    cfg: &WorkerConfig,
    order: &crate::sched::protocol::WorkOrder,
) -> Result<()> {
    for t in &order.tasks {
        let sub = cfg.storage.sub_ranges.get(t.g).ok_or_else(|| {
            Error::wire(format!(
                "task references sub-matrix {} (worker stores {})",
                t.g,
                cfg.storage.sub_ranges.len()
            ))
        })?;
        if t.rows.hi > sub.len() {
            return Err(Error::wire(format!(
                "task rows {}..{} exceed sub-matrix {} ({} rows)",
                t.rows.lo,
                t.rows.hi,
                t.g,
                sub.len()
            )));
        }
    }
    if order.w.len() != cfg.storage.matrix.cols() {
        return Err(Error::wire(format!(
            "iterate length {} != matrix cols {}",
            order.w.len(),
            cfg.storage.matrix.cols()
        )));
    }
    Ok(())
}

/// `usec worker --listen host:port [--once]`.
pub fn worker_cli(argv: &[String]) -> Result<()> {
    let specs = vec![
        ArgSpec::opt("listen", "127.0.0.1:7070", "address to bind"),
        ArgSpec::flag("once", "exit after a single master session"),
    ];
    let args = Args::parse(argv, &specs)?;
    let addr = args.get("listen").unwrap_or("127.0.0.1:7070");
    let listener = TcpListener::bind(addr)
        .map_err(|e| Error::Cluster(format!("bind {addr}: {e}")))?;
    println!("usec worker listening on {}", listener.local_addr()?);
    serve_worker(
        listener,
        DaemonOpts {
            once: args.has("once"),
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::types::BackendKind;
    use crate::net::codec::Hello;
    use crate::net::transport::WorkloadSpec;

    fn test_hello(worker: usize) -> Hello {
        Hello {
            version: WIRE_VERSION,
            worker,
            speed: 1.0,
            tile_rows: 8,
            backend: BackendKind::Host,
            g: 2,
            heartbeat_ms: 0,
            workload: WorkloadSpec::RandomDense {
                q: 16,
                r: 16,
                seed: 5,
            },
        }
    }

    #[test]
    fn daemon_rejects_version_mismatch() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || serve_worker(listener, DaemonOpts { once: true }));

        let stream = TcpStream::connect(addr).unwrap();
        let mut bad = test_hello(0);
        bad.version = 999;
        codec::write_msg(&mut &stream, &WireMsg::Hello(bad)).unwrap();
        // daemon must close without an ack: next read errors (EOF)
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        assert!(codec::read_msg(&mut &stream).is_err());
        h.join().unwrap().unwrap();
    }

    #[test]
    fn daemon_handshakes_and_shuts_down() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let h = std::thread::spawn(move || serve_worker(listener, DaemonOpts { once: true }));

        let stream = TcpStream::connect(addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        codec::write_msg(&mut &stream, &WireMsg::Hello(test_hello(4))).unwrap();
        match codec::read_msg(&mut &stream).unwrap() {
            WireMsg::HelloAck(ack) => {
                assert_eq!(ack.version, WIRE_VERSION);
                assert_eq!(ack.worker, 4);
            }
            other => panic!("expected HelloAck, got {other:?}"),
        }
        codec::write_msg(&mut &stream, &WireMsg::Shutdown).unwrap();
        h.join().unwrap().unwrap();
    }
}
